"""Slot-aware multi-tenant serving engine tests (paper §VI-C phenomenology
at the serving level)."""
import jax
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import transformer
from repro.serve.engine import EngineConfig, SlotServeEngine, Tenant

cb.load_all()


@pytest.fixture(scope="module")
def moe_setup():
    cfg = cb.get_config("arctic-480b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def tenants_for(cfg, n=2):
    rng = np.random.default_rng(1)
    out = []
    e = cfg.num_experts
    per = e // n
    for i in range(n):
        bias = np.full((e,), -6.0, np.float32)
        bias[i * per:(i + 1) * per] = 6.0
        out.append(Tenant(
            name=f"t{i}",
            tokens=rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32),
            router_bias=bias))
    return out


def run_engine(cfg, params, steps=40, **ecfg_kw):
    base = dict(quantum_tokens=8, slots_per_shard=2, expert_shards=1)
    base.update(ecfg_kw)
    eng = SlotServeEngine(cfg, params, EngineConfig(**base),
                          tenants_for(cfg), max_len=steps + 4)
    return eng.run(steps)


def test_round_robin_shares_steps(moe_setup):
    cfg, params = moe_setup
    rep = run_engine(cfg, params, steps=40)
    per = rep["per_tenant"]
    assert abs(per["t0"] - per["t1"]) <= 8


def test_more_slots_fewer_fills(moe_setup):
    cfg, params = moe_setup
    r2 = run_engine(cfg, params, slots_per_shard=2)
    r8 = run_engine(cfg, params, slots_per_shard=8)
    assert r8["fills"] < r2["fills"]
    assert r8["hit_rate"] >= r2["hit_rate"]


def test_longer_quantum_amortises_fills(moe_setup):
    """The paper's 1K->20K scheduler-quantum effect."""
    cfg, params = moe_setup
    short = run_engine(cfg, params, quantum_tokens=4)
    long = run_engine(cfg, params, quantum_tokens=32)
    assert long["fills"] <= short["fills"]


def test_slot_hit_routing_reduces_fills(moe_setup):
    """Beyond-paper: biasing routing toward resident experts cuts fill
    traffic."""
    cfg, params = moe_setup
    plain = run_engine(cfg, params, hit_bias=0.0)
    biased = run_engine(cfg, params, hit_bias=4.0)
    assert biased["fills"] < plain["fills"]


def test_serve_online_churn_flow(moe_setup):
    """The dynamic counterpart of plan_coresidency: an event stream served
    with online re-placement, then the engine restricted to one core's
    final residents."""
    from repro.sched import OnlineConfig, PlacementConfig, TenantEvent

    cfg, params = moe_setup
    tenants = tenants_for(cfg, n=3)
    tenants[2].name = "t2"
    eng = SlotServeEngine(cfg, params,
                          EngineConfig(quantum_tokens=8, slots_per_shard=4),
                          tenants, max_len=20)
    ocfg = OnlineConfig(
        num_cores=2, epoch_steps=2_000, probe_steps=800,
        placement=PlacementConfig(num_slots=4, quantum_cycles=2_000,
                                  trace_len=2_000, steps_per_program=2_000))
    events = [TenantEvent(0, "arrive", "t0", "minver"),
              TenantEvent(0, "arrive", "t1", "crc32"),
              TenantEvent(1, "arrive", "t2", "nbody")]
    rep = eng.serve_online(events, online_cfg=ocfg, num_epochs=3,
                           apply_core=0)
    assert rep.policy == "warm"
    assert set(rep.per_tenant) == {"t0", "t1", "t2"}
    # the engine now serves exactly core 0's final residents
    kept = {t.name for t in eng.tenants}
    assert kept == set(rep.final_cores[0])
    assert {t.name for t in eng.deferred} == {"t0", "t1", "t2"} - kept
    if eng.tenants:
        assert eng.run(4)["steps"] == 4


def test_dense_arch_engine_runs(moe_setup):
    """Dense archs have no expert slots; the engine still serves."""
    cfg = cb.get_config("granite-3-2b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tenants = [Tenant(name="t0",
                      tokens=rng.integers(0, cfg.vocab, (1, 8)).astype(
                          np.int32))]
    eng = SlotServeEngine(cfg, params, EngineConfig(), tenants, max_len=16)
    rep = eng.run(8)
    assert rep["steps"] == 8
    assert rep["hit_rate"] == 1.0  # nothing slotted
