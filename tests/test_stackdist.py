"""Stack-distance fast path: exact parity with the `lax.scan` reference.

The engine (`repro.core.stackdist`) is only ever allowed to serve results
that are bit-for-bit identical to the cycle-by-cycle scan, so every test
here asserts *exact* integer equality, never closeness.  The fig6-grid test
additionally pins the paper anchor (avg s2@50c ~ 0.71) so the Fig. 6
numbers cannot drift regardless of which engine serves them.
"""
import numpy as np
import pytest

from repro.core import isa, simulator, stackdist, traces

NO_PREEMPT = simulator.SchedulerConfig.no_preempt()


# shared bit-for-bit equality contract, tests/fleet_asserts.py
from fleet_asserts import assert_fleet_equal as _assert_fleet_equal  # noqa: E402


# ---------------------------------------------------------------------------
# distance-profile unit tests (hand-computed sequences)
# ---------------------------------------------------------------------------

def test_distance_profile_hand_sequence():
    # tags:      1  2  1   3  2   -1  1
    # distance:  c  c  1   c  2   --  2   (c = cold, -- = unslotted)
    tags = np.array([1, 2, 1, 3, 2, -1, 1], np.int32)
    costs = np.ones_like(tags)
    prof = stackdist.distance_profile(tags, costs, num_tags=4)
    assert int(prof.cold) == 3
    np.testing.assert_array_equal(np.asarray(prof.hist), [0, 1, 2, 0])
    assert int(prof.base_cycles) == 7
    # LRU of size S misses when distance >= S, plus the 3 cold accesses
    misses = stackdist.misses_for_counts(prof, np.array([1, 2, 3, 4]))
    np.testing.assert_array_equal(np.asarray(misses), [6, 5, 3, 3])


def test_distance_profile_all_unslotted():
    tags = np.full(10, -1, np.int32)
    prof = stackdist.distance_profile(tags, np.full(10, 2, np.int32),
                                      num_tags=1)
    assert int(prof.cold) == 0 and int(prof.hist.sum()) == 0
    assert int(prof.base_cycles) == 20


def test_cycles_grid_affine_reconstruction():
    tags = np.array([0, 1, 0, 1, 0], np.int32)
    costs = np.array([1, 2, 1, 2, 1], np.int32)
    prof = stackdist.distance_profile(tags, costs, num_tags=2)
    grid = stackdist.cycles_grid(prof, np.array([1, 2]), np.array([10, 50]),
                                 bs_miss_extra=100)
    # S=1: every access misses (5) ; S=2: only the 2 cold misses
    np.testing.assert_array_equal(np.asarray(grid.slot_misses), [5, 2])
    assert int(grid.bs_misses) == 2
    # cycles = 7 + misses*L + 2*100
    np.testing.assert_array_equal(
        np.asarray(grid.cycles),
        [[7 + 50 + 200, 7 + 250 + 200], [7 + 20 + 200, 7 + 100 + 200]])


# ---------------------------------------------------------------------------
# dispatcher semantics
# ---------------------------------------------------------------------------

def test_eligibility_rules():
    tag_row = isa.SCENARIO_2.instr_tag
    ok = dict(quantum_cycles=simulator.NO_PREEMPT_QUANTUM, bs_entries=64,
              max_miss_latency=250, bs_miss_extra=100, total_steps=40_000)
    assert simulator.stackdist_eligible(tag_row, **ok)
    # preempted
    assert not simulator.stackdist_eligible(
        tag_row, **{**ok, "quantum_cycles": 20_000})
    # cold bitstream cache (scenario 2 has 10 distinct tags)
    assert not simulator.stackdist_eligible(
        tag_row, **{**ok, "bs_entries": 4})
    # overflow guard: a grid whose worst case could reach the quantum
    assert not simulator.stackdist_eligible(
        tag_row, **{**ok, "max_miss_latency": 1 << 29})


def test_forcing_stackdist_on_ineligible_grid_raises():
    tr = traces.build_trace("nbody", 4_000)[None, None, :]
    with pytest.raises(ValueError, match="stack-distance"):
        simulator.sweep_fleet(
            tr, [50], isa.SCENARIO_2,
            simulator.SchedulerConfig(quantum_cycles=5_000),
            slot_counts=[4], total_steps=4_000, path="stackdist")
    with pytest.raises(ValueError, match="unknown path"):
        simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, NO_PREEMPT,
                              slot_counts=[4], total_steps=4_000,
                              path="bogus")


def test_auto_dispatch_matches_both_forced_paths():
    tr = traces.build_trace("cubic", 8_000)[None, None, :]
    kw = dict(slot_counts=[2, 4], total_steps=8_000)
    auto = simulator.sweep_fleet(tr, [10, 50], isa.SCENARIO_2, NO_PREEMPT,
                                 **kw)
    fast = simulator.sweep_fleet(tr, [10, 50], isa.SCENARIO_2, NO_PREEMPT,
                                 path="stackdist", **kw)
    scan = simulator.sweep_fleet(tr, [10, 50], isa.SCENARIO_2, NO_PREEMPT,
                                 path="scan", **kw)
    _assert_fleet_equal(auto, fast)
    _assert_fleet_equal(auto, scan)


def test_wraparound_total_steps_parity():
    """total_steps > trace_len wraps the cursor; both engines must agree."""
    tr = traces.build_trace("minver", 5_000)[None, None, :]
    kw = dict(slot_counts=[4], total_steps=12_500)
    fast = simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, NO_PREEMPT,
                                 path="stackdist", **kw)
    scan = simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, NO_PREEMPT,
                                 path="scan", **kw)
    _assert_fleet_equal(fast, scan)


def test_single_and_batch_paths_parity():
    cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=50)
    tr = traces.build_trace("st", 10_000)
    one_fast = simulator.simulate_single(tr, cfg, isa.SCENARIO_2,
                                         path="stackdist")
    one_scan = simulator.simulate_single(tr, cfg, isa.SCENARIO_2,
                                         path="scan")
    for x, y in zip(one_fast, one_scan):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    trs = np.stack([tr, traces.build_trace("wikisort", 10_000)])
    b_fast = simulator.simulate_single_batch(trs, [10, 250], cfg,
                                             isa.SCENARIO_2,
                                             path="stackdist")
    b_scan = simulator.simulate_single_batch(trs, [10, 250], cfg,
                                             isa.SCENARIO_2, path="scan")
    for x, y in zip(b_fast, b_scan):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_chunked_batch_axis_matches_unchunked(monkeypatch):
    """The memory-bounding fleet-axis chunking must not change results."""
    fleet = np.stack([traces.build_trace(n, 4_000)
                      for n in ("nbody", "st", "minver")])[:, None, :]
    kw = dict(slot_counts=[2, 4], total_steps=4_000, path="stackdist")
    whole = simulator.sweep_fleet(fleet, [10, 50], isa.SCENARIO_2,
                                  NO_PREEMPT, **kw)
    monkeypatch.setattr(simulator, "_STACKDIST_CHUNK_ELEMS", 40_000)
    chunked = simulator.sweep_fleet(fleet, [10, 50], isa.SCENARIO_2,
                                    NO_PREEMPT, **kw)
    _assert_fleet_equal(whole, chunked)

    cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=50)
    trs, lats = fleet[:, 0, :], [10, 50, 250]
    whole_b = simulator.simulate_single_batch(trs, lats, cfg,
                                              isa.SCENARIO_2,
                                              path="stackdist")
    monkeypatch.setattr(simulator, "_STACKDIST_CHUNK_ELEMS", 80_000)
    chunk_b = simulator.simulate_single_batch(trs, lats, cfg,
                                              isa.SCENARIO_2,
                                              path="stackdist")
    for x, y in zip(whole_b, chunk_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_cold_bitstream_cache_serves_scan_numbers():
    """An undersized bitstream cache (bitstream_study's axis) is ineligible
    for the warm-mode engine; auto now routes it through the stacked
    cold-bitstream pass (`repro.core.stackdist_cold`), which must still
    serve the historical scan numbers bit-for-bit."""
    tr = traces.build_trace("nbody", 8_000)[None, None, :]
    auto = simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, NO_PREEMPT,
                                 slot_counts=[4], bs_cache_entries=4,
                                 total_steps=8_000)
    scan = simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, NO_PREEMPT,
                                 slot_counts=[4], bs_cache_entries=4,
                                 total_steps=8_000, path="scan")
    _assert_fleet_equal(auto, scan)
    # a cold cache can only do worse than warm mode's one-miss-per-tag
    warm = simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, NO_PREEMPT,
                                 slot_counts=[4], total_steps=8_000)
    assert int(np.asarray(auto.bs_misses)[0, 0, 0, 0]) >= \
        int(np.asarray(warm.bs_misses)[0, 0, 0, 0]) > 0


# ---------------------------------------------------------------------------
# fixed-seed fig6-grid parity + paper anchor
# ---------------------------------------------------------------------------

def test_fig6_grid_bit_for_bit_parity_and_anchor():
    """The Fig. 6 grid served by either engine must be identical, and the
    s2@50c average must stay at the paper's ~0.71 anchor."""
    fleet = np.stack([traces.build_trace(n, 40_000)
                      for n in traces.FM_BENCHES])[:, None, :]
    cpis_s2 = None
    for scen in (isa.SCENARIO_1, isa.SCENARIO_2, isa.SCENARIO_3):
        kw = dict(slot_counts=(scen.num_slots,), total_steps=40_000)
        fast = simulator.sweep_fleet(fleet, (10, 50, 250), scen, NO_PREEMPT,
                                     path="stackdist", **kw)
        scan = simulator.sweep_fleet(fleet, (10, 50, 250), scen, NO_PREEMPT,
                                     path="scan", **kw)
        _assert_fleet_equal(fast, scan)
        if scen is isa.SCENARIO_2:
            cpis_s2 = np.asarray(fast.cpi)     # (5, 1, 3, 1)
    sp = [simulator.analytic_cpi(traces.mix_of(n), isa.RV32IMF)
          / cpis_s2[i, 0, 1, 0] for i, n in enumerate(traces.FM_BENCHES)]
    assert np.mean(sp) == pytest.approx(0.71, abs=0.06)


# ---------------------------------------------------------------------------
# property tests: random traces/scenarios/slot counts vs the scan, exactly
# ---------------------------------------------------------------------------

TRACE_LEN = 256  # fixed so the scan reference compiles once for all examples


def _check_random_grid(ops, tag_of, counts, lats, bs_extra):
    trace = np.resize(np.asarray(ops, np.int32), TRACE_LEN)
    scenario = isa.SlotScenario(
        name="rand", num_slots=max(counts),
        instr_tag=np.asarray(tag_of, np.int32))
    fleet = trace[None, None, :]
    kw = dict(slot_counts=sorted(counts), bs_miss_extra=int(bs_extra),
              total_steps=TRACE_LEN)
    fast = simulator.sweep_fleet(fleet, lats, scenario, NO_PREEMPT,
                                 path="stackdist", **kw)
    scan = simulator.sweep_fleet(fleet, lats, scenario, NO_PREEMPT,
                                 path="scan", **kw)
    _assert_fleet_equal(fast, scan)


def test_seeded_random_grids_match_scan_exactly():
    """Always-on (no hypothesis needed) seeded variant of the property:
    random traces, taxonomies, slot-count sets and latency grids."""
    rng = np.random.default_rng(42)
    for _ in range(6):
        _check_random_grid(
            ops=rng.integers(0, isa.NUM_INSTRUCTIONS, 64),
            tag_of=rng.integers(-1, 7, isa.NUM_INSTRUCTIONS),
            counts=[int(c) for c in rng.integers(1, 9, 3)],
            lats=[int(v) for v in rng.integers(0, 301, 2)],
            bs_extra=int(rng.integers(0, 201)))


try:  # dev extra, not a runtime dep — only these tests skip without it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        ops=st.lists(st.integers(0, isa.NUM_INSTRUCTIONS - 1),
                     min_size=1, max_size=64),
        tag_of=st.lists(st.integers(-1, 6), min_size=isa.NUM_INSTRUCTIONS,
                        max_size=isa.NUM_INSTRUCTIONS),
        counts=st.lists(st.integers(1, 8), min_size=3, max_size=3),
        lats=st.lists(st.integers(0, 300), min_size=2, max_size=2),
        bs_extra=st.integers(0, 200),
    )
    def test_stackdist_matches_scan_exactly(ops, tag_of, counts, lats,
                                            bs_extra):
        """Random trace, random instr->tag taxonomy, random slot-count set
        and latency grid: the fast path must equal the scan bit-for-bit."""
        _check_random_grid(ops, tag_of, counts, lats, bs_extra)
else:
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_stackdist_matches_scan_exactly():
        pass
