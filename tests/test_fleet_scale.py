"""Incremental per-epoch re-solve (repro.sched.online): bit-for-bit
parity with the full re-solve across churn and chaos event streams,
snapshot version validation, benchmark-merge provenance, and the
fleet-scale study vehicle.

The incremental mode's contract is that the per-host target cache is
*pure memoisation* of a deterministic solve: serving the same stream in
``resolve_mode="incremental"`` and ``resolve_mode="full"`` must produce
identical placements, move logs, epoch logs, fault logs and per-tenant
metrics — including under a fault-storm epoch that dirties several cores
(across hosts) at once."""
import json

import pytest

from repro.sched import (ContentionModel, FaultEvent, FaultPlan,
                         OnlineConfig, OnlineReplacer, PlacementConfig,
                         TenantEvent, Topology)

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=2_000, steps_per_program=2_000)
NUM_EPOCHS = 8

# churn: arrivals forcing a regroup, then light mid-serve roster churn
EVENTS = [
    TenantEvent(0, "arrive", "fgA", "minver"),
    TenantEvent(0, "arrive", "fgB", "cubic"),
    TenantEvent(0, "arrive", "m1", "qrduino"),
    TenantEvent(1, "arrive", "m2", "edn"),
    TenantEvent(1, "arrive", "m3", "crc32"),
    TenantEvent(2, "arrive", "m4", "tarfind"),
    TenantEvent(4, "depart", "m3"),
    TenantEvent(4, "arrive", "m5", "tarfind"),
]

# the chaos variant adds a same-epoch storm losing TWO cores at once —
# on the two-host topology they sit in different hosts, so one epoch
# dirties multiple placement domains simultaneously
STORM = FaultPlan(events=(
    FaultEvent(3, "core_loss", 0, repair_epochs=2, degraded_slots=1),
    FaultEvent(3, "core_loss", 2, repair_epochs=2),
    FaultEvent(5, "slot_seu", 1, num_hit=2),
    FaultEvent(5, "bitstream_flush", 3),
), seed=11)

TOPOLOGIES = [
    pytest.param(Topology.flat(4), id="flat4"),
    pytest.param(Topology(num_hosts=2, sockets_per_host=1,
                          cores_per_socket=2), id="hosts2x2"),
]


@pytest.fixture(scope="module")
def model():
    return ContentionModel(PCFG)


def _serve(model, topo, faults, mode):
    cfg = OnlineConfig(topology=topo, epoch_steps=2_000, probe_steps=800,
                       placement=PCFG)
    rep = OnlineReplacer(cfg, model=model, policy="warm", faults=faults,
                         recovery="warm", resolve_mode=mode)
    report = rep.run(EVENTS, NUM_EPOCHS)
    return rep, report


# ---------------------------------------------------------------------------
# incremental == full, bit for bit (the tentpole's correctness criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", TOPOLOGIES)
@pytest.mark.parametrize("stream", ["online_churn", "chaos_serve"])
def test_incremental_resolve_equals_full_bit_for_bit(model, topo, stream):
    faults = STORM if stream == "chaos_serve" else None
    rep_full, out_full = _serve(model, topo, faults, "full")
    rep_inc, out_inc = _serve(model, topo, faults, "incremental")
    assert out_inc.final_cores == out_full.final_cores
    assert out_inc.moves == out_full.moves
    assert out_inc.epoch_log == out_full.epoch_log
    assert out_inc.fault_log == out_full.fault_log
    assert out_inc.per_tenant == out_full.per_tenant
    assert out_inc.migrations == out_full.migrations
    assert out_inc.evacuations == out_full.evacuations
    # the cache did real work: full solved every domain every epoch,
    # incremental skipped clean domains on quiet epochs
    assert all(r["cached"] == 0 for r in rep_full.resolve_log)
    assert sum(r["cached"] for r in rep_inc.resolve_log) > 0
    assert sum(r["solved"] for r in rep_inc.resolve_log) < \
        sum(r["solved"] for r in rep_full.resolve_log)
    if faults is not None:
        # the storm epoch dirtied every lost core's host at once
        storm = [r for r in rep_inc.resolve_log if r["epoch"] == 3]
        assert storm and storm[0]["solved"] >= len(
            {topo.host_of(0), topo.host_of(2)})


def test_resolve_log_is_telemetry_only(model):
    """`resolve_log` never leaks into the report, the epoch log, or a
    snapshot — restored serves must stay bit-for-bit comparable."""
    rep, out = _serve(model, Topology.flat(4), None, "incremental")
    assert rep.resolve_log, "re-solve ran but logged nothing"
    for row in rep.resolve_log:
        assert set(row) == {"epoch", "mode", "solved", "cached", "seconds"}
    for row in out.epoch_log:
        assert "solved" not in row and "seconds" not in row
    assert "resolve_log" not in rep.snapshot()


# ---------------------------------------------------------------------------
# snapshot versioning (restore must reject what it cannot read)
# ---------------------------------------------------------------------------

def _mini_replacer(model, topo=None):
    cfg = OnlineConfig(topology=topo or Topology.flat(2),
                       epoch_steps=1_000, probe_steps=500, placement=PCFG)
    return OnlineReplacer(cfg, model=model, policy="never")


def test_restore_rejects_unknown_snapshot_version(model):
    rep = _mini_replacer(model)
    rep.run([TenantEvent(0, "arrive", "a", "minver")], 1)
    snap = rep.snapshot()
    assert snap["version"] == 2 and snap["topology"] == (1, 1, 2)
    for bad_version in (99, None, "2"):
        bad = dict(snap, version=bad_version)
        with pytest.raises(ValueError, match=(
                rf"unknown snapshot version {bad_version!r}.*"
                rf"supports versions \(1, 2\)")):
            _mini_replacer(model).restore(bad)


def test_restore_v1_snapshot_loads_onto_flat_topology_only(model):
    rep = _mini_replacer(model)
    rep.run([TenantEvent(0, "arrive", "a", "minver")], 2)
    v1 = rep.snapshot()
    v1["version"] = 1
    del v1["topology"]            # pre-topology writers never had it
    fresh = _mini_replacer(model)
    fresh.restore(v1)             # implicit flat geometry matches
    assert fresh._epoch == 2
    assert fresh.tenants["a"].bench == "minver"
    # every domain restarts dirty: the resumed re-solve is a full one
    assert fresh._dirty == {0} and fresh._domain_target == {}
    # same core count but different geometry must be rejected
    multi = _mini_replacer(model, Topology(num_hosts=2,
                                           sockets_per_host=1,
                                           cores_per_socket=1))
    with pytest.raises(ValueError, match=r"snapshot topology \(1, 1, 2\)"):
        multi.restore(v1)


def test_restore_rejects_mismatched_topology_geometry(model):
    topo = Topology(num_hosts=2, sockets_per_host=1, cores_per_socket=1)
    rep = _mini_replacer(model, topo)
    rep.run([TenantEvent(0, "arrive", "a", "minver")], 1)
    snap = rep.snapshot()
    assert snap["topology"] == (2, 1, 1)
    with pytest.raises(ValueError, match="does not match"):
        _mini_replacer(model).restore(snap)   # flat(2): same cores, no


# ---------------------------------------------------------------------------
# benchmark-merge provenance (BENCH_fleet.json legacy entries)
# ---------------------------------------------------------------------------

PROV = {"backend": "cpu", "device": "TFRT_CPU_0",
        "platform_version": "jax-0.4.37"}


def _entry(us, **extra):
    return {"us_per_call": us, "derived": "d", **extra}


def test_merge_drops_provenance_free_legacy_entries(tmp_path, capsys):
    from benchmarks.run import _record_fleet_json
    path = str(tmp_path / "BENCH_fleet.json")
    with open(path, "w") as f:
        json.dump({"legacy_bench": _entry(1),
                   "good_bench": _entry(2, **PROV)}, f)
    _record_fleet_json({"new_bench": _entry(3, **PROV)}, path)
    with open(path) as f:
        merged = json.load(f)
    # the pre-PR-9 provenance-free entry must not be resurrected
    assert set(merged) == {"good_bench", "new_bench"}
    assert "legacy_bench" in capsys.readouterr().out
    for entry in merged.values():
        assert all(k in entry for k in PROV)


def test_merge_rerecording_a_legacy_name_stamps_it(tmp_path):
    from benchmarks.run import _record_fleet_json
    path = str(tmp_path / "BENCH_fleet.json")
    with open(path, "w") as f:
        json.dump({"legacy_bench": _entry(1)}, f)
    _record_fleet_json({"legacy_bench": _entry(9, **PROV)}, path)
    with open(path) as f:
        merged = json.load(f)
    assert merged["legacy_bench"]["us_per_call"] == 9
    assert merged["legacy_bench"]["backend"] == "cpu"


def test_merge_asserts_every_entry_carries_provenance(tmp_path):
    from benchmarks.run import _record_fleet_json
    path = str(tmp_path / "BENCH_fleet.json")
    with pytest.raises(AssertionError, match="provenance"):
        _record_fleet_json({"bad_bench": _entry(1)}, path)


# ---------------------------------------------------------------------------
# the benchmark vehicle, at test size
# ---------------------------------------------------------------------------

def test_fleet_scale_study_smoke_tiny(monkeypatch):
    """The smoke entry (CI's reduced size) down-scaled further: parity
    asserts and the finding row must hold at any size."""
    from benchmarks import fleet_scale_study as study
    monkeypatch.setenv("REPRO_FLEET_SCALE", "smoke")
    monkeypatch.setattr(study, "SMOKE_SIZES", [
        ("16t_4c", 16, Topology(num_hosts=2, sockets_per_host=1,
                                cores_per_socket=2))])
    rows, out = study.run()
    assert any(r.startswith("# finding fleet-scale smoke") for r in rows)
    rep = out["16t_4c"]["incremental"]
    assert rep.final_cores == out["16t_4c"]["full"].final_cores
