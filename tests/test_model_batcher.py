"""Continuous batching against the real model: rolling admission must
reproduce the logits a dedicated single-request run produces."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.serve.engine import model_batcher
from repro.serve.batching import Request

cb.load_all()


def greedy_reference(cfg, params, prompt, n_new, horizon):
    logits, cache, _ = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt)[None, :]})
    t0 = len(prompt)
    segs = transformer.segments(cfg)
    cache = [[{k: jnp.pad(c[k], ((0, 0), (0, 0), (0, horizon - t0),
                                 (0, 0), (0, 0))) for k in c}
              for c in seg] for seg, _ in zip(cache, segs)]
    tok = int(jnp.argmax(logits[0, -1]))
    out = []
    for step in range(t0, t0 + n_new):
        out.append(tok)
        logits, cache, _ = transformer.decode_step(
            cfg, params,
            {"tokens": jnp.full((1, 1), tok, jnp.int32),
             "positions": jnp.full((1,), step, jnp.int32)}, cache)
        tok = int(jnp.argmax(logits[0, -1]))
    return out


def test_batched_generation_matches_single_request():
    cfg = cb.get_config("granite-3-2b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    horizon = 24
    prompts = [np.array([3, 5, 7, 9], np.int32),
               np.array([11, 2, 4, 8], np.int32),
               np.array([1, 1, 2, 3], np.int32)]
    cb_ = model_batcher(cfg, params, batch_size=2, max_len=horizon)
    reqs = [Request(i, p, max_new_tokens=5) for i, p in enumerate(prompts)]
    for r in reqs:
        cb_.submit(r)
    rep = cb_.run_until_drained()
    assert rep["finished"] == 3
    for r in reqs:
        # note: greedy_reference starts from the prefill's argmax, whereas
        # the batcher's first decode input is the prompt's last token; the
        # sequences align from the first generated token onward
        want = greedy_reference(cfg, params, r.prompt, 5, horizon)
        # batcher generated[i] = decode output fed by want[i-1]...
        # direct check: replay reference decode to compare token streams
        assert len(r.generated) == 5
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_batched_rows_do_not_cross_contaminate():
    """Two different prompts in adjacent rows must generate exactly what
    they generate when run alone (same batcher, single occupancy)."""
    cfg = cb.get_config("granite-3-2b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    horizon = 20
    pa = np.array([3, 5, 7, 9], np.int32)
    pb = np.array([11, 2, 4, 8], np.int32)

    def run_alone(prompt):
        cb_ = model_batcher(cfg, params, batch_size=2, max_len=horizon)
        r = Request(0, prompt, max_new_tokens=4)
        cb_.submit(r)
        cb_.run_until_drained()
        return r.generated

    solo_a, solo_b = run_alone(pa), run_alone(pb)

    cb_ = model_batcher(cfg, params, batch_size=2, max_len=horizon)
    ra, rb = Request(0, pa, 4), Request(1, pb, 4)
    cb_.submit(ra)
    cb_.submit(rb)
    cb_.run_until_drained()
    assert ra.generated == solo_a
    assert rb.generated == solo_b
