"""Resumable interleaved engine + stacked cold-bitstream pass: parity pins.

Two scan strongholds fell in this refactor, and this module pins both to
the cycle-by-cycle reference with exact integer equality:

  * **FleetState round-tripping** — `simulate_many(..., state=S,
    return_state=True)` now seeds the interleave-aware engine from S and
    materialises S' back out.  The tests assert that an engine-resumed
    segment equals the scan-resumed segment bit-for-bit INCLUDING the
    returned state's LRU clocks and bitstream-cache contents, across
    preempted P>=3 fleets, heterogeneous quanta + priorities, and
    mid-quantum split points; that auto routes resumed calls through the
    resumable entry (`resume_spy`, tests/conftest.py); and that
    hand-crafted states no scan could produce still fall back to the scan.

  * **Cold bitstream caches on unpreempted runs** — the stacked Mattson
    pass (`repro.core.stackdist_cold`) re-profiles the disambiguator's
    miss subsequence as its own LRU stream, serving every bitstream
    capacity from one profile.  The tests pin `simulator.sweep_bitstream`
    and the single-program entries to the scan, including
    `benchmarks/bitstream_study.py`'s exact rows at a reduced trace
    length.

The equality contract is shared with every other engine-parity suite via
tests/fleet_asserts.py: bit-for-bit integers, never closeness.
"""
import jax
import numpy as np
import pytest
from fleet_asserts import assert_fleet_equal

from repro.core import isa, simulator, traces

CFG = simulator.ReconfigConfig(num_slots=4, miss_latency=50)


def _fleet(p=3, n=4_000):
    return np.stack([traces.build_trace(b, n) for b in
                     ["minver", "nbody", "crc32", "cubic"][:p]])


def assert_state_equal(a, b):
    """Exact leaf-by-leaf FleetState equality (both engines return states
    in canonical form, so this never sees which engine ran)."""
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# seeded resume: engine == scan, bit for bit, state included
# ---------------------------------------------------------------------------

SCHEDS = [
    pytest.param(simulator.SchedulerConfig(quantum_cycles=1_500),
                 id="uniform-q1500-p3"),
    pytest.param(simulator.SchedulerConfig(quantum_cycles=(900, 2_100, 1_400),
                                           priorities=(2, 1, 3)),
                 id="hetero-quanta-prio-p3"),
]


@pytest.mark.parametrize("sched", SCHEDS)
@pytest.mark.parametrize("split", [1, 137, 2_500, 8_999])
def test_seeded_resume_equals_scan_resume(sched, split):
    """Split a preempted P=3 run at `split` (137 and 2_500 land
    mid-quantum), resume the tail on both engines, and require identical
    results AND identical final states — slot/bitstream tags, LRU
    clocks, cursors, scheduler state, every counter."""
    tr = _fleet(3)
    total = 9_000
    _, s1 = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, split,
                                    return_state=True, path="scan")
    fast, sf = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                       total - split, state=s1,
                                       return_state=True,
                                       path="interleaved")
    scan, ss = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                       total - split, state=s1,
                                       return_state=True, path="scan")
    assert int(fast.switches) > 0         # genuinely preempted
    assert_fleet_equal(fast, scan)
    assert_state_equal(sf, ss)
    # and the engine-resumed split equals the engine's one-shot run
    one, so = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, total,
                                      return_state=True, path="interleaved")
    assert_fleet_equal(fast, one)
    assert_state_equal(sf, so)


def test_auto_resume_rides_resumable_engine(resume_spy):
    """Auto dispatch: return_state and state= calls take the resumable
    entry, and a mid-quantum seed (q_cycles > 0) round-trips exactly."""
    tr = _fleet(2)
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    assert not resume_spy
    _, st = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_500,
                                    return_state=True)
    assert len(resume_spy) == 1
    assert int(st.q_cycles) > 0           # the split landed mid-quantum
    res = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 1_000,
                                  state=st)
    assert len(resume_spy) == 2
    scan = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 1_000,
                                   state=st, path="scan")
    assert_fleet_equal(res, scan)


def test_hand_crafted_unseedable_state_falls_back_to_scan(resume_spy):
    """A slot resident missing from the bitstream cache: no scan with a
    warm bitstream cache can produce this state, so the engine cannot
    seed from it — auto must keep the scan (exactly), and forcing the
    engine must refuse."""
    import jax.numpy as jnp
    tr = _fleet(2)
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    st = simulator.init_fleet_state(2, CFG.num_slots, CFG.bs_cache_entries)
    st = st._replace(slot_st=st.slot_st._replace(
        tags=st.slot_st.tags.at[0].set(3),
        last_use=st.slot_st.last_use.at[0].set(1),
        clock=jnp.int32(2)))
    res = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_000,
                                  state=st)
    assert not resume_spy
    scan = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_000,
                                   state=st, path="scan")
    assert_fleet_equal(res, scan)
    with pytest.raises(ValueError, match="scan-shaped"):
        simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_000,
                                state=st, path="interleaved")


def test_cold_bitstream_resume_stays_on_scan(resume_spy):
    """An undersized bitstream cache keeps resumed preempted runs on the
    scan — the resumable engine needs warmth just like the one-shot one."""
    cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=50,
                                   bs_cache_entries=4)
    tr = _fleet(2)
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    _, st = simulator.simulate_many(tr, cfg, isa.SCENARIO_2, sched, 1_500,
                                    return_state=True)
    res = simulator.simulate_many(tr, cfg, isa.SCENARIO_2, sched, 1_500,
                                  state=st)
    assert not resume_spy
    scan = simulator.simulate_many(tr, cfg, isa.SCENARIO_2, sched, 1_500,
                                   state=st, path="scan")
    assert_fleet_equal(res, scan)
    with pytest.raises(ValueError, match="warm bitstream"):
        simulator.simulate_many(tr, cfg, isa.SCENARIO_2, sched, 1_500,
                                state=st, path="interleaved")


def test_online_epoch_advance_and_probes_ride_fast_path(resume_spy):
    """The online layer's epoch advances and migration-penalty probes are
    the resumed runs the tentpole targets — every one of them must now
    dispatch to the resumable engine, with the report unchanged."""
    from repro.sched import (ContentionModel, OnlineConfig, OnlineReplacer,
                             PlacementConfig, TenantEvent)
    pcfg = PlacementConfig(num_slots=4, miss_latency=50,
                           quantum_cycles=2_000, trace_len=2_000,
                           steps_per_program=2_000)
    ocfg = OnlineConfig(num_cores=2, epoch_steps=2_000, probe_steps=800,
                        placement=pcfg)
    rep = OnlineReplacer(ocfg, model=ContentionModel(pcfg), policy="never")
    rep.run([TenantEvent(0, "arrive", "a", "minver"),
             TenantEvent(0, "arrive", "b", "crc32")], 2)
    advances = len(resume_spy)
    assert advances > 0                   # every epoch advance was seeded
    assert rep.migration_penalty("a") > 0.0
    assert len(resume_spy) == advances + 2   # warm + cold probe, both fast


# ---------------------------------------------------------------------------
# stacked cold-bitstream pass: sweep_bitstream / single entries == scan
# ---------------------------------------------------------------------------

def test_sweep_bitstream_matches_scan_grid():
    """Full {slot count x latency x capacity x penalty} grid, stacked pass
    vs one scan per cell — every counter bit-for-bit."""
    tr = np.stack([traces.build_trace("minver", 1_000),
                   traces.build_trace("nettle-aes", 1_000)])
    kw = dict(slot_counts=[2, 4], miss_latencies=[10, 50],
              bs_entries=[1, 4, 16], bs_miss_extras=[50, 250],
              total_steps=2_000)
    fast = simulator.sweep_bitstream(tr, isa.SCENARIO_2, **kw)
    forced = simulator.sweep_bitstream(tr, isa.SCENARIO_2,
                                       path="stackdist_cold", **kw)
    scan = simulator.sweep_bitstream(tr, isa.SCENARIO_2, path="scan", **kw)
    assert_fleet_equal(fast, scan)        # ColdGrid is a NamedTuple too
    assert_fleet_equal(forced, scan)
    with pytest.raises(ValueError, match="unknown path"):
        simulator.sweep_bitstream(tr, isa.SCENARIO_2, path="interleaved",
                                  **kw)


def test_bitstream_study_rows_pinned_to_scan():
    """The benchmark's exact output rows (miss rates and IMF speedups, as
    formatted) must not move between the stacked pass and the per-cell
    scans it replaced — at a reduced trace length to keep CI fast."""
    from benchmarks import bitstream_study
    fast = bitstream_study.run(trace_len=2_000)
    scan = bitstream_study.run(trace_len=2_000, path="scan")
    assert fast == scan


def test_single_entries_cold_parity_and_forcing():
    cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=50,
                                   bs_cache_entries=4)
    tr = traces.build_trace("nettle-aes", 3_000)
    fast = simulator.simulate_single(tr, cfg, isa.SCENARIO_2)
    scan = simulator.simulate_single(tr, cfg, isa.SCENARIO_2, path="scan")
    forced = simulator.simulate_single(tr, cfg, isa.SCENARIO_2,
                                       path="stackdist_cold")
    assert_fleet_equal(fast, scan)
    assert_fleet_equal(forced, scan)
    # the warm engine must still refuse a cold cache
    with pytest.raises(ValueError, match="stack-distance"):
        simulator.simulate_single(tr, cfg, isa.SCENARIO_2, path="stackdist")
    # batch lanes: (trace, latency) pairs through the stacked pass
    trs = np.stack([tr, traces.build_trace("ud", 3_000)])
    b_fast = simulator.simulate_single_batch(trs, [10, 50], cfg,
                                             isa.SCENARIO_2)
    b_scan = simulator.simulate_single_batch(trs, [10, 50], cfg,
                                             isa.SCENARIO_2, path="scan")
    assert_fleet_equal(b_fast, b_scan)


def test_stackdist_cold_eligibility_rules():
    ok = dict(quantum_cycles=simulator.NO_PREEMPT_QUANTUM,
              max_miss_latency=50, bs_miss_extra=100, total_steps=10_000)
    assert simulator.stackdist_cold_eligible(**ok)
    # preempted runs stay the scan's: the miss subsequence is
    # switch-point-dependent per grid cell
    assert not simulator.stackdist_cold_eligible(
        **{**ok, "quantum_cycles": 2_000})
    # overflow guard, same int32 accumulators as the scan
    assert not simulator.stackdist_cold_eligible(
        **{**ok, "max_miss_latency": 1 << 29})
    # forcing it on a preempted fleet raises
    with pytest.raises(ValueError, match="cold-bitstream"):
        simulator.sweep_fleet(
            _fleet(2)[None], [50], isa.SCENARIO_2,
            simulator.SchedulerConfig(quantum_cycles=2_000),
            slot_counts=[4], bs_cache_entries=4, total_steps=2_000,
            path="stackdist_cold")
