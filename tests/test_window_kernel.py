"""Window-distance Pallas kernel: interpret-mode parity with the jnp pass.

`repro.kernels.window_distance` fuses the interleaved engine's whole
window pass into one Pallas kernel.  Like every engine in this repo it
is only ever allowed to return results bit-for-bit identical to the
reference (`stackdist_interleaved._simulate_cell`), so the whole suite
runs the kernel in interpret mode (`pl.pallas_call(..., interpret=True)`)
and asserts exact integer equality — CPU CI proves the kernel without a
GPU.  Two inertness claims carry the proof from the padded kernel shapes
back to the unpadded jnp pass, and the randomized sweeps below exercise
both:

* tag pad (-> 128 lanes): padded tag columns never occur in any stream,
  so their `prev` entries stay -1 and are never > `prev_self`, never
  counted in a distance, and commit -1 back into `last_pos`;
* window pad (-> 8 sublanes): padded rows carry tag -1 / cost 0, so the
  cost cumsum is flat past the real window and a padded row expires iff
  row `window-1` already did — the first expiring index is always real.

Layout mirrors the PR-5 scan-parity harness (test_stackdist_interleaved):
white-box kernel-vs-jnp checks, dispatcher `use_kernel` semantics, a
fixed-seed always-on randomized sweep, and a hypothesis property that
degrades to the seeded variant when hypothesis is absent.  CI runs the
module under the "ci" hypothesis profile.
"""
import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from fleet_asserts import assert_fleet_equal

from repro.core import isa, simulator
from repro.core import stackdist_interleaved as sdi
from repro.kernels import window_distance as wd

CFG = simulator.ReconfigConfig(num_slots=4, miss_latency=50)


# ---------------------------------------------------------------------------
# `use_kernel` knob: resolve() vocabulary + session default
# ---------------------------------------------------------------------------

def test_resolve_knob_mapping():
    accel = jax.default_backend() in ("gpu", "tpu")
    assert wd.resolve("auto") == (accel, False)
    assert wd.resolve("kernel") == (True, not accel)
    assert wd.resolve(True) == (True, not accel)
    assert wd.resolve("interpret") == (True, True)
    assert wd.resolve("jnp") == (False, False)
    assert wd.resolve(False) == (False, False)
    with pytest.raises(ValueError, match="use_kernel"):
        wd.resolve("bogus")


def test_default_mode_setter_feeds_resolve_none():
    old = wd.DEFAULT_MODE
    try:
        wd.set_default_mode("interpret")
        assert wd.resolve(None) == (True, True)
        wd.set_default_mode("jnp")
        assert wd.resolve(None) == (False, False)
        wd.set_default_mode("auto")
        assert wd.resolve(None) == wd.resolve("auto")
        with pytest.raises(ValueError, match="window-kernel mode"):
            wd.set_default_mode("fast")
    finally:
        wd.set_default_mode(old)


# ---------------------------------------------------------------------------
# white-box parity: kernel vs `_simulate_cell`, pre-gathered streams
# ---------------------------------------------------------------------------

TRACE_LEN = 48     # small so interpret mode stays cheap
NUM_TAGS = 7
TOTAL_STEPS = 130  # > 2 * TRACE_LEN: every cursor wraps
# fixed quantum menu: 6 expires mid-window for every window size here,
# 1 << 30 never expires (the solo/unreachable regime)
QUANTUM_MENU = (6, 37, 120, 1 << 30)
# 1 degenerate, 13 unaligned, 64 aligned, 200 > TRACE_LEN (a single
# window wraps the whole trace)
WINDOWS = (1, 13, 64, 200)


@functools.partial(jax.jit, static_argnames=("num_tags", "total_steps",
                                             "window", "materialise"))
def _ref_cell(pt, pc, s, lat, qv, sched, handler, bs, seed=None, *,
              num_tags, total_steps, window, materialise=False):
    return sdi._simulate_cell(pt, pc, s, lat, qv, sched, handler, bs,
                              num_tags, total_steps, window, seed=seed,
                              materialise=materialise)


def _streams(rng, p):
    tags = rng.integers(-1, NUM_TAGS, (p, TRACE_LEN)).astype(np.int32)
    costs = rng.integers(0, 9, (p, TRACE_LEN)).astype(np.int32)
    return jnp.asarray(tags), jnp.asarray(costs)


def _sched_of(p):
    # weighted round-robin: program 0 gets a double turn when p > 1
    return jnp.asarray(list(range(p)) + [0], jnp.int32)


def _check_cell(rng, p, window, quanta_idx, *, seeded, materialise,
                total_steps=TOTAL_STEPS, streams=None):
    """One cell, kernel (interpret) vs jnp, every CellCarry field."""
    tags, costs = _streams(rng, p) if streams is None else streams
    sched = _sched_of(p)
    quanta = jnp.asarray([QUANTUM_MENU[i] for i in quanta_idx[:p]],
                         jnp.int32)
    s, lat, handler, bs = jnp.int32(3), jnp.int32(41), jnp.int32(9), \
        jnp.int32(17)
    kw = dict(num_tags=NUM_TAGS, total_steps=total_steps, window=window)
    if seeded:
        # engine-coordinate seed: virtual last_pos in [-1, num_tags) (the
        # shape `simulator._seed_carry` builds), counters mid-flight
        perm = rng.permutation(NUM_TAGS).astype(np.int32) - 1
        seed = sdi.CellCarry(
            last_pos=jnp.asarray(perm),
            last_miss_pos=jnp.full((NUM_TAGS,), -1, jnp.int32),
            cursors=jnp.asarray(rng.integers(0, 3 * TRACE_LEN, p),
                                jnp.int32),
            sched_idx=jnp.int32(rng.integers(0, p + 1)),
            steps_done=jnp.int32(0),
            q_cycles=jnp.int32(rng.integers(0, QUANTUM_MENU[0])),
            cycles=jnp.asarray(rng.integers(0, 9_000, p), jnp.int32),
            instrs=jnp.asarray(rng.integers(0, 900, p), jnp.int32),
            misses=jnp.asarray(rng.integers(0, 900, p), jnp.int32),
            bs_misses=jnp.asarray(rng.integers(0, 90, p), jnp.int32),
            switches=jnp.int32(rng.integers(0, 40)))
        kseed = (seed.last_pos, seed.cursors, seed.sched_idx,
                 seed.q_cycles, seed.cycles, seed.instrs, seed.misses,
                 seed.bs_misses, seed.switches)
    else:
        seed, kseed = None, None
    got = wd.window_cell(tags, costs, s, lat, quanta, sched, handler, bs,
                         seed=kseed, seeded=seeded,
                         materialise=materialise, interpret=True, **kw)
    if materialise:
        want = _ref_cell(tags, costs, s, lat, quanta, sched, handler, bs,
                         seed=seed, materialise=True, **kw)
        for field, g, w in zip(sdi.CellCarry._fields, got, want):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"{field} (p={p} window={window} seeded={seeded})")
    else:
        want = _ref_cell(tags, costs, s, lat, quanta, sched, handler, bs,
                         seed=seed, materialise=False, **kw)
        carry = sdi.CellCarry(*got)
        for field, g, w in zip(
                ("cycles", "instrs", "misses", "bs_misses", "switches"),
                (carry.cycles, carry.instrs, carry.misses,
                 carry.bs_misses, carry.switches), want):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"{field} (p={p} window={window} counter-mode)")
        # non-materialise runs must leave the miss vector untouched
        np.testing.assert_array_equal(np.asarray(carry.last_miss_pos),
                                      np.full((NUM_TAGS,), -1, np.int32))


@pytest.mark.parametrize("window", WINDOWS)
def test_cell_parity_unseeded_materialise(window):
    rng = np.random.default_rng(1_000 + window)
    _check_cell(rng, 3, window, (0, 2, 3), seeded=False, materialise=True)


@pytest.mark.parametrize("window", WINDOWS)
def test_cell_parity_seeded_materialise(window):
    rng = np.random.default_rng(2_000 + window)
    _check_cell(rng, 3, window, (1, 0, 3), seeded=True, materialise=True)


@pytest.mark.parametrize("window", (1, 13, 64))
def test_cell_parity_counter_mode(window):
    rng = np.random.default_rng(3_000 + window)
    _check_cell(rng, 2, window, (0, 3), seeded=False, materialise=False)


def test_grid_parity_full_cell_grid():
    """`window_grid` over a (Q, B, K, L) = (2, 2, 2, 2) grid vs one
    `_simulate_cell` per cell — the counter arrays the one-shot sweep
    serves."""
    rng = np.random.default_rng(4_242)
    p = 3
    ptags = jnp.stack([_streams(rng, p)[0] for _ in range(2)])
    pcosts = jnp.stack([_streams(rng, p)[1] for _ in range(2)])
    counts = jnp.asarray([1, 4], jnp.int32)
    lats = jnp.asarray([0, 73], jnp.int32)
    quanta = jnp.asarray([[6, 37, 120], [1 << 30] * 3], jnp.int32)
    sched = _sched_of(p)
    handler, bs = jnp.int32(11), jnp.int32(23)
    for window in WINDOWS:
        kw = dict(num_tags=NUM_TAGS, total_steps=TOTAL_STEPS,
                  window=window)
        got = wd.window_grid(ptags, pcosts, counts, lats, quanta, sched,
                             handler, bs, interpret=True, **kw)
        want = [np.zeros((2, 2, 2, 2, p), np.int32) for _ in range(4)]
        want.append(np.zeros((2, 2, 2, 2), np.int32))
        for q in range(2):
            for b in range(2):
                for k in range(2):
                    for l in range(2):
                        cell = _ref_cell(ptags[b], pcosts[b], counts[k],
                                         lats[l], quanta[q], sched,
                                         handler, bs, **kw)
                        for i in range(5):
                            want[i][q, b, k, l] = np.asarray(cell[i])
        for i, (g, w) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(
                np.asarray(g), w,
                err_msg=f"grid field {i} window={window}")


# ---------------------------------------------------------------------------
# dispatcher parity: sweep_fleet / simulate_many ride the knob unchanged
# ---------------------------------------------------------------------------

def _preempted_fleet(p=2, n=1_000, seed=7):
    rng = np.random.default_rng(seed)
    return rng.integers(0, isa.NUM_INSTRUCTIONS, (1, p, n)).astype(np.int32)


def test_sweep_fleet_kernel_matches_jnp_and_scan():
    fl = _preempted_fleet()
    sched = simulator.SchedulerConfig(quantum_cycles=700)
    kw = dict(slot_counts=[2, 4], total_steps=2_400, path="interleaved",
              interleave_window=96)
    jnp_r = simulator.sweep_fleet(fl, [10, 50], isa.SCENARIO_2, sched,
                                  use_kernel="jnp", **kw)
    ker_r = simulator.sweep_fleet(fl, [10, 50], isa.SCENARIO_2, sched,
                                  use_kernel="interpret", **kw)
    assert_fleet_equal(jnp_r, ker_r)
    scan = simulator.sweep_fleet(fl, [10, 50], isa.SCENARIO_2, sched,
                                 slot_counts=[2, 4], total_steps=2_400,
                                 path="scan")
    assert_fleet_equal(scan, ker_r)


def test_simulate_many_resume_rides_the_kernel():
    """Split a preempted run, resume through the kernel parity path, and
    require identical results AND identical final FleetState vs the jnp
    engine — the serving stack's warm-state contract."""
    tr = _preempted_fleet(p=3, n=1_200, seed=11)[0]
    sched = simulator.SchedulerConfig(quantum_cycles=900,
                                      priorities=(2, 1, 1))
    _, st = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                    total_steps=1_700, return_state=True)
    outs = {}
    for mode in ("jnp", "interpret"):
        outs[mode] = simulator.simulate_many(
            tr, CFG, isa.SCENARIO_2, sched, total_steps=1_300, state=st,
            return_state=True, path="interleaved", use_kernel=mode)
    assert_fleet_equal(outs["jnp"][0], outs["interpret"][0])
    for la, lb in zip(jax.tree_util.tree_leaves(outs["jnp"][1]),
                      jax.tree_util.tree_leaves(outs["interpret"][1])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_mesh_sharded_sweep_matches_scan():
    """Fleet axis on a 4-device host mesh (forced via XLA_FLAGS in a
    subprocess): B=3 (non-divisible, exercises the chunk round-up) must
    still equal the scan bit-for-bit, kernel and jnp alike."""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    script = textwrap.dedent("""
        import numpy as np
        import jax
        from repro.core import isa, simulator
        assert jax.device_count() == 4, jax.devices()
        rng = np.random.default_rng(5)
        fl = rng.integers(0, isa.NUM_INSTRUCTIONS, (3, 2, 400)).astype(
            np.int32)
        sched = simulator.SchedulerConfig(quantum_cycles=500)
        kw = dict(slot_counts=[4], total_steps=900)
        scan = simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched,
                                     path="scan", **kw)
        for mode in ("jnp", "interpret"):
            fast = simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched,
                                         path="interleaved",
                                         interleave_window=64,
                                         use_kernel=mode, **kw)
            for f, a, b in zip(scan._fields, scan, fast):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b), err_msg=f)
        print("MESH-OK")
    """)
    env = dict(os.environ, PYTHONPATH=src, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0 and "MESH-OK" in r.stdout, \
        r.stdout + "\n" + r.stderr


# ---------------------------------------------------------------------------
# randomized parity sweep (seeded always-on + hypothesis ci variant)
# ---------------------------------------------------------------------------

def _check_random_kernel(tag_rows, cost_rows, p, window_idx, quanta_idx,
                         seeded, materialise):
    # the drawn lists become the streams; seeds come from an rng derived
    # deterministically from the case shape, so hypothesis shrinking stays
    # meaningful
    rng = np.random.default_rng(7 + p + 31 * window_idx + 1009 * seeded)
    tags = jnp.asarray(np.resize(np.asarray(tag_rows, np.int32),
                                 (p, TRACE_LEN)))
    costs = jnp.asarray(np.resize(np.asarray(cost_rows, np.int32),
                                  (p, TRACE_LEN)))
    _check_cell(rng, p, WINDOWS[window_idx], quanta_idx, seeded=seeded,
                materialise=materialise, streams=(tags, costs))


def test_seeded_random_kernel_matches_jnp_exactly():
    """Always-on seeded variant: random streams, program counts, window
    sizes, quanta mixes, seeded/unseeded and both materialise modes."""
    rng = np.random.default_rng(20_260_809)
    for i in range(6):
        seeded = bool(i % 2)
        _check_random_kernel(
            tag_rows=rng.integers(-1, NUM_TAGS, 64),
            cost_rows=rng.integers(0, 9, 64),
            p=int(rng.integers(1, 4)),
            window_idx=int(rng.integers(0, len(WINDOWS))),
            quanta_idx=[int(q) for q in
                        rng.integers(0, len(QUANTUM_MENU), 3)],
            seeded=seeded,
            # seeded runs always materialise (the resume contract);
            # i == 2 exercises the unseeded counter-tuple mode
            materialise=seeded or i != 2)


try:  # dev extra, not a runtime dep — only these tests skip without it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(
        tag_rows=st.lists(st.integers(-1, NUM_TAGS - 1), min_size=1,
                          max_size=64),
        cost_rows=st.lists(st.integers(0, 8), min_size=1, max_size=64),
        p=st.integers(1, 3),
        window_idx=st.integers(0, len(WINDOWS) - 1),
        quanta_idx=st.lists(st.integers(0, len(QUANTUM_MENU) - 1),
                            min_size=3, max_size=3),
        seeded=st.booleans(),
    )
    def test_kernel_matches_jnp_exactly(tag_rows, cost_rows, p, window_idx,
                                        quanta_idx, seeded):
        """Random streams / taxonomy sizes / quanta mixes: the interpret-
        mode kernel must equal the jnp window pass bit-for-bit, every
        CellCarry field (seeded runs always materialise, matching the
        resume contract)."""
        _check_random_kernel(tag_rows, cost_rows, p, window_idx,
                             quanta_idx, seeded, materialise=True)
else:
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_kernel_matches_jnp_exactly():
        pass
