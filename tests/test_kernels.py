"""Pallas kernels vs pure-jnp oracles — interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_default_matmul_precision", "float32")


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,tq,tk,h,kh,dh,dtype", [
    (1, 128, 128, 2, 2, 64, jnp.float32),
    (2, 256, 256, 4, 2, 64, jnp.float32),
    (1, 128, 128, 4, 1, 128, jnp.bfloat16),   # MQA
    (2, 64, 64, 2, 2, 32, jnp.float32),
])
def test_flash_attention_shapes(b, tq, tk, h, kh, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (b, tq, h, dh), dtype)
    k = rand(ks[1], (b, tk, kh, dh), dtype)
    v = rand(ks[2], (b, tk, kh, dh), dtype)
    got = ops.flash_attention(q, k, v, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=atol)


def test_flash_attention_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 256, 2, 64), jnp.float32)
    k = rand(ks[1], (1, 256, 2, 64), jnp.float32)
    v = rand(ks[2], (1, 256, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, window=64, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (1, 128, 2, 64), jnp.float32)
    k = rand(ks[1], (1, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (1, 128, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kh,dh,dtype", [
    (2, 512, 4, 2, 64, jnp.float32),
    (1, 1024, 8, 1, 128, jnp.bfloat16),
    (3, 256, 2, 2, 32, jnp.float32),
])
def test_decode_attention(b, s, h, kh, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (b, h, dh), dtype)
    kc = rand(ks[1], (b, s, kh, dh), dtype)
    vc = rand(ks[2], (b, s, kh, dh), dtype)
    kv_len = jnp.array([s // 2 + 7 * i for i in range(b)], jnp.int32)
    got = ops.decode_attention(q, kc, vc, kv_len, block_kv=128)
    want = ref.decode_attention_ref(q, kc, vc, kv_len)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=atol)


# ---------------------------------------------------------------------------
# rwkv6 chunked recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,n,chunk", [
    (1, 128, 2, 32, 32),
    (2, 128, 1, 64, 64),
    (1, 64, 3, 16, 16),
])
def test_rwkv6_scan(b, t, h, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = rand(ks[0], (b, t, h, n), jnp.float32)
    k = rand(ks[1], (b, t, h, n), jnp.float32)
    v = rand(ks[2], (b, t, h, n), jnp.float32)
    logw = -jnp.exp(rand(ks[3], (b, t, h, n), jnp.float32) * 0.5)
    u = rand(ks[4], (h, n), jnp.float32) * 0.1
    got = ops.rwkv6_scan(r, k, v, logw, u, chunk=chunk)
    want = ref.rwkv6_scan_ref(r, k, v, logw, u)
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# rg-lru recurrence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,w,chunk,block_w", [
    (2, 128, 128, 64, 128),
    (1, 256, 256, 128, 128),
    (2, 64, 512, 32, 256),
])
def test_rglru_scan(b, t, w, chunk, block_w):
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    u = rand(ks[0], (b, t, w), jnp.float32)
    w_r = rand(ks[1], (w,), jnp.float32) * 0.1
    b_r = rand(ks[2], (w,), jnp.float32) * 0.1
    w_i = rand(ks[3], (w,), jnp.float32) * 0.1
    b_i = rand(ks[4], (w,), jnp.float32) * 0.1
    lam = jnp.linspace(2.0, 6.0, w)
    got = ops.rglru_scan(u, w_r, b_r, w_i, b_i, lam, chunk=chunk,
                         block_w=block_w)
    want = ref.rglru_scan_ref(u, w_r, b_r, w_i, b_i, lam)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# grouped expert FFN
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f,gated,dtype", [
    (2, 128, 128, 256, True, jnp.float32),
    (4, 128, 256, 512, True, jnp.bfloat16),
    (2, 128, 128, 128, False, jnp.float32),
])
def test_moe_gmm(e, c, d, f, gated, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    x = rand(ks[0], (e, c, d), dtype) * 0.5
    wg = rand(ks[1], (e, d, f), dtype) * d ** -0.5
    wi = rand(ks[2], (e, d, f), dtype) * d ** -0.5
    wo = rand(ks[3], (e, f, d), dtype) * f ** -0.5
    got = ops.moe_gmm(x, wg, wi, wo, gated=gated, block_c=64, block_f=128,
                      block_d=64)
    want = ref.moe_gmm_ref(x, wg, wi, wo, gated=gated)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=atol)


def test_moe_gmm_matches_model_expert_ffn():
    """Kernel == the model's einsum expert path (repro.models.moe)."""
    from repro.models import moe as model_moe

    class Cfg:
        mlp = "swiglu"

    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = rand(ks[0], (2, 64, 64), jnp.float32)
    wg = rand(ks[1], (2, 64, 128), jnp.float32) * 0.1
    wi = rand(ks[2], (2, 64, 128), jnp.float32) * 0.1
    wo = rand(ks[3], (2, 128, 64), jnp.float32) * 0.1
    got = ops.moe_gmm(x, wg, wi, wo, block_c=64, block_f=64, block_d=64)
    want = model_moe._expert_ffn(x, wi, wg, wo, Cfg)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_moe_gmm_skip_matches_dense_on_live_experts():
    """Count-aware GMM == dense GMM for live experts; empty experts 0."""
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    e, c, d, f = 4, 64, 64, 128
    x = rand(ks[0], (e, c, d), jnp.float32) * 0.5
    wg = rand(ks[1], (e, d, f), jnp.float32) * 0.1
    wi = rand(ks[2], (e, d, f), jnp.float32) * 0.1
    wo = rand(ks[3], (e, f, d), jnp.float32) * 0.1
    counts = jnp.array([5, 0, 3, 0], jnp.int32)
    got = ops.moe_gmm_skip(x, wg, wi, wo, counts, block_c=64, block_f=64,
                           block_d=64)
    want = ref.moe_gmm_ref(x, wg, wi, wo)
    for i in range(e):
        if int(counts[i]) > 0:
            np.testing.assert_allclose(got[i], want[i], atol=2e-5,
                                       rtol=2e-5)
        else:
            np.testing.assert_array_equal(np.asarray(got[i]), 0.0)
