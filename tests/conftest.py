"""Shared test configuration.

Registers the "ci" hypothesis profile at conftest-import time — before any
test module is imported and before the hypothesis pytest plugin resolves
HYPOTHESIS_PROFILE — so CI's `HYPOTHESIS_PROFILE=ci` pins EVERY randomized
parity sweep in the suite (test_stackdist.py, test_slots.py,
test_stackdist_interleaved.py) to a fixed, derandomized profile instead of
only the module that happened to register it.
"""
import os

import pytest

try:
    from hypothesis import settings
except ImportError:       # dev extra; the suites degrade to seeded variants
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=20, deadline=None,
                              derandomize=True)
    if os.environ.get("HYPOTHESIS_PROFILE") == "ci":
        settings.load_profile("ci")


@pytest.fixture
def route_spy(monkeypatch):
    """Record every dispatch into the interleaved fast-path engine, then
    delegate to the real implementation — shared by the dispatcher-routing
    tests (test_stackdist_interleaved.py) and the sched-layer wiring tests
    (test_sched.py)."""
    from repro.core import simulator

    calls = []
    real = simulator._sweep_fleet_interleaved

    def spy(*args, **kwargs):
        calls.append(args)
        return real(*args, **kwargs)

    monkeypatch.setattr(simulator, "_sweep_fleet_interleaved", spy)
    return calls


@pytest.fixture
def resume_spy(monkeypatch):
    """Record every dispatch into the *resumable* interleaved entry (the
    state-seeding/materialising path of simulate_many), then delegate —
    shared by the resume-dispatch tests (test_resume_fastpath.py) and the
    online-layer wiring tests."""
    from repro.core import simulator

    calls = []
    real = simulator._resume_fleet_interleaved

    def spy(*args, **kwargs):
        calls.append(args)
        return real(*args, **kwargs)

    monkeypatch.setattr(simulator, "_resume_fleet_interleaved", spy)
    return calls
