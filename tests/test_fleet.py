"""The N-program fleet simulator: P=2 parity with the pair path, slot-state
persistence across context switches, per-program slot taxonomies, and the
{fleets x slot counts x miss latencies} sweep grid."""
import numpy as np
import pytest

from repro.core import isa, scheduler, simulator, traces

CFG = simulator.ReconfigConfig(num_slots=4, miss_latency=50)
SCHED = simulator.SchedulerConfig(quantum_cycles=5_000)


@pytest.fixture(scope="module")
def pair_tr():
    return np.stack([traces.build_trace("nbody", 20_000),
                     traces.build_trace("cubic", 20_000)])


# ---------------------------------------------------------------------------
# P=2 parity: the pair path must be exactly the fleet path
# ---------------------------------------------------------------------------

def test_simulate_many_p2_matches_simulate_pair_exactly(pair_tr):
    pair = simulator.simulate_pair(pair_tr, CFG, isa.SCENARIO_2, SCHED,
                                   total_steps=40_000)
    fleet = simulator.simulate_many(pair_tr, CFG, isa.SCENARIO_2, SCHED,
                                    total_steps=40_000)
    np.testing.assert_array_equal(np.asarray(pair.cycles),
                                  np.asarray(fleet.cycles))
    np.testing.assert_array_equal(np.asarray(pair.instructions),
                                  np.asarray(fleet.instructions))
    np.testing.assert_array_equal(np.asarray(pair.slot_misses),
                                  np.asarray(fleet.slot_misses))
    assert int(pair.switches) == int(fleet.switches) > 0


def test_pair_batch_matches_per_pair_runs(pair_tr):
    """The batched pair path routes through the masked sweep grid; it must
    reproduce the unmasked per-pair scans bit-for-bit."""
    other = np.stack([traces.build_trace("minver", 20_000),
                      traces.build_trace("matmult-int", 20_000)])
    tensor = np.stack([pair_tr, other])
    batch = simulator.simulate_pair_batch(tensor, CFG, isa.SCENARIO_2,
                                          SCHED, total_steps=40_000)
    for i, tr in enumerate((pair_tr, other)):
        one = simulator.simulate_pair(tr, CFG, isa.SCENARIO_2, SCHED,
                                      total_steps=40_000)
        np.testing.assert_array_equal(np.asarray(batch.cycles)[i],
                                      np.asarray(one.cycles))
        np.testing.assert_array_equal(np.asarray(batch.slot_misses)[i],
                                      np.asarray(one.slot_misses))
        assert int(np.asarray(batch.switches)[i]) == int(one.switches)


def test_masked_slot_count_equals_dedicated_state(pair_tr):
    """Sweeping slot counts by masking one max-size disambiguator must equal
    simulating with a dedicated state of that size."""
    res = simulator.sweep_fleet(pair_tr[None], [50], isa.SCENARIO_2, SCHED,
                                slot_counts=[2, 4, 8], total_steps=40_000)
    for k, nslots in enumerate((2, 4, 8)):
        cfg = simulator.ReconfigConfig(num_slots=nslots, miss_latency=50)
        direct = simulator.simulate_many(pair_tr, cfg, isa.SCENARIO_2,
                                         SCHED, total_steps=40_000)
        np.testing.assert_array_equal(np.asarray(res.cycles)[0, k, 0],
                                      np.asarray(direct.cycles))
        np.testing.assert_array_equal(np.asarray(res.slot_misses)[0, k, 0],
                                      np.asarray(direct.slot_misses))


# ---------------------------------------------------------------------------
# slot-state persistence across context switches (the paper's point, §IV)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [2, 3, 4])
def test_slot_state_persists_across_switches(p):
    """P copies of the same M-only program share one slotted tag: with
    persistent slot state the fleet takes exactly ONE cold miss total, no
    matter how many context switches occur (a flush-on-switch core would
    re-miss every quantum)."""
    tr = np.stack([traces.build_trace("matmult-int", 20_000)] * p)
    sched = simulator.SchedulerConfig(quantum_cycles=1_000)
    res = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                  total_steps=30_000)
    assert int(res.switches) > 10 * p
    assert int(np.asarray(res.slot_misses).sum()) == 1


def test_shared_working_set_warms_across_programs():
    """A later-scheduled program with the same working set inherits the
    earlier program's resident slots: its own cold misses vanish."""
    solo = simulator.simulate_many(
        np.stack([traces.build_trace("matmult-int", 20_000)]),
        CFG, isa.SCENARIO_2, simulator.SchedulerConfig.no_preempt(),
        total_steps=20_000)
    assert int(np.asarray(solo.slot_misses)[0]) == 1  # its own cold miss

    fleet = simulator.simulate_many(
        np.stack([traces.build_trace("matmult-int", 20_000, seed=0),
                  traces.build_trace("matmult-int", 20_000, seed=1)]),
        CFG, isa.SCENARIO_2, SCHED, total_steps=40_000)
    # program 1 never cold-misses: program 0 already loaded the mul slot
    assert int(np.asarray(fleet.slot_misses)[1]) == 0


# ---------------------------------------------------------------------------
# per-program slot taxonomies
# ---------------------------------------------------------------------------

def test_per_program_scenarios_fm_vs_m_miss_counts():
    """An FM-class and an M-class program in one fleet, each with its own
    instr_tag table: the FM program's larger slotted working set must
    produce (far) more misses than the M program's single group."""
    tr = np.stack([traces.build_trace("minver", 20_000),
                   traces.build_trace("matmult-int", 20_000)])
    res = simulator.simulate_many(
        tr, CFG, [isa.SCENARIO_2, isa.SCENARIO_3], SCHED,
        total_steps=40_000)
    misses = np.asarray(res.slot_misses)
    assert misses[0] > 10 * max(int(misses[1]), 1)


def test_per_program_tag_table_changes_results():
    """Swapping one program's scenario (group-level -> extension-level)
    changes that program's miss count: tag tables are genuinely per-program,
    not shared."""
    tr = np.stack([traces.build_trace("minver", 20_000),
                   traces.build_trace("nbody", 20_000)])
    shared = simulator.simulate_many(
        tr, CFG, [isa.SCENARIO_2, isa.SCENARIO_2], SCHED,
        total_steps=40_000)
    mixed = simulator.simulate_many(
        tr, CFG, [isa.SCENARIO_2, isa.SCENARIO_3], SCHED,
        total_steps=40_000)
    assert (int(np.asarray(shared.slot_misses)[1])
            != int(np.asarray(mixed.slot_misses)[1]))
    # program 0's table is identical in both runs, but it shares the slot
    # pool, so cross-program interference may shift its counts — only the
    # swapped program is guaranteed to differ


def test_fleet_tag_table_shapes_and_errors():
    t = simulator.fleet_tag_table(isa.SCENARIO_2, 3)
    assert t.shape == (3, isa.NUM_INSTRUCTIONS)
    t2 = simulator.fleet_tag_table([isa.SCENARIO_1, isa.SCENARIO_3], 2)
    assert not np.array_equal(t2[0], t2[1])
    with pytest.raises(ValueError):
        simulator.fleet_tag_table([isa.SCENARIO_1], 2)


# ---------------------------------------------------------------------------
# sweep grid + fleet construction
# ---------------------------------------------------------------------------

def test_sweep_fleet_p4_grid_matches_individual_runs():
    fleets = scheduler.make_fleets(4)[:2]
    tensor = scheduler.fleet_traces(fleets, 15_000)
    lats = (10, 250)
    res = simulator.sweep_fleet(tensor, lats, isa.SCENARIO_2, SCHED,
                                slot_counts=[4], total_steps=30_000)
    assert np.asarray(res.cycles).shape == (2, 1, 2, 4)
    for b in range(2):
        for li, lat in enumerate(lats):
            cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=lat)
            one = simulator.simulate_many(tensor[b], cfg, isa.SCENARIO_2,
                                          SCHED, total_steps=30_000)
            np.testing.assert_array_equal(
                np.asarray(res.cycles)[b, 0, li], np.asarray(one.cycles))


def test_make_fleets_counts_and_pair_special_case():
    assert scheduler.make_fleets(2) == scheduler.make_pairs()
    assert len(scheduler.make_pairs()) == 50
    # C(5,3) + C(5,2) * 8 = 10 + 80
    f3 = scheduler.make_fleets(3)
    assert len(f3) == 90
    assert all(len(f) == 3 for f in f3)
    # every fleet competes for slots: at most one M-only member
    m = set(traces.M_BENCHES)
    assert all(sum(n in m for n in f) <= 1 for f in f3)
    with pytest.raises(ValueError):
        scheduler.make_fleets(1)


def test_fleet_traces_shape_and_mixed_size_error():
    f = scheduler.make_fleets(3)[:2]
    t = scheduler.fleet_traces(f, 5_000)
    assert t.shape == (2, 3, 5_000) and t.dtype == np.int32
    with pytest.raises(ValueError):
        scheduler.fleet_traces([("minver", "st"), ("minver", "st", "ud")],
                               5_000)
