"""Resumable fleet state (`simulator.FleetState`) and online re-placement
(`repro.sched.online`): split-resume parity with the one-shot scan,
migration-penalty probes, and the epoch-driven replacer's policies."""
import jax
import numpy as np
import pytest

from repro.core import isa, simulator, slots, traces
from repro.sched import (ContentionModel, OnlineConfig, OnlineReplacer,
                         PlacementConfig, TenantEvent)
from repro.sched.online import POLICIES

CFG = simulator.ReconfigConfig(num_slots=4, miss_latency=50)


def preempted_fleet(p=3, n=4_000):
    return np.stack([traces.build_trace(b, n) for b in
                     ["minver", "nbody", "crc32", "cubic"][:p]])


def assert_state_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# shared bit-for-bit equality contract, tests/fleet_asserts.py
from fleet_asserts import assert_fleet_equal  # noqa: E402


# ---------------------------------------------------------------------------
# resume parity: split-at-T == one-shot, bit for bit (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("split", [1, 1_000, 8_999])
def test_split_resume_equals_one_shot_preempted_p3(split):
    tr = preempted_fleet(3)
    sched = simulator.SchedulerConfig(quantum_cycles=1_500)
    total = 9_000
    one, s_one = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2, sched, total, return_state=True)
    assert int(one.switches) > 0          # genuinely preempted
    r1, s1 = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2, sched, split, return_state=True)
    r2, s2 = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2, sched, total - split, state=s1,
        return_state=True)
    assert_fleet_equal(r2, one)           # cumulative counters match
    assert_state_equal(s2, s_one)         # caches/cursors/clocks match


def test_split_resume_heterogeneous_quanta_and_priorities():
    tr = preempted_fleet(2)
    sched = simulator.SchedulerConfig(quantum_cycles=(1_000, 3_000),
                                      priorities=(2, 1))
    one = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 6_000)
    _, s1 = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_500,
                                    return_state=True)
    r2 = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 3_500,
                                 state=s1)
    assert_fleet_equal(r2, one)


def test_one_shot_result_unchanged_by_refactor_default_path():
    """The S = init special case: passing the explicit cold state equals
    not passing a state at all."""
    tr = preempted_fleet(2)
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    implicit = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                       5_000)
    explicit = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2, sched, 5_000,
        state=simulator.init_fleet_state(2, CFG.num_slots,
                                         CFG.bs_cache_entries))
    assert_fleet_equal(implicit, explicit)


def test_reset_counters_yields_segment_deltas():
    tr = preempted_fleet(2)
    sched = simulator.SchedulerConfig(quantum_cycles=1_500)
    r1, s1 = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 3_000,
                                     return_state=True)
    cum = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_000,
                                  state=s1)
    seg = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_000,
                                  state=s1.reset_counters())
    np.testing.assert_array_equal(
        np.asarray(seg.cycles),
        np.asarray(cum.cycles) - np.asarray(r1.cycles))
    np.testing.assert_array_equal(
        np.asarray(seg.instructions),
        np.asarray(cum.instructions) - np.asarray(r1.instructions))


def test_fleet_state_validation():
    tr = preempted_fleet(2)
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    with pytest.raises(ValueError, match="program cursors"):
        simulator.simulate_many(
            tr, CFG, isa.SCENARIO_2, sched, 100,
            state=simulator.init_fleet_state(3, 4))
    with pytest.raises(ValueError, match="slot geometry"):
        simulator.simulate_many(
            tr, CFG, isa.SCENARIO_2, sched, 100,
            state=simulator.init_fleet_state(2, 8))
    with pytest.raises(ValueError, match="bitstream cache"):
        simulator.simulate_many(
            tr, CFG, isa.SCENARIO_2, sched, 100,
            state=simulator.init_fleet_state(2, 4, bs_entries=7))
    with pytest.raises(ValueError, match="num_programs"):
        simulator.init_fleet_state(0, 4)


def test_resume_rejects_shorter_priority_schedule():
    """A state whose scheduler cursor points past the new schedule's end
    would gather-clamp to the wrong program — it must be rejected."""
    tr = preempted_fleet(2)
    weighted = simulator.SchedulerConfig(quantum_cycles=1_000,
                                         priorities=(2, 1))
    _, s1 = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, weighted,
                                    4_000, return_state=True)
    s1 = s1._replace(sched_idx=np.int32(2))     # a reachable cursor value
    uniform = simulator.SchedulerConfig(quantum_cycles=1_000)
    with pytest.raises(ValueError, match="scheduler cursor"):
        simulator.simulate_many(tr, CFG, isa.SCENARIO_2, uniform, 100,
                                state=s1)
    # same-or-longer schedules resume fine
    simulator.simulate_many(tr, CFG, isa.SCENARIO_2, weighted, 100,
                            state=s1)


def test_warm_state_resume_skips_cold_misses():
    """Resuming a warmed fleet takes no new cold misses — the carryable
    state really carries the disambiguator contents."""
    tr = np.stack([traces.build_trace("matmult-int", 4_000)])
    sched = simulator.SchedulerConfig.no_preempt()
    r1, s1 = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_000,
                                     return_state=True)
    assert int(np.asarray(r1.slot_misses)[0]) == 1      # its one cold miss
    seg = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched, 2_000,
                                  state=s1.reset_counters())
    assert int(np.asarray(seg.slot_misses)[0]) == 0     # stays resident


# ---------------------------------------------------------------------------
# slots: vectorized residency probe
# ---------------------------------------------------------------------------

def test_resident_many_matches_scalar_probe():
    st = slots.init(4)
    for t in (3, 5, 3, 9):
        st = slots.lookup(st, t).state
    probe = np.asarray(slots.resident_many(st, np.array([3, 5, 9, 7, -1])))
    np.testing.assert_array_equal(probe, [True, True, True, False, False])
    for tag, want in zip([3, 5, 9, 7, -1], probe):
        assert bool(slots.resident(st, np.int32(tag))) == bool(want)


# ---------------------------------------------------------------------------
# online replacer
# ---------------------------------------------------------------------------

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                      trace_len=2_000, steps_per_program=2_000)
OCFG = OnlineConfig(num_cores=2, epoch_steps=2_000, probe_steps=800,
                    placement=PCFG)


@pytest.fixture(scope="module")
def model():
    return ContentionModel(PCFG)


def test_event_validation():
    with pytest.raises(ValueError, match="arrive"):
        TenantEvent(0, "join", "a", "minver")
    with pytest.raises(ValueError, match="bench"):
        TenantEvent(0, "arrive", "a")
    with pytest.raises(ValueError, match="epoch"):
        TenantEvent(-1, "depart", "a")


def test_replacer_validation(model):
    with pytest.raises(ValueError, match="policy"):
        OnlineReplacer(OCFG, model=model, policy="sometimes")
    with pytest.raises(ValueError, match="slots"):
        OnlineReplacer(OnlineConfig(
            num_cores=2, placement=PlacementConfig(num_slots=8)),
            model=model)
    rep = OnlineReplacer(OCFG, model=model)
    with pytest.raises(ValueError, match="unknown tenant"):
        rep.run([TenantEvent(0, "depart", "ghost")], 2)
    rep = OnlineReplacer(OCFG, model=model)
    with pytest.raises(ValueError, match="twice"):
        rep.run([TenantEvent(0, "arrive", "a", "crc32"),
                 TenantEvent(1, "arrive", "a", "crc32")], 3)
    rep = OnlineReplacer(OCFG, model=model)
    with pytest.raises(ValueError, match="fresh name"):
        # a departed name may not be reused: its service record would be
        # shadowed in the final report
        rep.run([TenantEvent(0, "arrive", "a", "crc32"),
                 TenantEvent(1, "depart", "a"),
                 TenantEvent(2, "arrive", "a", "minver")], 4)
    rep = OnlineReplacer(OCFG, model=model)
    with pytest.raises(ValueError, match="horizon"):
        rep.run([TenantEvent(9, "arrive", "a", "crc32")], 3)
    with pytest.raises(ValueError, match="unknown tenant name"):
        # resolve_trace names both valid sets: Embench benches and
        # model-zoo "<arch>:<phase>" workloads
        OnlineReplacer(OCFG, model=model).run(
            [TenantEvent(0, "arrive", "a", "nosuchbench")], 2)


def test_migration_penalty_warm_beats_cold(model):
    """A slot-hungry tenant that has run a while is cheaper to resume on
    its warm core than on a cold one — the measured penalty is positive."""
    rep = OnlineReplacer(OCFG, model=model, policy="never")
    rep.run([TenantEvent(0, "arrive", "fg", "minver")], 2)
    assert rep.warm_fraction("fg") > 0.0
    assert rep.migration_penalty("fg") > 0.0


def test_departures_keep_service_records(model):
    rep = OnlineReplacer(OCFG, model=model, policy="never")
    report = rep.run([TenantEvent(0, "arrive", "a", "crc32"),
                      TenantEvent(0, "arrive", "b", "tarfind"),
                      TenantEvent(2, "depart", "a")], 4)
    assert set(report.per_tenant) == {"a", "b"}
    assert report.per_tenant["a"]["scheduled"]
    assert report.per_tenant["a"]["instrs"] > 0
    assert "a" not in {n for core in report.final_cores for n in core}


def test_epoch_accounting_conserves_steps(model):
    """Every epoch advances each non-empty core by exactly epoch_steps
    instructions, split across its residents."""
    rep = OnlineReplacer(OCFG, model=model, policy="never")
    report = rep.run([TenantEvent(0, "arrive", "a", "minver"),
                      TenantEvent(0, "arrive", "b", "crc32"),
                      TenantEvent(1, "arrive", "c", "nbody")], 3)
    total = sum(report.per_tenant[n]["instrs"] for n in "abc")
    # epoch 0: 2 cores busy (a, b solo); epochs 1-2: both cores, one
    # holding two tenants is still epoch_steps of scan budget
    assert total == 2 * OCFG.epoch_steps + 2 * 2 * OCFG.epoch_steps


@pytest.mark.parametrize("policy", POLICIES)
def test_policies_run_and_report(model, policy):
    events = [TenantEvent(0, "arrive", "fgA", "minver"),
              TenantEvent(0, "arrive", "fgB", "cubic"),
              TenantEvent(1, "arrive", "m1", "crc32"),
              TenantEvent(1, "arrive", "m2", "tarfind")]
    rep = OnlineReplacer(OCFG, model=model, policy=policy).run(events, 4)
    assert rep.policy == policy
    assert rep.epochs == 4
    assert rep.worst_slowdown >= 1.0
    assert set(rep.per_tenant) == {"fgA", "fgB", "m1", "m2"}
    if policy == "never":
        assert rep.migrations == 0 and not rep.moves
    roster = [n for core in rep.final_cores for n in core]
    assert sorted(roster) == ["fgA", "fgB", "m1", "m2"]


def test_warm_policy_declines_net_negative_moves(model):
    """Two interchangeable light tenants: any re-solve diff is a
    zero-benefit swap, so warm must never migrate while always executes
    whatever the re-solve implies."""
    events = [TenantEvent(0, "arrive", "a", "minver"),
              TenantEvent(0, "arrive", "b", "cubic"),
              TenantEvent(1, "arrive", "c", "tarfind"),
              TenantEvent(2, "arrive", "d", "tarfind")]
    warm = OnlineReplacer(OCFG, model=model, policy="warm").run(events, 5)
    always = OnlineReplacer(OCFG, model=model,
                            policy="always").run(events, 5)
    for m in warm.moves:
        assert m["applied"] == (m["net_cycles"] > 0)
    assert warm.migrations <= always.migrations


def test_exchange_units_decompose_swaps_and_chains(model):
    rep = OnlineReplacer(OCFG, model=model)
    for name, core in (("a", 0), ("b", 1), ("c", 0), ("d", 1)):
        rep._arrive(name, "crc32")
        rep.tenants[name].core = core
    # a<->b swap plus a lone c move: one 2-cycle + one chain
    units = rep._exchange_units({"a": 1, "b": 0, "c": 1, "d": 1})
    assert sorted(sorted(u) for u in units) == [["a", "b"], ["c"]]
