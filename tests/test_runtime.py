"""Substrate tests: data determinism, checkpoint integrity, fault-tolerant
supervision (restart / straggler), elastic re-shard, optimizer, and the
end-to-end smoke training driver (loss must go down)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import base as cb
from repro.data import pipeline
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import fault

cb.load_all()


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_step_dependent():
    cfg = pipeline.DataConfig(vocab=1000, seq_len=32, global_batch=4)
    a = pipeline.global_batch_at(cfg, 7)
    b = pipeline.global_batch_at(cfg, 7)
    c = pipeline.global_batch_at(cfg, 8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_data_sharded_matches_global():
    cfg = pipeline.DataConfig(vocab=500, seq_len=16, global_batch=8)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    arr = pipeline.make_batch(cfg, 3, sharding)
    np.testing.assert_array_equal(np.asarray(arr),
                                  pipeline.global_batch_at(cfg, 3))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.int32(7)]}
    ckpt.save(str(tmp_path), 42, tree)
    assert ckpt.latest_step(str(tmp_path)) == 42
    shapes = jax.eval_shape(lambda: tree)
    back = ckpt.restore(str(tmp_path), 42, shapes)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(8, dtype=jnp.float32)}
    path = ckpt.save(str(tmp_path), 1, tree)
    arr = np.load(os.path.join(path, "arr_0.npy"))
    arr[0] = 999.0
    np.save(os.path.join(path, "arr_0.npy"), arr)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: tree))


def test_partial_checkpoint_invisible(tmp_path):
    os.makedirs(tmp_path / "step_00000009")  # no manifest -> torn write
    assert ckpt.latest_step(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_supervised_restart_resumes_from_checkpoint(tmp_path):
    """Inject a failure; the run must restore and produce the same final
    state a failure-free run produces (deterministic data)."""
    def make_run(fail_at):
        saved = {}
        state = {"x": 0}

        def init_fn():
            if "ckpt" in saved:
                return dict(saved["ckpt"]), saved["step"]
            return dict(state), 0

        def step_fn(st, step):
            st = {"x": st["x"] + (step + 1)}
            return st, {}

        def save_fn(st, step):
            saved["ckpt"] = dict(st)
            saved["step"] = step

        failed = {"done": False}

        def fail_hook(step):
            if fail_at is not None and step == fail_at and not failed["done"]:
                failed["done"] = True
                raise fault.TrainingFailure("boom")

        report = fault.run_supervised(
            init_fn=init_fn, step_fn=step_fn, save_fn=save_fn,
            restore_fn=init_fn, num_steps=10, ckpt_every=3,
            fail_hook=fail_hook)
        # recompute final x
        st, s0 = init_fn()
        return report, saved["ckpt"]["x"]

    clean_report, clean_x = make_run(None)
    fail_report, fail_x = make_run(7)
    assert fail_report["restarts"] == 1
    assert fail_report["final_step"] == clean_report["final_step"] == 10
    assert fail_x == clean_x  # deterministic replay


def test_restart_budget_exhausted():
    def fail_hook(step):
        raise fault.TrainingFailure("always")

    with pytest.raises(fault.TrainingFailure):
        fault.run_supervised(
            init_fn=lambda: ({}, 0), step_fn=lambda s, i: (s, {}),
            save_fn=lambda s, i: None, restore_fn=lambda: ({}, 0),
            num_steps=5, ckpt_every=100,
            policy=fault.RestartPolicy(max_restarts=2),
            fail_hook=fail_hook)


def test_straggler_monitor_flags_slow_steps():
    mon = fault.StragglerMonitor(window=16, threshold=2.0)
    for i in range(20):
        mon.observe(i, 0.1)
    assert mon.observe(20, 0.5)  # 5x median
    assert len(mon.events) == 1
    assert not mon.observe(21, 0.11)


def test_straggler_monitor_times_bounded_by_window():
    """The sliding window is also the storage bound: a long run must not
    accrete one float per step forever."""
    mon = fault.StragglerMonitor(window=16, threshold=2.0)
    for i in range(500):
        mon.observe(i, 0.1)
    assert len(mon.times) == 16
    # trimming must not change detection: the median window still sees
    # the same last-16 history
    assert mon.observe(500, 0.5)


def test_run_supervised_custom_retryable():
    """A widened `retryable` tuple absorbs infrastructure exceptions the
    default policy would propagate."""
    class FlakyIO(OSError):
        pass

    failed = {"done": False}

    def fail_hook(step):
        if step == 2 and not failed["done"]:
            failed["done"] = True
            raise FlakyIO("transient")

    kw = dict(init_fn=lambda: ({}, 0), step_fn=lambda s, i: (s, {}),
              save_fn=lambda s, i: None, restore_fn=lambda: ({}, 0),
              num_steps=5, ckpt_every=100, fail_hook=fail_hook)
    # default policy: FlakyIO is not retryable -> propagates
    with pytest.raises(FlakyIO):
        fault.run_supervised(**kw)
    failed["done"] = False
    report = fault.run_supervised(
        retryable=(fault.TrainingFailure, FlakyIO), **kw)
    assert report["restarts"] == 1 and report["final_step"] == 5
    with pytest.raises(TypeError, match="retryable"):
        fault.run_supervised(retryable=("not-a-type",), **kw)


def test_heartbeat(tmp_path):
    hb = fault.Heartbeat(str(tmp_path / "hb.json"))
    hb.beat(3, 0.5)
    assert hb.age() < 5.0


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup=0,
                            schedule="constant")
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init_state(cfg, params)
    for _ in range(120):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(state.params)
        state, _ = adamw.apply_updates(cfg, state, grads)
    assert float(jnp.abs(state.params["w"]).max()) < 0.15


def test_adamw_factored_v_close_to_full():
    full = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup=0,
                             schedule="constant")
    fact = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup=0,
                             schedule="constant", factored_v=True)
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (8, 8))

    def train(cfg):
        params = {"w": jnp.zeros((8, 8))}
        state = adamw.init_state(cfg, params)
        for _ in range(150):
            grads = jax.grad(
                lambda p: jnp.mean((p["w"] - target) ** 2))(state.params)
            state, _ = adamw.apply_updates(cfg, state, grads)
        return float(jnp.mean((state.params["w"] - target) ** 2))

    assert train(fact) < 0.05 and train(full) < 0.05


def test_grad_clipping_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                            warmup=0, schedule="constant")
    params = {"w": jnp.zeros((4,))}
    state = adamw.init_state(cfg, params)
    grads = {"w": jnp.full((4,), 1e6)}
    state, metrics = adamw.apply_updates(cfg, state, grads)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.abs(state.params["w"]).max()) < 1.5


# ---------------------------------------------------------------------------
# end-to-end smoke training (driver + pipeline + ckpt + fault runtime)
# ---------------------------------------------------------------------------

def test_train_driver_loss_decreases(tmp_path):
    from repro.launch import train as train_mod
    # 50 steps: the smoke run trains on random embeds, so the learnable
    # signal is the label marginals — 30 steps leaves the mean decrease
    # right at the 0.1 threshold on the pinned jax
    report = train_mod.run("musicgen-medium", smoke=True, steps=50,
                           batch=4, seq=32, ckpt_dir=str(tmp_path),
                           ckpt_every=10, log_every=0)
    losses = report["losses"]
    assert report["final_step"] == 50
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_driver_restart_matches_clean_run(tmp_path):
    from repro.launch import train as train_mod
    clean = train_mod.run("granite-3-2b", smoke=True, steps=16, batch=2,
                          seq=32, ckpt_dir=str(tmp_path / "clean"),
                          ckpt_every=4, log_every=0)
    failed = train_mod.run("granite-3-2b", smoke=True, steps=16, batch=2,
                           seq=32, ckpt_dir=str(tmp_path / "fail"),
                           ckpt_every=4, fail_at=10, log_every=0)
    assert failed["restarts"] == 1
    # after restart, replayed losses must match the clean run's tail
    assert failed["losses"][-1] == pytest.approx(clean["losses"][-1],
                                                 rel=1e-4)
