"""Topology layer (repro.sched.topology): geometry and distance tiers,
tiered migration pricing in the OnlineReplacer, per-host static placement
(`place_fleet`), the canonical prediction-cache key, and mesh-sharded
candidate-group sweeps.

The load-bearing equivalences pinned here:

  * `Topology.flat(C)` reproduces the pre-topology flat pool exactly —
    every distance intra-socket, every reload surcharge zero, so
    `migration_penalty(n, dst) == migration_penalty(n)` and
    `place_fleet == place_tenants`;
  * `(group, width)` prediction-cache keys are canonical: a permuted
    group at a degraded width hits the sorted twin's entry, and degraded
    entries never alias (nor get served from) the full-width one;
  * candidate sweeps shard across a forced multi-device host mesh with
    predictions bit-identical to the single-device scan path.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import simulator
from repro.sched import (ContentionModel, OnlineConfig, OnlineReplacer,
                         PlacementConfig, TenantEvent, Topology,
                         place_fleet, place_tenants)
from repro.sched.topology import DISTANCES

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=2_000, steps_per_program=2_000)


@pytest.fixture(scope="module")
def model():
    return ContentionModel(PCFG)


# ---------------------------------------------------------------------------
# pure geometry
# ---------------------------------------------------------------------------

def test_topology_validation():
    with pytest.raises(ValueError, match="num_hosts"):
        Topology(num_hosts=0)
    with pytest.raises(ValueError, match="cores_per_socket"):
        Topology(cores_per_socket=0)
    with pytest.raises(ValueError, match="multipliers"):
        Topology(cross_socket_reload=-1.0)
    with pytest.raises(ValueError, match="cross_host_reload"):
        Topology(cross_socket_reload=8.0, cross_host_reload=2.0)


def test_topology_geometry_and_distances():
    t = Topology(num_hosts=2, sockets_per_host=2, cores_per_socket=2)
    assert t.num_cores == 8
    assert t.cores_per_host == 4 and t.num_sockets == 4
    assert t.geometry() == (2, 2, 2)
    assert list(t.cores_of_host(1)) == [4, 5, 6, 7]
    assert t.host_of(3) == 0 and t.host_of(4) == 1
    assert t.socket_of(1) == 0 and t.socket_of(2) == 1
    assert t.distance(5, 5) == "intra_core"
    assert t.distance(4, 5) == "intra_socket"
    assert t.distance(4, 6) == "cross_socket"
    assert t.distance(3, 4) == "cross_host"
    assert t.reload_multiplier("intra_core") == 0.0
    assert t.reload_multiplier("intra_socket") == 0.0
    assert t.reload_multiplier("cross_socket") == 4.0
    assert t.reload_multiplier("cross_host") == 16.0
    assert all(d in DISTANCES for d in
               (t.distance(a, b) for a in range(8) for b in range(8)))
    with pytest.raises(ValueError, match="unknown distance"):
        t.reload_multiplier("adjacent")
    with pytest.raises(ValueError, match="core 8"):
        t.distance(0, 8)
    with pytest.raises(ValueError, match="host 2"):
        t.cores_of_host(2)


def test_flat_topology_is_the_pre_topology_pool():
    t = Topology.flat(5)
    assert t.geometry() == (1, 1, 5) and t.num_cores == 5
    for a in range(5):
        for b in range(5):
            d = t.distance(a, b)
            assert d == ("intra_core" if a == b else "intra_socket")
            assert t.reload_multiplier(d) == 0.0


def test_online_config_topology_wiring():
    # default: a flat pool of num_cores
    assert OnlineConfig(num_cores=3).topology.geometry() == (1, 1, 3)
    # explicit topology *defines* num_cores
    cfg = OnlineConfig(num_cores=1, topology=Topology(
        num_hosts=2, sockets_per_host=1, cores_per_socket=3))
    assert cfg.num_cores == 6
    with pytest.raises(TypeError, match="Topology"):
        OnlineConfig(topology=(2, 1, 3))


# ---------------------------------------------------------------------------
# canonical (group, width) prediction-cache keys — the PR 7 keying bugfix
# ---------------------------------------------------------------------------

def test_permuted_degraded_group_hits_the_same_cache_entry(model):
    before = model.groups_simulated
    a = model.predict([("nbody", "tarfind")], num_slots=2)[0]
    assert model.groups_simulated == before + 1
    # the permuted twin at the same degraded width must be a cache hit
    b = model.predict([("tarfind", "nbody")], num_slots=2)[0]
    assert model.groups_simulated == before + 1
    np.testing.assert_array_equal(a, b)
    # and the cache holds exactly one canonical entry for it
    assert model._cache_key(("tarfind", "nbody"), 2) in model._groups
    assert model._cache_key(("nbody", "tarfind"), 2) == \
        model._cache_key(("tarfind", "nbody"), 2)


def test_degraded_width_never_aliases_full_width(model):
    g = ("cubic", "minver")
    before = model.groups_simulated
    full = model.predict([g])[0]
    assert model.groups_simulated == before + 1
    # pricing the same group at a degraded width must simulate anew —
    # serving it from the full-width entry would hide the degradation
    deg = model.predict([g], num_slots=1)[0]
    assert model.groups_simulated == before + 2
    assert model._cache_key(g, 1) in model._groups
    assert model._cache_key(g, PCFG.num_slots) in model._groups
    # the 1-slot core thrashes harder than the full-width one
    assert float(np.max(deg)) > float(np.max(full))


# ---------------------------------------------------------------------------
# topology-aware static placement
# ---------------------------------------------------------------------------

ROSTER = {"a": "minver", "b": "cubic", "c": "qrduino",
          "d": "edn", "e": "crc32"}


def test_place_fleet_flat_equals_place_tenants(model):
    flat = place_fleet(ROSTER, Topology.flat(3), model)
    plain = place_tenants(ROSTER, 3, model)
    assert flat.cores == plain.cores
    assert flat.tenant_slowdown == plain.tenant_slowdown
    assert flat.worst_slowdown == plain.worst_slowdown


def test_place_fleet_partitions_tenants_across_hosts(model):
    topo = Topology(num_hosts=2, sockets_per_host=1, cores_per_socket=2)
    pl = place_fleet(ROSTER, topo, model)
    placed = [n for core in pl.cores for n in core]
    assert sorted(placed) == sorted(ROSTER)       # everyone exactly once
    assert len(pl.cores) <= topo.num_cores
    with pytest.raises(ValueError, match="at least one tenant"):
        place_fleet({}, topo, model)


# ---------------------------------------------------------------------------
# tiered migration pricing in the online replacer
# ---------------------------------------------------------------------------

def _warmed_replacer(model, topo):
    cfg = OnlineConfig(topology=topo, epoch_steps=2_000, probe_steps=800,
                       placement=PCFG)
    rep = OnlineReplacer(cfg, model=model, policy="never")
    rep.run([TenantEvent(0, "arrive", "a", "minver")], 2)
    assert rep.tenants["a"].core == 0     # deterministic arrival tie-break
    return rep


def test_flat_migration_penalty_is_the_bare_probe(model):
    rep = _warmed_replacer(model, Topology.flat(3))
    bare = rep.migration_penalty("a")
    for dst in range(3):
        assert rep.reload_cycles("a", dst) == 0.0
        assert rep.migration_penalty("a", dst) == bare


def test_cross_socket_and_cross_host_moves_pay_the_reload_tiers(model):
    # 4 cores: 0,1 = host 0 (sockets 0,1); 2,3 = host 1 (sockets 2,3)
    topo = Topology(num_hosts=2, sockets_per_host=2, cores_per_socket=1)
    rep = _warmed_replacer(model, topo)
    bare = rep.migration_penalty("a")
    # the serve left warm bitstreams on core 0, so the surcharge is real
    cross_socket = rep.reload_cycles("a", 1)
    cross_host = rep.reload_cycles("a", 2)
    assert cross_socket > 0.0
    assert cross_host == pytest.approx(
        cross_socket * topo.cross_host_reload / topo.cross_socket_reload)
    # the surcharge is resident_bitstreams x bs_miss_extra x multiplier
    assert cross_socket % (rep.cfg.bs_miss_extra
                           * topo.cross_socket_reload) == 0.0
    assert rep.reload_cycles("a", 0) == 0.0            # intra_core
    assert rep.migration_penalty("a", 1) == bare + cross_socket
    assert rep.migration_penalty("a", 2) == bare + cross_host
    # a stranded tenant has no warm state to re-load
    rep.tenants["a"].core = -1
    assert rep.reload_cycles("a", 3) == 0.0


# ---------------------------------------------------------------------------
# mesh-sharded candidate sweeps (forced 4-device host mesh, subprocess)
# ---------------------------------------------------------------------------

def test_fleet_mesh_size_is_a_positive_int():
    n = simulator.fleet_mesh_size()
    assert isinstance(n, int) and n >= 1


def test_mesh_sharded_candidate_sweep_matches_scan():
    """ContentionModel predictions on a forced 4-device mesh (batches pad
    to a multiple of the device count) must equal the single-path scan
    bit-for-bit — 3 candidate groups exercise the non-divisible
    round-up."""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    script = textwrap.dedent("""
        import numpy as np
        import jax
        from repro.core import simulator
        from repro.sched import ContentionModel, PlacementConfig
        assert jax.device_count() == 4, jax.devices()
        assert simulator.fleet_mesh_size() == 4
        pcfg = PlacementConfig(num_slots=4, miss_latency=50,
                               quantum_cycles=500, trace_len=1_000,
                               steps_per_program=1_000)
        groups = [("minver", "cubic"), ("crc32", "edn"),
                  ("qrduino", "nbody")]
        fast = ContentionModel(pcfg).predict(groups)
        scan = ContentionModel(pcfg, path="scan").predict(groups)
        for g, a, b in zip(groups, fast, scan):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=str(g))
        print("MESH-PREDICT-OK")
    """)
    env = dict(os.environ, PYTHONPATH=src, JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0 and "MESH-PREDICT-OK" in r.stdout, \
        r.stdout + "\n" + r.stderr
