"""Tests for the TPU adaptation: slot-resident expert serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # dev extra, not runtime dep
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import expert_slots as es


def cfg(**kw):
    base = dict(num_experts=8, slots_per_device=3, expert_bytes=1 << 20,
                fill_bandwidth=1e9)
    base.update(kw)
    return es.ExpertSlotConfig(**base)


def test_cold_block_all_miss():
    c = cfg()
    state = es.init_state(c)
    state, stats = es.access_block(state, jnp.array([0, 1, 1, 2]), c)
    assert int(stats.accessed) == 3
    assert int(stats.misses) == 3
    assert float(stats.fill_seconds) == pytest.approx(3 * c.fill_seconds)


def test_warm_block_hits():
    c = cfg()
    state = es.init_state(c)
    state, _ = es.access_block(state, jnp.array([0, 1, 2]), c)
    state, stats = es.access_block(state, jnp.array([0, 2]), c)
    assert int(stats.misses) == 0
    assert float(stats.hit_rate) == 1.0


def test_lru_eviction_block_granular():
    c = cfg(slots_per_device=2)
    state = es.init_state(c)
    state, _ = es.access_block(state, jnp.array([0]), c)   # res {0}
    state, _ = es.access_block(state, jnp.array([1]), c)   # res {0,1}
    state, _ = es.access_block(state, jnp.array([2]), c)   # evict 0
    assert not bool(state.resident[0])
    assert bool(state.resident[1]) and bool(state.resident[2])
    _, stats = es.access_block(state, jnp.array([0]), c)
    assert int(stats.misses) == 1


def test_residency_capped_at_slot_count():
    c = cfg(slots_per_device=3)
    state = es.init_state(c)
    state, _ = es.access_block(state, jnp.arange(8), c)  # 8 distinct at once
    assert int(jnp.sum(state.resident)) <= 3


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(st.integers(0, 7), min_size=1, max_size=6),
                min_size=1, max_size=8),
       st.integers(min_value=1, max_value=5))
def test_block_lru_invariants(blocks, slots):
    """Residency never exceeds slots; misses bounded by distinct accesses;
    a fully-resident re-access never misses."""
    c = cfg(slots_per_device=slots)
    state = es.init_state(c)
    for blk in blocks:
        ids = jnp.array(blk, jnp.int32)
        state, stats = es.access_block(state, ids, c)
        assert int(jnp.sum(state.resident)) <= slots
        assert int(stats.misses) <= int(stats.accessed)
        assert int(stats.accessed) == len(set(blk))
    # repeat the last block: if it fits the pool entirely, it must all hit
    if len(set(blocks[-1])) <= slots:
        _, stats = es.access_block(state, jnp.array(blocks[-1]), c)
        assert int(stats.misses) == 0


def test_slot_hit_routing_prefers_resident_within_margin():
    c = cfg(num_experts=4, slots_per_device=2, hit_bias=10.0, hit_margin=1.0)
    state = es.init_state(c)
    state, _ = es.access_block(state, jnp.array([2]), c)  # expert 2 resident
    # token A: expert 0 best by 0.5 (within margin) -> reroute to 2
    # token B: expert 1 best by 5.0 (outside margin) -> stays 1
    logits = jnp.array([[1.0, 0.0, 0.5, -1.0],
                        [0.0, 5.0, 0.0, -1.0]])
    ids, gates = es.slot_hit_routing(logits, state, c, k=1)
    assert int(ids[0, 0]) == 2
    assert int(ids[1, 0]) == 1
    assert gates.shape == (2, 1)


def test_slot_hit_routing_zero_bias_is_pure_topk():
    c = cfg(hit_bias=0.0)
    state = es.init_state(c)
    logits = jnp.array([[0.1, 3.0, -1.0, 0.0, 0.0, 0.0, 0.0, 0.0]])
    ids, _ = es.slot_hit_routing(logits, state, c, k=2)
    assert set(np.asarray(ids[0]).tolist()) == {1, 3} or \
        np.asarray(ids[0]).tolist()[0] == 1


def test_jit_scan_compatible():
    c = cfg()

    @jax.jit
    def run(blocks):
        def step(state, blk):
            state, stats = es.access_block(state, blk, c)
            return state, stats.misses
        return jax.lax.scan(step, es.init_state(c), blocks)[1]

    blocks = jnp.array([[0, 1, 2], [0, 1, 2], [3, 4, 5]], jnp.int32)
    misses = run(blocks)
    np.testing.assert_array_equal(np.asarray(misses), [3, 0, 3])
