"""Model-zoo tests: per-arch smoke (reduced config, one forward/train step,
shape + finiteness asserts) and layer-level correctness oracles, including
the prefill->decode consistency golden check for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import kvcache, layers, moe, rglru, rwkv6, transformer

cb.load_all()
jax.config.update("jax_default_matmul_precision", "float32")


# ---------------------------------------------------------------------------
# layer oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tq,tk,h,kh,dh,causal,window", [
    (16, 16, 4, 2, 8, True, 0),
    (8, 24, 4, 4, 16, False, 0),
    (32, 32, 2, 1, 8, True, 12),
])
def test_flash_attention_matches_ref(tq, tk, h, kh, dh, causal, window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, tq, h, dh))
    k = jax.random.normal(k2, (2, tk, kh, dh))
    v = jax.random.normal(k3, (2, tk, kh, dh))
    got = layers.flash_attention(q, k, v, causal=causal, window=window,
                                 block=8, q_offset=tk - tq)
    want = layers.attention_ref(q, k, v, causal=causal, window=window,
                                q_offset=tk - tq)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_local_attention_two_chunk_trick():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    b, t, h, dh, w = 2, 64, 2, 8, 16
    q = jax.random.normal(k1, (b, t, h, dh))
    k = jax.random.normal(k2, (b, t, h, dh))
    v = jax.random.normal(k3, (b, t, h, dh))
    got = transformer._local_attention(q, k, v, w)
    want = layers.attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_rwkv_chunked_matches_scan():
    key = jax.random.PRNGKey(2)
    b, t, h, n = 2, 64, 3, 8
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, n)) * 0.5)
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    s0 = jnp.zeros((b, h, n, n))
    o1, s1 = rwkv6.recurrence_scan(r, k, v, logw, u, s0)
    o2, s2 = rwkv6.recurrence_chunked(r, k, v, logw, u, s0, chunk=16)
    np.testing.assert_allclose(o1, o2, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(s1, s2, atol=2e-4, rtol=2e-4)


def test_rglru_associative_scan_matches_stepwise():
    cfg = cb.get_config("recurrentgemma-9b").smoke()
    p = rglru.init_rec_block(jax.random.PRNGKey(3), cfg)
    b, t = 2, 12
    u_c = jax.random.normal(jax.random.PRNGKey(4), (b, t, cfg.lru_width))
    h0 = jnp.zeros((b, cfg.lru_width))
    h_par, last_par = rglru.rglru_scan(p, u_c, h0)
    h = h0
    outs = []
    for i in range(t):
        o, h = rglru.rglru_step(p, u_c[:, i:i + 1], h)
        outs.append(o[:, 0])
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(h_par, h_seq, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(last_par, h, atol=1e-5, rtol=1e-5)


def test_moe_dense_routes_and_conserves():
    cfg = cb.get_config("arctic-480b").smoke()
    p = moe.init_moe(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, cfg.d_model))
    y, aux = moe.moe_apply_dense(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
    # every token routes top_k slots, minus capacity drops
    assert int(aux["expert_load"].sum()) <= 2 * 8 * cfg.top_k
    assert int(aux["expert_load"].sum()) >= 2 * 8 * cfg.top_k * 0.5


def test_mrope_sections_shapes():
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 6, 4, 16))
    pos3 = jnp.stack([jnp.arange(6)] * 3, -1)[None].repeat(2, 0)
    out = layers.apply_mrope(x, pos3, 1e4, (2, 3, 3))
    assert out.shape == x.shape
    # text-mode mrope (t=h=w) must equal plain rope
    ref = layers.apply_rope(x, pos3[..., 0], 1e4)
    np.testing.assert_allclose(out, ref, atol=1e-5)


# ---------------------------------------------------------------------------
# per-arch smoke: one train step + prefill/decode golden consistency
# ---------------------------------------------------------------------------

def make_batch(cfg, b=2, t=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(key, (b, t), 0, cfg.vocab)
    else:
        batch["embeds"] = jax.random.normal(key, (b, t, cfg.d_model),
                                            jnp.dtype(cfg.dtype))
        batch["labels"] = jax.random.randint(key, (b, t), 0, cfg.vocab)
    if cfg.pos == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(t)[None, :, None], (b, t, 3)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = cb.get_config(arch).smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, aux = transformer.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: transformer.loss_fn(cfg, p, batch)[0])(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_smoke_prefill_decode_consistency(arch):
    """Golden check: teacher-forced decode must reproduce full-seq logits."""
    cfg = cb.get_config(arch).smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    b, t = 2, 16
    batch = make_batch(cfg, b, t, seed=1)

    # full forward logits at every position
    x, _, _, ctx = transformer.forward(cfg, params, batch)
    full_logits = transformer._logits(cfg, params, x, ctx)

    # prefill on the first t0 tokens, then decode the rest one by one
    t0 = t // 2
    pre = {k: (v[:, :t0] if v.ndim > 1 else v) for k, v in batch.items()}
    logits0, cache, _ = transformer.prefill(cfg, params, pre)
    np.testing.assert_allclose(
        np.asarray(logits0[:, 0], np.float32),
        np.asarray(full_logits[:, t0 - 1], np.float32),
        atol=2e-3, rtol=2e-3)

    # pad attention caches out to t for decode writes
    def pad_cache(seg_cache, types):
        out = []
        for j, bt in enumerate(types):
            c = seg_cache[j]
            if bt in ("attn", "moe"):
                padlen = t - c["k"].shape[2]
                c = {n: jnp.pad(c[n], ((0, 0), (0, 0), (0, padlen),
                                       (0, 0), (0, 0))) for n in c}
            out.append(c)
        return out

    segs = transformer.segments(cfg)
    cache = [pad_cache(c, types) for c, (types, _) in zip(cache, segs)]

    for step in range(t0, t):
        db = {"positions": jnp.full((b,), step, jnp.int32)}
        if cfg.embed_inputs:
            db["tokens"] = batch["tokens"][:, step:step + 1]
        else:
            db["embeds"] = batch["embeds"][:, step:step + 1]
        logits, cache, _ = transformer.decode_step(cfg, params, db, cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, step], np.float32),
            atol=2e-3, rtol=2e-3)


def test_param_counts_match_config_estimates():
    for arch in cb.ARCH_IDS:
        cfg = cb.get_config(arch).smoke()
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        n = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(params))
        est = cfg.param_count()
        assert abs(n - est) / est < 0.35, (arch, n, est)
