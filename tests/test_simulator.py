"""System tests for the cycle-approximate reconfigurable-core simulator,
validated against the paper's published numbers (see EXPERIMENTS.md)."""
import numpy as np
import pytest

from repro.core import isa, scheduler, simulator, traces


@pytest.fixture(scope="module")
def fm_traces():
    return {n: traces.build_trace(n, 40_000) for n in traces.FM_BENCHES}


# ---------------------------------------------------------------------------
# Fig. 4 — fixed-ISA analytic model
# ---------------------------------------------------------------------------

def test_minver_f_speedup_matches_paper():
    """Paper: minver 2106M -> 77M cycles with "F" (27.5x)."""
    m = traces.mix_of("minver")
    s = simulator.analytic_cpi(m, isa.RV32I) / simulator.analytic_cpi(m, isa.RV32IF)
    assert s == pytest.approx(27.5, rel=0.02)


def test_matmult_int_m_speedup_matches_paper():
    m = traces.mix_of("matmult-int")
    s = simulator.analytic_cpi(m, isa.RV32I) / simulator.analytic_cpi(m, isa.RV32IM)
    assert s == pytest.approx(4.6, rel=0.02)


def test_wikisort_imf_speedup_matches_paper():
    """Paper: wikisort collective 2.9x for RV32IMF."""
    m = traces.mix_of("wikisort")
    s = simulator.analytic_cpi(m, isa.RV32I) / simulator.analytic_cpi(m, isa.RV32IMF)
    assert s == pytest.approx(2.9, rel=0.05)


def test_minver_rv32if_close_to_rv32imf():
    """Paper: minver's RV32IF performance is very close to RV32IMF."""
    m = traces.mix_of("minver")
    ratio = simulator.analytic_cpi(m, isa.RV32IF) / simulator.analytic_cpi(m, isa.RV32IMF)
    assert 1.0 <= ratio < 1.1


def test_classification_matches_paper():
    """Fig. 5: 5 FM-improved, 8 M-only, 9 insensitive; no F-only class."""
    for n in traces.BENCHES:
        m = traces.mix_of(n)
        s_m = simulator.analytic_cpi(m, isa.RV32I) / simulator.analytic_cpi(m, isa.RV32IM)
        s_f = simulator.analytic_cpi(m, isa.RV32I) / simulator.analytic_cpi(m, isa.RV32IF)
        cls = traces.BENCHES[n].cls
        if cls == traces.FM_CLASS:
            assert s_m > 1.1 and s_f > 1.1, n
        elif cls == traces.M_CLASS:
            assert s_m > 1.1 and s_f == pytest.approx(1.0), n
        else:
            assert s_m < 1.3 and s_f == pytest.approx(1.0), n
        # paper: "there is no class where a benchmark is only benefited
        # from F and not from M"
        assert not (s_f > 1.1 and s_m < 1.05), n


def test_extension_absent_is_never_faster():
    """ABI soft expansion must never beat hardware support."""
    for n in traces.BENCHES:
        m = traces.mix_of(n)
        cpis = {s: simulator.analytic_cpi(m, isa.SPECS[s])
                for s in ("RV32I", "RV32IM", "RV32IF", "RV32IMF")}
        assert cpis["RV32IMF"] <= cpis["RV32IM"] + 1e-9
        assert cpis["RV32IMF"] <= cpis["RV32IF"] + 1e-9
        assert cpis["RV32IM"] <= cpis["RV32I"] + 1e-9
        assert cpis["RV32IF"] <= cpis["RV32I"] + 1e-9


# ---------------------------------------------------------------------------
# Fig. 6 — single-benchmark slot scenarios
# ---------------------------------------------------------------------------

def _speedup_vs_imf(trace, name, scenario, latency):
    r = simulator.simulate_single(
        trace, simulator.ReconfigConfig(num_slots=scenario.num_slots,
                                        miss_latency=latency), scenario)
    imf = simulator.analytic_cpi(traces.mix_of(name), isa.RV32IMF)
    return imf / float(r.cpi)


def test_zero_latency_reconfig_equals_imf(fm_traces):
    """With free reconfiguration the core must match fixed RV32IMF."""
    r = simulator.simulate_single(
        fm_traces["nbody"],
        simulator.ReconfigConfig(num_slots=4, miss_latency=0,
                                 bs_miss_extra=0),
        isa.SCENARIO_2)
    imf = simulator.analytic_cpi(traces.mix_of("nbody"), isa.RV32IMF)
    assert imf / float(r.cpi) == pytest.approx(1.0, rel=5e-3)


def test_latency_ordering_monotone(fm_traces):
    for n, t in fm_traces.items():
        sp = [_speedup_vs_imf(t, n, isa.SCENARIO_2, L) for L in (10, 50, 250)]
        assert sp[0] > sp[1] > sp[2], (n, sp)


def test_scenario2_50c_average_near_paper(fm_traces):
    """Paper: scenario 2 @ 50 cycles averages ~71% of RV32IMF."""
    sp = [_speedup_vs_imf(t, n, isa.SCENARIO_2, 50)
          for n, t in fm_traces.items()]
    assert np.mean(sp) == pytest.approx(0.71, abs=0.06)


def test_scenario_1_and_2_over_90pct_at_10c(fm_traces):
    """Paper: scenarios 1 and 2 at 10-cycle run at over 90% of RV32IMF."""
    for sc in (isa.SCENARIO_1, isa.SCENARIO_2):
        sp = [_speedup_vs_imf(t, n, sc, 10) for n, t in fm_traces.items()]
        assert np.mean(sp) > 0.88, (sc.name, sp)


def test_scenario3_is_worst(fm_traces):
    """Paper: one-slot-per-extension is the worst scenario."""
    for L in (10, 50):
        s3 = np.mean([_speedup_vs_imf(t, n, isa.SCENARIO_3, L)
                      for n, t in fm_traces.items()])
        s2 = np.mean([_speedup_vs_imf(t, n, isa.SCENARIO_2, L)
                      for n, t in fm_traces.items()])
        s1 = np.mean([_speedup_vs_imf(t, n, isa.SCENARIO_1, L)
                      for n, t in fm_traces.items()])
        assert s3 < s2 and s3 < s1


def test_sporadic_benchmarks_beat_best_fixed_extension(fm_traces):
    """Paper: s2@50c exceeds max(IM,IF) for st and wikisort."""
    for n in ("st", "wikisort"):
        m = traces.mix_of(n)
        imf = simulator.analytic_cpi(m, isa.RV32IMF)
        best_fixed = max(
            imf / simulator.analytic_cpi(m, isa.RV32IM),
            imf / simulator.analytic_cpi(m, isa.RV32IF))
        rec = _speedup_vs_imf(fm_traces[n], n, isa.SCENARIO_2, 50)
        assert rec > best_fixed, n


# ---------------------------------------------------------------------------
# Fig. 7 — multi-program
# ---------------------------------------------------------------------------

def test_pair_slot_competition_and_quantum_effect():
    """Pairs with different extension working sets compete for slots; a
    longer scheduler quantum amortises the reconfiguration (paper §VI-C)."""
    tr = np.stack([traces.build_trace("nbody", 60_000),
                   traces.build_trace("cubic", 60_000)])
    cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=50)
    out = {}
    for q in (1_000, 20_000):
        r = simulator.simulate_pair(
            tr, cfg, isa.SCENARIO_2,
            simulator.SchedulerConfig(quantum_cycles=q),
            total_steps=120_000)
        sp = []
        for i, n in enumerate(("nbody", "cubic")):
            imf = simulator.fixed_pair_cpi(
                traces.mix_of(n), isa.RV32IMF,
                simulator.SchedulerConfig(quantum_cycles=q))
            sp.append(imf / float(np.asarray(r.cpi)[i]))
        out[q] = np.mean(sp)
        assert int(r.switches) > 0
    assert out[20_000] > out[1_000]  # longer quantum -> better


def test_pair_more_slots_is_better():
    tr = np.stack([traces.build_trace("nbody", 60_000),
                   traces.build_trace("matmult-int", 60_000)])
    sched = simulator.SchedulerConfig(quantum_cycles=20_000)
    cpis = {}
    for s, scen in ((2, isa.SCENARIO_2_2SLOT), (4, isa.SCENARIO_2),
                    (8, isa.SCENARIO_2_8SLOT)):
        r = simulator.simulate_pair(
            tr, simulator.ReconfigConfig(num_slots=s, miss_latency=50),
            scen, sched, total_steps=120_000)
        cpis[s] = float(np.asarray(r.cpi)[0])
    assert cpis[2] >= cpis[4] >= cpis[8]


def test_pair_set_matches_paper_counts():
    assert len(scheduler.make_pairs()) == 50
    assert len(scheduler.fm_fm_pairs()) == 10
    assert len(scheduler.fm_m_pairs()) == 40


# ---------------------------------------------------------------------------
# trace model invariants
# ---------------------------------------------------------------------------

def test_trace_mix_matches_solved_mix():
    for n in ("minver", "nbody", "matmult-int"):
        t = traces.build_trace(n, 120_000)
        got = traces.trace_mix(t)
        want = traces.mix_of(n).frac
        np.testing.assert_allclose(got, want, atol=0.012)


def test_traces_deterministic():
    a = traces.build_trace("cubic", 5_000, seed=3)
    b = traces.build_trace("cubic", 5_000, seed=3)
    np.testing.assert_array_equal(a, b)
