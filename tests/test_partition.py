"""Property tests for the sharding plans: every spec a plan emits must
divide the tensor dims on the production meshes, for every arch, mode and
strategy — the invariant the 64-cell dry-run rests on."""
import os

import jax
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import transformer
from repro.optim import adamw
from repro.sharding.partition import ShardingPlan

cb.load_all()


class FakeMesh:
    """Shape-only stand-in (plans never touch devices until .ns())."""

    def __init__(self, shape_map):
        self.shape = dict(shape_map)
        self.axis_names = tuple(shape_map)
        self.devices = np.empty((0,))


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]


def axis_size(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def check_specs(mesh, specs, shapes):
    leaves_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    leaves_t = jax.tree_util.tree_leaves(shapes)
    assert len(leaves_s) == len(leaves_t)
    for spec, shape in zip(leaves_s, leaves_t):
        for dim, entry in zip(shape.shape, tuple(spec)):
            size = axis_size(mesh, entry)
            assert dim % size == 0, (spec, shape.shape)


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
@pytest.mark.parametrize("mesh", MESHES, ids=["1pod", "2pod"])
@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_param_specs_always_divide(arch, mesh, mode):
    cfg = cb.get_config(arch)
    plan = ShardingPlan(mesh, cfg, mode=mode)
    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    check_specs(mesh, plan.param_specs(shapes), shapes)


@pytest.mark.parametrize("arch", ["granite-3-2b", "qwen1.5-110b"])
def test_dp_strategy_specs_divide(arch):
    cfg = cb.get_config(arch)
    mesh = MESHES[0]
    plan = ShardingPlan(mesh, cfg, mode="train")
    plan.strategy_override = "dp"
    plan.strategy = "dp"
    shapes = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    check_specs(mesh, plan.param_specs(shapes), shapes)


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_cache_specs_always_divide(arch):
    cfg = cb.get_config(arch)
    mesh = MESHES[0]
    plan = ShardingPlan(mesh, cfg, mode="decode")
    shapes = jax.eval_shape(lambda: transformer.init_cache(cfg, 128, 32768))
    check_specs(mesh, plan.cache_specs(shapes), shapes)


@pytest.mark.parametrize("arch", ["arctic-480b", "qwen1.5-110b"])
def test_optimizer_state_specs_divide(arch):
    from repro.launch.dryrun import opt_config_for
    from repro.train import step as train_step
    cfg = cb.get_config(arch)
    mesh = MESHES[0]
    plan = ShardingPlan(mesh, cfg, mode="train")
    shapes = train_step.abstract_state(cfg, opt_config_for(cfg))
    check_specs(mesh, plan.param_specs(shapes.m), shapes.m)
    check_specs(mesh, plan.param_specs(shapes.v), shapes.v)


def test_full_attention_cache_is_seq_sharded():
    cfg = cb.get_config("qwen1.5-110b")
    mesh = MESHES[0]
    plan = ShardingPlan(mesh, cfg, mode="decode")
    shapes = jax.eval_shape(lambda: transformer.init_cache(cfg, 128, 32768))
    specs = plan.cache_specs(shapes)
    leaf = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec))[0]
    assert tuple(leaf)[:3] == (None, "data", "model")
