"""Interleave-aware stack-distance fast path: exact parity with the scan.

The engine (`repro.core.stackdist_interleaved`) serves *preempted* fleets —
heterogeneous quanta, weighted round-robin priorities, swept quantum axes —
and, like its unpreempted sibling, is only ever allowed to return results
bit-for-bit identical to the cycle-by-cycle `lax.scan` reference, so every
parity assertion here is exact integer equality, never closeness.

Layout mirrors tests/test_stackdist.py: hand-computed goldens, dispatcher
semantics (routing spies + forcing + fallbacks), a fixed-seed always-on
randomized sweep, and a hypothesis property that degrades to the seeded
variant when hypothesis is absent.  CI runs this module under the "ci"
hypothesis profile (fixed seed, see bottom) so the randomized sweep is
reproducible PR-over-PR.
"""
import numpy as np
import pytest
from fleet_asserts import assert_fleet_equal as _assert_fleet_equal

from repro.core import isa, simulator, traces

CFG = simulator.ReconfigConfig(num_slots=4, miss_latency=50)


# ---------------------------------------------------------------------------
# hand-computed golden: switch points, handler attribution, q-carry
# ---------------------------------------------------------------------------

def test_hand_computed_preempted_pair():
    """P=2, 1 slot, quantum 10: every switch point, handler charge, miss
    and bitstream miss below is computed by hand from the scan semantics
    (the crossing access executes, then pays the handler; slot state
    persists across switches; the bitstream cache is warm)."""
    mul, fadd = isa.INSTR_ID["mul"], isa.INSTR_ID["fadd.s"]
    base = isa.INSTR_ID["base"]
    tag_of = np.full(isa.NUM_INSTRUCTIONS, -1, np.int32)
    tag_of[mul], tag_of[fadd] = 0, 1
    scen = isa.SlotScenario(name="hand", num_slots=1, instr_tag=tag_of)
    trs = np.array([[mul, fadd, mul], [base, mul, base]], np.int32)
    sched = simulator.SchedulerConfig(quantum_cycles=10, handler_cycles=3)
    kw = dict(slot_counts=[1], bs_miss_extra=2, total_steps=8)
    for path in ("scan", "interleaved"):
        r = simulator.sweep_fleet(trs[None], [5], scen, sched, path=path,
                                  **kw)
        np.testing.assert_array_equal(
            np.asarray(r.cycles)[0, 0, 0], [30, 15], err_msg=path)
        np.testing.assert_array_equal(
            np.asarray(r.instructions)[0, 0, 0], [2, 6], err_msg=path)
        np.testing.assert_array_equal(
            np.asarray(r.slot_misses)[0, 0, 0], [2, 0], err_msg=path)
        np.testing.assert_array_equal(
            np.asarray(r.bs_misses)[0, 0, 0], [2, 0], err_msg=path)
        assert int(np.asarray(r.switches)[0, 0, 0]) == 3, path


# ---------------------------------------------------------------------------
# dispatcher semantics: routing, forcing, fallbacks
# (`route_spy` — the engine-dispatch recorder — lives in tests/conftest.py,
# shared with the sched-layer wiring tests)
# ---------------------------------------------------------------------------

def _preempted_fleet(b=1, p=2, n=3_000, seed=7):
    rng = np.random.default_rng(seed)
    return rng.integers(0, isa.NUM_INSTRUCTIONS, (b, p, n)).astype(np.int32)


def test_auto_routes_preempted_warm_grid_through_interleaved(route_spy):
    fl = _preempted_fleet()
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    kw = dict(slot_counts=[2, 4], total_steps=6_000)
    auto = simulator.sweep_fleet(fl, [10, 50], isa.SCENARIO_2, sched, **kw)
    assert len(route_spy) == 1
    scan = simulator.sweep_fleet(fl, [10, 50], isa.SCENARIO_2, sched,
                                 path="scan", **kw)
    assert len(route_spy) == 1          # forcing scan bypasses the engine
    _assert_fleet_equal(auto, scan)


def test_auto_cold_bitstream_cache_still_falls_back_to_scan(route_spy):
    """An undersized bitstream cache is ineligible for BOTH fast paths;
    auto must serve the historical scan numbers untouched."""
    fl = _preempted_fleet()
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    kw = dict(slot_counts=[4], bs_cache_entries=4, total_steps=6_000)
    auto = simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched, **kw)
    scan = simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched,
                                 path="scan", **kw)
    assert not route_spy
    _assert_fleet_equal(auto, scan)


def test_warmth_is_judged_on_the_fleets_merged_tag_set(route_spy):
    """Program 1 slots more opcodes than program 0: a bitstream cache warm
    for program 0 alone can be cold for the merged stream — eligibility
    must use the union of the per-program tag tables."""
    table = simulator.fleet_tag_table([isa.SCENARIO_3, isa.SCENARIO_1], 2)
    union_tags = int(np.max(table)) + 1
    p0_tags = int(np.max(table[0])) + 1
    assert p0_tags < union_tags
    kw = dict(miss_latencies=[50], bs_miss_extra=100, handler_cycles=150,
              total_steps=4_000)
    assert simulator.interleaved_eligible(table, bs_entries=union_tags,
                                          **kw)
    assert not simulator.interleaved_eligible(table, bs_entries=p0_tags,
                                              **kw)
    fl = _preempted_fleet()
    sched = simulator.SchedulerConfig(quantum_cycles=1_000)
    auto = simulator.sweep_fleet(
        fl, [50], [isa.SCENARIO_3, isa.SCENARIO_1], sched, slot_counts=[4],
        bs_cache_entries=p0_tags, total_steps=4_000)
    assert not route_spy                # cold for the union -> scan
    scan = simulator.sweep_fleet(
        fl, [50], [isa.SCENARIO_3, isa.SCENARIO_1], sched, slot_counts=[4],
        bs_cache_entries=p0_tags, total_steps=4_000, path="scan")
    _assert_fleet_equal(auto, scan)


def test_interleaved_eligibility_rules():
    table = simulator.fleet_tag_table(isa.SCENARIO_2, 2)
    ok = dict(bs_entries=64, miss_latencies=[10, 250], bs_miss_extra=100,
              handler_cycles=150, total_steps=40_000)
    assert simulator.interleaved_eligible(table, **ok)
    # cold bitstream cache (scenario 2 has 10 distinct tags)
    assert not simulator.interleaved_eligible(table,
                                              **{**ok, "bs_entries": 4})
    # negative costs break monotone in-window accumulation
    assert not simulator.interleaved_eligible(
        table, **{**ok, "miss_latencies": [-1, 50]})
    assert not simulator.interleaved_eligible(
        table, **{**ok, "bs_miss_extra": -5})
    # overflow guard
    assert not simulator.interleaved_eligible(
        table, **{**ok, "miss_latencies": [1 << 29]})


def test_forcing_interleaved_on_ineligible_grid_raises():
    fl = _preempted_fleet(n=1_000)
    sched = simulator.SchedulerConfig(quantum_cycles=500)
    with pytest.raises(ValueError, match="interleaved path"):
        simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched,
                              slot_counts=[4], bs_cache_entries=4,
                              total_steps=1_000, path="interleaved")
    # forcing the unpreempted engine on a preempted grid still raises
    with pytest.raises(ValueError, match="stack-distance"):
        simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched,
                              slot_counts=[4], total_steps=1_000,
                              path="stackdist")
    with pytest.raises(ValueError, match="unknown path"):
        simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched,
                              slot_counts=[4], total_steps=1_000,
                              path="bogus")


def test_unpreempted_grids_still_take_the_stackdist_engine(route_spy):
    """The quantum-unreachable regime keeps its cheaper grid-collapsing
    engine; the interleaved engine must not poach it under auto."""
    tr = traces.build_trace("cubic", 4_000)[None, None, :]
    nop = simulator.SchedulerConfig.no_preempt()
    kw = dict(slot_counts=[2, 4], total_steps=4_000)
    auto = simulator.sweep_fleet(tr, [10, 50], isa.SCENARIO_2, nop, **kw)
    assert not route_spy
    fast = simulator.sweep_fleet(tr, [10, 50], isa.SCENARIO_2, nop,
                                 path="stackdist", **kw)
    _assert_fleet_equal(auto, fast)
    # forcing the interleaved engine on the same grid is allowed (exact,
    # just not auto's choice) and must agree bit-for-bit
    inter = simulator.sweep_fleet(tr, [10, 50], isa.SCENARIO_2, nop,
                                  path="interleaved", **kw)
    assert len(route_spy) == 1
    _assert_fleet_equal(auto, inter)


def test_tiny_quanta_stay_on_scan_under_auto(route_spy):
    """Below the auto floor the window engine degenerates toward one
    iteration per run; auto keeps the scan, forcing still works."""
    fl = _preempted_fleet(n=1_500)
    sched = simulator.SchedulerConfig(
        quantum_cycles=simulator._INTERLEAVED_AUTO_MIN_QUANTUM // 2)
    kw = dict(slot_counts=[4], total_steps=3_000)
    auto = simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched, **kw)
    assert not route_spy
    forced = simulator.sweep_fleet(fl, [50], isa.SCENARIO_2, sched,
                                   path="interleaved", **kw)
    assert len(route_spy) == 1
    _assert_fleet_equal(auto, forced)


def test_simulate_many_dispatch_one_shot_and_resume(route_spy, resume_spy):
    """One-shot result-only simulate_many rides the windowed engine;
    state-returning and resumed calls ride the *resumable* entry (and
    agree with scan bit-for-bit — the deep parity lives in
    test_resume_fastpath.py, this pins the routing)."""
    tr = _preempted_fleet()[0]
    sched = simulator.SchedulerConfig(quantum_cycles=2_000)
    auto = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                   total_steps=5_000)
    assert len(route_spy) == 1
    scan = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                   total_steps=5_000, path="scan")
    assert len(route_spy) == 1
    _assert_fleet_equal(auto, scan)

    # return_state / resume: the resumable engine, not the scan
    assert not resume_spy
    res, st = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                      total_steps=5_000, return_state=True)
    assert len(resume_spy) == 1
    _assert_fleet_equal(auto, res)
    simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                            total_steps=1_000, state=st)
    assert len(resume_spy) == 2
    # forcing the engine on a resumable call is allowed and exact
    forced = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                     total_steps=1_000, state=st,
                                     path="interleaved")
    assert len(resume_spy) == 3
    scan_res = simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                       total_steps=1_000, state=st,
                                       path="scan")
    _assert_fleet_equal(forced, scan_res)
    assert len(route_spy) == 1          # windowed one-shot entry untouched
    with pytest.raises(ValueError, match="unknown path"):
        simulator.simulate_many(tr, CFG, isa.SCENARIO_2, sched,
                                total_steps=1_000, path="stackdist")


# ---------------------------------------------------------------------------
# structural parity: wrap, window spanning, chunking, quanta axis
# ---------------------------------------------------------------------------

def test_wraparound_and_window_spanning_parity():
    """total_steps > trace_len wraps every cursor mid-quantum, and a
    window far smaller than the quantum forces the carried quantum-cycle
    counter to span iterations; results must not move."""
    tr = np.stack([traces.build_trace("minver", 2_000),
                   traces.build_trace("crc32", 2_000)])
    sched = simulator.SchedulerConfig(quantum_cycles=4_000)
    kw = dict(slot_counts=[4], total_steps=9_000)
    scan = simulator.sweep_fleet(tr[None], [50], isa.SCENARIO_2, sched,
                                 path="scan", **kw)
    for window in (1, 13, 256, 8_192):
        fast = simulator.sweep_fleet(tr[None], [50], isa.SCENARIO_2, sched,
                                     path="interleaved",
                                     interleave_window=window, **kw)
        _assert_fleet_equal(scan, fast)


def test_chunked_fleet_axis_matches_unchunked(monkeypatch):
    """The memory-bounding fleet-axis chunking must not change results."""
    fl = _preempted_fleet(b=3, n=1_500)
    sched = simulator.SchedulerConfig(quantum_cycles=1_000)
    kw = dict(slot_counts=[2, 4], total_steps=3_000, path="interleaved")
    whole = simulator.sweep_fleet(fl, [10, 50], isa.SCENARIO_2, sched, **kw)
    monkeypatch.setattr(simulator, "_INTERLEAVED_CHUNK_ELEMS", 10_000)
    chunked = simulator.sweep_fleet(fl, [10, 50], isa.SCENARIO_2, sched,
                                    **kw)
    _assert_fleet_equal(whole, chunked)


def test_quanta_axis_mixed_preempted_and_unreachable_cells():
    """A swept quantum axis mixing preempted cells with an unreachable one
    is exactly the regime only the interleaved engine can fast-path (the
    unpreempted engine needs EVERY cell unreachable)."""
    fl = _preempted_fleet(b=2, p=2, n=1_200)
    sched = simulator.SchedulerConfig(quantum_cycles=999,
                                      priorities=(2, 1))
    kw = dict(slot_counts=[2, 4],
              quanta=[700, (137, 2_900), simulator.NO_PREEMPT_QUANTUM],
              total_steps=3_600)
    scan = simulator.sweep_fleet(fl, [10, 250], isa.SCENARIO_2, sched,
                                 path="scan", **kw)
    fast = simulator.sweep_fleet(fl, [10, 250], isa.SCENARIO_2, sched,
                                 path="interleaved", **kw)
    assert np.asarray(scan.cycles).shape == (3, 2, 2, 2, 2)
    _assert_fleet_equal(scan, fast)
    # the unreachable cell agrees with the dedicated unpreempted engine
    nop = simulator.sweep_fleet(
        fl, [10, 250], isa.SCENARIO_2,
        simulator.SchedulerConfig.no_preempt(), slot_counts=[2, 4],
        total_steps=3_600, path="stackdist")
    np.testing.assert_array_equal(np.asarray(fast.cycles)[2],
                                  np.asarray(nop.cycles))


def test_solo_preempted_program_pays_self_switches():
    """P=1 with a reachable quantum: the round-robin 'switches' to the
    same program, paying the handler each expiry — a regime neither the
    solo fast path (unpreempted only) nor the pair path covers."""
    tr = traces.build_trace("st", 2_500)[None, None, :]
    sched = simulator.SchedulerConfig(quantum_cycles=800)
    kw = dict(slot_counts=[4], total_steps=5_000)
    scan = simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, sched,
                                 path="scan", **kw)
    fast = simulator.sweep_fleet(tr, [50], isa.SCENARIO_2, sched,
                                 path="interleaved", **kw)
    _assert_fleet_equal(scan, fast)
    assert int(np.asarray(scan.switches)[0, 0, 0]) > 5


# ---------------------------------------------------------------------------
# randomized scan-parity sweep: fleets x quanta x priorities x grids
# ---------------------------------------------------------------------------

TRACE_LEN = 192   # fixed so the scan reference compiles once per s_max
TOTAL_STEPS = 260  # > TRACE_LEN: every program wraps at least once
# quanta come from a fixed menu so the engine compiles a handful of window
# sizes instead of one per drawn integer
QUANTUM_MENU = (6, 37, 120, 900, 1 << 30)


def _check_random_interleaved(ops, tag_of, p, quanta_idx, priorities,
                              counts, lats, bs_extra, handler):
    rolled = np.resize(np.asarray(ops, np.int32), (TRACE_LEN,))
    fleet = np.stack([np.roll(rolled, 17 * i) for i in range(p)])[None]
    scenario = isa.SlotScenario(
        name="rand", num_slots=max(counts),
        instr_tag=np.asarray(tag_of, np.int32))
    quanta_cell = tuple(QUANTUM_MENU[i] for i in quanta_idx[:p])
    sched = simulator.SchedulerConfig(
        quantum_cycles=quanta_cell, handler_cycles=int(handler),
        priorities=tuple(priorities[:p]))
    kw = dict(slot_counts=sorted(counts), bs_miss_extra=int(bs_extra),
              total_steps=TOTAL_STEPS)
    fast = simulator.sweep_fleet(fleet, lats, scenario, sched,
                                 path="interleaved", **kw)
    scan = simulator.sweep_fleet(fleet, lats, scenario, sched,
                                 path="scan", **kw)
    _assert_fleet_equal(fast, scan)


def _random_case(rng):
    p = int(rng.integers(1, 4))
    return dict(
        ops=rng.integers(0, isa.NUM_INSTRUCTIONS, 64),
        tag_of=rng.integers(-1, 7, isa.NUM_INSTRUCTIONS),
        p=p,
        quanta_idx=[int(i) for i in
                    rng.integers(0, len(QUANTUM_MENU), 3)],
        priorities=[int(w) for w in rng.integers(1, 4, 3)],
        counts=[int(c) for c in rng.integers(1, 9, 3)],
        lats=[int(v) for v in rng.integers(0, 301, 2)],
        bs_extra=int(rng.integers(0, 201)),
        handler=int(rng.integers(0, 301)),
    )


def test_seeded_random_preempted_grids_match_scan_exactly():
    """Always-on (no hypothesis needed) seeded variant of the property:
    random fleets, taxonomies, per-program quanta, priority weights,
    slot-count sets, latency grids, handler costs."""
    rng = np.random.default_rng(20_240_802)
    for _ in range(6):
        _check_random_interleaved(**_random_case(rng))


try:  # dev extra, not a runtime dep — only these tests skip without it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    # CI pins the randomized sweep: HYPOTHESIS_PROFILE=ci selects the fixed
    # derandomized profile registered in tests/conftest.py (suite-wide, so
    # every randomized parity module is reproducible PR-over-PR)
    @settings(max_examples=20, deadline=None)
    @given(
        ops=st.lists(st.integers(0, isa.NUM_INSTRUCTIONS - 1),
                     min_size=1, max_size=64),
        tag_of=st.lists(st.integers(-1, 6), min_size=isa.NUM_INSTRUCTIONS,
                        max_size=isa.NUM_INSTRUCTIONS),
        p=st.integers(1, 3),
        quanta_idx=st.lists(st.integers(0, len(QUANTUM_MENU) - 1),
                            min_size=3, max_size=3),
        priorities=st.lists(st.integers(1, 3), min_size=3, max_size=3),
        counts=st.lists(st.integers(1, 8), min_size=3, max_size=3),
        lats=st.lists(st.integers(0, 300), min_size=2, max_size=2),
        bs_extra=st.integers(0, 200),
        handler=st.integers(0, 300),
    )
    def test_interleaved_matches_scan_exactly(ops, tag_of, p, quanta_idx,
                                              priorities, counts, lats,
                                              bs_extra, handler):
        """Random preempted fleet, taxonomy, heterogeneous quanta,
        weighted priorities, slot-count set and latency grid: the
        interleaved fast path must equal the scan bit-for-bit."""
        _check_random_interleaved(ops, tag_of, p, quanta_idx, priorities,
                                  counts, lats, bs_extra, handler)
else:
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_interleaved_matches_scan_exactly():
        pass
