"""The roofline HLO walker: exact FLOPs under (nested) lax.scan, correct
collective accounting inside loop bodies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo

A = jax.ShapeDtypeStruct((256, 256), jnp.float32)


def _flops(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return hlo.analyze_module(txt)


def test_plain_matmul_flops_exact():
    r = _flops(lambda a, b: a @ b, A, A)
    assert r["flops"] == pytest.approx(2 * 256**3, rel=0.02)


def test_scan_multiplies_body_flops():
    def scanned(a, b):
        def body(x, _):
            return jax.lax.dot_general(
                x, b, (((1,), (0,)), ((), ()))), None
        return jax.lax.scan(body, a, None, length=8)[0]

    r = _flops(scanned, A, A)
    assert r["flops"] == pytest.approx(16 * 256**3, rel=0.02)


def test_nested_scan_multiplies_both_levels():
    def nested(a, b):
        def outer(x, _):
            def inner(y, _):
                return jax.lax.dot_general(
                    y, b, (((1,), (0,)), ((), ()))), None
            return jax.lax.scan(inner, x, None, length=4)[0], None
        return jax.lax.scan(outer, a, None, length=3)[0]

    r = _flops(nested, A, A)
    assert r["flops"] == pytest.approx(3 * 4 * 2 * 256**3, rel=0.02)


def test_xla_cost_analysis_undercounts_scans():
    """Documents WHY the walker exists."""
    def scanned(a, b):
        def body(x, _):
            return jax.lax.dot_general(
                x, b, (((1,), (0,)), ((), ()))), None
        return jax.lax.scan(body, a, None, length=8)[0]

    compiled = jax.jit(scanned).lower(A, A).compile()
    # cost_analysis() is a per-device list on older jax, a flat dict on
    # newer — hlo.xla_cost_analysis normalises both to one dict
    xla = float(hlo.xla_cost_analysis(compiled).get("flops", 0.0))
    walk = hlo.analyze_module(compiled.as_text())["flops"]
    assert xla < walk / 4  # cost_analysis counts the body once


def test_memory_bytes_scale_with_scan():
    def scanned(a, b):
        def body(x, _):
            return jax.lax.dot_general(
                x, b, (((1,), (0,)), ((), ()))), None
        return jax.lax.scan(body, a, None, length=8)[0]

    r1 = _flops(lambda a, b: a @ b, A, A)
    r8 = _flops(scanned, A, A)
    assert r8["bytes"] > 4 * r1["bytes"]


class _FakeCompiled:
    """Stand-in for jax's Compiled on backends with broken cost analysis."""

    def __init__(self, ca, platform="fake-tpu"):
        self._ca = ca
        self.platform = platform

    def cost_analysis(self):
        if isinstance(self._ca, Exception):
            raise self._ca
        return self._ca


def test_xla_cost_analysis_raising_backend_names_the_backend():
    bad = _FakeCompiled(NotImplementedError("no cost model"),
                        platform="neuron")
    with pytest.raises(ValueError, match="neuron"):
        hlo.xla_cost_analysis(bad)


def test_xla_cost_analysis_empty_properties_names_the_backend():
    for empty in (None, {}, [], [{}]):
        with pytest.raises(ValueError, match="fake-tpu"):
            hlo.xla_cost_analysis(_FakeCompiled(empty))


def test_xla_cost_analysis_normalises_list_and_dict_forms():
    # older jax returns a per-device list, newer a flat dict — callers get
    # one dict either way
    assert hlo.xla_cost_analysis(
        _FakeCompiled([{"flops": 7.0}]))["flops"] == 7.0
    assert hlo.xla_cost_analysis(
        _FakeCompiled({"flops": 9.0}))["flops"] == 9.0


def test_roofline_terms_dominance():
    t = hlo.roofline_terms(197e12, 0.0, 0.0)
    assert t["dominant"] == "compute" and t["compute_s"] == pytest.approx(1.0)
    t = hlo.roofline_terms(0.0, 819e9, 1.0)
    assert t["dominant"] == "memory"
    t = hlo.roofline_terms(0.0, 0.0, 50e9)
    assert t["dominant"] == "collective" and \
        t["collective_s"] == pytest.approx(1.0)
