"""The scheduling subsystem: heterogeneous quanta / priority weights in the
fleet scan (Layer 1) and contention-aware placement + admission (Layer 2).

The parity section pins PR-2 semantics: uniform-quantum `sweep_fleet`
results are asserted bit-for-bit against golden integers captured from the
pre-subsystem code.  The goldens use raw numpy-Generator draws over the isa
alphabet rather than `traces.build_trace` because they were captured while
build_trace was still `hash()`-seeded (PYTHONHASHSEED-randomised across
processes); build_trace is crc32-seeded and process-deterministic now, but
the synthetic goldens stay independent of the trace synthesizer by design.
"""
import math

import numpy as np
import pytest

from repro.core import isa, scheduler, simulator, traces
from repro.sched import (AdmissionController, ContentionModel, Placement,
                         PlacementConfig, PriorityPolicy, fifo_placement,
                         place_tenants, quantum_grid, random_placement,
                         score_placement)

CFG = simulator.ReconfigConfig(num_slots=4, miss_latency=50)


def synthetic_fleet(b=2, p=3, n=4_000, seed=1234):
    rng = np.random.default_rng(seed)
    return rng.integers(0, isa.NUM_INSTRUCTIONS, (b, p, n)).astype(np.int32)


# shared bit-for-bit equality contract, tests/fleet_asserts.py
from fleet_asserts import assert_fleet_equal  # noqa: E402


# ---------------------------------------------------------------------------
# policy construction
# ---------------------------------------------------------------------------

def test_priority_schedule_construction():
    np.testing.assert_array_equal(simulator.priority_schedule(None, 3),
                                  [0, 1, 2])
    np.testing.assert_array_equal(simulator.priority_schedule((2, 1), 2),
                                  [0, 0, 1])
    np.testing.assert_array_equal(simulator.priority_schedule((1, 3, 2), 3),
                                  [0, 1, 1, 1, 2, 2])
    with pytest.raises(ValueError, match="positive"):
        simulator.priority_schedule((1, 0), 2)
    with pytest.raises(ValueError, match="shape"):
        simulator.priority_schedule((1, 2, 3), 2)


def test_quanta_vector_normalisation():
    np.testing.assert_array_equal(simulator.quanta_vector(5_000, 3),
                                  [5_000] * 3)
    np.testing.assert_array_equal(simulator.quanta_vector((1, 2, 3), 3),
                                  [1, 2, 3])
    with pytest.raises(ValueError, match=r"shape \(2,\)"):
        simulator.quanta_vector((1, 2), 3)
    with pytest.raises(ValueError, match="positive"):
        simulator.quanta_vector(0, 2)


def test_priority_policy_presets():
    pol = PriorityPolicy.weighted((3, 1), quantum_cycles=8_000)
    sched = pol.scheduler()
    assert sched.priorities == (3, 1)
    assert sched.quantum_cycles == 8_000
    np.testing.assert_allclose(pol.cpu_share(2), [0.75, 0.25])

    fb = PriorityPolicy.foreground_background(3, fg_weight=4,
                                              fg_quantum=40_000,
                                              bg_quantum=10_000)
    share = fb.cpu_share(3)
    # fg: 4 * 40K = 160K of 180K total
    np.testing.assert_allclose(share, [160 / 180, 10 / 180, 10 / 180])
    with pytest.raises(ValueError):
        PriorityPolicy.foreground_background(1)

    grid = quantum_grid(5_000, (1_000, 20_000), num_programs=2)
    np.testing.assert_array_equal(grid[0], [5_000, 5_000])
    np.testing.assert_array_equal(grid[1], [1_000, 20_000])
    with pytest.raises(ValueError):
        quantum_grid()


# ---------------------------------------------------------------------------
# PR-2 parity pins (uniform quantum must stay bit-for-bit)
# ---------------------------------------------------------------------------

# golden integers from the pre-subsystem (PR-2) scan on
# synthetic_fleet(2, 3, 4_000, seed=1234), quantum 3_000, SCENARIO_2,
# slot_counts [2, 4], latencies [10, 250], 10_000 steps
PR2_CYCLES = [
    [[[41053, 41061, 38814], [605033, 604706, 601422]],
     [[33289, 31568, 31557], [396887, 394361, 393696]]],
    [[[41026, 41037, 40321], [612738, 610764, 611228]],
     [[34085, 31552, 31553], [406319, 402932, 403026]]]]
PR2_SWITCHES = [[[38, 560], [30, 361]], [[38, 567], [30, 369]]]


def _pin_sweep(sched, **kw):
    return simulator.sweep_fleet(
        synthetic_fleet(), [10, 250], isa.SCENARIO_2, sched,
        slot_counts=[2, 4], total_steps=10_000, path="scan", **kw)


def test_uniform_quantum_sweep_matches_pr2_golden():
    res = _pin_sweep(simulator.SchedulerConfig(quantum_cycles=3_000))
    np.testing.assert_array_equal(np.asarray(res.cycles), PR2_CYCLES)
    np.testing.assert_array_equal(np.asarray(res.switches), PR2_SWITCHES)


def test_interleaved_fast_path_reproduces_pr2_golden():
    """The interleave-aware engine must hit the exact PR-2 golden integers
    on the preempted pin grid — same numbers whichever engine serves."""
    sched = simulator.SchedulerConfig(quantum_cycles=3_000)
    res = simulator.sweep_fleet(
        synthetic_fleet(), [10, 250], isa.SCENARIO_2, sched,
        slot_counts=[2, 4], total_steps=10_000, path="interleaved")
    np.testing.assert_array_equal(np.asarray(res.cycles), PR2_CYCLES)
    np.testing.assert_array_equal(np.asarray(res.switches), PR2_SWITCHES)
    # and auto now serves this grid from the interleaved engine
    auto = simulator.sweep_fleet(
        synthetic_fleet(), [10, 250], isa.SCENARIO_2, sched,
        slot_counts=[2, 4], total_steps=10_000)
    assert_fleet_equal(res, auto)


def test_uniform_vector_and_unit_priorities_reproduce_scalar_exactly():
    """A per-program quantum vector of identical values plus unit priority
    weights must reproduce the uniform scan bit-for-bit."""
    scalar = _pin_sweep(simulator.SchedulerConfig(quantum_cycles=3_000))
    vector = _pin_sweep(simulator.SchedulerConfig(
        quantum_cycles=(3_000, 3_000, 3_000), priorities=(1, 1, 1)))
    assert_fleet_equal(scalar, vector)


def test_simulate_many_uniform_vector_parity():
    tr = synthetic_fleet()[0]
    a = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2,
        simulator.SchedulerConfig(quantum_cycles=2_500), total_steps=8_000)
    b = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2,
        simulator.SchedulerConfig(quantum_cycles=(2_500,) * 3),
        total_steps=8_000)
    assert_fleet_equal(a, b)


# ---------------------------------------------------------------------------
# heterogeneous quanta + priorities: behaviour
# ---------------------------------------------------------------------------

def test_priority_weights_shift_instruction_share():
    tr = synthetic_fleet(1, 3)[0]
    kw = dict(total_steps=12_000)
    uni = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2,
        simulator.SchedulerConfig(quantum_cycles=1_000), **kw)
    wtd = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2,
        simulator.SchedulerConfig(quantum_cycles=1_000,
                                  priorities=(4, 1, 1)), **kw)
    u = np.asarray(uni.instructions, np.float64)
    w = np.asarray(wtd.instructions, np.float64)
    # uniform: roughly equal share; weighted: program 0 gets ~4x a peer
    assert u.max() / u.min() < 1.3
    assert w[0] / w[1] > 3.0 and w[0] / w[2] > 3.0
    assert w[0] > u[0] * 1.5


def test_per_program_quanta_shift_cycle_share():
    """A longer personal quantum holds the core longer per turn: that
    program retires more instructions at the same step budget."""
    tr = np.stack([traces.build_trace("matmult-int", 6_000, seed=0),
                   traces.build_trace("matmult-int", 6_000, seed=1)])
    base = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2,
        simulator.SchedulerConfig(quantum_cycles=(1_000, 1_000)),
        total_steps=12_000)
    fav = simulator.simulate_many(
        tr, CFG, isa.SCENARIO_2,
        simulator.SchedulerConfig(quantum_cycles=(8_000, 1_000)),
        total_steps=12_000)
    b = np.asarray(base.instructions, np.float64)
    f = np.asarray(fav.instructions, np.float64)
    assert b[0] / b[1] < 1.2            # equal quanta -> equal share
    assert f[0] / f[1] > 4.0            # 8:1 quanta -> lopsided share
    assert int(fav.switches) < int(base.switches)


def test_sweep_fleet_quanta_axis_matches_individual_runs():
    tensor = synthetic_fleet(2, 2, 2_000)
    quanta = [1_500, (1_500, 6_000)]
    sched = simulator.SchedulerConfig(quantum_cycles=999)  # overridden
    res = simulator.sweep_fleet(
        tensor, [10, 50], isa.SCENARIO_2, sched, slot_counts=[2, 4],
        quanta=quanta, total_steps=6_000, path="scan")
    assert np.asarray(res.cycles).shape == (2, 2, 2, 2, 2)
    for qi, q in enumerate(quanta):
        for b in range(2):
            for li, lat in enumerate((10, 50)):
                one = simulator.simulate_many(
                    tensor[b],
                    simulator.ReconfigConfig(num_slots=4, miss_latency=lat),
                    isa.SCENARIO_2,
                    simulator.SchedulerConfig(quantum_cycles=q),
                    total_steps=6_000)
                np.testing.assert_array_equal(
                    np.asarray(res.cycles)[qi, b, 1, li],
                    np.asarray(one.cycles))
    # without quanta= the historical 4-axis shape survives
    legacy = simulator.sweep_fleet(
        tensor, [10, 50], isa.SCENARIO_2,
        simulator.SchedulerConfig(quantum_cycles=1_500),
        slot_counts=[2, 4], total_steps=6_000, path="scan")
    assert np.asarray(legacy.cycles).shape == (2, 2, 2, 2)
    np.testing.assert_array_equal(np.asarray(legacy.cycles),
                                  np.asarray(res.cycles)[0])
    # malformed quanta axes fail with clear errors, not low-level stack
    # traces
    with pytest.raises(ValueError, match="bare scalar"):
        simulator.sweep_fleet(tensor, [10], isa.SCENARIO_2, sched,
                              slot_counts=[4], quanta=1_500,
                              total_steps=100)
    with pytest.raises(ValueError, match="at least one quantum cell"):
        simulator.sweep_fleet(tensor, [10], isa.SCENARIO_2, sched,
                              slot_counts=[4], quanta=[], total_steps=100)


def test_stackdist_eligibility_under_per_program_quanta():
    """Eligible only when EVERY program's quantum is unreachable: one
    preemptible program anywhere in the vector (or quantum grid) kills
    the fast path."""
    tag_row = isa.SCENARIO_2.instr_tag
    kw = dict(bs_entries=64, max_miss_latency=250, bs_miss_extra=100,
              total_steps=40_000)
    big = simulator.NO_PREEMPT_QUANTUM
    assert simulator.stackdist_eligible(
        tag_row, quantum_cycles=(big, big), **kw)
    assert not simulator.stackdist_eligible(
        tag_row, quantum_cycles=(big, 20_000), **kw)
    assert not simulator.stackdist_eligible(
        tag_row, quantum_cycles=np.array([[big, big], [big, 20_000]]), **kw)
    # forcing the fast path on a partially-preemptible grid raises
    with pytest.raises(ValueError, match="stack-distance"):
        simulator.sweep_fleet(
            synthetic_fleet(1, 2, 1_000), [50], isa.SCENARIO_2,
            simulator.SchedulerConfig(quantum_cycles=(big, 20_000)),
            slot_counts=[4], total_steps=1_000, path="stackdist")


def test_stackdist_quanta_axis_broadcast_matches_scan():
    """An all-unpreempted quanta axis collapses to one stack-distance pass
    broadcast over Q — and must still equal the scan bit-for-bit."""
    tensor = synthetic_fleet(2, 1, 2_000)
    big = simulator.NO_PREEMPT_QUANTUM
    kw = dict(slot_counts=[2, 4], total_steps=2_000,
              quanta=[big, big + 1])
    nop = simulator.SchedulerConfig.no_preempt()
    fast = simulator.sweep_fleet(tensor, [10, 50], isa.SCENARIO_2, nop,
                                 path="stackdist", **kw)
    scan = simulator.sweep_fleet(tensor, [10, 50], isa.SCENARIO_2, nop,
                                 path="scan", **kw)
    assert np.asarray(fast.cycles).shape == (2, 2, 2, 2, 1)
    assert_fleet_equal(fast, scan)


# ---------------------------------------------------------------------------
# satellite: make_fleets(k) properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 4])
def test_make_fleets_count_formula(k):
    fleets = scheduler.make_fleets(k)
    n_fm, n_m = len(traces.FM_BENCHES), len(traces.M_BENCHES)
    assert len(fleets) == math.comb(n_fm, k) + math.comb(n_fm, k - 1) * n_m
    assert len(set(fleets)) == len(fleets)          # no duplicate fleets
    assert all(len(f) == k == len(set(f)) for f in fleets)


@pytest.mark.parametrize("k", [3, 4])
def test_make_fleets_slot_competition_invariant(k):
    """Every fleet carries >= k-1 FM-class members (slot competition is
    guaranteed); insensitive benchmarks never appear."""
    fm = set(traces.FM_BENCHES)
    m = set(traces.M_BENCHES)
    for fleet in scheduler.make_fleets(k):
        assert sum(n in fm for n in fleet) >= k - 1
        assert all(n in fm | m for n in fleet)


def test_make_fleets_custom_pools_follow_formula():
    fm = traces.FM_BENCHES[:4]
    m = traces.M_BENCHES[:3]
    for k in (2, 3, 4):
        fleets = scheduler.make_fleets(k, fm=fm, m=m)
        assert len(fleets) == (math.comb(len(fm), k)
                               + math.comb(len(fm), k - 1) * len(m))
    with pytest.raises(ValueError, match="k-1"):
        scheduler.make_fleets(6, fm=fm, m=m)


# ---------------------------------------------------------------------------
# satellite: shape validation
# ---------------------------------------------------------------------------

def test_simulate_many_rejects_wrong_trace_rank():
    with pytest.raises(ValueError, match=r"\(P, N\).*\(4000,\)"):
        simulator.simulate_many(
            synthetic_fleet()[0, 0], CFG, isa.SCENARIO_2,
            simulator.SchedulerConfig(), total_steps=100)


def test_sweep_fleet_rejects_wrong_fleet_rank():
    with pytest.raises(ValueError, match=r"\(B, P, N\).*\(3, 4000\)"):
        simulator.sweep_fleet(
            synthetic_fleet()[0], [50], isa.SCENARIO_2,
            simulator.SchedulerConfig(), slot_counts=[4], total_steps=100)


def test_fleet_tag_table_reports_offending_shapes():
    with pytest.raises(ValueError, match="2 slot scenarios.*P=3"):
        simulator.fleet_tag_table([isa.SCENARIO_1, isa.SCENARIO_2], 3)
    bad = isa.SlotScenario(name="bad", num_slots=4,
                           instr_tag=np.zeros(5, np.int32))
    with pytest.raises(ValueError, match=r"shape \(5,\)"):
        simulator.fleet_tag_table([isa.SCENARIO_1, bad], 2)


def test_scheduler_config_rejects_mismatched_vectors():
    tr = synthetic_fleet()[0]          # P=3
    with pytest.raises(ValueError, match=r"shape \(2,\)"):
        simulator.simulate_many(
            tr, CFG, isa.SCENARIO_2,
            simulator.SchedulerConfig(quantum_cycles=(1_000, 2_000)),
            total_steps=100)
    with pytest.raises(ValueError, match="priorities"):
        simulator.simulate_many(
            tr, CFG, isa.SCENARIO_2,
            simulator.SchedulerConfig(priorities=(1, 2)), total_steps=100)


# ---------------------------------------------------------------------------
# Layer 2: contention model, placement, admission
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    return ContentionModel(PlacementConfig(
        quantum_cycles=2_000, trace_len=3_000, steps_per_program=4_000))


TENANTS = {"a": "minver", "b": "nbody", "c": "crc32", "d": "tarfind"}


def test_contention_model_caches_and_batches(model):
    groups = [("minver", "crc32"), ("crc32", "minver"), ("nbody",)]
    calls0 = model.sim_calls
    preds = model.predict(groups)
    # canonicalisation: order inside a group is irrelevant
    np.testing.assert_array_equal(preds[0], preds[1])
    assert preds[2].shape == (1,)
    calls_after = model.sim_calls
    again = model.predict(groups)
    assert model.sim_calls == calls_after          # fully cached
    for x, y in zip(preds, again):
        np.testing.assert_array_equal(x, y)
    assert calls_after > calls0


def test_contention_slowdowns_exceed_solo(model):
    """Preempted co-residency with slot competition must predict slowdown
    above 1 for slot-hungry tenants."""
    pred = model.predict([("minver", "nbody")])[0]
    assert pred.shape == (2,)
    assert np.all(pred > 1.0)


def test_score_placement_and_baselines(model):
    cores = fifo_placement(sorted(TENANTS), 2)
    assert [len(c) for c in cores] == [2, 2]
    pl = score_placement(cores, TENANTS, model)
    assert isinstance(pl, Placement)
    assert set(pl.tenant_slowdown) == set(TENANTS)
    assert pl.worst_slowdown >= pl.mean_slowdown > 0
    rnd = random_placement(sorted(TENANTS), 2, seed=3)
    assert sorted(n for c in rnd for n in c) == sorted(TENANTS)


def test_place_tenants_beats_or_matches_all_baselines(model):
    placed = place_tenants(TENANTS, 2, model)
    assert sorted(n for c in placed.cores for n in c) == sorted(TENANTS)
    fifo = score_placement(fifo_placement(sorted(TENANTS), 2), TENANTS,
                           model)
    assert placed.worst_slowdown <= fifo.worst_slowdown + 1e-9
    for seed in range(4):
        rnd = score_placement(random_placement(sorted(TENANTS), 2, seed),
                              TENANTS, model)
        assert placed.objective <= rnd.objective or \
            placed.worst_slowdown <= rnd.worst_slowdown + 1e-9


def test_place_tenants_deterministic(model):
    a = place_tenants(TENANTS, 2, model)
    b = place_tenants(TENANTS, 2, model)
    assert a.cores == b.cores
    assert a.objective == b.objective


def test_admission_loose_slo_admits_all(model):
    dec = AdmissionController(slo=100.0, num_cores=2,
                              model=model).decide(TENANTS)
    assert dec.admitted_all
    assert sorted(dec.admitted) == sorted(TENANTS)
    assert dec.predicted_worst <= 100.0
    assert dec.core_of("a") >= 0


def test_admission_impossible_slo_defers_all(model):
    dec = AdmissionController(slo=1e-6, num_cores=2,
                              model=model).decide(TENANTS)
    assert not dec.admitted
    assert sorted(dec.deferred) == sorted(TENANTS)
    assert math.isnan(dec.predicted_worst)
    assert dec.placement is None
    assert dec.core_of("a") == -1


def test_admission_tight_slo_defers_the_most_contended(model):
    loose = AdmissionController(slo=100.0, num_cores=2,
                                model=model).decide(TENANTS)
    slo = float(loose.predicted_worst) - 1e-6   # just below the best case
    dec = AdmissionController(slo=slo, num_cores=2,
                              model=model).decide(TENANTS)
    assert 0 < len(dec.admitted) < len(TENANTS)
    assert set(dec.admitted) | set(dec.deferred) == set(TENANTS)
    assert dec.predicted_worst <= slo


def test_admission_controller_validation(model):
    with pytest.raises(ValueError):
        AdmissionController(slo=0.0)
    with pytest.raises(ValueError):
        AdmissionController(num_cores=0)
    with pytest.raises(ValueError):
        place_tenants({}, 1, model)
    with pytest.raises(ValueError):
        place_tenants(TENANTS, 0, model)


# ---------------------------------------------------------------------------
# satellite: priority-aware admission (per-tenant SLO weights)
# ---------------------------------------------------------------------------

def test_weighted_admission_protects_the_foreground_tenant(model):
    """With an SLO tight enough to force deferrals, the unweighted victim
    (worst predicted slowdown) must survive when its weight makes every
    other tenant a better deferral candidate."""
    ctrl = AdmissionController(slo=1e-6, num_cores=2, model=model)
    baseline = ctrl.decide(TENANTS)
    first_victim = baseline.deferred[0]
    weighted = ctrl.decide(TENANTS, slo_weights={first_victim: 1e6})
    assert weighted.deferred[0] != first_victim
    # an impossible SLO eventually defers everyone — but the protected
    # tenant goes last, not first
    assert weighted.deferred[-1] == first_victim
    assert weighted.slo_weights == {first_victim: 1e6}


def test_weighted_admission_unit_weights_match_unweighted(model):
    ctrl = AdmissionController(slo=1e-6, num_cores=2, model=model)
    a = ctrl.decide(TENANTS)
    b = ctrl.decide(TENANTS, slo_weights={n: 1.0 for n in TENANTS})
    assert a.deferred == b.deferred
    assert a.admitted == b.admitted


def test_weighted_admission_validation(model):
    ctrl = AdmissionController(slo=1.5, num_cores=2, model=model)
    with pytest.raises(ValueError, match="unknown tenant"):
        ctrl.decide(TENANTS, slo_weights={"ghost": 2.0})
    with pytest.raises(ValueError, match="positive"):
        ctrl.decide(TENANTS, slo_weights={"a": 0.0})


# ---------------------------------------------------------------------------
# contention model rides the interleaved fast path (dispatch wiring)
# ---------------------------------------------------------------------------

def test_contention_model_group_sweeps_ride_interleaved_engine(route_spy):
    """The placement search's candidate-group sweeps are one-shot preempted
    warm-cache runs: auto dispatch must serve them from the interleaved
    engine, with predictions bit-for-bit equal to a scan-forced model.
    (`route_spy` is the shared engine-dispatch recorder, tests/conftest.py.)
    """
    cfg = PlacementConfig(quantum_cycles=2_000, trace_len=3_000,
                          steps_per_program=4_000)
    groups = [("minver", "crc32"), ("nbody", "tarfind")]
    auto_model = ContentionModel(cfg)
    preds = auto_model.predict(groups)
    assert route_spy, "group sweep did not dispatch to the interleaved engine"
    scan_model = ContentionModel(cfg, path="scan")
    scan_preds = scan_model.predict(groups)
    for a, b in zip(preds, scan_preds):
        np.testing.assert_array_equal(a, b)
    # solo references too: identical between the two models
    for b in ("minver", "crc32"):
        assert auto_model.solo_cpi(b) == scan_model.solo_cpi(b)


# ---------------------------------------------------------------------------
# satellite: per-tenant slot taxonomies + bench-name validation
# ---------------------------------------------------------------------------

def test_contention_model_rejects_unknown_bench(model):
    # names resolve through repro.workloads.resolve_trace, whose error
    # names both valid sets (Embench benches + "<arch>:<phase>" workloads)
    with pytest.raises(ValueError, match="unknown tenant name.*nosuch"):
        model.predict([("nosuch", "minver")])
    with pytest.raises(ValueError, match="unknown tenant name"):
        model.solo_cpi("alsonosuch")


def test_per_tenant_scenarios_change_predictions():
    cfg = PlacementConfig(quantum_cycles=2_000, trace_len=3_000,
                          steps_per_program=4_000)
    shared = ContentionModel(cfg)
    mapped = ContentionModel(cfg, scenarios={"minver": isa.SCENARIO_3})
    assert mapped.scenario_of("minver") is isa.SCENARIO_3
    assert mapped.scenario_of("crc32") is mapped.scenario
    g = ("crc32", "minver")
    a = shared.predict([g])[0]
    b = mapped.predict([g])[0]
    assert a.shape == b.shape == (2,)
    # minver under the 1-slot extension taxonomy thrashes differently:
    # the group's prediction must genuinely reflect the per-tenant table
    assert not np.allclose(a, b)
    # solo references split by taxonomy too
    assert shared.solo_cpi("minver") != mapped.solo_cpi("minver")
    assert shared.solo_cpi("crc32") == mapped.solo_cpi("crc32")


def test_per_tenant_scenarios_batch_by_signature():
    cfg = PlacementConfig(quantum_cycles=2_000, trace_len=3_000,
                          steps_per_program=4_000)
    m = ContentionModel(cfg, scenarios={"minver": isa.SCENARIO_3})
    groups = [("crc32", "tarfind"), ("crc32", "nbody"),   # same signature
              ("crc32", "minver")]                        # mapped member
    m.predict(groups)
    again = m.predict(groups)
    calls = m.sim_calls
    m.predict(groups)
    assert m.sim_calls == calls            # fully cached
    assert all(p.shape == (2,) for p in again)


# ---------------------------------------------------------------------------
# satellite: placement edge cases + greedy-vs-swap pin
# ---------------------------------------------------------------------------

def test_place_single_tenant(model):
    pl = place_tenants({"only": "minver"}, 1, model)
    assert pl.cores == (("only",),)
    assert pl.worst_slowdown == pl.mean_slowdown > 0


def test_place_one_tenant_per_core(model):
    pl = place_tenants(TENANTS, len(TENANTS), model)
    assert sorted(n for c in pl.cores for n in c) == sorted(TENANTS)
    assert all(len(c) == 1 for c in pl.cores)
    # solo cores: everyone's "contention" is just quantum/handler overhead,
    # identical across cores for identical benches
    assert pl.worst_slowdown < 1.2


def test_place_more_cores_than_tenants(model):
    pl = place_tenants(dict(list(TENANTS.items())[:2]), 5, model)
    placed = [n for c in pl.cores for n in c]
    assert sorted(placed) == sorted(list(TENANTS)[:2])
    assert all(c for c in pl.cores)        # empty cores dropped
    assert len(pl.cores) <= 2


def test_swap_search_never_worsens_greedy_seed(model):
    """Golden pin on the local search's contract: the swap phase may only
    improve the greedy seed's lexicographic objective."""
    greedy = place_tenants(TENANTS, 2, model, max_rounds=0)
    full = place_tenants(TENANTS, 2, model, max_rounds=8)
    assert full.objective <= greedy.objective
    # and on a roster engineered so greedy's miss-rate order misleads it
    roster = {"a": "minver", "b": "cubic", "c": "qrduino", "d": "ud",
              "e": "edn", "f": "crc32"}
    greedy2 = place_tenants(roster, 3, model, max_rounds=0)
    full2 = place_tenants(roster, 3, model, max_rounds=8)
    assert full2.objective <= greedy2.objective


# ---------------------------------------------------------------------------
# perf gate (CI satellite)
# ---------------------------------------------------------------------------

def test_perf_gate_compare():
    from benchmarks.perf_gate import compare
    base = {"fig6": {"us_per_call": 1_000_000},
            "tiny": {"us_per_call": 10},
            "other": {"us_per_call": 180_000},
            "gone": {"us_per_call": 2_000_000}}
    cur = {"fig6": {"us_per_call": 1_200_000},
           "tiny": {"us_per_call": 900},
           "new": {"us_per_call": 5}}
    rows, fails = compare(base, cur, max_slowdown=1.25, min_us=100_000)
    assert not fails                       # 1.2x within budget; tiny skipped
    assert any("new module" in r for r in rows)
    _, fails = compare(base, {"fig6": {"us_per_call": 1_300_000}},
                       max_slowdown=1.25, min_us=100_000)
    assert fails and "fig6" in fails[0]
    # --modules allowlist restricts gating to re-benchmarked entries, and
    # an allowlist matching NOTHING fails closed (vacuous gate)
    _, fails = compare(base, {"fig6": {"us_per_call": 1_300_000},
                              "other": {"us_per_call": 200_000}},
                       max_slowdown=1.25, min_us=100_000,
                       modules=["other"])
    assert not fails                       # fig6 regression not in scope
    _, fails = compare(base, {"fig6": {"us_per_call": 1_300_000}},
                       max_slowdown=1.25, min_us=100_000, modules=["other"])
    assert fails and "vacuous" in fails[0]
    # same-backend rule: entries recorded on different backends are never
    # compared (a CPU baseline must not gate a GPU run); provenance-free
    # pre-PR-9 entries keep the old behaviour
    rows, fails = compare(
        {"fig6": {"us_per_call": 1_000_000, "backend": "cpu"}},
        {"fig6": {"us_per_call": 9_000_000, "backend": "gpu"}},
        max_slowdown=1.25, min_us=100_000)
    assert not fails
    assert any("backend" in r for r in rows)
    _, fails = compare(
        {"fig6": {"us_per_call": 1_000_000, "backend": "cpu"}},
        {"fig6": {"us_per_call": 9_000_000, "backend": "cpu"}},
        max_slowdown=1.25, min_us=100_000)
    assert fails and "fig6" in fails[0]
