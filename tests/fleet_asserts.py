"""Shared exact-equality assertion for fleet-simulator results.

Every engine-parity suite (test_stackdist.py, test_stackdist_interleaved.py,
test_sched.py, test_online.py) pins the same contract: results from
different engines/resume splits must be bit-for-bit identical integers,
never merely close.  One helper, so the contract cannot drift per module.
"""
import numpy as np


def assert_fleet_equal(a, b):
    """Exact integer equality, field by field, for FleetResult-like
    NamedTuples (works for PairResult/SimResult too)."""
    for field, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"field {field}")
