"""Model-zoo workloads: HLO-derived mixes, deterministic trace lowering,
registry resolution, and fast-path engine eligibility."""
import os
import subprocess
import sys
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import workloads
from repro.core import isa, simulator, traces as core_traces
from repro.workloads.opcounts import OpCount, opcount_from_hlo

# one small config exercised for real (compiles once per process; the
# opcounts layer caches per (arch, phase))
ARCH = "qwen1.5-4b"
PRE = f"{ARCH}:prefill"
DEC = f"{ARCH}:decode"


# ---------------------------------------------------------------------------
# OpCount accounting
# ---------------------------------------------------------------------------


def test_opcount_algebra_and_roundtrip():
    a = OpCount({"fma": 100.0, "base": 50.0}, flops=200.0, bytes=40.0)
    b = OpCount({"fadd": 10.0, "base": 10.0}, flops=10.0, bytes=8.0,
                transcendental_elems=3.0)
    s = a + b
    assert s.counts == {"fma": 100.0, "base": 60.0, "fadd": 10.0}
    assert s.flops == 210.0 and s.bytes == 48.0
    assert s.transcendental_elems == 3.0
    d = 2 * a
    assert d.counts["fma"] == 200.0 and d.flops == 400.0
    rt = OpCount.from_dict(s.to_dict())
    assert rt.counts == s.counts and rt.flops == s.flops
    frac = s.frac()
    assert frac.shape == (isa.NUM_GROUPS,)
    assert frac.sum() == pytest.approx(1.0)
    assert frac[isa.GROUP_ID["fma"]] == pytest.approx(100 / 170)
    with pytest.raises(ValueError):
        OpCount({}).frac()


def test_opcount_from_compiled_hlo_charges_expected_groups():
    # dot -> fma, divide -> fdiv, exp -> transcendental expansion,
    # bytes -> base; everything lands on the isa alphabet
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    txt = jax.jit(
        lambda x, y: jnp.exp(x @ y) / y).lower(a, a).compile().as_text()
    oc = opcount_from_hlo(txt)
    fma = oc.counts.get("fma", 0.0)
    assert fma >= 32 ** 3  # the dot's FLOPs/2 at minimum
    assert oc.counts.get("fdiv", 0.0) > 0
    assert oc.transcendental_elems >= 32 * 32
    assert oc.counts.get("base", 0.0) > 0  # HBM-traffic proxy
    assert set(oc.counts) <= set(isa.GROUP_NAMES)
    assert oc.frac().sum() == pytest.approx(1.0)


def test_op_histogram_applies_scan_trip_counts():
    from repro.analysis import hlo

    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(x, y):
        def body(c, _):
            return jax.lax.dot_general(
                c, y, (((1,), (0,)), ((), ()))), None
        return jax.lax.scan(body, x, None, length=8)[0]

    txt = jax.jit(scanned).lower(a, a).compile().as_text()
    hist = hlo.op_histogram(txt)
    # dot entries carry FLOPs; the 8-trip scan body must count 8 times
    assert hist["dot:f"] == pytest.approx(8 * 2 * 64 ** 3, rel=0.02)


# ---------------------------------------------------------------------------
# registry + resolution
# ---------------------------------------------------------------------------


def test_registry_names_cover_the_zoo_without_compiling():
    names = workloads.list_workloads()
    assert len(names) == 20  # 10 archs x 2 phases
    assert PRE in names and DEC in names
    assert all(workloads.is_workload_name(n) for n in names)
    assert not workloads.is_workload_name("minver")
    assert not workloads.is_workload_name("qwen1.5-4b:train")
    assert not workloads.is_workload_name("no-such-model:prefill")


def test_resolve_trace_embench_passthrough_is_bit_for_bit():
    np.testing.assert_array_equal(
        workloads.resolve_trace("minver", 9_000, seed=3),
        core_traces.build_trace("minver", 9_000, seed=3))


def test_resolve_trace_unknown_name_names_both_sets():
    with pytest.raises(ValueError, match="minver"):
        workloads.resolve_trace("not-a-tenant")
    with pytest.raises(ValueError, match="prefill"):
        workloads.resolve_trace("not-a-tenant")


def test_contention_model_rejects_unknown_profile():
    from repro.sched import ContentionModel, PlacementConfig

    model = ContentionModel(PlacementConfig(trace_len=2_000))
    with pytest.raises(ValueError, match="unknown"):
        model.trace("qwen1.5-4b:finetune")


# ---------------------------------------------------------------------------
# lowered traces: fidelity, determinism, engine eligibility
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def specs():
    return {p: workloads.get_workload(f"{ARCH}:{p}")
            for p in ("prefill", "decode")}


def test_lowered_traces_match_their_mix_table(specs):
    for spec in specs.values():
        tr = spec.build_trace(40_000)
        emp = core_traces.trace_mix(tr)
        np.testing.assert_allclose(emp, spec.mix(), atol=0.01)
        # alphabet stays the isa one (29 tags < bs_cache_entries=64, so
        # warm-cache engine eligibility is preserved by construction)
        assert tr.dtype == np.int32
        assert tr.min() >= 0 and tr.max() < isa.NUM_INSTRUCTIONS


def test_phases_lower_asymmetrically(specs):
    pre, dec = specs["prefill"].mix(), specs["decode"].mix()
    base = isa.GROUP_ID["base"]
    f_ids = [isa.GROUP_ID[g] for g in isa.F_GROUPS]
    # prefill is F-hot/slot-hungry; decode is memory-bound/base-heavy
    assert pre[base] < dec[base]
    assert pre[f_ids].sum() > dec[f_ids].sum()
    assert specs["prefill"].f_run_len > specs["decode"].f_run_len
    assert specs["decode"].sporadic and not specs["prefill"].sporadic


def test_traces_are_deterministic_across_processes(specs):
    """Two fresh processes with different PYTHONHASHSEEDs must lower the
    exact same trace (crc32-seeded painter, not str-hash-seeded)."""
    in_proc = zlib.crc32(specs["decode"].build_trace(6_000).tobytes())
    prog = ("import zlib; from repro import workloads; "
            f"print(zlib.crc32(workloads.build_trace("
            f"{DEC!r}, 6_000).tobytes()))")
    crcs = []
    for hashseed in ("0", "1"):
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(workloads.__file__))))
        env = dict(os.environ, PYTHONPATH=src, JAX_PLATFORMS="cpu",
                   PYTHONHASHSEED=hashseed)
        out = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            check=True, env=env)
        crcs.append(int(out.stdout.strip()))
    assert crcs[0] == crcs[1] == in_proc


def test_workload_fleet_rides_fast_paths(route_spy, monkeypatch, specs):
    """A model-zoo fleet through the ContentionModel must dispatch to the
    stackdist/interleaved engines only — zero scan-fallback calls."""
    from repro.sched import ContentionModel, PlacementConfig

    scan_calls = []
    real = simulator._sweep_fleet
    monkeypatch.setattr(
        simulator, "_sweep_fleet",
        lambda *a, **kw: (scan_calls.append(a) or real(*a, **kw)))

    cfg = PlacementConfig(quantum_cycles=2_000, trace_len=3_000,
                          steps_per_program=4_000)
    model = ContentionModel(cfg)
    groups = [(PRE, DEC), (DEC, DEC)]
    preds = model.predict(groups)
    assert route_spy, "group sweep did not hit the interleaved engine"
    assert not scan_calls, "model-zoo fleet fell back to the scan engine"
    assert all(np.all(p >= 1.0 - 1e-9) for p in preds)


def test_serve_engine_contention_accepts_workload_names(specs):
    from repro.serve.engine import estimate_fleet_contention

    est = estimate_fleet_contention(
        [PRE, DEC], trace_len=4_000, total_steps=12_000)
    assert set(est["tenants"]) == {f"0:{PRE}", f"1:{DEC}"}
    for t in est["tenants"].values():
        assert t["fleet_cpi"] > 0 and t["solo_cpi"] > 0
        assert t["contention_slowdown"] > 0


# ---------------------------------------------------------------------------
# benchmark harness wiring
# ---------------------------------------------------------------------------


def test_benchmark_registration_audit_passes_and_detects_orphans(
        monkeypatch):
    from benchmarks import run as bench_run

    bench_run.audit_registration()  # current state must be clean
    # an unmapped module (neither registered nor excluded) must trip it
    monkeypatch.setitem(bench_run.EXCLUDED, "perf_gate", None)
    monkeypatch.delitem(bench_run.EXCLUDED, "perf_gate")
    with pytest.raises(AssertionError, match="perf_gate"):
        bench_run.audit_registration()


def test_mix_table_rows_serialize_round_trippable_fractions(specs):
    # restrict to the already-compiled arch cells to keep the test light;
    # the full-zoo CSV is written by benchmarks/model_serve_study.py
    header, rows = workloads.mix_table_rows([PRE, DEC])
    assert header[:3] == ["workload", "arch", "phase"]
    assert header[6:] == [f"frac_{g}" for g in isa.GROUP_NAMES]
    assert [r[0] for r in rows] == [PRE, DEC]
    for r in rows:
        assert len(r) == len(header)
        fracs = [float(x) for x in r[6:]]
        assert sum(fracs) == pytest.approx(1.0, abs=1e-4)
        assert float(r[3]) > 0 and float(r[4]) > 0  # flops, bytes
