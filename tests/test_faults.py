"""Fault injection & chaos-hardened serving (repro.sched.faults +
FleetState surgery in repro.core.simulator + OnlineReplacer recovery).

Pins the PR's core equivalences and behaviours:

  * degraded-core property: `num_active=k` masking over an S-slot
    disambiguator is bit-for-bit an LRU cache of physical size k — via
    `sweep_fleet`'s masked scan cells AND `simulate_many(num_active=k)`
    (seeded always-on variant + hypothesis variant under the "ci"
    profile, like test_stackdist_interleaved.py);
  * FleetState surgery (`seu_fleet_state` / `flush_bitstream` /
    `degrade_fleet_state`) and its dispatch consequences: mutated states
    ride the scan for one segment, then re-qualify for the resumable
    interleaved entry once the caches re-warm;
  * FaultPlan determinism (storm + per-event counter-based rng);
  * OnlineReplacer recovery: warm evacuation vs stranding, reconfig
    backoff retries, lifetime-slowdown accounting, checkpoint/restore
    crash-restart parity, benchmarks/run.py --only typo detection.
"""
import jax
import numpy as np
import pytest
from fleet_asserts import assert_fleet_equal

from repro.core import isa, simulator, slots, traces
from repro.sched import (ContentionModel, FaultEvent, FaultPlan,
                         OnlineConfig, OnlineReplacer, PlacementConfig,
                         TenantEvent)
from repro.sched.faults import FAULT_KINDS, RECOVERY_POLICIES

CFG4 = simulator.ReconfigConfig(num_slots=4, miss_latency=50)
BENCHES = ["minver", "nbody", "crc32", "cubic"]


def fleet(p=2, n=3_000):
    return np.stack([traces.build_trace(b, n) for b in BENCHES[:p]])


def assert_state_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# degraded-core property: masking == physically smaller cache, bit for bit
# ---------------------------------------------------------------------------

def _check_masked_equals_physical(ops, p, k, smax, quantum, lat):
    """Core property check shared by the seeded and hypothesis variants:
    the K=k cell of a masked smax-allocated scan sweep equals the
    physically k-slot sweep, and `simulate_many(num_active=k)` equals the
    physically k-slot `simulate_many` — counters AND final caches."""
    tr = np.asarray(ops, np.int32).reshape(p, -1)
    sched = simulator.SchedulerConfig(quantum_cycles=quantum)
    total = tr.shape[1] * 2
    kw = dict(slot_counts=None, total_steps=total, path="scan")

    kw["slot_counts"] = [k, smax]
    both = simulator.sweep_fleet(tr[None], [lat], isa.SCENARIO_2, sched,
                                 **kw)
    kw["slot_counts"] = [k]
    phys = simulator.sweep_fleet(tr[None], [lat], isa.SCENARIO_2, sched,
                                 **kw)
    for field, x, y in zip(both._fields, both, phys):
        np.testing.assert_array_equal(
            np.asarray(x)[:, 0], np.asarray(y)[:, 0],
            err_msg=f"sweep cell K={k} of {smax}: field {field}")

    cfg_m = simulator.ReconfigConfig(num_slots=smax, miss_latency=lat)
    cfg_p = simulator.ReconfigConfig(num_slots=k, miss_latency=lat)
    res_m, st_m = simulator.simulate_many(
        tr, cfg_m, isa.SCENARIO_2, sched, total, num_active=k,
        return_state=True)
    res_p, st_p = simulator.simulate_many(
        tr, cfg_p, isa.SCENARIO_2, sched, total, return_state=True)
    assert_fleet_equal(res_m, res_p)
    # the masked cache IS the k-slot cache plus permanently-dead slots:
    # canonical (LRU-ascending prefix) layouts coincide on the live k
    tags_m = np.asarray(st_m.slot_st.tags)
    np.testing.assert_array_equal(tags_m[:k],
                                  np.asarray(st_p.slot_st.tags))
    np.testing.assert_array_equal(np.asarray(st_m.slot_st.last_use)[:k],
                                  np.asarray(st_p.slot_st.last_use))
    assert (tags_m[k:] == -1).all()
    assert int(st_m.slot_st.clock) == int(st_p.slot_st.clock)
    assert_state_equal(st_m.bs_st, st_p.bs_st)


@pytest.mark.parametrize("k", [1, 2, 3])
def test_masked_slots_equal_physical_cache_seeded(k):
    tr = fleet(2, 2_000)
    _check_masked_equals_physical(tr, 2, k, 4, quantum=1_500, lat=50)


def test_masked_slots_equal_physical_cache_random_seeded():
    """Always-on seeded variant over random traces/geometries."""
    rng = np.random.default_rng(20_260_809)
    for _ in range(4):
        p = int(rng.integers(1, 4))
        smax = int(rng.integers(2, 7))
        k = int(rng.integers(1, smax))
        ops = rng.integers(0, isa.NUM_INSTRUCTIONS, (p, 1_200))
        _check_masked_equals_physical(
            ops, p, k, smax, quantum=int(rng.integers(300, 2_000)),
            lat=int(rng.integers(0, 200)))


try:  # dev extra, not a runtime dep — only these tests skip without it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    # HYPOTHESIS_PROFILE=ci (tests/conftest.py) pins this sweep in CI
    @settings(max_examples=20, deadline=None)
    @given(
        ops=st.lists(st.integers(0, isa.NUM_INSTRUCTIONS - 1),
                     min_size=1, max_size=64),
        p=st.integers(1, 3),
        smax=st.integers(2, 6),
        k_frac=st.floats(0.0, 0.999),
        quantum=st.integers(50, 2_000),
        lat=st.integers(0, 200),
    )
    def test_masked_slots_equal_physical_cache(ops, p, smax, k_frac,
                                               quantum, lat):
        """Random trace/geometry: `num_active=k` masking must be
        bit-for-bit an LRU cache of physical size k."""
        k = 1 + int(k_frac * (smax - 1))
        tr = np.tile(np.asarray(ops, np.int32), (p, 1))
        _check_masked_equals_physical(tr, p, k, smax, quantum, lat)
else:
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_masked_slots_equal_physical_cache():
        pass


# ---------------------------------------------------------------------------
# FleetState surgery + dispatch consequences
# ---------------------------------------------------------------------------

def warm_state(p=2, total=6_000):
    tr = fleet(p)
    sched = simulator.SchedulerConfig(quantum_cycles=1_500)
    _, st = simulator.simulate_many(tr, CFG4, isa.SCENARIO_2, sched,
                                    total, return_state=True)
    return tr, sched, st


def test_seu_surgery_kills_chosen_residents_and_keeps_lru_order():
    _, _, st = warm_state()
    tags0 = np.asarray(st.slot_st.tags)
    occupied = np.nonzero(tags0 >= 0)[0]
    assert occupied.size >= 2
    hit = occupied[:2]
    mut = simulator.seu_fleet_state(st, hit)
    tags1 = np.asarray(mut.slot_st.tags)
    # canonical layout: survivors prefix-packed in LRU-ascending order,
    # the SEU'd entries gone
    survivors = [t for i, t in enumerate(tags0) if t >= 0 and i not in hit]
    assert sorted(tags1[tags1 >= 0].tolist()) == sorted(survivors)
    assert int((tags1 >= 0).sum()) == len(survivors)
    with pytest.raises(ValueError, match="out of range"):
        simulator.seu_fleet_state(st, [99])


def test_flush_bitstream_colds_only_the_bs_cache():
    _, _, st = warm_state()
    mut = simulator.flush_bitstream(st)
    assert int(slots.occupancy(mut.bs_st)) == 0
    assert int(mut.bs_st.clock) == 0
    assert_state_equal(mut.slot_st, st.slot_st)


def test_degrade_fleet_state_packs_mru_residents_into_prefix():
    _, _, st = warm_state()
    tags0 = np.asarray(simulator.canonical_slot_state(st.slot_st).tags)
    filled = int((tags0 >= 0).sum())
    assert filled >= 3
    k = 2
    deg = simulator.degrade_fleet_state(st, k)
    tags1 = np.asarray(deg.slot_st.tags)
    assert int((tags1 >= 0).sum()) == k
    assert (tags1[k:] == -1).all()
    # canonical order is LRU-ascending, so the survivors are the most
    # recently used residents (the LRU ones fell into the dead slots)
    assert sorted(tags1[:k].tolist()) == \
        sorted(tags0[filled - k:filled].tolist())
    for bad in (0, 5):
        with pytest.raises(ValueError):
            simulator.degrade_fleet_state(st, bad)


def test_masked_resume_validates_and_interleaved_refuses():
    tr, sched, st = warm_state()
    with pytest.raises(ValueError, match="degrade_fleet_state"):
        simulator.simulate_many(tr, CFG4, isa.SCENARIO_2, sched, 2_000,
                                state=st, num_active=2)
    with pytest.raises(ValueError, match="scan"):
        simulator.simulate_many(tr, CFG4, isa.SCENARIO_2, sched, 2_000,
                                num_active=2, path="interleaved")
    deg = simulator.degrade_fleet_state(st, 2)
    res = simulator.simulate_many(tr, CFG4, isa.SCENARIO_2, sched, 2_000,
                                  state=deg, num_active=2)
    assert int(np.asarray(res.instructions).sum()) > 0


def test_mutated_states_scan_one_segment_then_reseed(resume_spy):
    """SEU- and flush-mutated states are not interleaved-seedable (the
    caches no scan could have produced), so the next resumed segment
    rides the scan; the segment re-warms the caches and the one after
    re-qualifies for the resumable interleaved entry."""
    tr, sched, st = warm_state()
    for mutate in (lambda s: simulator.seu_fleet_state(
                       s, np.nonzero(
                           np.asarray(s.slot_st.tags) >= 0)[0][:1]),
                   simulator.flush_bitstream):
        mut = mutate(st)
        n0 = len(resume_spy)
        _, st1 = simulator.simulate_many(
            tr, CFG4, isa.SCENARIO_2, sched, 4_000, state=mut,
            return_state=True)
        assert len(resume_spy) == n0          # scan served the segment
        simulator.simulate_many(tr, CFG4, isa.SCENARIO_2, sched, 2_000,
                                state=st1)
        assert len(resume_spy) == n0 + 1      # re-warmed -> fast again
        # and the scan fallback is still bit-for-bit the forced scan
        a = simulator.simulate_many(tr, CFG4, isa.SCENARIO_2, sched,
                                    1_000, state=mut)
        b = simulator.simulate_many(tr, CFG4, isa.SCENARIO_2, sched,
                                    1_000, state=mut, path="scan")
        assert_fleet_equal(a, b)


# ---------------------------------------------------------------------------
# FaultPlan: validation, ordering, deterministic storms
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(0, "meteor", 0)
    with pytest.raises(ValueError, match="epoch"):
        FaultEvent(-1, "core_loss", 0)
    with pytest.raises(ValueError, match="repair_epochs"):
        FaultEvent(0, "core_loss", 0, repair_epochs=0)
    with pytest.raises(ValueError, match="num_hit"):
        FaultEvent(0, "slot_seu", 0, num_hit=0)
    with pytest.raises(ValueError, match="stall_epochs"):
        FaultEvent(0, "reconfig_stall", 0, stall_epochs=0)
    with pytest.raises(TypeError):
        FaultPlan(events=("not-an-event",))


def test_fault_plan_sorts_and_indexes():
    plan = FaultPlan(events=(
        FaultEvent(4, "slot_seu", 0),
        FaultEvent(1, "core_loss", 2),
        FaultEvent(1, "core_loss", 0),
    ), seed=5)
    assert [e.epoch for e in plan.events] == [1, 1, 4]
    assert [e.core for e in plan.events] == [0, 2, 0]
    assert plan.horizon() == 5 and plan.max_core() == 2
    assert plan.at(1) == list(plan.events[:2]) and plan.at(3) == []
    # per-event rng is counter-based: same event -> same stream, and
    # independent of any other event's draws
    ev = plan.events[2]
    a = plan.rng(ev).integers(0, 1_000, 8)
    b = plan.rng(ev).integers(0, 1_000, 8)
    np.testing.assert_array_equal(a, b)


def test_storm_is_seed_deterministic_and_keeps_one_core_up():
    s1 = FaultPlan.storm(seed=3, num_epochs=20, num_cores=3)
    s2 = FaultPlan.storm(seed=3, num_epochs=20, num_cores=3)
    assert s1 == s2
    assert s1 != FaultPlan.storm(seed=4, num_epochs=20, num_cores=3)
    # throttle invariant: never all cores down at once
    crowded = FaultPlan.storm(seed=1, num_epochs=30, num_cores=2,
                              p_core_loss=0.9, p_permanent=0.5)
    down_until: dict = {}
    for ev in crowded.events:
        if ev.kind != "core_loss":
            continue
        down = {c for c, u in down_until.items() if ev.epoch < u}
        assert len(down) < 2
        down_until[ev.core] = (np.inf if ev.permanent
                               else ev.epoch + ev.repair_epochs)


# ---------------------------------------------------------------------------
# OnlineReplacer recovery
# ---------------------------------------------------------------------------

PCFG = PlacementConfig(num_slots=4, miss_latency=50,
                       quantum_cycles=2_000, trace_len=2_000,
                       steps_per_program=2_000)
OCFG = OnlineConfig(num_cores=3, epoch_steps=3_000, probe_steps=800,
                    placement=PCFG)
EVENTS = [TenantEvent(0, "arrive", "a", "minver"),
          TenantEvent(0, "arrive", "b", "cubic"),
          TenantEvent(0, "arrive", "c", "crc32"),
          TenantEvent(1, "arrive", "d", "tarfind")]


@pytest.fixture(scope="module")
def model():
    return ContentionModel(PCFG)


def _loss_plan(**kw):
    return FaultPlan(events=(
        FaultEvent(2, "core_loss", kw.pop("core", 0), **kw),), seed=1)


def test_replacer_fault_arg_validation(model):
    with pytest.raises(ValueError, match="recovery"):
        OnlineReplacer(OCFG, model=model, recovery="pray")
    with pytest.raises(TypeError, match="FaultPlan"):
        OnlineReplacer(OCFG, model=model, faults=[FaultEvent(
            0, "core_loss", 0)])
    rep = OnlineReplacer(OCFG, model=model, faults=_loss_plan(core=9))
    with pytest.raises(ValueError, match="core 9"):
        rep.run(EVENTS, 5)
    with pytest.raises(ValueError, match="save_fn"):
        OnlineReplacer(OCFG, model=model).run(EVENTS, 5,
                                              checkpoint_every=2)


def test_core_loss_warm_evacuates_none_strands(model):
    plan = _loss_plan(repair_epochs=2)
    warm = OnlineReplacer(OCFG, model=model, faults=plan,
                          recovery="warm").run(EVENTS, 6)
    assert warm.evacuations >= 1
    evacs = [f for f in warm.fault_log if f["kind"] == "evacuation"]
    assert evacs and all(f["src"] == 0 for f in evacs)
    assert all(t.get("stall_cycles", 0.0) == 0.0
               for t in warm.per_tenant.values())
    # recovery separated from migration policy: the loss is detected
    loss = [f for f in warm.fault_log if f["kind"] == "core_loss"]
    assert loss and loss[0]["stranded"] == tuple(f["tenant"]
                                                 for f in evacs)

    none = OnlineReplacer(OCFG, model=model, faults=plan,
                          recovery="none").run(EVENTS, 6)
    assert none.evacuations == 0
    stranded = [t for t in none.per_tenant.values()
                if t.get("stall_cycles", 0.0) > 0.0]
    assert stranded      # someone sat out the outage
    assert none.worst_lifetime_slowdown > none.worst_slowdown
    assert warm.worst_lifetime_slowdown <= \
        none.worst_lifetime_slowdown + 1e-9
    # the repaired core came back and the repair is logged
    assert any(f["kind"] == "repair" for f in warm.fault_log)


def test_degraded_repair_masks_slots_and_prices_reduced_width(model):
    plan = _loss_plan(repair_epochs=1, degraded_slots=2)
    rep = OnlineReplacer(OCFG, model=model, faults=plan, recovery="warm")
    rep.run(EVENTS, 6)
    repair = [f for f in rep.fault_log if f["kind"] == "repair"]
    assert repair and repair[0]["active_slots"] == 2
    assert rep.cores[0].active_slots == 2
    # the dead slots never fill, even after epochs of serving
    assert (np.asarray(rep.cores[0].slot_st.tags)[2:] == -1).all()
    # degraded-width predictions are cached under (group, width) keys
    assert any(k and isinstance(k[-1], int) and k[-1] == 2
               for k in model._groups)


def test_reconfig_stall_blocks_evacuation_with_capped_backoff(model):
    # every surviving core's port stalls at the loss epoch: the
    # evacuation is blocked, backs off, and lands when the stall clears
    plan = FaultPlan(events=(
        FaultEvent(2, "core_loss", 0, repair_epochs=4),
        FaultEvent(2, "reconfig_stall", 1, stall_epochs=1),
        FaultEvent(2, "reconfig_stall", 2, stall_epochs=1),
    ), seed=1)
    rep = OnlineReplacer(OCFG, model=model, faults=plan,
                         recovery="warm").run(EVENTS, 7)
    retries = [f for f in rep.fault_log if f["kind"] == "reconfig_retry"]
    evacs = [f for f in rep.fault_log if f["kind"] == "evacuation"]
    assert retries and all(r["epoch"] == 2 for r in retries)
    assert all(r["next_attempt"] == 3 for r in retries)
    assert evacs and all(f["epoch"] == 3 for f in evacs)
    assert all(f["retries"] == 1 for f in evacs)


def test_backoff_delay_is_capped():
    rep = OnlineReplacer(OCFG, model=ContentionModel(PCFG),
                         faults=_loss_plan(), backoff_cap=4)
    rep.cores[1].stall_until = 100
    for epoch in range(0, 40):
        rep._attempt_move("t", 1, epoch, why="test")
    retries = rep._retry["t"]["retries"]
    assert retries >= 4
    # delays: 1, 2, 4, 4, 4, ... — capped at backoff_cap
    assert rep._retry["t"]["next"] <= 39 + 4


def test_cold_restart_flushes_survivors(model):
    plan = _loss_plan(repair_epochs=2)
    rep = OnlineReplacer(OCFG, model=model, faults=plan,
                         recovery="cold_restart")
    out = rep.run(EVENTS, 6)
    assert any(f["kind"] == "cold_restart" for f in out.fault_log)
    assert out.evacuations >= 1   # cold_restart still evacuates


def test_checkpoint_restore_is_bit_for_bit(model):
    plan = FaultPlan(events=(
        FaultEvent(2, "core_loss", 0, repair_epochs=2, degraded_slots=1),
        FaultEvent(3, "slot_seu", 1, num_hit=1),
        FaultEvent(4, "bitstream_flush", 2),
    ), seed=9)
    snaps = {}
    full = OnlineReplacer(OCFG, model=model, policy="warm", faults=plan,
                          recovery="warm")
    rep1 = full.run(EVENTS, 7, checkpoint_every=3,
                    save_fn=lambda s, e: snaps.setdefault(e, s))
    assert sorted(snaps) == [2, 5]
    for epoch in (2, 5):
        fresh = OnlineReplacer(OCFG, model=ContentionModel(PCFG),
                               policy="warm", faults=plan,
                               recovery="warm")
        fresh.restore(snaps[epoch])
        rep2 = fresh.run(EVENTS, 7)
        assert rep2.per_tenant == rep1.per_tenant, epoch
        assert rep2.fault_log == rep1.fault_log, epoch
        assert rep2.moves == rep1.moves, epoch
        assert rep2.epoch_log == rep1.epoch_log, epoch
        assert rep2.final_cores == rep1.final_cores, epoch
    # geometry/policy mismatches are refused
    other = OnlineReplacer(OCFG, model=model, policy="always",
                           faults=plan, recovery="warm")
    with pytest.raises(ValueError, match="policy"):
        other.restore(snaps[2])


def test_no_fault_serve_unchanged_by_fault_machinery(model):
    """faults=None must be bit-for-bit the pre-fault serve: same moves,
    same epoch-log schema, lifetime == classic slowdown."""
    rep = OnlineReplacer(OCFG, model=model, policy="warm").run(EVENTS, 6)
    assert rep.fault_log == [] and rep.evacuations == 0
    assert all(set(row) == {"epoch", "tenants", "moved", "cores"}
               for row in rep.epoch_log)
    for t in rep.per_tenant.values():
        if t["scheduled"]:
            assert t["lifetime_slowdown"] == pytest.approx(t["slowdown"])
    assert rep.worst_lifetime_slowdown == pytest.approx(
        rep.worst_slowdown)


def test_serve_online_passes_faults_through(model):
    """Engine wiring: SlotServeEngine.serve_online(faults=...) reaches
    the replacer (checked structurally, no model build needed)."""
    import inspect

    from repro.serve.engine import SlotServeEngine
    sig = inspect.signature(SlotServeEngine.serve_online)
    assert "faults" in sig.parameters and "recovery" in sig.parameters
    assert sig.parameters["recovery"].default == "warm"


# ---------------------------------------------------------------------------
# benchmarks/run.py --only typo detection
# ---------------------------------------------------------------------------

def test_bench_runner_rejects_unmatched_only(capsys):
    from benchmarks.run import main
    with pytest.raises(SystemExit) as exc:
        main(["--only", "fig6,apocalypse"])
    assert exc.value.code != 0
    err = capsys.readouterr().err
    assert "apocalypse" in err and "chaos_serve" in err
    with pytest.raises(SystemExit):
        main(["--only", "definitely-not-a-bench"])
