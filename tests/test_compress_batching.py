"""Gradient compression (cross-pod int8 + error feedback) and continuous
batching."""
import subprocess
import sys

import numpy as np
import pytest

from repro.serve.batching import ContinuousBatcher, Request


# ---------------------------------------------------------------------------
# gradient compression — runs on a forced 2-pod host mesh in a subprocess
# (the main test process must keep a single device)
# ---------------------------------------------------------------------------

COMPRESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.optim.compress import cross_pod_mean_tree
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
key = jax.random.PRNGKey(0)
# per-pod gradients: leading dim 2 = pod
g = {"w": jax.random.normal(key, (2, 64, 64)), "b": jax.random.normal(key, (2, 16))}
with mesh:
    (mean, ef) = cross_pod_mean_tree(g, None, mesh)
want_w = np.broadcast_to(np.mean(np.asarray(g["w"]), 0, keepdims=True), g["w"].shape)
got_w = np.asarray(mean["w"])
err = np.abs(got_w - want_w).max() / (np.abs(want_w).max() + 1e-9)
assert err < 0.02, f"quantised mean error too large: {err}"
# error feedback: residual bounded by one quantisation step
scale = np.abs(np.asarray(g["w"])).max() / 127.0
assert np.abs(np.asarray(ef["w"])).max() <= scale * 1.01
# EF accumulation drives the long-run average error to ~0
acc_err = np.zeros_like(got_w)
efs = ef
for _ in range(8):
    with mesh:
        mean2, efs = cross_pod_mean_tree(g, efs, mesh)
    acc_err += np.asarray(mean2["w"]) - want_w
assert np.abs(acc_err / 8).max() < scale
print("COMPRESS_OK")
"""


def test_cross_pod_compressed_mean():
    res = subprocess.run(
        [sys.executable, "-c", COMPRESS_SCRIPT],
        capture_output=True, text=True, timeout=300,
        # JAX_PLATFORMS=cpu: without it a stripped env lets an installed
        # TPU plugin probe (and retry) cloud instance metadata for minutes
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert "COMPRESS_OK" in res.stdout, res.stderr[-2000:]


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

class ToyBackend:
    """Echo-ish decode: next token = position + row (deterministic)."""

    def __init__(self):
        self.prefills = []

    def prefill_row(self, row, tokens):
        self.prefills.append((row, len(tokens)))

    def decode(self, tokens, positions):
        return positions + 1


def make_reqs(n, lens):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, 100, (4,)).astype(np.int32),
                    max_new_tokens=lens[i % len(lens)])
            for i in range(n)]


def test_all_requests_finish_and_rows_recycle():
    be = ToyBackend()
    cb = ContinuousBatcher(4, max_len=64, prefill_row=be.prefill_row,
                           decode=be.decode)
    for r in make_reqs(10, [3, 7, 5]):
        cb.submit(r)
    rep = cb.run_until_drained()
    assert rep["finished"] == 10
    assert len(be.prefills) == 10          # each admission prefilled once
    assert rep["mean_occupancy"] > 2.0     # rows stay busy


def test_short_requests_not_blocked_by_long():
    be = ToyBackend()
    cb = ContinuousBatcher(2, max_len=256, prefill_row=be.prefill_row,
                           decode=be.decode)
    long_req = Request(0, np.zeros(4, np.int32), max_new_tokens=100)
    shorts = [Request(i + 1, np.zeros(4, np.int32), max_new_tokens=2)
              for i in range(6)]
    cb.submit(long_req)
    for s in shorts:
        cb.submit(s)
    rep = cb.run_until_drained()
    assert rep["finished"] == 7
    # the 6 short requests fit inside the long one's lifetime: total steps
    # barely exceed the long request's 100 decode steps
    assert rep["steps"] <= 105


def test_generation_is_per_row_consistent():
    be = ToyBackend()
    cb = ContinuousBatcher(2, max_len=32, prefill_row=be.prefill_row,
                           decode=be.decode)
    reqs = make_reqs(2, [5])
    for r in reqs:
        cb.submit(r)
    cb.run_until_drained()
    for r in reqs:
        # positions advance from len(prompt): tokens = pos+1 sequence
        start = len(r.prompt)
        assert r.generated == [start + 1 + i for i in range(5)]


def test_active_router_bias_unions_tenants():
    be = ToyBackend()
    cb = ContinuousBatcher(2, max_len=16, prefill_row=be.prefill_row,
                           decode=be.decode)
    b0 = np.array([6.0, -6.0, -6.0, -6.0], np.float32)
    b1 = np.array([-6.0, 6.0, -6.0, -6.0], np.float32)
    cb.submit(Request(0, np.zeros(2, np.int32), 8, router_bias=b0))
    cb.submit(Request(1, np.zeros(2, np.int32), 8, router_bias=b1))
    cb.step()
    bias = cb.active_router_bias(4)
    np.testing.assert_array_equal(bias, [6.0, 6.0, -6.0, -6.0])


def test_async_checkpoint_roundtrip(tmp_path):
    import jax.numpy as jnp
    from repro.checkpoint import ckpt
    saver = ckpt.AsyncSaver()
    tree = {"a": jnp.arange(10, dtype=jnp.float32)}
    saver.save(str(tmp_path), 5, tree)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 5
    back = ckpt.restore(str(tmp_path), 5, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))


import jax  # noqa: E402  (used by the async test)
