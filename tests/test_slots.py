"""Unit + property tests for the instruction disambiguator (exact LRU).

The deterministic tests always run; the hypothesis property tests skip when
the dev extra is not installed (they do run in CI, which installs
``.[dev]``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # dev extra, not a runtime dep — only the property tests need it
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import slots


def run_sequence(num_slots, tags):
    state = slots.init(num_slots)
    hits = []
    for t in tags:
        res = slots.lookup(state, jnp.int32(t))
        state = res.state
        hits.append(bool(res.hit))
    return state, hits


class PyLRU:
    """Reference LRU cache (python oracle)."""

    def __init__(self, size):
        self.size = size
        self.order = []  # most recent last

    def access(self, tag):
        if tag < 0:
            return True
        if tag in self.order:
            self.order.remove(tag)
            self.order.append(tag)
            return True
        if len(self.order) >= self.size:
            self.order.pop(0)
        self.order.append(tag)
        return False


def test_cold_miss_then_hit():
    state, hits = run_sequence(2, [5, 5, 5])
    assert hits == [False, True, True]


def test_unslotted_tag_never_misses_or_mutates():
    state = slots.init(2)
    res = slots.lookup(state, jnp.int32(-1))
    assert bool(res.hit)
    np.testing.assert_array_equal(res.state.tags, state.tags)


def test_lru_eviction_order():
    # fill 2 slots with 1,2; touch 1; insert 3 -> 2 evicted
    _, hits = run_sequence(2, [1, 2, 1, 3, 1, 2])
    assert hits == [False, False, True, False, True, False]


def test_eviction_reports_victim_tag():
    state = slots.init(1)
    state = slots.lookup(state, jnp.int32(7)).state
    res = slots.lookup(state, jnp.int32(9))
    assert int(res.evicted_tag) == 7


def _lru_vs_oracle(num_slots, tags):
    """JAX exact-LRU == reference python LRU for arbitrary tag sequences."""
    _, got = run_sequence(num_slots, tags)
    ref = PyLRU(num_slots)
    want = [ref.access(t) for t in tags]
    assert got == want


def test_lru_matches_python_oracle_seeded():
    """Always-on seeded variant of the oracle property."""
    rng = np.random.default_rng(7)
    for _ in range(8):
        _lru_vs_oracle(int(rng.integers(1, 7)),
                       [int(t) for t in rng.integers(-1, 10,
                                                     rng.integers(1, 61))])


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        num_slots=st.integers(min_value=1, max_value=6),
        tags=st.lists(st.integers(min_value=-1, max_value=9), min_size=1,
                      max_size=60),
    )
    def test_lru_matches_python_oracle(num_slots, tags):
        _lru_vs_oracle(num_slots, tags)

    @settings(max_examples=20, deadline=None)
    @given(
        num_slots=st.integers(min_value=1, max_value=5),
        tags=st.lists(st.integers(min_value=0, max_value=8), min_size=1,
                      max_size=40),
    )
    def test_occupancy_bounded_and_monotone(num_slots, tags):
        state = slots.init(num_slots)
        prev = 0
        for t in tags:
            state = slots.lookup(state, jnp.int32(t)).state
            occ = int(slots.occupancy(state))
            assert prev <= occ <= min(num_slots, len(set(tags)))
            prev = occ
else:
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_lru_matches_python_oracle():
        pass

    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_occupancy_bounded_and_monotone():
        pass


def test_lookup_batch_matches_sequential():
    tags = [3, 1, 3, 2, 4, 1, -1, 3]
    _, seq_hits = run_sequence(3, tags)
    state = slots.init(3)
    _, batch_hits = slots.lookup_batch(state, jnp.array(tags, jnp.int32))
    assert [bool(h) for h in batch_hits] == seq_hits


def test_lookup_batch_num_active_matches_masked_lookup():
    """`num_active` must thread through lookup_batch exactly like per-step
    `lookup` masking."""
    tags = jnp.array([3, 1, 3, 2, 4, 1, -1, 3, 2, 2], jnp.int32)
    for k in (1, 2, 3):
        state = slots.init(4)
        seq_hits = []
        for t in np.asarray(tags):
            r = slots.lookup(state, jnp.int32(int(t)), jnp.int32(k))
            state = r.state
            seq_hits.append(bool(r.hit))
        _, batch_hits = slots.lookup_batch(slots.init(4), tags,
                                           num_active=jnp.int32(k))
        assert [bool(h) for h in batch_hits] == seq_hits


def test_lookup_batch_num_active_equals_dedicated_size():
    """Masking a max-size pool down to k slots behaves exactly like a
    dedicated k-slot pool — the property the simulator's slot-count sweep
    and the expert-slot runtime both rely on."""
    tags = jnp.array([5, 6, 5, 7, 8, 6, 5, 9, 7, 7, 6], jnp.int32)
    for k in (1, 2, 3, 4):
        _, masked = slots.lookup_batch(slots.init(8), tags,
                                       num_active=jnp.int32(k))
        _, dedicated = slots.lookup_batch(slots.init(k), tags)
        np.testing.assert_array_equal(np.asarray(masked),
                                      np.asarray(dedicated))


def _fused_vs_chained(num_slots, bs_slots, num_active, tags):
    """The fused fleet-scan update must equal the two chained `lookup`
    calls it replaces — states and hit bits, bit for bit."""
    num_active = min(num_active, num_slots)
    fused_slot, fused_bs = slots.init(num_slots), slots.init(bs_slots)
    ref_slot, ref_bs = slots.init(num_slots), slots.init(bs_slots)
    for t in tags:
        fused_slot, fused_bs, hit, bs_hit = slots.lookup_fused(
            fused_slot, fused_bs, jnp.int32(t), jnp.int32(num_active))
        res = slots.lookup(ref_slot, jnp.int32(t), jnp.int32(num_active))
        bs_res = slots.lookup(
            ref_bs, jnp.where(res.hit, slots.EMPTY, jnp.int32(t)))
        ref_slot, ref_bs = res.state, bs_res.state
        assert bool(hit) == bool(res.hit)
        assert bool(bs_hit) == bool(bs_res.hit)
        for a, b in zip(fused_slot, ref_slot):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(fused_bs, ref_bs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lookup_fused_matches_chained_lookups_seeded():
    rng = np.random.default_rng(11)
    for _ in range(6):
        _fused_vs_chained(
            int(rng.integers(1, 6)), int(rng.integers(1, 6)),
            int(rng.integers(1, 6)),
            [int(t) for t in rng.integers(-1, 7, rng.integers(1, 41))])


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(
        num_slots=st.integers(min_value=1, max_value=5),
        bs_slots=st.integers(min_value=1, max_value=5),
        num_active=st.integers(min_value=1, max_value=5),
        tags=st.lists(st.integers(min_value=-1, max_value=6), min_size=1,
                      max_size=40),
    )
    def test_lookup_fused_matches_chained_lookups(num_slots, bs_slots,
                                                  num_active, tags):
        _fused_vs_chained(num_slots, bs_slots, num_active, tags)
else:
    @pytest.mark.skip(reason="hypothesis not installed (dev extra)")
    def test_lookup_fused_matches_chained_lookups():
        pass


def test_jit_and_vmap_compatible():
    @jax.jit
    def f(state, tags):
        return slots.lookup_batch(state, tags)[1]

    states = jax.vmap(lambda _: slots.init(2))(jnp.arange(3))
    tags = jnp.array([[1, 2, 1], [1, 1, 1], [3, 4, 5]], jnp.int32)
    hits = jax.vmap(lambda s, t: f(s, t))(states, tags)
    np.testing.assert_array_equal(
        np.asarray(hits),
        [[False, False, True], [False, True, True],
         [False, False, False]])
