"""Unit + property tests for the instruction disambiguator (exact LRU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # dev extra, not runtime dep
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import slots


def run_sequence(num_slots, tags):
    state = slots.init(num_slots)
    hits = []
    for t in tags:
        res = slots.lookup(state, jnp.int32(t))
        state = res.state
        hits.append(bool(res.hit))
    return state, hits


class PyLRU:
    """Reference LRU cache (python oracle)."""

    def __init__(self, size):
        self.size = size
        self.order = []  # most recent last

    def access(self, tag):
        if tag < 0:
            return True
        if tag in self.order:
            self.order.remove(tag)
            self.order.append(tag)
            return True
        if len(self.order) >= self.size:
            self.order.pop(0)
        self.order.append(tag)
        return False


def test_cold_miss_then_hit():
    state, hits = run_sequence(2, [5, 5, 5])
    assert hits == [False, True, True]


def test_unslotted_tag_never_misses_or_mutates():
    state = slots.init(2)
    res = slots.lookup(state, jnp.int32(-1))
    assert bool(res.hit)
    np.testing.assert_array_equal(res.state.tags, state.tags)


def test_lru_eviction_order():
    # fill 2 slots with 1,2; touch 1; insert 3 -> 2 evicted
    _, hits = run_sequence(2, [1, 2, 1, 3, 1, 2])
    assert hits == [False, False, True, False, True, False]


def test_eviction_reports_victim_tag():
    state = slots.init(1)
    state = slots.lookup(state, jnp.int32(7)).state
    res = slots.lookup(state, jnp.int32(9))
    assert int(res.evicted_tag) == 7


@settings(max_examples=30, deadline=None)
@given(
    num_slots=st.integers(min_value=1, max_value=6),
    tags=st.lists(st.integers(min_value=-1, max_value=9), min_size=1,
                  max_size=60),
)
def test_lru_matches_python_oracle(num_slots, tags):
    """JAX exact-LRU == reference python LRU for arbitrary tag sequences."""
    _, got = run_sequence(num_slots, tags)
    ref = PyLRU(num_slots)
    want = [ref.access(t) for t in tags]
    assert got == want


@settings(max_examples=20, deadline=None)
@given(
    num_slots=st.integers(min_value=1, max_value=5),
    tags=st.lists(st.integers(min_value=0, max_value=8), min_size=1,
                  max_size=40),
)
def test_occupancy_bounded_and_monotone(num_slots, tags):
    state = slots.init(num_slots)
    prev = 0
    for t in tags:
        state = slots.lookup(state, jnp.int32(t)).state
        occ = int(slots.occupancy(state))
        assert prev <= occ <= min(num_slots, len(set(tags)))
        prev = occ


def test_lookup_batch_matches_sequential():
    tags = [3, 1, 3, 2, 4, 1, -1, 3]
    _, seq_hits = run_sequence(3, tags)
    state = slots.init(3)
    _, batch_hits = slots.lookup_batch(state, jnp.array(tags, jnp.int32))
    assert [bool(h) for h in batch_hits] == seq_hits


def test_jit_and_vmap_compatible():
    @jax.jit
    def f(state, tags):
        return slots.lookup_batch(state, tags)[1]

    states = jax.vmap(lambda _: slots.init(2))(jnp.arange(3))
    tags = jnp.array([[1, 2, 1], [1, 1, 1], [3, 4, 5]], jnp.int32)
    hits = jax.vmap(lambda s, t: f(s, t))(states, tags)
    np.testing.assert_array_equal(
        np.asarray(hits),
        [[False, False, True], [False, True, True],
         [False, False, False]])
