"""Fault-storm serving demo: the online serve survives core losses, slot
SEUs, bitstream flushes and reconfig stalls with warm-state-aware
recovery (repro.sched.faults + repro.sched.online).

A seeded random storm (`FaultPlan.storm`) hits a 3-core fleet mid-serve.
The replacer detects each fault at its epoch, evacuates tenants off lost
cores through the contention model (a mandatory move — priced for
destination choice only), retries attempts blocked by a stalled
reconfiguration port with capped exponential backoff, and prices
degraded cores at their reduced slot width.  The demo prints the
structured FaultLog the report carries, then shows a crash-restart: the
serve is killed after a mid-run checkpoint and resumed from the snapshot
in a fresh replacer, finishing bit-for-bit identical.

    PYTHONPATH=src python examples/serve_faulty.py
"""
import jax
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.sched import (ContentionModel, FaultPlan, OnlineConfig,
                         OnlineReplacer, PlacementConfig, TenantEvent)
from repro.serve.engine import EngineConfig, SlotServeEngine, Tenant

cb.load_all()

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=3_000, steps_per_program=3_000)
OCFG = OnlineConfig(num_cores=3, epoch_steps=4_000, probe_steps=1_200,
                    placement=PCFG)
NUM_EPOCHS = 10

EVENTS = [
    TenantEvent(0, "arrive", "tenant0", "minver"),
    TenantEvent(0, "arrive", "tenant1", "cubic"),
    TenantEvent(1, "arrive", "tenant2", "crc32"),
    TenantEvent(1, "arrive", "tenant3", "tarfind"),
    TenantEvent(3, "depart", "tenant2"),
]

STORM = FaultPlan.storm(seed=11, num_epochs=NUM_EPOCHS, num_cores=3,
                        p_core_loss=0.18, p_seu=0.2, p_flush=0.15,
                        p_stall=0.15)


def main():
    cfg = cb.get_config("llama4-maverick-400b-a17b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tenants = [Tenant(name=f"tenant{i}",
                      tokens=rng.integers(0, cfg.vocab, (2, 8)).astype(
                          np.int32))
               for i in range(4)]
    eng = SlotServeEngine(
        cfg, params, EngineConfig(quantum_tokens=16, slots_per_shard=4),
        tenants, max_len=70)
    model = ContentionModel(PCFG)

    print(f"-- fault storm: {len(STORM.events)} event(s) --")
    for ev in STORM.events:
        print(f"  epoch {ev.epoch}: {ev.kind} on core {ev.core}")

    print("-- serve under the storm (warm recovery) --")
    rep = eng.serve_online(EVENTS, online_cfg=OCFG, model=model,
                           num_epochs=NUM_EPOCHS, faults=STORM,
                           recovery="warm")
    print(f"policy={rep.policy} recovery={rep.recovery} "
          f"epochs={rep.epochs} migrations={rep.migrations} "
          f"evacuations={rep.evacuations}")
    print(f"worst slowdown={rep.worst_slowdown:.4f} "
          f"worst lifetime slowdown={rep.worst_lifetime_slowdown:.4f}")
    print("-- fault log --")
    for f in rep.fault_log:
        detail = {k: v for k, v in f.items()
                  if k not in ("epoch", "kind")}
        print(f"  epoch {f['epoch']}: {f['kind']} {detail}")

    # crash-restart: serve again with a mid-run checkpoint, restore it
    # into a fresh replacer and finish — the reports must coincide
    print("-- crash-restart from a mid-run checkpoint --")
    snaps = {}
    full = OnlineReplacer(OCFG, model=model, policy="warm", faults=STORM,
                          recovery="warm")
    full_rep = full.run(EVENTS, NUM_EPOCHS, checkpoint_every=4,
                        save_fn=lambda s, e: snaps.setdefault(e, s))
    epoch, snap = sorted(snaps.items())[0]
    fresh = OnlineReplacer(OCFG, model=ContentionModel(PCFG),
                           policy="warm", faults=STORM, recovery="warm")
    fresh.restore(snap)
    resumed = fresh.run(EVENTS, NUM_EPOCHS)
    match = (resumed.per_tenant == full_rep.per_tenant
             and resumed.fault_log == full_rep.fault_log
             and resumed.final_cores == full_rep.final_cores)
    print(f"restored at epoch {epoch}; bit-for-bit match: {match}")
    assert match


if __name__ == "__main__":
    main()
