"""Quickstart: train a tiny granite-family LM on CPU and decode from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.launch import train as train_mod
from repro.models import transformer

cb.load_all()


def main():
    # 1. train a reduced granite config for a few steps (full driver:
    #    deterministic data, checkpointing, fault supervision)
    report = train_mod.run("granite-3-2b", smoke=True, steps=20, batch=4,
                           seq=64, ckpt_dir="/tmp/quickstart_ckpt",
                           ckpt_every=10, log_every=5)
    print(f"trained to step {report['final_step']}; "
          f"loss {report['losses'][0]:.3f} -> {report['losses'][-1]:.3f}")

    # 2. greedy-decode a few tokens with the prefill/decode serving path
    cfg = cb.get_config("granite-3-2b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.array([[5, 17, 9, 2]], jnp.int32)
    logits, cache, _ = transformer.prefill(cfg, params, {"tokens": prompt})
    # pad the prefill cache to the decode horizon
    t0, horizon = prompt.shape[1], 16
    segs = transformer.segments(cfg)
    cache = [[{k: jnp.pad(c[k], ((0, 0), (0, 0), (0, horizon - t0),
                                 (0, 0), (0, 0))) for k in c}
              for c in seg] for seg, (types, _) in zip(cache, segs)]
    toks = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for step in range(t0, horizon):
        toks.append(int(tok[0, 0]))
        logits, cache, _ = transformer.decode_step(
            cfg, params, {"tokens": tok,
                          "positions": jnp.full((1,), step, jnp.int32)},
            cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
    print("decoded token ids:", toks)


if __name__ == "__main__":
    main()
