"""Multi-tenant slot-resident MoE serving demo — the paper's architecture
(disambiguator + slots + round-robin quantum) applied to expert serving.

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import jax
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.serve.engine import EngineConfig, SlotServeEngine, Tenant

cb.load_all()


def main():
    cfg = cb.get_config("llama4-maverick-400b-a17b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tenants = []
    for i in range(3):  # three "processes" with distinct expert mixes
        bias = np.full((cfg.num_experts,), -6.0, np.float32)
        bias[i * 3:(i * 3) + 4] = 6.0
        tenants.append(Tenant(
            name=f"tenant{i}",
            tokens=rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32),
            router_bias=bias))

    for slots in (2, 4):
        for bias in (0.0, 4.0):
            eng = SlotServeEngine(
                cfg, params,
                EngineConfig(quantum_tokens=16, slots_per_shard=slots,
                             hit_bias=bias),
                [Tenant(t.name, t.tokens, t.router_bias) for t in tenants],
                max_len=70)
            rep = eng.run(60)
            print(f"slots={slots} hit_bias={bias}: "
                  f"hit_rate={rep['hit_rate']:.3f} fills={rep['fills']} "
                  f"modelled fill time={rep['fill_seconds'] * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
