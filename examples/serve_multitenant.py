"""Multi-tenant slot-resident MoE serving demo — the paper's architecture
(disambiguator + slots + round-robin quantum) applied to expert serving,
now with contention-aware admission: instead of serving tenants in arrival
order, the engine asks `repro.sched` which tenants should co-reside and
which should be deferred to another replica/round.

    PYTHONPATH=src python examples/serve_multitenant.py
"""
import jax
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.sched import ContentionModel, PlacementConfig
from repro.serve.engine import EngineConfig, SlotServeEngine, Tenant

cb.load_all()

# each serving tenant's instruction-mix profile: the benchmark whose slot
# behaviour best matches its routing churn (FM-class = slot-hungry,
# M-class = light)
TENANT_PROFILES = {"tenant0": "minver", "tenant1": "nbody",
                   "tenant2": "crc32"}


def admission_demo(cfg, params, tenants):
    print("-- contention-aware admission (repro.sched) --")
    eng = SlotServeEngine(
        cfg, params, EngineConfig(quantum_tokens=16, slots_per_shard=4),
        [Tenant(t.name, t.tokens, t.router_bias) for t in tenants],
        max_len=70)
    model = ContentionModel(PlacementConfig(
        num_slots=4, quantum_cycles=2_000,
        trace_len=4_000, steps_per_program=4_000))
    plan = eng.plan_coresidency(TENANT_PROFILES, slo=1.2, num_cores=2,
                                model=model)
    print(f"slo=1.2 cores=2: admitted={plan.admitted} "
          f"deferred={plan.deferred} "
          f"predicted worst slowdown={plan.predicted_worst:.3f}")
    for ci, core in enumerate(plan.placement.cores if plan.placement
                              else ()):
        print(f"  core {ci}: {core} "
              f"({[TENANT_PROFILES[n] for n in core]})")
    kept = eng.apply_admission(plan, core=0)
    print(f"serving core 0 with {[t.name for t in kept]}; "
          f"{len(eng.deferred)} tenant(s) parked")
    rep = eng.run(30)
    print(f"core-0 round: hit_rate={rep['hit_rate']:.3f} "
          f"fills={rep['fills']}")


def main():
    cfg = cb.get_config("llama4-maverick-400b-a17b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tenants = []
    for i in range(3):  # three "processes" with distinct expert mixes
        bias = np.full((cfg.num_experts,), -6.0, np.float32)
        bias[i * 3:(i * 3) + 4] = 6.0
        tenants.append(Tenant(
            name=f"tenant{i}",
            tokens=rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32),
            router_bias=bias))

    for slots in (2, 4):
        for bias in (0.0, 4.0):
            eng = SlotServeEngine(
                cfg, params,
                EngineConfig(quantum_tokens=16, slots_per_shard=slots,
                             hit_bias=bias),
                [Tenant(t.name, t.tokens, t.router_bias) for t in tenants],
                max_len=70)
            rep = eng.run(60)
            print(f"slots={slots} hit_bias={bias}: "
                  f"hit_rate={rep['hit_rate']:.3f} fills={rep['fills']} "
                  f"modelled fill time={rep['fill_seconds'] * 1e3:.2f} ms")

    admission_demo(cfg, params, tenants)


if __name__ == "__main__":
    main()
