"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production stack (sharded step fn, deterministic pipeline,
checkpoint/restart, straggler monitoring).

    PYTHONPATH=src python examples/train_lm.py --steps 300

On this CPU container a ~100M model at seq 128 runs ~seconds/step; pass
--tiny for a fast sanity run, or run on a real slice for full speed.
"""
import argparse
import dataclasses

import jax

from repro.configs import base as cb
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cb.load_all()
    base = cb.get_config("granite-3-2b")
    if args.tiny:
        arch = "granite-3-2b"
    else:  # ~100M params: 8 x 512 with a 16k vocab
        cfg = dataclasses.replace(
            base, name="granite-100m", num_layers=8, d_model=512,
            num_heads=8, num_kv_heads=4, d_ff=2048, vocab=16384,
            head_dim=64, dtype="float32", remat="none", loss_chunk=0,
            skip_shapes={})
        cb.register(cfg)
        arch = cfg.name
    report = train_mod.run(
        arch, smoke=args.tiny, steps=args.steps, batch=4, seq=128,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    print(f"final loss {report['losses'][-1]:.4f} after "
          f"{report['final_step']} steps "
          f"({report['restarts']} restarts, "
          f"{len(report['straggler_events'])} straggler events)")


if __name__ == "__main__":
    main()
