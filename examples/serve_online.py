"""Online multi-tenant serving demo: tenants arrive and leave mid-serve,
and the engine re-places them across cores with warm-state-aware migration
pricing (repro.sched.online) instead of freezing the arrival-order
placement.

Each epoch the replacer re-solves placement through the contention model
and prices every implied move as predicted-contention-delta minus a
*measured* warm-state migration penalty — the mover's resumable
`FleetState` is replayed on its warm core and on a cold core, and the
cycle difference is what the move must pay back.

    PYTHONPATH=src python examples/serve_online.py
"""
import jax
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.sched import (ContentionModel, OnlineConfig, PlacementConfig,
                         TenantEvent)
from repro.serve.engine import EngineConfig, SlotServeEngine, Tenant

cb.load_all()

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=3_000, steps_per_program=3_000)
OCFG = OnlineConfig(num_cores=2, epoch_steps=4_000, probe_steps=1_200,
                    placement=PCFG)

# churn: the two slot-hungry FM-class tenants are forced onto different
# cores by arrival order; light tenants churn around them
EVENTS = [
    TenantEvent(0, "arrive", "tenant0", "minver"),
    TenantEvent(0, "arrive", "tenant1", "cubic"),
    TenantEvent(1, "arrive", "tenant2", "crc32"),
    TenantEvent(1, "arrive", "tenant3", "tarfind"),
    TenantEvent(3, "depart", "tenant2"),
]


def main():
    cfg = cb.get_config("llama4-maverick-400b-a17b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tenants = [Tenant(name=f"tenant{i}",
                      tokens=rng.integers(0, cfg.vocab, (2, 8)).astype(
                          np.int32))
               for i in range(4)]
    eng = SlotServeEngine(
        cfg, params, EngineConfig(quantum_tokens=16, slots_per_shard=4),
        tenants, max_len=70)

    model = ContentionModel(PCFG)
    print("-- online re-placement (warm-state-aware) --")
    rep = eng.serve_online(EVENTS, online_cfg=OCFG, model=model,
                           num_epochs=6, apply_core=0)
    print(f"policy={rep.policy} epochs={rep.epochs} "
          f"migrations={rep.migrations} "
          f"worst slowdown={rep.worst_slowdown:.4f}")
    for m in rep.moves:
        warm = ",".join(f"{w:.2f}" for w in m["warm_fraction"])
        print(f"  epoch {m['epoch']}: move {m['tenants']} "
              f"{m['src']}->{m['dst']} benefit={m['benefit_cycles']:.0f} "
              f"penalty={m['penalty_cycles']:.0f} warm_frac=[{warm}] "
              f"applied={m['applied']}")
    for ci, core in enumerate(rep.final_cores):
        print(f"  core {ci}: {core}")
    print(f"engine now serves core 0: {[t.name for t in eng.tenants]}; "
          f"{len(eng.deferred)} tenant(s) parked")
    if eng.tenants:
        out = eng.run(20)
        print(f"core-0 round: hit_rate={out['hit_rate']:.3f} "
              f"fills={out['fills']}")


if __name__ == "__main__":
    main()
