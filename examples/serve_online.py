"""Online multi-tenant serving demo: tenants arrive and leave mid-serve,
and the engine re-places them across cores with warm-state-aware migration
pricing (repro.sched.online) instead of freezing the arrival-order
placement.

Each epoch the replacer re-solves placement through the contention model
and prices every implied move as predicted-contention-delta minus a
*measured* warm-state migration penalty — the mover's resumable
`FleetState` is replayed on its warm core and on a cold core, and the
cycle difference is what the move must pay back.

Every resumed segment — the per-epoch advances and both migration probes
— rides the interleaved engine's resumable entry rather than the
cycle-by-cycle scan; the demo instruments the dispatcher to print which
engine served each epoch.

    PYTHONPATH=src python examples/serve_online.py
"""
import contextlib

import jax
import numpy as np

from repro.configs import base as cb
from repro.core import simulator
from repro.models import transformer
from repro.sched import (ContentionModel, OnlineConfig, PlacementConfig,
                         TenantEvent)
from repro.serve.engine import EngineConfig, SlotServeEngine, Tenant

cb.load_all()


@contextlib.contextmanager
def engine_log():
    """Tag every fleet-simulator dispatch while the block runs: the
    resumable interleaved entry vs the cycle-by-cycle scan."""
    calls = []
    real_resume = simulator._resume_fleet_interleaved
    real_scan = simulator._simulate_fleet

    def spy_resume(*a, **k):
        calls.append("interleaved-resume")
        return real_resume(*a, **k)

    def spy_scan(*a, **k):
        calls.append("scan")
        return real_scan(*a, **k)

    simulator._resume_fleet_interleaved = spy_resume
    simulator._simulate_fleet = spy_scan
    try:
        yield calls
    finally:
        simulator._resume_fleet_interleaved = real_resume
        simulator._simulate_fleet = real_scan

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=3_000, steps_per_program=3_000)
OCFG = OnlineConfig(num_cores=2, epoch_steps=4_000, probe_steps=1_200,
                    placement=PCFG)

# churn: the two slot-hungry FM-class tenants are forced onto different
# cores by arrival order; light tenants churn around them
EVENTS = [
    TenantEvent(0, "arrive", "tenant0", "minver"),
    TenantEvent(0, "arrive", "tenant1", "cubic"),
    TenantEvent(1, "arrive", "tenant2", "crc32"),
    TenantEvent(1, "arrive", "tenant3", "tarfind"),
    TenantEvent(3, "depart", "tenant2"),
]


def main():
    cfg = cb.get_config("llama4-maverick-400b-a17b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tenants = [Tenant(name=f"tenant{i}",
                      tokens=rng.integers(0, cfg.vocab, (2, 8)).astype(
                          np.int32))
               for i in range(4)]
    eng = SlotServeEngine(
        cfg, params, EngineConfig(quantum_tokens=16, slots_per_shard=4),
        tenants, max_len=70)

    model = ContentionModel(PCFG)

    # multi-epoch resumed serve of one core, state carried epoch to epoch:
    # each segment seeds the interleaved engine from the previous epoch's
    # FleetState (never the scan)
    print("-- multi-epoch resumed serve (one core, state carried) --")
    benches = ("minver", "crc32")
    tr = np.stack([np.asarray(model.trace(b)) for b in benches])
    scens = [model.scenario_of(b) for b in benches]
    st = simulator.init_fleet_state(len(benches), PCFG.num_slots,
                                    OCFG.bs_cache_entries)
    for epoch in range(3):
        with engine_log() as calls:
            res, st = simulator.simulate_many(
                tr, OCFG.reconfig(), scens, PCFG.scheduler(),
                total_steps=OCFG.epoch_steps, state=st, return_state=True)
        print(f"  epoch {epoch}: engine={'+'.join(calls)} "
              f"cycles={np.asarray(res.cycles).tolist()} "
              f"switches={int(res.switches)}")

    print("-- online re-placement (warm-state-aware) --")
    with engine_log() as calls:
        rep = eng.serve_online(EVENTS, online_cfg=OCFG, model=model,
                               num_epochs=6, apply_core=0)
    print(f"policy={rep.policy} epochs={rep.epochs} "
          f"migrations={rep.migrations} "
          f"worst slowdown={rep.worst_slowdown:.4f}")
    n_fast = sum(c == "interleaved-resume" for c in calls)
    n_scan = sum(c == "scan" for c in calls)
    print(f"resumed dispatches during serve: {n_fast} interleaved-resume, "
          f"{n_scan} scan")
    for m in rep.moves:
        warm = ",".join(f"{w:.2f}" for w in m["warm_fraction"])
        print(f"  epoch {m['epoch']}: move {m['tenants']} "
              f"{m['src']}->{m['dst']} benefit={m['benefit_cycles']:.0f} "
              f"penalty={m['penalty_cycles']:.0f} warm_frac=[{warm}] "
              f"applied={m['applied']}")
    for ci, core in enumerate(rep.final_cores):
        print(f"  core {ci}: {core}")
    print(f"engine now serves core 0: {[t.name for t in eng.tenants]}; "
          f"{len(eng.deferred)} tenant(s) parked")
    if eng.tenants:
        out = eng.run(20)
        print(f"core-0 round: hit_rate={out['hit_rate']:.3f} "
              f"fills={out['fills']}")


if __name__ == "__main__":
    main()
