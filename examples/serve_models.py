"""Model-fleet serving demo: the in-repo model zoo, lowered to traces and
served end-to-end (repro.workloads + repro.sched + repro.sched.online).

The pipeline in one script:

  1. lower two configs through `repro.workloads` — compile the smoke
     prefill/decode steps, walk the optimized HLO into an OpCount mix
     over the RV32IMF isa groups, and print the tables side by side
     (prefill lowers F-hot, decode lowers base-heavy);
  2. place a mixed prefill/decode model fleet with `place_tenants` —
     tenant names are "<arch>:<phase>" workload names, resolved by the
     same `ContentionModel` the Embench studies use;
  3. run a short online serve over arrival/departure events for those
     same model tenants, with a seeded `FaultPlan.storm` hitting the
     fleet mid-serve — chaos recovery machinery, unchanged, on a
     model-zoo fleet.

    PYTHONPATH=src python examples/serve_models.py
"""
import numpy as np

from repro import workloads
from repro.core import isa
from repro.sched import (ContentionModel, FaultPlan, OnlineConfig,
                         OnlineReplacer, PlacementConfig, TenantEvent,
                         place_tenants)

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=3_000, steps_per_program=3_000)
OCFG = OnlineConfig(num_cores=2, epoch_steps=4_000, probe_steps=1_200,
                    placement=PCFG)
NUM_EPOCHS = 8

FLEET = {
    "svc0": "qwen1.5-4b:prefill",
    "svc1": "recurrentgemma-9b:prefill",
    "svc2": "qwen1.5-4b:decode",
    "svc3": "musicgen-medium:decode",
}

EVENTS = [
    TenantEvent(0, "arrive", "svc0", FLEET["svc0"]),
    TenantEvent(0, "arrive", "svc2", FLEET["svc2"]),
    TenantEvent(1, "arrive", "svc1", FLEET["svc1"]),
    TenantEvent(2, "arrive", "svc3", FLEET["svc3"]),
    TenantEvent(5, "depart", "svc2"),
]

STORM = FaultPlan.storm(seed=7, num_epochs=NUM_EPOCHS, num_cores=2,
                        p_seu=0.2, p_flush=0.15, p_stall=0.1)


def main():
    print("-- instruction mixes from compiled HLO (fraction per group) --")
    show = ["base", "fadd", "fmul", "fdiv", "fcmp", "fma"]
    print("workload".ljust(28) + "".join(g.rjust(8) for g in show))
    for name in FLEET.values():
        spec = workloads.get_workload(name)
        frac = spec.mix()
        cells = "".join(f"{frac[isa.GROUP_ID[g]]:8.3f}" for g in show)
        print(name.ljust(28) + cells)

    print("-- contention-aware placement of the model fleet --")
    model = ContentionModel(PCFG)
    placed = place_tenants(FLEET, num_cores=2, model=model)
    for i, core in enumerate(placed.cores):
        print(f"  core {i}: " + ", ".join(
            f"{t} ({FLEET[t]})" for t in core))
    print(f"  worst slowdown={placed.worst_slowdown:.4f} "
          f"mean={placed.mean_slowdown:.4f}")

    print("-- online serve of the model fleet under a fault storm --")
    rep = OnlineReplacer(OCFG, model=model, policy="warm", faults=STORM,
                         recovery="warm").run(EVENTS, NUM_EPOCHS)
    print(f"policy={rep.policy} epochs={rep.epochs} "
          f"migrations={rep.migrations} faults={len(rep.fault_log)}")
    print(f"worst slowdown={rep.worst_slowdown:.4f} "
          f"worst lifetime slowdown={rep.worst_lifetime_slowdown:.4f}")
    for t, m in sorted(rep.per_tenant.items()):
        print(f"  {t} ({FLEET[t]}): lifetime slowdown "
              f"{m['lifetime_slowdown']:.4f}")
    assert rep.worst_lifetime_slowdown < 2.0, rep.per_tenant


if __name__ == "__main__":
    main()
