"""Reproduce the paper's evaluation (Figs. 4-7) end to end and print the
validation against every number stated in the text.

    PYTHONPATH=src python examples/paper_repro.py
"""
import numpy as np

from benchmarks import fig4_extensions, fig5_classification, fig6_single, fig7_multi


def main():
    print("== Fig 4: fixed-ISA speedups ==")
    rows = fig4_extensions.run()
    for r in rows:
        if r.startswith(("minver", "matmult-int", "wikisort")):
            print("  " + r)
    print("== Fig 5: classification ==")
    print("  " + fig5_classification.run()[-1])
    print("== Fig 6: slot scenarios (speedup vs RV32IMF) ==")
    rows, _ = fig6_single.run()
    for r in rows:
        if r.startswith(("AVERAGE", "#")):
            print("  " + r)
    print("== Fig 7: multi-program (50 pairs) ==")
    rows, _ = fig7_multi.run()
    for r in rows:
        if r.startswith(("AVERAGE", "#")):
            print("  " + r)


if __name__ == "__main__":
    main()
