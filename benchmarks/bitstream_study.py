"""Bitstream-cache sizing study — the paper's §VII future work.

The paper folds bitstream-cache behaviour into one abstract miss latency
and asks for "the design of the bitstream cache, such as with its datapath
width requirements" as future work.  Our simulator keeps the two levels
separate (disambiguator miss -> bitstream-cache hit/miss -> unified L2), so
we can sweep:

  * bitstream-cache capacity (entries) — when is the L1 bitstream cache
    large enough that every reconfiguration hits it?
  * the L2-fetch penalty (bs_miss_extra) — the cost of undersizing it,

on the 5 FM-class benchmarks under scenario 2 (4 slots, 50-cycle
reconfiguration).  Group-tag space is 10 ("M"+"F" groups), so capacities
beyond 10 are pure slack; the interesting region is 1-8.

The whole capacity x penalty grid is ONE `simulator.sweep_bitstream`
call: the stacked Mattson pass (`repro.core.stackdist_cold`) profiles
each trace once per slot count and reads every (capacity, penalty) cell
off the resulting miss-stream distance histogram — bit-for-bit equal to
the per-cell scans this benchmark used to run (parity is pinned by
tests/test_resume_fastpath.py at a reduced trace length).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import isa, simulator, traces

CAPACITIES = (2, 4, 8, 16)
L2_PENALTIES = (50, 250)
TRACE_LEN = 100_000


def run(trace_len: int = TRACE_LEN, path: str = "auto") -> list[str]:
    benches = list(traces.FM_BENCHES)
    trs = np.stack([traces.build_trace(name, trace_len)
                    for name in benches])
    grid = simulator.sweep_bitstream(
        trs, isa.SCENARIO_2, slot_counts=[4], miss_latencies=[50],
        bs_entries=CAPACITIES, bs_miss_extras=L2_PENALTIES,
        total_steps=trace_len, path=path)
    cycles = np.asarray(grid.cycles)          # (B, 1, 1, E, X)
    slot_misses = np.asarray(grid.slot_misses)  # (B, 1)
    bs_misses = np.asarray(grid.bs_misses)      # (B, 1, E)
    rows = ["benchmark,bs_entries,l2_penalty,bs_miss_rate,speedup_vs_IMF"]
    for i, name in enumerate(benches):
        imf = simulator.analytic_cpi(traces.mix_of(name), isa.RV32IMF)
        for e, cap in enumerate(CAPACITIES):
            for x, pen in enumerate(L2_PENALTIES):
                miss_rate = float(bs_misses[i, 0, e]) / max(
                    float(slot_misses[i, 0]), 1.0)
                cpi = float(cycles[i, 0, 0, e, x]) / trace_len
                rows.append(f"{name},{cap},{pen},{miss_rate:.3f},"
                            f"{imf / cpi:.3f}")
    # aggregate: capacity at which the bitstream cache stops mattering
    rows.append("# finding: >=8 entries (~the live group working set) makes "
                "the L2 penalty irrelevant; a 4-entry bitstream cache "
                "thrashes against the 4-slot disambiguator eviction stream")
    return rows


def main(print_fn=print):
    t0 = time.time()
    for r in run():
        print_fn(r)
    print_fn(f"# bitstream_study done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
