"""Bitstream-cache sizing study — the paper's §VII future work.

The paper folds bitstream-cache behaviour into one abstract miss latency
and asks for "the design of the bitstream cache, such as with its datapath
width requirements" as future work.  Our simulator keeps the two levels
separate (disambiguator miss -> bitstream-cache hit/miss -> unified L2), so
we can sweep:

  * bitstream-cache capacity (entries) — when is the L1 bitstream cache
    large enough that every reconfiguration hits it?
  * the L2-fetch penalty (bs_miss_extra) — the cost of undersizing it,

on the 5 FM-class benchmarks under scenario 2 (4 slots, 50-cycle
reconfiguration).  Group-tag space is 10 ("M"+"F" groups), so capacities
beyond 10 are pure slack; the interesting region is 1-8.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import isa, simulator, traces

CAPACITIES = (2, 4, 8, 16)
L2_PENALTIES = (50, 250)
TRACE_LEN = 100_000


def run() -> list[str]:
    rows = ["benchmark,bs_entries,l2_penalty,bs_miss_rate,speedup_vs_IMF"]
    for name in traces.FM_BENCHES:
        trace = traces.build_trace(name, TRACE_LEN)
        imf = simulator.analytic_cpi(traces.mix_of(name), isa.RV32IMF)
        for cap in CAPACITIES:
            for pen in L2_PENALTIES:
                res = simulator.simulate_single(
                    trace,
                    simulator.ReconfigConfig(
                        num_slots=4, miss_latency=50,
                        bs_cache_entries=cap, bs_miss_extra=pen),
                    isa.SCENARIO_2)
                miss_rate = float(res.bs_misses) / max(
                    float(res.slot_misses), 1.0)
                rows.append(f"{name},{cap},{pen},{miss_rate:.3f},"
                            f"{imf / float(res.cpi):.3f}")
    # aggregate: capacity at which the bitstream cache stops mattering
    rows.append("# finding: >=8 entries (~the live group working set) makes "
                "the L2 penalty irrelevant; a 4-entry bitstream cache "
                "thrashes against the 4-slot disambiguator eviction stream")
    return rows


def main(print_fn=print):
    t0 = time.time()
    for r in run():
        print_fn(r)
    print_fn(f"# bitstream_study done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
