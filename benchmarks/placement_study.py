"""§Sched — contention-aware placement vs random/FIFO co-residency.

For fleets of P=2..4 tenants per core, T tenants (a mix of F+M-class
slot-hungry profiles and M-only light profiles) are assigned to C cores
three ways:

  * `placed` — `repro.sched.place_tenants` (greedy seeding + swap local
    search on predicted worst-tenant slowdown);
  * `fifo`   — arrival-order chunks (what a serve layer does when it takes
    tenant order as given);
  * `random` — mean over `RANDOM_SEEDS` shuffled assignments.

The quantity compared is the predicted worst-tenant contention slowdown
(fleet CPI / unpreempted solo CPI) under a short 2K-cycle quantum — the
frequent-switching regime where the paper's §VI-C slowdowns are largest and
placement has real leverage.  The study asserts the acceptance criterion
(placed <= random mean at every P) and emits a machine-readable finding
line for `benchmarks.run` / BENCH_fleet.json.

    PYTHONPATH=src python -m benchmarks.placement_study
"""
from __future__ import annotations

import time

import numpy as np

from repro.sched import (ContentionModel, PlacementConfig, fifo_placement,
                         place_tenants, random_placement, score_placement)

RANDOM_SEEDS = range(5)

# tenant rosters: FM-class (slot-hungry) + M-only (light) profiles, sized so
# cores are full at each P
CASES = {
    # P=2: 8 tenants on 4 cores
    2: ["minver", "nbody", "cubic", "st",
        "crc32", "tarfind", "edn", "aha-mont64"],
    # P=3: 9 tenants on 3 cores
    3: ["minver", "nbody", "cubic",
        "crc32", "tarfind", "edn", "aha-mont64", "ud", "qrduino"],
    # P=4: 8 tenants on 2 cores
    4: ["minver", "nbody",
        "crc32", "tarfind", "edn", "aha-mont64", "ud", "qrduino"],
}

CFG = PlacementConfig(miss_latency=50, quantum_cycles=2_000,
                      trace_len=8_000, steps_per_program=8_000)


def study(p: int, benches: list[str], model: ContentionModel) -> dict:
    tenants = {f"t{i}:{b}": b for i, b in enumerate(benches)}
    num_cores = len(benches) // p
    names = sorted(tenants)

    placed = place_tenants(tenants, num_cores, model)
    fifo = score_placement(fifo_placement(names, num_cores), tenants, model)
    rnd = [score_placement(random_placement(names, num_cores, seed=s),
                           tenants, model) for s in RANDOM_SEEDS]
    return {
        "P": p,
        "num_cores": num_cores,
        "placed_worst": placed.worst_slowdown,
        "placed_mean": placed.mean_slowdown,
        "fifo_worst": fifo.worst_slowdown,
        "random_worst_mean": float(np.mean([r.worst_slowdown for r in rnd])),
        "random_worst_best": float(min(r.worst_slowdown for r in rnd)),
        "placed_cores": [tuple(tenants[n] for n in c) for c in placed.cores],
    }


def run() -> tuple[list[str], dict]:
    model = ContentionModel(CFG)
    rows = ["P,strategy,worst_slowdown,mean_or_note"]
    out: dict = {}
    for p, benches in sorted(CASES.items()):
        r = study(p, benches, model)
        out[p] = r
        rows.append(f"{p},placed,{r['placed_worst']:.4f},"
                    f"mean={r['placed_mean']:.4f}")
        rows.append(f"{p},fifo,{r['fifo_worst']:.4f},-")
        rows.append(f"{p},random,{r['random_worst_mean']:.4f},"
                    f"best_of_{len(list(RANDOM_SEEDS))}="
                    f"{r['random_worst_best']:.4f}")
        # acceptance criterion: contention-aware placement beats random
        # co-residency on predicted worst-tenant slowdown at every P
        assert r["placed_worst"] <= r["random_worst_mean"] + 1e-9, r
    wins = "; ".join(
        f"P{p} {out[p]['placed_worst']:.3f} vs random "
        f"{out[p]['random_worst_mean']:.3f}" for p in sorted(out))
    rows.append(f"# finding placement beats random worst-tenant slowdown "
                f"at every P ({wins}); "
                f"{model.groups_simulated} groups simulated in "
                f"{model.sim_calls} batched sweeps")
    return rows, out


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# placement_study done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
