"""§Roofline — the full baseline table from the dry-run artifacts.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits,
per (arch x shape x mesh): the three roofline terms, the dominant term,
MODEL_FLOPS = 6·N(_active)·D (train) or 2·N(_active)·tokens (decode/
prefill-forward-only: 2·N·D), and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs · chips).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import base as cb


def model_flops(cfg, shape_name: str) -> float:
    spec = cb.SHAPES[shape_name]
    n = cfg.active_param_count()
    if spec.kind == "train":
        return 6.0 * n * spec.seq_len * spec.global_batch
    if spec.kind == "prefill":
        return 2.0 * n * spec.seq_len * spec.global_batch
    return 2.0 * n * spec.global_batch  # decode: one token per sequence


def run(dryrun_dir: str = "experiments/dryrun") -> list[str]:
    cb.load_all()
    rows = ["arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
            "model_tflops,useful_ratio,fits_hbm"]
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(path))
        cfg = cb.get_config(r["arch"])
        mf = model_flops(cfg, r["shape"])
        hlo_total = r["flops_per_device"] * r["chips"]
        ratio = mf / hlo_total if hlo_total else float("nan")
        rf = r["roofline"]
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{rf['compute_s']:.3e},{rf['memory_s']:.3e},"
            f"{rf['collective_s']:.3e},{rf['dominant']},"
            f"{mf / 1e12:.1f},{ratio:.2f},"
            f"{r['memory'].get('fits_hbm')}")
    return rows


def main(print_fn=print):
    for row in run():
        print_fn(row)


if __name__ == "__main__":
    main()
