"""Fig. 5 — benchmark classification by ("M", "F") speedups over RV32I.

Validates the paper's class structure: 5 improved-by-both, 8 M-only,
9 insensitive, and no F-only class.
"""
from __future__ import annotations

from repro.core import isa, simulator, traces


def run() -> list[str]:
    rows = ["benchmark,speedup_M,speedup_F,class"]
    counts = {traces.FM_CLASS: 0, traces.M_CLASS: 0, traces.INSENSITIVE: 0}
    for name, bench in traces.BENCHES.items():
        mix = traces.mix_of(name)
        s_m = (simulator.analytic_cpi(mix, isa.RV32I) /
               simulator.analytic_cpi(mix, isa.RV32IM))
        s_f = (simulator.analytic_cpi(mix, isa.RV32I) /
               simulator.analytic_cpi(mix, isa.RV32IF))
        counts[bench.cls] += 1
        rows.append(f"{name},{s_m:.2f},{s_f:.2f},{bench.cls}")
    rows.append(f"# classes: FM={counts[traces.FM_CLASS]} "
                f"M={counts[traces.M_CLASS]} "
                f"insensitive={counts[traces.INSENSITIVE]} "
                f"(paper: 5/8/9)")
    return rows


def main(print_fn=print):
    for row in run():
        print_fn(row)


if __name__ == "__main__":
    main()
