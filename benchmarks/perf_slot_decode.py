"""§Perf iteration 3 — arctic-480b decode_32k, the paper-technique cell.

Baseline: dense expert streaming.  Every decode step, each of the 16
expert shards computes its 8 experts' capacity buffers through the grouped
FFN, so each device streams all resident expert weights from HBM:

    8 experts x 3 x 7168 x 4864 x 2 B  =  1.67 GB/device/step  (2.04 ms)

Change (the paper's architecture, DESIGN.md §2): per-shard expert slots
with the block-LRU disambiguator + slot-hit routing bias, and the
count-aware Pallas GMM (`moe_gmm_skip`) whose scalar-prefetch index map
skips the weight streams of empty experts.  Expert-weight traffic then
scales with (slot working set + fill traffic), not with E.

Measurement: routing dynamics are simulated with a width-reduced arctic
(exact 128-expert router dimensionality, 4 tenants with banded working
sets) through the real serving engine; the byte model then applies the
FULL config's expert_bytes.  The kernel-level skip is validated by
tests/test_kernels.py::test_moe_gmm_skip_matches_dense_on_live_experts.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.serve.engine import (EngineConfig, SlotServeEngine, Tenant,
                                estimate_fleet_contention)

STEPS = 96
SHARDS = 16

# instruction-mix profiles backing the 4 tenants' contention estimate:
# mixed FM/M working sets, like the banded expert sets below
TENANT_PROFILES = ("nbody", "minver", "matmult-int", "cubic")


def make_tenants(cfg, n=4, batch=8, width=16):
    rng = np.random.default_rng(0)
    out = []
    e = cfg.num_experts
    band = e // n
    for i in range(n):
        bias = np.full((e,), -6.0, np.float32)
        bias[i * band:(i + 1) * band + 8] = 6.0 + rng.normal(
            0, 0.5, min(band + 8, e - i * band))
        out.append(Tenant(
            name=f"tenant{i}",
            tokens=rng.integers(0, cfg.vocab, (batch, width)).astype(
                np.int32),
            router_bias=bias))
    return out


def run() -> list[str]:
    cb.load_all()
    full = cb.get_config("arctic-480b")
    # width-reduced model with the REAL router dimensionality (128 experts)
    cfg = dataclasses.replace(
        full.smoke(), num_experts=128, top_k=2, capacity_factor=8.0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    mlp_mats = 3
    expert_bytes_full = mlp_mats * full.d_model * full.d_ff * 2  # 209 MB
    e_per_shard = full.num_experts // SHARDS                      # 8

    base_bytes = e_per_shard * expert_bytes_full  # dense streaming /step
    rows = ["variant,slots,hit_bias,hit_rate,experts_live_per_step,"
            "bytes_per_step_GB,mem_term_ms,vs_base"]
    rows.append(f"base(dense-stream),-,-,-,{e_per_shard},"
                f"{base_bytes / 1e9:.2f},{base_bytes / 819e9 * 1e3:.3f},"
                f"1.00x")
    for slots in (2, 4):
        for bias in (0.0, 4.0):
            eng = SlotServeEngine(
                cfg, params,
                EngineConfig(quantum_tokens=16, slots_per_shard=slots,
                             expert_shards=SHARDS, hit_bias=bias),
                make_tenants(cfg), max_len=STEPS + 4)
            rep = eng.run(STEPS)
            # live experts per shard-step = accesses / (steps * layers...)
            layer_steps = rep["steps"] * sum(cfg.moe_layer_mask()) * SHARDS
            live = rep["accesses"] / max(layer_steps, 1)
            # per-step traffic: live experts hit VMEM-resident slots (free
            # re-stream avoided), misses stream full expert weights
            fill_bytes = rep["fills"] / max(rep["steps"], 1) / SHARDS * \
                expert_bytes_full
            resident_bytes = min(live, slots) * expert_bytes_full
            per_step = fill_bytes + resident_bytes
            rows.append(
                f"slots,{slots},{bias},{rep['hit_rate']:.3f},{live:.2f},"
                f"{per_step / 1e9:.2f},{per_step / 819e9 * 1e3:.3f},"
                f"{base_bytes / per_step:.2f}x")

    # core-level contention estimate for the same 4-tenant mix, from the
    # fleet simulator behind the Fig. 7 sweeps (serve-layer endpoint)
    rows.append("fleet,tenant,profile,fleet_cpi,solo_cpi,slowdown")
    for slots in (2, 4):
        est = estimate_fleet_contention(
            list(TENANT_PROFILES), num_slots=slots,
            trace_len=30_000, total_steps=80_000)
        for key, t in est["tenants"].items():
            i, prof = key.split(":", 1)
            rows.append(
                f"fleet,{slots}slot/t{i},{prof},{t['fleet_cpi']:.3f},"
                f"{t['solo_cpi']:.3f},{t['contention_slowdown']:.2f}x")
    return rows


def main(print_fn=print):
    t0 = time.time()
    rows = run()
    os.makedirs("experiments/perf", exist_ok=True)
    with open("experiments/perf/arctic_decode_slots.csv", "w") as f:
        f.write("\n".join(rows) + "\n")
    for r in rows:
        print_fn(r)
    print_fn(f"# perf_slot_decode done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
