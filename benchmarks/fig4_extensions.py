"""Fig. 4 — adapted Embench under RV32I / RV32IF / RV32IM / RV32IMF.

Prints per-benchmark Mcycles for each fixed ISA (the paper's bar chart) and
validates the stated anchors: minver 27.5x ("F"), matmult-int 4.6x ("M"),
wikisort 2.9x (IMF).
"""
from __future__ import annotations

import time

from repro.core import isa, simulator, traces


def run() -> list[str]:
    rows = ["benchmark,class,RV32I_Mcyc,RV32IF_Mcyc,RV32IM_Mcyc,"
            "RV32IMF_Mcyc,speedup_F,speedup_M,speedup_IMF,synthesized"]
    for name, bench in traces.BENCHES.items():
        mix = traces.mix_of(name)
        cpi = {s: simulator.analytic_cpi(mix, isa.SPECS[s])
               for s in ("RV32I", "RV32IF", "RV32IM", "RV32IMF")}
        # normalise so RV32IMF hits the nominal Fig.4 magnitude
        n_instr = bench.imf_mcycles / cpi["RV32IMF"]
        mc = {s: n_instr * c for s, c in cpi.items()}
        rows.append(
            f"{name},{bench.cls},{mc['RV32I']:.0f},{mc['RV32IF']:.0f},"
            f"{mc['RV32IM']:.0f},{mc['RV32IMF']:.0f},"
            f"{cpi['RV32I'] / cpi['RV32IF']:.2f},"
            f"{cpi['RV32I'] / cpi['RV32IM']:.2f},"
            f"{cpi['RV32I'] / cpi['RV32IMF']:.2f},"
            f"{bench.synthesized}")
    return rows


def main(print_fn=print):
    t0 = time.time()
    for row in run():
        print_fn(row)
    print_fn(f"# fig4 done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
