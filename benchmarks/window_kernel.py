"""§Perf — fused window-distance kernel vs the jnp window pass.

The interleaved engine's `use_kernel` knob (PR 9) swaps the jnp window
pass in `repro.core.stackdist_interleaved._simulate_cell` for the fused
Pallas kernel in `repro.kernels.window_distance`.  This module times the
two implementations head-to-head on a small preempted grid — the
one-shot counter sweep AND a state-seeded resume segment (the serving
stack's epoch-advance shape) — with bit-for-bit parity asserted before
any timing, mirroring every other engine benchmark in this directory.

The kernel mode is whatever `resolve("kernel")` picks for the local
backend: the compiled Pallas kernel on GPU/TPU, interpret mode on CPU.
Interpret mode is a correctness vehicle, not a fast path, so CPU records
honestly show the kernel losing to XLA's fused jnp loop — the recorded
`kernel_mode` field keeps the two regimes from ever being compared as if
they were one (see benchmarks/perf_gate.py's same-backend rule).

Feeds the `window_kernel` section of BENCH_sweep.json via
benchmarks/perf_sweep.py and runs standalone through benchmarks/run.py.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import isa, scheduler, simulator
from repro.kernels import window_distance

WK_FLEETS = 2
WK_PROGRAMS = 2
WK_TRACE_LEN = 4_000
WK_TOTAL_STEPS = 8_000
WK_QUANTUM = 2_000
WK_SLOT_COUNTS = (2, 4)
WK_LATENCIES = (10, 50)
REPS = 2


def _best_of(fn, reps: int = REPS) -> float:
    """Compile/warm once, then best-of-`reps` wall-clock seconds (the
    perf_sweep protocol)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def bench_kernel_vs_jnp() -> dict:
    """Kernel vs jnp window pass, one-shot sweep + resumed segment."""
    _, interpret = window_distance.resolve("kernel")
    mode = "interpret" if interpret else "compiled"
    tensor = scheduler.fleet_traces(
        scheduler.make_fleets(WK_PROGRAMS)[:WK_FLEETS], WK_TRACE_LEN)
    sched = simulator.SchedulerConfig(quantum_cycles=WK_QUANTUM)
    kw = dict(slot_counts=WK_SLOT_COUNTS, total_steps=WK_TOTAL_STEPS,
              path="interleaved")

    def sweep(use_kernel):
        return simulator.sweep_fleet(tensor, WK_LATENCIES, isa.SCENARIO_2,
                                     sched, use_kernel=use_kernel, **kw)

    # correctness first: the kernel must agree with the jnp pass
    # bit-for-bit (the randomized grid lives in tests/test_window_kernel)
    for a, b in zip(sweep("jnp"), sweep("kernel")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    jnp_s = _best_of(lambda: sweep("jnp"))
    kernel_s = _best_of(lambda: sweep("kernel"))

    # state-seeded resume: the materialise/seeded kernel form behind
    # resume_preempted (what every online epoch advance rides)
    cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=50)
    tr = np.asarray(tensor)[0]
    half = WK_TOTAL_STEPS // 2
    _, seed = simulator.simulate_many(tr, cfg, isa.SCENARIO_2, sched, half,
                                      return_state=True)

    def segment(use_kernel):
        return simulator.simulate_many(tr, cfg, isa.SCENARIO_2, sched,
                                       half, state=seed,
                                       path="interleaved",
                                       use_kernel=use_kernel)

    for a, b in zip(segment("jnp"), segment("kernel")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    resume_jnp_s = _best_of(lambda: segment("jnp"))
    resume_kernel_s = _best_of(lambda: segment("kernel"))
    return {
        "grid": f"{WK_FLEETS} fleets x P={WK_PROGRAMS} x "
                f"{WK_TOTAL_STEPS} steps, quantum {WK_QUANTUM}, "
                f"{len(WK_SLOT_COUNTS)} slots x {len(WK_LATENCIES)} "
                f"latencies",
        "kernel_mode": mode,
        "window": simulator.INTERLEAVE_WINDOW,
        "jnp_s": jnp_s,
        "kernel_s": kernel_s,
        "speedup": jnp_s / kernel_s,
        "resume_jnp_s": resume_jnp_s,
        "resume_kernel_s": resume_kernel_s,
        "resume_speedup": resume_jnp_s / resume_kernel_s,
    }


def run() -> tuple[list[str], dict]:
    r = bench_kernel_vs_jnp()
    mode = r["kernel_mode"]
    rows = [
        "section,variant,seconds,speedup",
        f"window_kernel,jnp,{r['jnp_s']:.3f},1.00x",
        f"window_kernel,kernel[{mode}],{r['kernel_s']:.3f},"
        f"{r['speedup']:.2f}x",
        f"window_kernel_resume,jnp,{r['resume_jnp_s']:.3f},1.00x",
        f"window_kernel_resume,kernel[{mode}],{r['resume_kernel_s']:.3f},"
        f"{r['resume_speedup']:.2f}x",
        f"# finding fused window kernel ({mode}, window {r['window']}) "
        f"{r['speedup']:.2f}x vs jnp on the one-shot sweep, "
        f"{r['resume_speedup']:.2f}x on resumed segments; parity asserted "
        f"bit-for-bit before timing",
    ]
    return rows, r


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# window_kernel done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
