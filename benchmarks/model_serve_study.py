"""§Workloads — which model-zoo configs can co-reside on a slot-constrained core.

The multi-tenant question the serve layer asks, answered for the models
this repo actually ships: a mixed prefill/decode fleet of model-zoo
workloads (`repro.workloads` lowers each config's compiled HLO into an
isa-alphabet trace) is assigned to cores three ways — contention-aware
`place_tenants`, arrival-order FIFO, and the mean over `RANDOM_SEEDS`
shuffles — and compared on predicted worst-tenant contention slowdown,
exactly like `placement_study` does for Embench.

The fleet mixes the two serving phases deliberately: prefill tenants
lower F-hot/slot-hungry (dense GEMM bursts), decode tenants base-heavy/
light (memory-bound single-token steps), so the placement question has
real leverage — pairing two prefills on one core thrashes the slots,
pairing prefill with decode co-resides cheaply.

Asserted invariants (acceptance criteria):
  * placed <= random-mean worst-tenant slowdown at every P;
  * zero scan-engine dispatches — every lowered trace rides the
    stackdist/interleaved fast paths (`simulator._sweep_fleet` is
    counted during the study);
  * per-tenant trace checksums are printed so cross-PR output diffs
    catch any determinism drift.

Also serializes the full-zoo per-config instruction-mix table to
``experiments/bench/workload_mix.csv`` (roofline_table idiom) so mixes
are diffable across PRs.

    PYTHONPATH=src python -m benchmarks.model_serve_study
"""
from __future__ import annotations

import os
import time
import zlib

import numpy as np

from repro import workloads
from repro.core import simulator
from repro.sched import (ContentionModel, PlacementConfig, fifo_placement,
                         place_tenants, random_placement, score_placement)

RANDOM_SEEDS = range(5)

# six tenants over five distinct configs, three families (attention MoE,
# RWKV6, RG-LRU) and both serving phases
FLEET = [
    "qwen1.5-4b:prefill",
    "recurrentgemma-9b:prefill",
    "rwkv6-7b:prefill",
    "llama4-maverick-400b-a17b:decode",
    "qwen1.5-4b:decode",
    "musicgen-medium:decode",
]

# same roster at two densities: P=2 (3 cores) and P=3 (2 cores)
CASES = {2: FLEET, 3: FLEET}

CFG = PlacementConfig(miss_latency=50, quantum_cycles=2_000,
                      trace_len=8_000, steps_per_program=8_000)

MIX_CSV = os.path.join("experiments", "bench", "workload_mix.csv")


class _ScanCounter:
    """Counts dispatches into the scan fallback engine."""

    def __init__(self):
        self.calls = 0
        self._orig = None

    def __enter__(self):
        self._orig = simulator._sweep_fleet

        def counting(*a, **kw):
            self.calls += 1
            return self._orig(*a, **kw)

        simulator._sweep_fleet = counting
        return self

    def __exit__(self, *exc):
        simulator._sweep_fleet = self._orig
        return False


def study(p: int, names: list[str], model: ContentionModel) -> dict:
    tenants = {f"t{i}:{n}": n for i, n in enumerate(names)}
    num_cores = len(names) // p
    order = sorted(tenants)

    placed = place_tenants(tenants, num_cores, model)
    fifo = score_placement(fifo_placement(order, num_cores), tenants, model)
    rnd = [score_placement(random_placement(order, num_cores, seed=s),
                           tenants, model) for s in RANDOM_SEEDS]
    return {
        "P": p,
        "num_cores": num_cores,
        "placed_worst": placed.worst_slowdown,
        "placed_mean": placed.mean_slowdown,
        "fifo_worst": fifo.worst_slowdown,
        "random_worst_mean": float(np.mean([r.worst_slowdown for r in rnd])),
        "random_worst_best": float(min(r.worst_slowdown for r in rnd)),
        "placed_cores": [tuple(tenants[n] for n in c) for c in placed.cores],
    }


def write_mix_csv(path: str = MIX_CSV) -> int:
    """Serialize the full-zoo instruction-mix table (diffable across PRs)."""
    header, rows = workloads.mix_table_rows()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(r) + "\n")
    return len(rows)


def run() -> tuple[list[str], dict]:
    assert len({n.rsplit(":", 1)[0] for n in FLEET}) >= 4, \
        "fleet must span >= 4 distinct model-zoo configs"
    assert {n.rsplit(":", 1)[1] for n in FLEET} == {"prefill", "decode"}, \
        "fleet must mix both serving phases"

    model = ContentionModel(CFG)
    rows = ["P,strategy,worst_slowdown,mean_or_note"]
    out: dict = {}
    with _ScanCounter() as scans:
        for p, names in sorted(CASES.items()):
            r = study(p, names, model)
            out[p] = r
            rows.append(f"{p},placed,{r['placed_worst']:.4f},"
                        f"mean={r['placed_mean']:.4f}")
            rows.append(f"{p},fifo,{r['fifo_worst']:.4f},-")
            rows.append(f"{p},random,{r['random_worst_mean']:.4f},"
                        f"best_of_{len(list(RANDOM_SEEDS))}="
                        f"{r['random_worst_best']:.4f}")
            # acceptance: contention-aware placement beats random
            # co-residency on predicted worst-tenant slowdown at every P
            assert r["placed_worst"] <= r["random_worst_mean"] + 1e-9, r
    # acceptance: model-zoo traces ride the fast-path engines end-to-end
    assert scans.calls == 0, \
        f"model-zoo fleet hit the scan fallback {scans.calls}x"

    # determinism pins: crc32 per lowered tenant trace (diffable output)
    for n in FLEET:
        crc = zlib.crc32(model.trace(n).tobytes())
        rows.append(f"# trace_crc,{n},{crc}")

    n_mix = write_mix_csv()
    rows.append(f"# mix_table {n_mix} workloads -> {MIX_CSV}")

    pair = " + ".join(out[2]["placed_cores"][0])
    wins = "; ".join(
        f"P{p} {out[p]['placed_worst']:.3f} vs random "
        f"{out[p]['random_worst_mean']:.3f}" for p in sorted(out))
    rows.append(f"# finding model-zoo placement beats random worst-tenant "
                f"slowdown at every P ({wins}); 0 scan dispatches; "
                f"first placed core: {pair}")
    return rows, out


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# model_serve_study done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
