"""§Online — warm-state-aware re-placement vs never-migrate / always-rebalance.

A synthetic churn trace over 3 reconfigurable cores: two slot-hungry
FM-class tenants arrive in an order that forces the least-loaded arrival
rule to split them onto different cores next to M-class tenants (the bad
co-residency: disjoint tag sets fight for slots, while FM+FM *share* their
F-group slots — the paper's §IV point), followed by light-tenant churn
(departure + same-profile replacement) that perturbs the roster without
changing what a good placement looks like.

Three policies serve the same event stream through
`repro.sched.online.OnlineReplacer` (epochs over resumable `FleetState`,
per-core warm caches):

  * `never`  — arrival placement is final (the static serve layer's
    behaviour under churn);
  * `always` — apply every move the per-epoch re-solve implies, blind to
    migration cost;
  * `warm`   — apply a move only when predicted contention savings beat
    the *measured* warm-state migration penalty (resume-on-cold-core
    probe).

Acceptance (asserted): warm-aware re-placement achieves worst-tenant
slowdown <= the never-migrate baseline AND fewer migrations than
always-rebalance.  The expected shape: warm takes the one big regroup move
(net benefit ~10k cycles/epoch) and declines the ~zero-benefit light-tenant
swaps that always-rebalance keeps executing.

    PYTHONPATH=src python -m benchmarks.online_churn
"""
from __future__ import annotations

import time

from repro.sched import (ContentionModel, OnlineConfig, OnlineReplacer,
                         PlacementConfig, TenantEvent)

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=4_000, steps_per_program=4_000)
CFG = OnlineConfig(num_cores=3, epoch_steps=8_000, probe_steps=2_000,
                   placement=PCFG)
NUM_EPOCHS = 10

# the churn trace: FM-class tenants fgA/fgB forced apart by arrival order,
# light M-class tenants around them, then a light departure/replacement
EVENTS = [
    TenantEvent(0, "arrive", "fgA", "minver"),
    TenantEvent(0, "arrive", "fgB", "cubic"),
    TenantEvent(0, "arrive", "m1", "qrduino"),
    TenantEvent(1, "arrive", "m2", "edn"),
    TenantEvent(1, "arrive", "m3", "crc32"),
    TenantEvent(2, "arrive", "m4", "tarfind"),
    TenantEvent(5, "depart", "m3"),
    TenantEvent(5, "arrive", "m5", "tarfind"),
]

POLICIES = ("never", "always", "warm")


def run() -> tuple[list[str], dict]:
    # one shared contention model: predictions are policy-independent, so
    # the three serves reuse one prediction cache
    model = ContentionModel(PCFG)
    rows = ["policy,worst_slowdown,mean_slowdown,migrations,"
            "moves_declined"]
    out: dict = {}
    for policy in POLICIES:
        rep = OnlineReplacer(CFG, model=model, policy=policy).run(
            EVENTS, NUM_EPOCHS)
        declined = sum(1 for m in rep.moves if not m["applied"])
        out[policy] = rep
        rows.append(f"{policy},{rep.worst_slowdown:.4f},"
                    f"{rep.mean_slowdown:.4f},{rep.migrations},{declined}")
    warm, never, always = out["warm"], out["never"], out["always"]
    # acceptance: warm-aware re-placement beats/meets never-migrate on
    # worst-tenant slowdown with fewer migrations than always-rebalance
    assert warm.worst_slowdown <= never.worst_slowdown + 1e-9, (
        warm.worst_slowdown, never.worst_slowdown)
    assert warm.migrations < always.migrations, (
        warm.migrations, always.migrations)
    applied = [m for m in warm.moves if m["applied"]]
    rows.append(
        f"# finding warm-aware re-placement: worst slowdown "
        f"{warm.worst_slowdown:.4f} vs never {never.worst_slowdown:.4f} "
        f"(always {always.worst_slowdown:.4f}) with {warm.migrations} "
        f"migration(s) vs always {always.migrations}; warm applied "
        f"{len(applied)} unit(s), declined "
        f"{sum(1 for m in warm.moves if not m['applied'])} "
        f"(largest net {max((m['net_cycles'] for m in applied), default=0):.0f} cycles/epoch); "
        f"{model.groups_simulated} groups in {model.sim_calls} sweeps")
    return rows, out


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# online_churn done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
