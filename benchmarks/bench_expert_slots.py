"""Paper §IV applied to TPU serving: slot-resident experts under
multi-tenant round-robin scheduling (the Fig. 6/7 phenomenology at the
serving level).

Three tenants with disjoint token distributions (= processes with distinct
instruction mixes) decode against a reduced MoE model; per-shard expert
slots are managed by the block-LRU disambiguator.  Swept: slots/shard
{2, 4, 8} (Fig. 7's slot variants), quantum {8, 64} tokens (1K vs 20K
cycles), and the beyond-paper slot-hit routing bias.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.serve.engine import EngineConfig, SlotServeEngine, Tenant

STEPS = 120


def make_tenants(cfg, n=3, batch=2, width=16):
    """Tenants with explicit expert working sets (router-bias bands): the
    paper's processes with distinct instruction distributions."""
    rng = np.random.default_rng(0)
    tenants = []
    e = cfg.num_experts
    band = e // n + 1
    for i in range(n):
        toks = rng.integers(0, cfg.vocab, size=(batch, width)).astype(
            np.int32)
        bias = np.full((e,), -6.0, np.float32)
        lo = (i * band) % e
        members = [(lo + j) % e for j in range(band + 1)]
        bias[members] = 6.0 + rng.normal(0, 0.5, len(members))
        tenants.append(Tenant(name=f"tenant{i}", tokens=toks,
                              router_bias=bias))
    return tenants


def run() -> list[str]:
    cb.load_all()
    cfg = cb.get_config("arctic-480b").smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rows = ["slots,quantum,hit_bias,hit_rate,fills,fill_s,overhead_frac"]
    for slots in (2, 4, 8):
        for quantum in (8, 64):
            for bias in (0.0, 4.0):
                ecfg = EngineConfig(
                    quantum_tokens=quantum, slots_per_shard=slots,
                    expert_shards=1, hit_bias=bias)
                eng = SlotServeEngine(cfg, params, ecfg,
                                      make_tenants(cfg), max_len=STEPS + 4)
                rep = eng.run(STEPS)
                rows.append(
                    f"{slots},{quantum},{bias},{rep['hit_rate']:.3f},"
                    f"{rep['fills']},{rep['fill_seconds']:.3f},"
                    f"{rep['overhead_frac']:.3f}")
    rows.append("# expectations: hit_rate grows with slots and with "
                "quantum; hit_bias trades routing fidelity for fewer fills")
    return rows


def main(print_fn=print):
    t0 = time.time()
    for row in run():
        print_fn(row)
    print_fn(f"# bench_expert_slots done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
