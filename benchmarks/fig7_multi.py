"""Fig. 7 — multi-program environment: 50 benchmark pairs under a
round-robin scheduler, slot-count variations {2, 4, 8} at 50-cycle misses,
with 1K- vs 20K-cycle scheduler quanta; speedups vs fixed RV32IMF, plus the
fixed RV32I/IM/IF references.  Validates the paper's aggregate anchors:
4-slot@20K ~ 0.82x IMF average and 3.39x / 1.48x / 2.04x over I / IM / IF;
quantum lengthening 1K->20K improves the reconfigurable series.

The whole {2 quanta x 50 pairs x 3 slot counts x miss latency} grid runs
as ONE jitted `simulator.sweep_fleet` call (slot counts sweep via
disambiguator masking, quanta via the quantum axis).  `run_fleets` extends
the experiment beyond the paper: P=4 fleets (`scheduler.make_fleets(4)`)
across a miss-latency grid, again one jitted call.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import isa, scheduler, simulator, traces

SLOT_COUNTS = (2, 4, 8)
QUANTA = (1_000, 20_000)
TRACE_LEN = 60_000
TOTAL_STEPS = 160_000
MISS_LATENCY = 50

# beyond-paper fleet sweep: 4-way mixes, miss-latency grid
FLEET_K = 4
FLEET_LATENCIES = (10, 50, 250)
FLEET_TOTAL_STEPS = 240_000


def run(pairs=None) -> tuple[list[str], dict]:
    pairs = pairs or scheduler.make_pairs()
    tensor = scheduler.fleet_traces(pairs, TRACE_LEN)
    rows = ["pair,series,quantum,avg_speedup_vs_IMF"]
    agg: dict = {}

    # reconfigurable slot-count variants: ONE jitted sweep over the whole
    # {quanta x pairs x slot counts x latency} grid — the scheduler quantum
    # is just another sweep axis now
    res = simulator.sweep_fleet(
        tensor, [MISS_LATENCY], isa.SCENARIO_2,
        simulator.SchedulerConfig(), slot_counts=SLOT_COUNTS,
        quanta=QUANTA, total_steps=TOTAL_STEPS)
    cpis_all = np.asarray(res.cpi)          # (Q, B, K, 1, 2)

    for qi, q in enumerate(QUANTA):
        sched = simulator.SchedulerConfig(quantum_cycles=q)
        # fixed-ISA references (analytic fleet CPI)
        for spec_name in ("RV32I", "RV32IM", "RV32IF"):
            spec = isa.SPECS[spec_name]
            for (a, b) in pairs:
                sp = []
                for n in (a, b):
                    mix = traces.mix_of(n)
                    sp.append(simulator.fixed_fleet_cpi(mix, isa.RV32IMF,
                                                        sched) /
                              simulator.fixed_fleet_cpi(mix, spec, sched))
                agg.setdefault((spec_name, q), []).append(float(np.mean(sp)))
        cpis = cpis_all[qi]                 # (B, K, 1, 2)
        for k, nslots in enumerate(SLOT_COUNTS):
            vname = f"{nslots}slot"
            for i, (a, b) in enumerate(pairs):
                sp = []
                for j, n in enumerate((a, b)):
                    ref = simulator.fixed_fleet_cpi(
                        traces.mix_of(n), isa.RV32IMF, sched)
                    sp.append(ref / cpis[i, k, 0, j])
                val = float(np.mean(sp))
                agg.setdefault((vname, q), []).append(val)
                rows.append(f"{a}+{b},{vname},{q},{val:.3f}")

    for (series, q), vals in sorted(agg.items()):
        rows.append(f"AVERAGE,{series},{q},{np.mean(vals):.3f}")
    # paper's headline ratios (4-slot @ 20K over fixed subsets)
    k = np.mean(agg[("4slot", 20_000)])
    rows.append("# 4slot@20K vs fixed-ISA averages: "
                f"x{k / np.mean(agg[('RV32I', 20_000)]):.2f} over RV32I "
                f"(paper 3.39), "
                f"x{k / np.mean(agg[('RV32IM', 20_000)]):.2f} over RV32IM "
                f"(paper 1.48), "
                f"x{k / np.mean(agg[('RV32IF', 20_000)]):.2f} over RV32IF "
                f"(paper 2.04); abs {k:.2f} of IMF (paper 0.82)")
    return rows, agg


def run_fleets(k: int = FLEET_K, max_fleets: int | None = 24,
               quantum: int = 20_000) -> tuple[list[str], dict]:
    """Beyond-paper: k-way fleets x miss-latency grid, one jitted call.

    Also emits per-benchmark *solo references* — each program alone on the
    core, unpreempted, same latency grid — and the per-fleet contention
    slowdown against them.  The solo columns are unpreempted + warm-cache,
    so the sweep dispatcher serves them from one stack-distance pass per
    benchmark instead of K x L scans."""
    fleets = scheduler.make_fleets(k)
    if max_fleets is not None:
        fleets = fleets[:max_fleets]
    tensor = scheduler.fleet_traces(fleets, TRACE_LEN)
    sched = simulator.SchedulerConfig(quantum_cycles=quantum)
    res = simulator.sweep_fleet(
        tensor, FLEET_LATENCIES, isa.SCENARIO_2, sched,
        slot_counts=(4,), total_steps=FLEET_TOTAL_STEPS)
    cpis = np.asarray(res.cpi)              # (B, 1, L, k)
    rows = [f"fleet,latency,avg_speedup_vs_IMF,avg_contention_vs_solo "
            f"(P={k}, 4 slots, quantum {quantum})"]
    agg: dict = {}
    benches = sorted({n for f in fleets for n in f})
    refs = {n: simulator.fixed_fleet_cpi(traces.mix_of(n), isa.RV32IMF,
                                         sched)
            for n in benches}
    # solo-reference columns: (B=|benches|, P=1) unpreempted sweep over the
    # same latency grid — stack-distance fast path, no scans
    solo = simulator.sweep_fleet(
        np.stack([traces.build_trace(n, TRACE_LEN) for n in benches])[
            :, None, :],
        FLEET_LATENCIES, isa.SCENARIO_2, simulator.SchedulerConfig.no_preempt(),
        slot_counts=(4,), total_steps=TRACE_LEN)
    solo_cpi = {n: np.asarray(solo.cpi)[bi, 0, :, 0]
                for bi, n in enumerate(benches)}
    for li, lat in enumerate(FLEET_LATENCIES):
        for n in benches:
            # unpreempted solo vs plain analytic IMF (no handler term) —
            # the same quantity fig6_single reports for these cells
            imf = simulator.analytic_cpi(traces.mix_of(n), isa.RV32IMF)
            rows.append(f"solo:{n},{lat},"
                        f"{imf / solo_cpi[n][li]:.3f},1.00x")
        for i, fleet in enumerate(fleets):
            sp = float(np.mean([refs[n] / cpis[i, 0, li, j]
                                for j, n in enumerate(fleet)]))
            slowdown = float(np.mean([cpis[i, 0, li, j] / solo_cpi[n][li]
                                      for j, n in enumerate(fleet)]))
            agg.setdefault(lat, []).append(sp)
            rows.append(f"{'+'.join(fleet)},{lat},{sp:.3f},{slowdown:.2f}x")
    for lat, vals in sorted(agg.items()):
        rows.append(f"AVERAGE,{lat},{np.mean(vals):.3f},-")
    rows.append(f"# {len(fleets)} fleets of {k}; slot competition grows "
                "with P at fixed slot count (avg falls with latency); "
                "contention = fleet CPI / unpreempted solo CPI")
    return rows, agg


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for row in rows[-12:]:
        print_fn(row)
    frows, _ = run_fleets()
    for row in frows[-6:]:
        print_fn(row)
    print_fn(f"# fig7 done in {time.time() - t0:.1f}s "
             f"({len(rows) + len(frows)} rows total)")


if __name__ == "__main__":
    main()
