"""Fig. 7 — multi-program environment: 50 benchmark pairs under a
round-robin scheduler, slot-count variations {2, 4, 8} at 50-cycle misses,
with 1K- vs 20K-cycle scheduler quanta; speedups vs fixed RV32IMF, plus the
fixed RV32I/IM/IF references.  Validates the paper's aggregate anchors:
4-slot@20K ~ 0.82x IMF average and 3.39x / 1.48x / 2.04x over I / IM / IF;
quantum lengthening 1K->20K improves the reconfigurable series.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import isa, scheduler, simulator, traces

SLOT_VARIANTS = (("2slot", isa.SCENARIO_2_2SLOT),
                 ("4slot", isa.SCENARIO_2),
                 ("8slot", isa.SCENARIO_2_8SLOT))
QUANTA = (1_000, 20_000)
TRACE_LEN = 60_000
TOTAL_STEPS = 160_000
MISS_LATENCY = 50


def run(pairs=None) -> tuple[list[str], dict]:
    pairs = pairs or scheduler.make_pairs()
    tensor = scheduler.pair_traces(pairs, TRACE_LEN)
    rows = ["pair,series,quantum,avg_speedup_vs_IMF"]
    agg: dict = {}

    for q in QUANTA:
        sched = simulator.SchedulerConfig(quantum_cycles=q)
        # fixed-ISA references (analytic pair CPI)
        for spec_name in ("RV32I", "RV32IM", "RV32IF"):
            spec = isa.SPECS[spec_name]
            for (a, b) in pairs:
                sp = []
                for n in (a, b):
                    mix = traces.mix_of(n)
                    sp.append(simulator.fixed_pair_cpi(mix, isa.RV32IMF,
                                                       sched) /
                              simulator.fixed_pair_cpi(mix, spec, sched))
                agg.setdefault((spec_name, q), []).append(float(np.mean(sp)))
        # reconfigurable variants (simulated)
        for vname, scen in SLOT_VARIANTS:
            cfg = simulator.ReconfigConfig(num_slots=scen.num_slots,
                                           miss_latency=MISS_LATENCY)
            res = simulator.simulate_pair_batch(
                tensor, cfg, scen, sched, total_steps=TOTAL_STEPS)
            cpis = np.asarray(res.cpi)          # (B, 2)
            for i, (a, b) in enumerate(pairs):
                sp = []
                for j, n in enumerate((a, b)):
                    ref = simulator.fixed_pair_cpi(
                        traces.mix_of(n), isa.RV32IMF, sched)
                    sp.append(ref / cpis[i, j])
                val = float(np.mean(sp))
                agg.setdefault((vname, q), []).append(val)
                rows.append(f"{a}+{b},{vname},{q},{val:.3f}")

    for (series, q), vals in sorted(agg.items()):
        rows.append(f"AVERAGE,{series},{q},{np.mean(vals):.3f}")
    # paper's headline ratios (4-slot @ 20K over fixed subsets)
    k = np.mean(agg[("4slot", 20_000)])
    rows.append("# 4slot@20K vs fixed-ISA averages: "
                f"x{k / np.mean(agg[('RV32I', 20_000)]):.2f} over RV32I "
                f"(paper 3.39), "
                f"x{k / np.mean(agg[('RV32IM', 20_000)]):.2f} over RV32IM "
                f"(paper 1.48), "
                f"x{k / np.mean(agg[('RV32IF', 20_000)]):.2f} over RV32IF "
                f"(paper 2.04); abs {k:.2f} of IMF (paper 0.82)")
    return rows, agg


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for row in rows[-12:]:
        print_fn(row)
    print_fn(f"# fig7 done in {time.time() - t0:.1f}s "
             f"({len(rows)} rows total)")


if __name__ == "__main__":
    main()
