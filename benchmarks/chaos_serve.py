"""§Faults — online serving under a fault storm: recovery-policy study.

The online_churn scenario (FM-class tenants forced apart by arrival
order, light M-class churn around them) is re-served under a curated
fault storm (`repro.sched.faults.FaultPlan`): a transient core loss that
repairs *degraded* (one fewer usable slot), a double slot-SEU, a
bitstream-cache flush, and a reconfiguration-port stall.  Three recovery
policies face the identical storm (same seed, same events, same shared
`ContentionModel`):

  * `none`         — stranded tenants stall until their core repairs;
  * `cold_restart` — stranded tenants evacuate, but every surviving
    core's caches are flushed on each fault epoch (restart-everything);
  * `warm`         — only stranded tenants move (destination picked
    through the contention model, degraded cores priced at their reduced
    width); surviving cores keep their warm slot/bitstream state.

Scored on *lifetime* slowdown: stranded epochs charge the denied service
(epoch_steps x solo CPI) as stall, so "park the tenant and wait" is
visible instead of free.  Acceptance (asserted): warm recovery's
worst-tenant lifetime slowdown <= cold_restart's and <= none's, with
bounded migrations; and a serve crash-restarted from a mid-run
`FleetState` checkpoint reproduces the uninterrupted serve bit-for-bit.

    PYTHONPATH=src python -m benchmarks.chaos_serve
"""
from __future__ import annotations

import time

from repro.sched import (ContentionModel, FaultEvent, FaultPlan,
                         OnlineConfig, OnlineReplacer, PlacementConfig,
                         TenantEvent)

PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=2_000,
                       trace_len=4_000, steps_per_program=4_000)
CFG = OnlineConfig(num_cores=3, epoch_steps=8_000, probe_steps=2_000,
                   placement=PCFG)
NUM_EPOCHS = 12

EVENTS = [
    TenantEvent(0, "arrive", "fgA", "minver"),
    TenantEvent(0, "arrive", "fgB", "cubic"),
    TenantEvent(0, "arrive", "m1", "qrduino"),
    TenantEvent(1, "arrive", "m2", "edn"),
    TenantEvent(1, "arrive", "m3", "crc32"),
    TenantEvent(2, "arrive", "m4", "tarfind"),
    TenantEvent(5, "depart", "m3"),
    TenantEvent(5, "arrive", "m5", "tarfind"),
]

# the storm: every fault kind fires once, after the roster settles.  The
# core loss is transient but repairs degraded (3 of 4 slots usable), so
# the masked-slot path and the width-aware contention pricing are both on
# the measured path.
FAULTS = FaultPlan(events=(
    FaultEvent(3, "core_loss", 1, repair_epochs=3, degraded_slots=1),
    FaultEvent(4, "slot_seu", 0, num_hit=2),
    FaultEvent(5, "bitstream_flush", 2),
    FaultEvent(6, "reconfig_stall", 0, stall_epochs=2),
), seed=7)

CHECKPOINT_EPOCH = 6      # crash-restart parity is checked from here


def _serve(model, recovery, *, snap_box=None):
    rep = OnlineReplacer(CFG, model=model, policy="warm", faults=FAULTS,
                         recovery=recovery)
    if snap_box is None:
        return rep.run(EVENTS, NUM_EPOCHS)
    return rep.run(EVENTS, NUM_EPOCHS,
                   checkpoint_every=CHECKPOINT_EPOCH,
                   save_fn=lambda s, e: snap_box.setdefault(e, s))


def _report_key(rep):
    """Everything the serve produced, as a comparable value."""
    return (rep.migrations, rep.evacuations, rep.per_tenant,
            rep.final_cores, rep.moves, rep.epoch_log, rep.fault_log,
            rep.worst_slowdown, rep.worst_lifetime_slowdown)


def run() -> tuple[list[str], dict]:
    model = ContentionModel(PCFG)
    rows = ["recovery,worst_lifetime_slowdown,worst_slowdown,"
            "migrations,evacuations,faults,retries"]
    out: dict = {}
    snaps: dict = {}
    for recovery in ("none", "cold_restart", "warm"):
        rep = _serve(model, recovery,
                     snap_box=snaps if recovery == "warm" else None)
        out[recovery] = rep
        retries = sum(1 for f in rep.fault_log
                      if f["kind"] == "reconfig_retry")
        wl = rep.worst_lifetime_slowdown
        rows.append(f"{recovery},{wl:.4f},{rep.worst_slowdown:.4f},"
                    f"{rep.migrations},{rep.evacuations},"
                    f"{len(FAULTS.events)},{retries}")
    warm = out["warm"]
    cold = out["cold_restart"]
    none = out["none"]
    # acceptance: warm-state-aware recovery beats both baselines on
    # worst-tenant lifetime slowdown under the identical storm, with
    # bounded migrations (evacuations are mandatory, not counted)
    assert warm.worst_lifetime_slowdown <= cold.worst_lifetime_slowdown \
        + 1e-9, (warm.worst_lifetime_slowdown,
                 cold.worst_lifetime_slowdown)
    assert warm.worst_lifetime_slowdown <= none.worst_lifetime_slowdown \
        + 1e-9, (warm.worst_lifetime_slowdown,
                 none.worst_lifetime_slowdown)
    assert warm.migrations <= CFG.max_moves_per_epoch * NUM_EPOCHS
    assert warm.evacuations >= 1, "the core loss must force an evacuation"

    # crash-restart: restore the mid-run checkpoint into a *fresh*
    # replacer (fresh ContentionModel too — nothing carries over) and
    # finish the serve; every report field must match bit-for-bit
    assert snaps, "the warm serve must have checkpointed"
    epoch, snap = sorted(snaps.items())[0]
    rep2 = OnlineReplacer(CFG, model=ContentionModel(PCFG),
                          policy="warm", faults=FAULTS, recovery="warm")
    rep2.restore(snap)
    resumed = rep2.run(EVENTS, NUM_EPOCHS)
    assert _report_key(resumed) == _report_key(warm), (
        "crash-restart diverged from the uninterrupted serve")
    rows.append(f"# crash-restart from epoch {epoch} checkpoint: "
                f"bit-for-bit match")

    evac = [f for f in warm.fault_log if f["kind"] == "evacuation"]
    rows.append(
        f"# finding warm-aware recovery: worst lifetime slowdown "
        f"{warm.worst_lifetime_slowdown:.4f} vs cold_restart "
        f"{cold.worst_lifetime_slowdown:.4f} and none "
        f"{none.worst_lifetime_slowdown:.4f} under the same "
        f"{len(FAULTS.events)}-event storm; {warm.evacuations} "
        f"evacuation(s) (max cold-resume "
        f"{max((f['cold_resume_cycles'] for f in evac), default=0):.0f} "
        f"cycles), {warm.migrations} migration(s); crash-restart from "
        f"epoch {epoch} reproduced the serve bit-for-bit")
    return rows, out


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# chaos_serve done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
