"""CI perf-regression gate over BENCH_fleet.json anchors.

Compares a freshly benchmarked `BENCH_fleet.json` against a baseline
artifact and fails when any gated module's `us_per_call` regressed by more
than `--max-slowdown` — so sweep-engine changes can't silently slow the
grid down.  Absolute wall-clock only compares meaningfully on the SAME
machine, so the baseline must be produced on the machine running the gate:
CI re-runs the smoke from the PR's base ref in a worktree (see
.github/workflows/ci.yml); locally, snapshot before re-benchmarking:

    cp BENCH_fleet.json /tmp/bench_baseline.json
    PYTHONPATH=src python -m benchmarks.run --only fig6
    python -m benchmarks.perf_gate --baseline /tmp/bench_baseline.json \\
        --modules fig6_single

`--modules` restricts the gate to entries actually re-benchmarked on both
sides (BENCH_fleet.json merges partial runs, so other entries are stale
carry-overs).  Modules below `--min-us` are skipped (timer noise), as are
modules present on only one side (new or retired benchmarks) and modules
whose two sides were recorded on different backends (entries carry
{backend, device, platform_version} provenance since PR 9 — a CPU
baseline must never gate a GPU run).

Exit code 0 = within budget, 1 = regression (CI fails the step).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_CURRENT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_fleet.json")


def compare(baseline: dict, current: dict, *, max_slowdown: float,
            min_us: float, modules=None) -> tuple[list[str], list[str]]:
    """Returns (report_rows, failures).  `modules` restricts the gate to the
    listed names (the ones actually re-benchmarked on both sides — stale
    carried-over entries must not be compared)."""
    rows, failures = [], []
    shared = sorted(set(baseline) & set(current))
    if modules is not None:
        shared = [n for n in shared if n in set(modules)]
        if not shared:
            # fail CLOSED: an allowlist that matches nothing means the gate
            # isn't gating anything (renamed module, missing rerun) — that
            # must surface as a failure, not a silent green
            failures.append(
                f"none of the allowlisted modules {sorted(set(modules))} "
                f"exist on both sides — gate is vacuous")
    for name in shared:
        base_be = baseline[name].get("backend")
        cur_be = current[name].get("backend")
        if base_be and cur_be and base_be != cur_be:
            # cross-backend wall-clock is not comparable; entries without
            # provenance (pre-PR-9 baselines) keep the old behaviour
            rows.append(f"{name}: skipped (baseline backend {base_be} != "
                        f"current {cur_be})")
            continue
        base_us = float(baseline[name].get("us_per_call", 0))
        cur_us = float(current[name].get("us_per_call", 0))
        if base_us < min_us or cur_us <= 0:
            rows.append(f"{name}: skipped (baseline {base_us:.0f}us below "
                        f"{min_us:.0f}us floor)")
            continue
        ratio = cur_us / base_us
        verdict = "OK" if ratio <= max_slowdown else "REGRESSION"
        rows.append(f"{name}: {base_us:.0f}us -> {cur_us:.0f}us "
                    f"({ratio:.2f}x) {verdict}")
        if ratio > max_slowdown:
            failures.append(
                f"{name} slowed {ratio:.2f}x (> {max_slowdown:.2f}x budget)")
    for name in sorted(set(current) - set(baseline)):
        rows.append(f"{name}: new module (no baseline), skipped")
    if not shared:
        rows.append("no shared modules between baseline and current — "
                    "nothing gated")
    return rows, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="previous-PR BENCH_fleet.json snapshot")
    ap.add_argument("--current", default=DEFAULT_CURRENT)
    ap.add_argument("--max-slowdown", type=float, default=1.25,
                    help="fail when us_per_call exceeds baseline by this "
                         "factor (default 1.25 = >25%% slower)")
    ap.add_argument("--min-us", type=float, default=100_000,
                    help="ignore modules whose baseline is below this "
                         "(timer noise)")
    ap.add_argument("--modules", default=None,
                    help="comma-separated module allowlist — gate only "
                         "entries re-benchmarked on both sides")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    rows, failures = compare(
        baseline, current, max_slowdown=args.max_slowdown,
        min_us=args.min_us,
        modules=args.modules.split(",") if args.modules else None)
    for r in rows:
        print(r)
    if failures:
        print("PERF GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    print(f"perf gate passed (budget {args.max_slowdown:.2f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
