"""§Perf — sweep-engine wall-clock: PR 2's two claims plus the PR-5
interleaved fast path, measured head-to-head.

1. **Stack-distance fast path vs the `lax.scan` path** on the Fig. 6 grid
   ({3 scenarios x 3 miss latencies x 5 FM benchmarks}, the paper's §V-D
   axis): the scan pays one 120k-step LRU state machine per {slot count x
   latency} lane, the fast path one Mattson pass per benchmark with the
   grid reconstructed affinely (`repro.core.stackdist`).  Both are run to
   completion and asserted bit-for-bit equal before timing is reported.

2. **Optimized preempted scan vs the PR-1 step** on a P=4 round-robin
   fleet: the PR-1 implementation (dependent double gather per step, two
   separate `slots.lookup` calls, no unroll) is frozen below as
   `_legacy_simulate_fleet` so the gather-hoist + fused-lookup win stays
   measurable after the live code moves on; a `scan_unroll` sweep records
   where unrolling pays on this backend.

3. **Interleaved fast path vs the optimized scan** on preempted
   fig6-style grids ({slot counts x miss latencies}, preempting quantum,
   P=2..4): the regime the serving stack lives in (placement search,
   online re-placement pricing), where the unpreempted engine cannot go —
   switch points are cost-dependent, so every cell replays its own
   interleaving at scheduler-window granularity
   (`repro.core.stackdist_interleaved`).  Parity is asserted bit-for-bit
   before timing; an `interleave_window` sweep records where the window
   knob pays on this backend.

4. **Stacked cold-bitstream pass vs the per-cell scan loop** on the
   bitstream_study grid ({capacity x penalty} on the FM benches): one
   `sweep_bitstream` call (`repro.core.stackdist_cold`) against one scan
   per cell — the loop `benchmarks/bitstream_study.py` used to run.

5. **Resumable interleaved engine vs the scan on state-seeded segments**:
   a preempted P=3 run split at the midpoint, its second half resumed
   from the materialised `FleetState` on both engines — the shape of
   every online-serving epoch advance and migration probe.

6. **Fused window-distance kernel vs the jnp window pass** (PR 9): the
   `window_kernel` section, delegated to `benchmarks/window_kernel.py` —
   one-shot sweep + resumed segment through `use_kernel="kernel"`
   (compiled Pallas on GPU/TPU, interpret mode on CPU, recorded as
   `kernel_mode` so the regimes are never conflated).

Emits machine-readable `BENCH_sweep.json` at the repo root so the perf
trajectory is tracked PR-over-PR, and a CSV under experiments/bench via
benchmarks.run.  The JSON is keyed per backend (``{"cpu": {...sections,
meta}, "gpu": {...}}``): a run replaces its own backend's section and
preserves the others, and every section's meta carries {backend, device,
platform_version}.  Standalone flags::

    PYTHONPATH=src python -m benchmarks.perf_sweep [--backend gpu]
    PYTHONPATH=src python -m benchmarks.perf_sweep [--interpret]
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.run import _backend_meta
from repro.core import isa, scheduler, simulator, slots, traces
from repro.kernels import window_distance

FIG6_TRACE_LEN = 120_000          # matches benchmarks/fig6_single.py
FIG6_LATENCIES = (10, 50, 250)
FIG6_SCENARIOS = (("s1", isa.SCENARIO_1), ("s2", isa.SCENARIO_2),
                  ("s3", isa.SCENARIO_3))

P4_FLEETS = 6
P4_TRACE_LEN = 30_000
P4_TOTAL_STEPS = 60_000
P4_QUANTUM = 20_000
# always include the live default so retuning SCAN_UNROLL keeps the sweep
# (and the optimized_s lookup below) well-defined
UNROLLS = tuple(sorted({1, 2, 4, 8, simulator.SCAN_UNROLL}))
REPS = 2

# BENCH_sweep.json lives at the repo root (not the cwd), next to
# BENCH_fleet.json, so the perf trajectory is diffable PR-over-PR
SWEEP_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_sweep.json")


def _best_of(fn, reps: int = REPS) -> float:
    """Compile/warm once, then best-of-`reps` wall-clock seconds."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# 1. fig6 grid: fast path vs scan path
# ---------------------------------------------------------------------------


def _fig6_grid(fleet, path: str):
    out = []
    for _, scen in FIG6_SCENARIOS:
        out.append(simulator.sweep_fleet(
            fleet, FIG6_LATENCIES, scen, simulator.SchedulerConfig.no_preempt(),
            slot_counts=(scen.num_slots,), total_steps=FIG6_TRACE_LEN,
            path=path))
    return out


def bench_fig6_grid() -> dict:
    fleet = np.stack([traces.build_trace(n, FIG6_TRACE_LEN)
                      for n in traces.FM_BENCHES])[:, None, :]
    # correctness first: the two engines must agree bit-for-bit
    for scan_r, fast_r in zip(_fig6_grid(fleet, "scan"),
                              _fig6_grid(fleet, "stackdist")):
        for a, b in zip(scan_r, fast_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    scan_s = _best_of(lambda: _fig6_grid(fleet, "scan"))
    fast_s = _best_of(lambda: _fig6_grid(fleet, "stackdist"))
    return {
        "grid": f"{len(FIG6_SCENARIOS)} scenarios x {len(FIG6_LATENCIES)} "
                f"latencies x {fleet.shape[0]} benches @ {FIG6_TRACE_LEN} steps",
        "scan_s": scan_s,
        "stackdist_s": fast_s,
        "speedup": scan_s / fast_s,
    }


# ---------------------------------------------------------------------------
# 2. preempted P=4 fleet: PR-1 step (frozen) vs optimized scan
# ---------------------------------------------------------------------------


def _legacy_simulate_fleet_impl(trs, tag_table, miss_latency, active_slots,
                                quantum, handler, num_slots: int,
                                bs_entries: int, bs_miss_extra,
                                total_steps: int):
    """The PR-1 fleet scan, frozen verbatim as the perf baseline: per-step
    dependent double gather (trace -> instr -> tag/hw) and two separate
    `slots.lookup` calls, unroll=1."""
    hw = jnp.asarray(isa.INSTR_HW_CYCLES, jnp.int32)
    tags = jnp.asarray(tag_table, jnp.int32)
    num_progs, trace_len = trs.shape

    def step(c, _):
        p = c["active"]
        ins = trs[p, jnp.remainder(c["cursors"][p], trace_len)]
        tag = tags[p, ins]
        res = slots.lookup(c["slot_st"], tag, active_slots)
        bs_res = slots.lookup(
            c["bs_st"], jnp.where(res.hit, jnp.int32(-1), tag))
        cost = hw[ins]
        cost = cost + jnp.where(res.hit, 0, miss_latency).astype(jnp.int32)
        cost = cost + jnp.where(res.hit | bs_res.hit, 0,
                                bs_miss_extra).astype(jnp.int32)
        q = c["q_cycles"] + cost
        do_switch = q >= quantum
        cost_p = cost + jnp.where(do_switch, handler, 0).astype(jnp.int32)
        return {
            "slot_st": res.state,
            "bs_st": bs_res.state,
            "cursors": c["cursors"].at[p].add(1),
            "active": jnp.where(do_switch, (p + 1) % num_progs, p),
            "q_cycles": jnp.where(do_switch, 0, q),
            "cycles": c["cycles"].at[p].add(cost_p),
            "instrs": c["instrs"].at[p].add(1),
            "misses": c["misses"].at[p].add((~res.hit).astype(jnp.int32)),
            "bs_misses": c["bs_misses"].at[p].add(
                (~(res.hit | bs_res.hit)).astype(jnp.int32)),
            "switches": c["switches"] + do_switch.astype(jnp.int32),
        }, None

    init = {
        "slot_st": slots.init(num_slots),
        "bs_st": slots.init(bs_entries),
        "cursors": jnp.zeros((num_progs,), jnp.int32),
        "active": jnp.int32(0),
        "q_cycles": jnp.int32(0),
        "cycles": jnp.zeros((num_progs,), jnp.int32),
        "instrs": jnp.zeros((num_progs,), jnp.int32),
        "misses": jnp.zeros((num_progs,), jnp.int32),
        "bs_misses": jnp.zeros((num_progs,), jnp.int32),
        "switches": jnp.int32(0),
    }
    final, _ = jax.lax.scan(step, init, None, length=total_steps)
    return simulator.FleetResult(
        final["cycles"], final["instrs"], final["misses"],
        final["bs_misses"], final["switches"])


@functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps"))
def _legacy_sweep(fleets, tag_table, miss_latencies, slot_counts, quantum,
                  handler, num_slots: int, bs_entries: int, bs_miss_extra,
                  total_steps: int):
    def one(t, s, lat):
        return _legacy_simulate_fleet_impl(
            t, tag_table, lat, s, quantum, handler, num_slots, bs_entries,
            bs_miss_extra, total_steps)

    f = jax.vmap(one, in_axes=(None, None, 0))
    f = jax.vmap(f, in_axes=(None, 0, None))
    f = jax.vmap(f, in_axes=(0, None, None))
    return f(fleets, slot_counts, miss_latencies)


def bench_p4_preempted() -> dict:
    tensor = jnp.asarray(scheduler.fleet_traces(
        scheduler.make_fleets(4)[:P4_FLEETS], P4_TRACE_LEN), jnp.int32)
    table = simulator.fleet_tag_table(isa.SCENARIO_2, 4)
    sched = simulator.SchedulerConfig(quantum_cycles=P4_QUANTUM)

    def legacy():
        return _legacy_sweep(
            tensor, table, jnp.asarray([50], jnp.int32),
            jnp.asarray([4], jnp.int32), jnp.int32(P4_QUANTUM),
            jnp.int32(sched.handler_cycles), 4, 64, jnp.int32(100),
            P4_TOTAL_STEPS)

    def optimized(unroll):
        return simulator.sweep_fleet(
            tensor, [50], isa.SCENARIO_2, sched, slot_counts=[4],
            total_steps=P4_TOTAL_STEPS, path="scan", scan_unroll=unroll)

    # the optimized step must reproduce the PR-1 numbers exactly
    np.testing.assert_array_equal(
        np.asarray(legacy().cycles),
        np.asarray(optimized(simulator.SCAN_UNROLL).cycles))

    legacy_s = _best_of(legacy)
    unroll_sweep = {str(u): _best_of(lambda u=u: optimized(u))
                    for u in UNROLLS}
    optimized_s = unroll_sweep[str(simulator.SCAN_UNROLL)]
    return {
        "grid": f"{P4_FLEETS} fleets x P=4 x {P4_TOTAL_STEPS} steps, "
                f"quantum {P4_QUANTUM}, 50c misses",
        "legacy_s": legacy_s,
        "optimized_s": optimized_s,
        "speedup": legacy_s / optimized_s,
        "default_unroll": simulator.SCAN_UNROLL,
        "unroll_sweep_s": unroll_sweep,
    }


# ---------------------------------------------------------------------------
# 3. preempted fig6-style grid: interleaved fast path vs optimized scan
# ---------------------------------------------------------------------------

PG_FLEETS = 3
PG_TRACE_LEN = 30_000
PG_TOTAL_STEPS = 60_000
PG_QUANTUM = 20_000           # preempting: the paper's Fig. 7 quantum
PG_SLOT_COUNTS = (2, 4, 8)
PG_LATENCIES = (10, 50, 250)
PG_PROGRAMS = (2, 3, 4)
# always include the live default so retuning INTERLEAVE_WINDOW keeps the
# sweep (and the interleaved_s lookup below) well-defined; 256/512/1024
# stay fixed so the recorded sweep is comparable across backends whose
# defaults differ (cpu retuned to 256 in PR 9, accelerators keep 512)
PG_WINDOWS = tuple(sorted({256, 512, 1024, simulator.INTERLEAVE_WINDOW}))


def bench_preempted_grid() -> dict:
    """Interleaved fast path vs optimized scan, P=2..4, preempting quanta.

    This is the grid the unpreempted engine can never serve (every {slot
    count x latency} cell has its own cost-dependent switch points); the
    acceptance bar for the interleaved engine is >= 5x over the optimized
    scan here, recorded per fleet size in BENCH_sweep.json.
    """
    sched = simulator.SchedulerConfig(quantum_cycles=PG_QUANTUM)
    out = {}
    for p in PG_PROGRAMS:
        tensor = scheduler.fleet_traces(
            scheduler.make_fleets(p)[:PG_FLEETS], PG_TRACE_LEN)

        def sweep(path, window=None, p=p, tensor=tensor):
            return simulator.sweep_fleet(
                tensor, PG_LATENCIES, isa.SCENARIO_2, sched,
                slot_counts=PG_SLOT_COUNTS, total_steps=PG_TOTAL_STEPS,
                path=path, interleave_window=window)

        # correctness first: the two engines must agree bit-for-bit
        scan_r, fast_r = sweep("scan"), sweep("interleaved")
        for a, b in zip(scan_r, fast_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        scan_s = _best_of(lambda: sweep("scan"))
        window_sweep = {str(w): _best_of(lambda w=w: sweep("interleaved", w))
                        for w in PG_WINDOWS}
        fast_s = window_sweep[str(simulator.INTERLEAVE_WINDOW)]
        out[f"p{p}"] = {
            "grid": f"{PG_FLEETS} fleets x P={p} x {PG_TOTAL_STEPS} steps, "
                    f"quantum {PG_QUANTUM}, {len(PG_SLOT_COUNTS)} slots x "
                    f"{len(PG_LATENCIES)} latencies",
            "scan_s": scan_s,
            "interleaved_s": fast_s,
            "speedup": scan_s / fast_s,
            "default_window": simulator.INTERLEAVE_WINDOW,
            "window_sweep_s": window_sweep,
        }
    return out


# ---------------------------------------------------------------------------
# 4. cold-bitstream grid: stacked Mattson pass vs per-cell scan loop
# ---------------------------------------------------------------------------

BS_TRACE_LEN = 20_000
BS_CAPACITIES = (2, 4, 8, 16)
BS_PENALTIES = (50, 250)


def bench_cold_bitstream() -> dict:
    """`benchmarks/bitstream_study.py`'s {capacity x penalty} grid: one
    stacked-pass `sweep_bitstream` call vs the per-cell scan loop it
    replaced.  The acceptance bar is >= 5x on this grid; parity is
    asserted bit-for-bit before timing."""
    trs = np.stack([traces.build_trace(n, BS_TRACE_LEN)
                    for n in traces.FM_BENCHES])
    kw = dict(slot_counts=[4], miss_latencies=[50],
              bs_entries=BS_CAPACITIES, bs_miss_extras=BS_PENALTIES,
              total_steps=BS_TRACE_LEN)

    def grid(path):
        return simulator.sweep_bitstream(trs, isa.SCENARIO_2, path=path,
                                         **kw)

    for a, b in zip(grid("scan"), grid("stackdist_cold")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    scan_s = _best_of(lambda: grid("scan"))
    fast_s = _best_of(lambda: grid("stackdist_cold"))
    return {
        "grid": f"{trs.shape[0]} benches x {len(BS_CAPACITIES)} capacities "
                f"x {len(BS_PENALTIES)} penalties @ {BS_TRACE_LEN} steps",
        "scan_s": scan_s,
        "stackdist_cold_s": fast_s,
        "speedup": scan_s / fast_s,
    }


# ---------------------------------------------------------------------------
# 5. resumed segments: resumable interleaved engine vs scan
# ---------------------------------------------------------------------------

RS_TRACE_LEN = 30_000
RS_TOTAL_STEPS = 60_000


def bench_resumed_segment() -> dict:
    """State-seeded resume (the online layer's epoch-advance shape): a
    preempted P=3 run split at the midpoint, the second half resumed from
    the materialised FleetState on both engines."""
    tensor = scheduler.fleet_traces(
        scheduler.make_fleets(3)[:1], RS_TRACE_LEN)[0]
    sched = simulator.SchedulerConfig(quantum_cycles=PG_QUANTUM)
    cfg = simulator.ReconfigConfig(num_slots=4, miss_latency=50)
    half = RS_TOTAL_STEPS // 2
    _, seed = simulator.simulate_many(tensor, cfg, isa.SCENARIO_2, sched,
                                      half, return_state=True)

    def segment(path):
        return simulator.simulate_many(tensor, cfg, isa.SCENARIO_2, sched,
                                       half, state=seed, return_state=True,
                                       path=path)

    # correctness first: results AND final states must agree bit-for-bit
    (scan_r, scan_st), (fast_r, fast_st) = segment("scan"), segment(
        "interleaved")
    for a, b in zip(scan_r, fast_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(scan_st),
                    jax.tree_util.tree_leaves(fast_st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    scan_s = _best_of(lambda: segment("scan"))
    fast_s = _best_of(lambda: segment("interleaved"))
    return {
        "grid": f"P=3 x {half} resumed steps, quantum {PG_QUANTUM}, "
                f"50c misses, mid-run FleetState seed",
        "scan_s": scan_s,
        "interleaved_resume_s": fast_s,
        "speedup": scan_s / fast_s,
    }


# ---------------------------------------------------------------------------


def _merge_per_backend(report: dict) -> dict:
    """BENCH_sweep.json is keyed per backend: this run replaces its own
    backend's section and preserves the others (a legacy flat layout —
    sections at the top level — is migrated under its meta backend)."""
    existing: dict = {}
    if os.path.exists(SWEEP_JSON):
        try:
            with open(SWEEP_JSON) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = {}
    if "meta" in existing:            # legacy single-backend flat layout
        existing = {existing["meta"].get("backend", "cpu"): existing}
    existing[report["meta"]["backend"]] = report
    return existing


def run() -> tuple[list[str], dict]:
    from benchmarks import window_kernel
    report = {
        "fig6_grid": bench_fig6_grid(),
        "p4_preempted": bench_p4_preempted(),
        "preempted_grid": bench_preempted_grid(),
        "cold_bitstream": bench_cold_bitstream(),
        "resumed_segment": bench_resumed_segment(),
        "window_kernel": window_kernel.bench_kernel_vs_jnp(),
        "meta": {
            **_backend_meta(),
            "machine": platform.machine(),
            "reps": REPS,
        },
    }
    with open(SWEEP_JSON, "w") as f:
        json.dump(_merge_per_backend(report), f, indent=2)
    g, p = report["fig6_grid"], report["p4_preempted"]
    pg = report["preempted_grid"]
    rows = [
        "section,variant,seconds,speedup",
        f"fig6_grid,scan,{g['scan_s']:.3f},1.00x",
        f"fig6_grid,stackdist,{g['stackdist_s']:.3f},{g['speedup']:.1f}x",
        f"p4_preempted,legacy_pr1,{p['legacy_s']:.3f},1.00x",
        f"p4_preempted,optimized,{p['optimized_s']:.3f},{p['speedup']:.2f}x",
    ]
    rows += [f"p4_preempted,unroll={u},{s:.3f},-"
             for u, s in p["unroll_sweep_s"].items()]
    for key in sorted(pg):
        e = pg[key]
        rows += [
            f"preempted_grid_{key},scan,{e['scan_s']:.3f},1.00x",
            f"preempted_grid_{key},interleaved,{e['interleaved_s']:.3f},"
            f"{e['speedup']:.1f}x",
        ]
        rows += [f"preempted_grid_{key},window={w},{s:.3f},-"
                 for w, s in e["window_sweep_s"].items()]
    cb, rs = report["cold_bitstream"], report["resumed_segment"]
    wk = report["window_kernel"]
    rows += [
        f"cold_bitstream,scan,{cb['scan_s']:.3f},1.00x",
        f"cold_bitstream,stackdist_cold,{cb['stackdist_cold_s']:.3f},"
        f"{cb['speedup']:.1f}x",
        f"resumed_segment,scan,{rs['scan_s']:.3f},1.00x",
        f"resumed_segment,interleaved,{rs['interleaved_resume_s']:.3f},"
        f"{rs['speedup']:.1f}x",
        f"window_kernel,jnp,{wk['jnp_s']:.3f},1.00x",
        f"window_kernel,kernel[{wk['kernel_mode']}],{wk['kernel_s']:.3f},"
        f"{wk['speedup']:.2f}x",
    ]
    worst = min(e["speedup"] for e in pg.values())
    rows.append(f"# fast path {g['speedup']:.1f}x on the fig6 grid; "
                f"optimized scan {p['speedup']:.2f}x on the preempted P=4 "
                f"fleet; interleaved >= {worst:.1f}x on the preempted "
                f"fig6-style grids; stacked cold-bitstream "
                f"{cb['speedup']:.1f}x on the bitstream_study grid; "
                f"resumed segments {rs['speedup']:.1f}x; window kernel "
                f"[{wk['kernel_mode']}] {wk['speedup']:.2f}x vs jnp; "
                "BENCH_sweep.json written "
                f"[{report['meta']['backend']}]")
    return rows, report


def main(print_fn=print, argv=None):
    ap = argparse.ArgumentParser(description="sweep-engine wall-clock")
    ap.add_argument("--backend", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="select the jax backend before any computation "
                         "runs (the recorded section is keyed by it)")
    ap.add_argument("--interpret", action="store_true",
                    help="force the window-distance kernel parity path "
                         "(use_kernel session default -> 'interpret')")
    args = ap.parse_args(argv if argv is not None else [])
    if args.backend:
        # jax is imported but no backend is initialised until the first
        # computation, so the platform choice still lands
        os.environ["JAX_PLATFORMS"] = args.backend
        jax.config.update("jax_platforms", args.backend)
    if args.interpret:
        os.environ["REPRO_WINDOW_KERNEL"] = "interpret"
        window_distance.set_default_mode("interpret")
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# perf_sweep done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    import sys
    main(argv=sys.argv[1:])

