"""Fig. 6 — single-benchmark reconfigurable core: 3 slot-granularity
scenarios x {10, 50, 250}-cycle miss latencies, on the 5 FM-class
benchmarks, as speedup relative to fixed RV32IMF (plus the max(IM, IF)
fixed-extension reference series).

Runs through `simulator.sweep_fleet` as P=1 fleets with a quantum no run
can reach (a single program is never preempted), so the whole
{5 benchmarks x 3 latencies} grid per scenario is one call — the same
machinery as the Fig. 7 multi-program sweeps.  Being unpreempted with a
warm bitstream cache, the grid is eligible for the stack-distance fast
path: the dispatcher serves every {slot count x latency} cell from one
Mattson pass per benchmark (see `repro.core.stackdist`), bit-for-bit equal
to the scan (tests/test_stackdist.py pins the parity and the paper
anchors).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import isa, simulator, traces

LATENCIES = (10, 50, 250)
SCENARIOS = (("s1", isa.SCENARIO_1), ("s2", isa.SCENARIO_2),
             ("s3", isa.SCENARIO_3))
TRACE_LEN = 120_000
# single program, never preempted
NO_PREEMPT = simulator.SchedulerConfig.no_preempt()


def run() -> tuple[list[str], dict]:
    rows = ["benchmark,series,latency,speedup_vs_IMF"]
    agg: dict = {}
    fleet = np.stack([traces.build_trace(n, TRACE_LEN)
                      for n in traces.FM_BENCHES])[:, None, :]  # (5, 1, N)
    imf = {n: simulator.analytic_cpi(traces.mix_of(n), isa.RV32IMF)
           for n in traces.FM_BENCHES}
    per_scen = {}
    for sname, scen in SCENARIOS:
        res = simulator.sweep_fleet(
            fleet, LATENCIES, scen, NO_PREEMPT,
            slot_counts=(scen.num_slots,), total_steps=TRACE_LEN)
        per_scen[sname] = np.asarray(res.cpi)   # (5, 1, L, 1)
    for bi, name in enumerate(traces.FM_BENCHES):
        mix = traces.mix_of(name)
        best_fixed = max(
            imf[name] / simulator.analytic_cpi(mix, isa.RV32IM),
            imf[name] / simulator.analytic_cpi(mix, isa.RV32IF))
        rows.append(f"{name},max(IM;IF),-,{best_fixed:.3f}")
        for sname, _ in SCENARIOS:
            for li, lat in enumerate(LATENCIES):
                sp = imf[name] / float(per_scen[sname][bi, 0, li, 0])
                rows.append(f"{name},{sname},{lat},{sp:.3f}")
                agg.setdefault((sname, lat), []).append(sp)
    for (sname, lat), vals in sorted(agg.items()):
        rows.append(f"AVERAGE,{sname},{lat},{np.mean(vals):.3f}")
    rows.append("# paper anchors: s1@10>0.90, s2@10>0.90, s2@50~0.71, "
                "s3@10~0.55 (worst), s1@250~0.52")
    return rows, agg


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for row in rows:
        print_fn(row)
    print_fn(f"# fig6 done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
