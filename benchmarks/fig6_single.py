"""Fig. 6 — single-benchmark reconfigurable core: 3 slot-granularity
scenarios x {10, 50, 250}-cycle miss latencies, on the 5 FM-class
benchmarks, as speedup relative to fixed RV32IMF (plus the max(IM, IF)
fixed-extension reference series).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import isa, simulator, traces

LATENCIES = (10, 50, 250)
SCENARIOS = (("s1", isa.SCENARIO_1), ("s2", isa.SCENARIO_2),
             ("s3", isa.SCENARIO_3))
TRACE_LEN = 120_000


def run() -> tuple[list[str], dict]:
    rows = ["benchmark,series,latency,speedup_vs_IMF"]
    agg: dict = {}
    for name in traces.FM_BENCHES:
        trace = traces.build_trace(name, TRACE_LEN)
        mix = traces.mix_of(name)
        imf = simulator.analytic_cpi(mix, isa.RV32IMF)
        best_fixed = max(
            imf / simulator.analytic_cpi(mix, isa.RV32IM),
            imf / simulator.analytic_cpi(mix, isa.RV32IF))
        rows.append(f"{name},max(IM;IF),-,{best_fixed:.3f}")
        for sname, scen in SCENARIOS:
            res = simulator.simulate_single_batch(
                np.stack([trace] * len(LATENCIES)),
                np.asarray(LATENCIES),
                simulator.ReconfigConfig(num_slots=scen.num_slots,
                                         miss_latency=0),
                scen)
            for lat, cpi in zip(LATENCIES, np.asarray(res.cpi)):
                sp = imf / float(cpi)
                rows.append(f"{name},{sname},{lat},{sp:.3f}")
                agg.setdefault((sname, lat), []).append(sp)
    for (sname, lat), vals in sorted(agg.items()):
        rows.append(f"AVERAGE,{sname},{lat},{np.mean(vals):.3f}")
    rows.append("# paper anchors: s1@10>0.90, s2@10>0.90, s2@50~0.71, "
                "s3@10~0.55 (worst), s1@250~0.52")
    return rows, agg


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for row in rows:
        print_fn(row)
    print_fn(f"# fig6 done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
