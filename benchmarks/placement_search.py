"""§Perf — placement-search wall-clock anchor (the serving stack's hot loop).

`place_tenants` (greedy seeding + swap local search) prices every candidate
co-residency group through `ContentionModel` -> `sweep_fleet`; since PR 5
those one-shot preempted warm-cache sweeps ride the interleave-aware
stack-distance engine (`repro.core.stackdist_interleaved`) instead of the
cycle-by-cycle scan, which is where the search spends its time.  This
module times one full search on a fixed 6-tenant roster so the CI perf
gate (`benchmarks/perf_gate.py`, fig6-smoke allowlist) covers the new
path: a regression on the interleaved engine shows up here as a slower
search.

Timed twice: a cold process-first search (jit compiles included) and the
steady-state search (fresh model, warm jit caches — what a serving epoch
loop actually pays per re-solve).  Registered in benchmarks/run.py ->
BENCH_fleet.json.
"""
from __future__ import annotations

import time

from repro.sched import ContentionModel, PlacementConfig, place_tenants

# fixed roster: four FM-class tenants (slot-hungry) + two M-class, three
# cores — big enough that greedy + swap explores a real candidate set,
# small enough for a CI smoke step
TENANTS = {
    "t-minver": "minver", "t-nbody": "nbody", "t-cubic": "cubic",
    "t-st": "st", "t-crc32": "crc32", "t-tarfind": "tarfind",
}
NUM_CORES = 3
CFG = PlacementConfig(quantum_cycles=2_000, trace_len=8_000,
                      steps_per_program=10_000)


def _search():
    model = ContentionModel(CFG)
    placed = place_tenants(TENANTS, NUM_CORES, model)
    return model, placed


def run() -> tuple[list[str], dict]:
    t0 = time.perf_counter()
    model, placed = _search()
    cold_s = time.perf_counter() - t0

    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        model, placed = _search()
        best = min(best, time.perf_counter() - t0)

    report = {
        "roster": f"{len(TENANTS)} tenants / {NUM_CORES} cores, quantum "
                  f"{CFG.quantum_cycles}, {CFG.steps_per_program} "
                  "steps/program",
        "cold_search_s": cold_s,
        "search_s": best,
        "sim_calls": model.sim_calls,
        "groups_simulated": model.groups_simulated,
        "worst_slowdown": placed.worst_slowdown,
        "mean_slowdown": placed.mean_slowdown,
        "cores": [list(c) for c in placed.cores],
    }
    rows = [
        "metric,value",
        f"cold_search_s,{cold_s:.3f}",
        f"search_s,{best:.3f}",
        f"sim_calls,{model.sim_calls}",
        f"groups_simulated,{model.groups_simulated}",
        f"worst_slowdown,{placed.worst_slowdown:.4f}",
        f"# finding: steady-state placement search {best:.3f}s "
        f"({model.groups_simulated} groups priced through the interleaved "
        f"fast path), worst-tenant slowdown {placed.worst_slowdown:.4f}",
    ]
    return rows, report


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# placement_search done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
