"""§Fleet scale — incremental vs full per-epoch re-solve at datacenter size.

The ROADMAP's north-star is ~1000 tenants on a fleet of reconfigurable
cores; the flat-pool `place_tenants` re-solve is O(T^2) swap search over
the whole fleet every epoch, which is hopeless there.  The topology layer
(`repro.sched.topology`) splits the fleet into per-host placement domains
and the `OnlineReplacer`'s incremental mode re-solves only domains dirtied
by arrivals/departures/faults/applied moves since the last epoch — a
quiet host costs nothing.

This study serves the same deterministic churn stream at 2–3 fleet sizes
(constant tenant density, growing host count) twice per size — once with
`resolve_mode="full"` (every domain, every epoch) and once with
`resolve_mode="incremental"` — and asserts:

  * **bit-for-bit parity**: final cores, the complete move log, the epoch
    log and the migration count are identical between the two modes (the
    incremental cache is pure memoisation of a deterministic solve);
  * **sublinearity**: steady-state (post-ramp) re-solve seconds grow
    strictly slower than fleet size for the incremental mode, and slower
    than the full mode's growth — churn touches O(churn) hosts per epoch
    regardless of how many hosts the fleet has.

The full run serves >= 1000 tenants on >= 128 cores (32 hosts x 2 sockets
x 2 cores).  ``REPRO_FLEET_SCALE=smoke`` serves one reduced size
(64 tenants / 16 cores) and checks parity only — the CI-sized vehicle;
timing asserts need the real sizes.

    PYTHONPATH=src python -m benchmarks.fleet_scale_study
    REPRO_FLEET_SCALE=smoke PYTHONPATH=src python -m benchmarks.fleet_scale_study
"""
from __future__ import annotations

import os
import time

from repro.sched import (ContentionModel, OnlineConfig, OnlineReplacer,
                         PlacementConfig, TenantEvent, Topology)

# small simulator geometry: the study measures *re-solve* scaling, so the
# per-group simulations just need to be cheap and cacheable (4 profiles
# bound the distinct-group space; every group simulates once, then every
# later predict is a cache hit)
PCFG = PlacementConfig(num_slots=4, miss_latency=50, quantum_cycles=512,
                       trace_len=768, steps_per_program=768)
PROFILES = ("minver", "cubic", "qrduino", "crc32")

RAMP_EPOCHS = 2          # arrivals spread over epochs [0, RAMP_EPOCHS)
CHURN_START = 2          # steady-state churn (and timing) begins here
NUM_EPOCHS = 6
CHURN_K = 4              # departures + replacements per churn epoch

# (label, tenants, topology) — constant ~8 tenants/core density so the
# per-host solve cost is flat and only the host count grows
FULL_SIZES = [
    ("256t_32c", 256, Topology(num_hosts=8, sockets_per_host=2,
                               cores_per_socket=2)),
    ("512t_64c", 512, Topology(num_hosts=16, sockets_per_host=2,
                               cores_per_socket=2)),
    ("1000t_128c", 1000, Topology(num_hosts=32, sockets_per_host=2,
                                  cores_per_socket=2)),
]
SMOKE_SIZES = [
    ("64t_16c", 64, Topology(num_hosts=4, sockets_per_host=2,
                             cores_per_socket=2)),
]


def _events(num_tenants: int) -> list[TenantEvent]:
    """Deterministic churn stream: a ramp of `num_tenants` arrivals, then
    CHURN_K departure+replacement pairs per steady epoch (spread across
    the roster by a fixed stride — no RNG, so every size/mode serves an
    exactly reproducible stream)."""
    ev = [TenantEvent(i % RAMP_EPOCHS, "arrive", f"t{i:04d}",
                      PROFILES[i % len(PROFILES)])
          for i in range(num_tenants)]
    gone: set[str] = set()
    nxt = 0
    for epoch in range(CHURN_START, NUM_EPOCHS - 1):
        for j in range(CHURN_K):
            v = (epoch * 131 + j * 37) % num_tenants
            while f"t{v:04d}" in gone:
                v = (v + 1) % num_tenants
            gone.add(f"t{v:04d}")
            ev.append(TenantEvent(epoch, "depart", f"t{v:04d}"))
            ev.append(TenantEvent(epoch, "arrive", f"n{nxt:04d}",
                                  PROFILES[nxt % len(PROFILES)]))
            nxt += 1
    return ev


def _serve(model: ContentionModel, topo: Topology, events, mode: str):
    cfg = OnlineConfig(topology=topo, epoch_steps=1_024, probe_steps=512,
                       placement=PCFG)
    rep = OnlineReplacer(cfg, model=model, policy="warm",
                         resolve_mode=mode)
    report = rep.run(events, NUM_EPOCHS)
    steady = [r for r in rep.resolve_log if r["epoch"] >= CHURN_START]
    return report, sum(r["seconds"] for r in steady), steady


def run() -> tuple[list[str], dict]:
    smoke = os.environ.get("REPRO_FLEET_SCALE", "") == "smoke"
    sizes = SMOKE_SIZES if smoke else FULL_SIZES
    rows = ["fleet,tenants,cores,hosts,mode,steady_resolve_s,"
            "solved_domains,cached_domains,migrations"]
    out: dict = {}
    inc_s, full_s, tenants_n = [], [], []
    for label, num_tenants, topo in sizes:
        events = _events(num_tenants)
        # one shared model per size: both modes see identical (cached)
        # predictions, so the timing difference is solve machinery, and
        # the full mode runs first so it pays any residual cache misses
        # (a handicap for the mode we claim is slower — conservative)
        model = ContentionModel(PCFG)
        rep_full, t_full, log_full = _serve(model, topo, events, "full")
        rep_inc, t_inc, log_inc = _serve(model, topo, events,
                                         "incremental")
        # --- bit-for-bit parity: same placements, same move log -------
        assert rep_inc.final_cores == rep_full.final_cores, label
        assert rep_inc.moves == rep_full.moves, label
        assert rep_inc.epoch_log == rep_full.epoch_log, label
        assert rep_inc.migrations == rep_full.migrations, label
        assert rep_inc.per_tenant == rep_full.per_tenant, label
        solved = sum(r["solved"] for r in log_inc)
        cached = sum(r["cached"] for r in log_inc)
        # churn touches O(CHURN_K) hosts/epoch: incremental must actually
        # skip domains in steady state (otherwise it is full with hats on)
        assert cached > 0, (label, log_inc)
        for mode, t, lg, rep in (("full", t_full, log_full, rep_full),
                                 ("incremental", t_inc, log_inc, rep_inc)):
            s = sum(r["solved"] for r in lg)
            c = sum(r["cached"] for r in lg)
            rows.append(f"{label},{num_tenants},{topo.num_cores},"
                        f"{topo.num_hosts},{mode},{t:.4f},{s},{c},"
                        f"{rep.migrations}")
        out[label] = {"full": rep_full, "incremental": rep_inc,
                      "t_full": t_full, "t_inc": t_inc}
        inc_s.append(t_inc)
        full_s.append(t_full)
        tenants_n.append(num_tenants)
    if not smoke:
        # --- sublinearity across fleet sizes --------------------------
        t_ratio = tenants_n[-1] / tenants_n[0]
        inc_ratio = inc_s[-1] / max(inc_s[0], 1e-9)
        full_ratio = full_s[-1] / max(full_s[0], 1e-9)
        assert inc_s[-1] < full_s[-1], (
            f"incremental steady re-solve ({inc_s[-1]:.4f}s) not faster "
            f"than full ({full_s[-1]:.4f}s) at the largest fleet")
        assert inc_ratio < t_ratio, (
            f"incremental re-solve grew {inc_ratio:.2f}x over a "
            f"{t_ratio:.2f}x fleet-size increase — not sublinear")
        assert inc_ratio < full_ratio, (
            f"incremental growth ({inc_ratio:.2f}x) not below full "
            f"re-solve growth ({full_ratio:.2f}x)")
        rows.append(
            f"# finding fleet-scale incremental re-solve: "
            f"{tenants_n[-1]} tenants / "
            f"{sizes[-1][2].num_cores} cores steady re-solve "
            f"{inc_s[-1]:.3f}s incremental vs {full_s[-1]:.3f}s full; "
            f"growth over {t_ratio:.1f}x fleet: {inc_ratio:.2f}x "
            f"incremental vs {full_ratio:.2f}x full (sublinear); "
            f"placements and move logs bit-identical in both modes at "
            f"{len(sizes)} sizes")
    else:
        label, num_tenants, topo = sizes[0]
        rows.append(
            f"# finding fleet-scale smoke: {num_tenants} tenants / "
            f"{topo.num_cores} cores incremental == full bit-for-bit "
            f"(steady re-solve {inc_s[0]:.3f}s vs {full_s[0]:.3f}s)")
    return rows, out


def main(print_fn=print):
    t0 = time.time()
    rows, _ = run()
    for r in rows:
        print_fn(r)
    print_fn(f"# fleet_scale_study done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
