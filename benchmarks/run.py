"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark module),
writes each module's full output under experiments/bench/, and records the
same {us_per_call, derived} per module in ``BENCH_fleet.json`` at the repo
root — the machine-readable perf trajectory CI uploads per PR.  Partial
runs (``--only``) merge into the existing JSON instead of clobbering it.

    PYTHONPATH=src python -m benchmarks.run [--only fig6]
    PYTHONPATH=src python -m benchmarks.run [--only fig6,placement_search]
    PYTHONPATH=src python -m benchmarks.run --list   # names --only matches
    PYTHONPATH=src python -m benchmarks.run --backend gpu   # JAX_PLATFORMS
    PYTHONPATH=src python -m benchmarks.run --interpret     # kernel parity

Every recorded entry carries {backend, device, platform_version}
provenance so numbers from different backends are never conflated (the
perf gate only compares same-backend entries).
"""
from __future__ import annotations

import argparse
import json
import os
import time

# anchored to the repo root (not the cwd) so partial runs always merge into
# the same file CI uploads
FLEET_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_fleet.json")


def _backend_meta() -> dict:
    """{backend, device, platform_version} provenance stamped into every
    recorded entry.  Imports jax lazily so `--backend` can set
    JAX_PLATFORMS before the backend is chosen; CPU devices carry no
    platform_version attribute, so the jax version stands in."""
    import jax
    dev = jax.devices()[0]
    version = getattr(dev, "platform_version", "") or f"jax-{jax.__version__}"
    return {"backend": jax.default_backend(), "device": str(dev),
            "platform_version": " ".join(str(version).split())}


def _capture(mod_main):
    lines: list[str] = []
    mod_main(print_fn=lines.append)
    return lines


def bench_fig4():
    from benchmarks import fig4_extensions
    lines = _capture(fig4_extensions.main)
    minver = [l for l in lines if l.startswith("minver,")][0].split(",")
    return lines, f"minver_speedup_F={minver[6]} (paper 27.5)"


def bench_fig5():
    from benchmarks import fig5_classification
    lines = _capture(fig5_classification.main)
    return lines, [l for l in lines if l.startswith("# classes")][0][2:]


def bench_fig6():
    from benchmarks import fig6_single
    lines = _capture(fig6_single.main)
    s2_50 = [l for l in lines if l.startswith("AVERAGE,s2,50")][0]
    return lines, f"avg_s2@50c={s2_50.split(',')[-1]} (paper ~0.71)"


def bench_fig7():
    from benchmarks import fig7_multi
    lines, _ = fig7_multi.run()   # full rows (main() prints only the tail)
    head = [l for l in lines if l.startswith("# 4slot@20K")][0]
    return lines, head[2:]


def bench_fleet_sweep():
    """Beyond-paper P=4 fleet sweep (one jitted sweep_fleet call)."""
    from benchmarks import fig7_multi
    lines, agg = fig7_multi.run_fleets()
    import numpy as np
    derived = "; ".join(f"P4_avg@{lat}c={np.mean(v):.3f}"
                        for lat, v in sorted(agg.items()))
    return lines, derived


def bench_expert_slots():
    from benchmarks import bench_expert_slots as mod
    lines = _capture(mod.main)
    return lines, lines[1] if len(lines) > 1 else ""


def bench_bitstream_study():
    from benchmarks import bitstream_study
    lines = _capture(bitstream_study.main)
    return lines, [l for l in lines if l.startswith("# finding")][0][2:]


def bench_perf_slot_decode():
    from benchmarks import perf_slot_decode
    lines = _capture(perf_slot_decode.main)
    best = [l for l in lines if l.startswith("slots,2,4.0")]
    return lines, (best[0] if best else "")


def bench_roofline():
    from benchmarks import roofline_table
    lines = _capture(roofline_table.main)
    return lines, f"{len(lines) - 1} dry-run cells tabulated"


def bench_perf_sweep():
    """Sweep-engine wall-clock: stack-distance vs scan (+ BENCH_sweep.json)."""
    from benchmarks import perf_sweep
    lines, _ = perf_sweep.run()
    head = [l for l in lines if l.startswith("# fast path")][0]
    return lines, head[2:]


def bench_placement_study():
    """Contention-aware placement vs random/FIFO co-residency (repro.sched)."""
    from benchmarks import placement_study
    lines, _ = placement_study.run()
    head = [l for l in lines if l.startswith("# finding")][0]
    return lines, head[2:]


def bench_placement_search():
    """Placement-search timing anchor (rides the interleaved fast path)."""
    from benchmarks import placement_search
    lines, _ = placement_search.run()
    head = [l for l in lines if l.startswith("# finding")][0]
    return lines, head[2:]


def bench_online_churn():
    """Warm-state-aware online re-placement vs never/always baselines."""
    from benchmarks import online_churn
    lines, _ = online_churn.run()
    head = [l for l in lines if l.startswith("# finding")][0]
    return lines, head[2:]


def bench_chaos_serve():
    """Online serving under a fault storm: recovery-policy comparison."""
    from benchmarks import chaos_serve
    lines, _ = chaos_serve.run()
    head = [l for l in lines if l.startswith("# finding")][0]
    return lines, head[2:]


def bench_model_serve_study():
    """Model-zoo fleets (prefill/decode workloads) through place_tenants."""
    from benchmarks import model_serve_study
    lines, _ = model_serve_study.run()
    head = [l for l in lines if l.startswith("# finding")][0]
    return lines, head[2:]


def bench_fleet_scale_study():
    """Incremental vs full per-epoch re-solve at datacenter fleet sizes."""
    from benchmarks import fleet_scale_study
    lines, _ = fleet_scale_study.run()
    head = [l for l in lines if l.startswith("# finding")][0]
    return lines, head[2:]


def bench_window_kernel():
    """Fused window-distance kernel vs the jnp window pass (parity first)."""
    from benchmarks import window_kernel
    lines, _ = window_kernel.run()
    head = [l for l in lines if l.startswith("# finding")][0]
    return lines, head[2:]


BENCHES = {
    "fig4_extensions": bench_fig4,
    "fig5_classification": bench_fig5,
    "fig6_single": bench_fig6,
    "fig7_multi": bench_fig7,
    "fleet_sweep": bench_fleet_sweep,
    "expert_slots": bench_expert_slots,
    "bitstream_study": bench_bitstream_study,
    "perf_slot_decode": bench_perf_slot_decode,
    "roofline_table": bench_roofline,
    "perf_sweep": bench_perf_sweep,
    "placement_study": bench_placement_study,
    "placement_search": bench_placement_search,
    "online_churn": bench_online_churn,
    "chaos_serve": bench_chaos_serve,
    "model_serve_study": bench_model_serve_study,
    "fleet_scale_study": bench_fleet_scale_study,
    "window_kernel": bench_window_kernel,
}

# registration audit: every benchmark module in this directory must either
# back a BENCHES entry or be listed here with the reason it is excluded.
# `audit_registration()` enforces the invariant (tests call it), so a new
# module that forgets both shows up as a test failure, not a silent orphan.
MODULE_OF = {
    "fig4_extensions": "fig4_extensions",
    "fig5_classification": "fig5_classification",
    "fig6_single": "fig6_single",
    "fig7_multi": "fig7_multi",
    "fleet_sweep": "fig7_multi",            # second entry point (run_fleets)
    "expert_slots": "bench_expert_slots",
    "bitstream_study": "bitstream_study",
    "perf_slot_decode": "perf_slot_decode",
    "roofline_table": "roofline_table",
    "perf_sweep": "perf_sweep",
    "placement_study": "placement_study",
    "placement_search": "placement_search",
    "online_churn": "online_churn",
    "chaos_serve": "chaos_serve",
    "model_serve_study": "model_serve_study",
    "fleet_scale_study": "fleet_scale_study",
    "window_kernel": "window_kernel",
}
EXCLUDED = {
    "run": "the harness itself",
    "perf_gate": "CI gate comparing BENCH_fleet.json across refs, "
                 "not a benchmark",
}


def audit_registration() -> None:
    """Raise if any benchmarks/*.py module is neither registered (MODULE_OF)
    nor explicitly excluded (EXCLUDED), or if either map is stale."""
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    modules = {os.path.splitext(f)[0] for f in os.listdir(bench_dir)
               if f.endswith(".py") and not f.startswith("_")}
    missing_map = set(BENCHES) - set(MODULE_OF)
    registered = set(MODULE_OF.values())
    orphans = modules - registered - set(EXCLUDED)
    stale = (registered | set(EXCLUDED)) - modules
    if missing_map or orphans or stale:
        raise AssertionError(
            f"benchmark registration audit failed: "
            f"BENCHES entries missing from MODULE_OF={sorted(missing_map)}, "
            f"orphan modules={sorted(orphans)}, "
            f"stale references={sorted(stale)}")


PROVENANCE_KEYS = ("backend", "device", "platform_version")


def _record_fleet_json(results: dict, path: str = FLEET_JSON) -> None:
    """Merge this run's {bench: {us_per_call, derived}} into BENCH_fleet.json
    at the repo root, preserving entries for modules not run this time.

    Preserved entries must carry {backend, device, platform_version}
    provenance.  A legacy entry written before the per-backend keying
    migration has none — merging it forward would hand the perf gate a
    number it cannot attribute to a backend and would happily compare
    same-backend, so legacy entries are dropped (the next full run
    re-records them with provenance), and the merged result is asserted
    clean before it is written."""
    existing: dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = {}
    dropped = [name for name, entry in existing.items()
               if name not in results
               and any(k not in entry for k in PROVENANCE_KEYS)]
    for name in dropped:
        print(f"# dropping provenance-free legacy entry {name!r} from "
              f"{os.path.basename(path)} (re-run it to re-record)")
        del existing[name]
    existing.update(results)
    bad = sorted(name for name, entry in existing.items()
                 if any(k not in entry for k in PROVENANCE_KEYS))
    assert not bad, (
        f"entries {bad} lack {PROVENANCE_KEYS} provenance after merge")
    with open(path, "w") as f:
        json.dump(existing, f, indent=2)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings; a module runs when "
                         "any of them matches its name")
    ap.add_argument("--list", action="store_true",
                    help="print the registered module names (the values "
                         "--only matches against) and exit")
    ap.add_argument("--out", default="experiments/bench")
    ap.add_argument("--backend", default=None,
                    choices=("cpu", "gpu", "tpu"),
                    help="set JAX_PLATFORMS before any benchmark imports "
                         "jax (entries are stamped with the backend that "
                         "actually ran)")
    ap.add_argument("--interpret", action="store_true",
                    help="force the window-distance kernel parity path "
                         "(REPRO_WINDOW_KERNEL=interpret) — a correctness "
                         "vehicle, not a fast path")
    args = ap.parse_args(argv)
    if args.list:
        for name in BENCHES:
            print(name)
        return
    # env, not jax.config: benchmark modules import jax lazily inside the
    # bench functions, so nothing has initialised a backend yet
    if args.backend:
        os.environ["JAX_PLATFORMS"] = args.backend
    if args.interpret:
        os.environ["REPRO_WINDOW_KERNEL"] = "interpret"
    only = [s for s in (args.only or "").split(",") if s]
    # a substring matching nothing is a typo, not an empty run: silently
    # running zero modules and exiting 0 once masked a dead perf gate
    dead = [s for s in only if not any(s in name for name in BENCHES)]
    if dead:
        ap.error(
            f"--only substring(s) {dead} match no registered module; "
            f"valid names: {', '.join(BENCHES)}")
    os.makedirs(args.out, exist_ok=True)
    results: dict = {}
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if only and not any(s in name for s in only):
            continue
        t0 = time.time()
        lines, derived = fn()
        us = (time.time() - t0) * 1e6
        with open(os.path.join(args.out, f"{name}.csv"), "w") as f:
            f.write("\n".join(lines) + "\n")
        derived = str(derived).replace(",", ";")
        results[name] = {"us_per_call": round(us), "derived": derived,
                         **_backend_meta()}
        print(f"{name},{us:.0f},{derived}", flush=True)
    if results:
        _record_fleet_json(results)


if __name__ == "__main__":
    main()
