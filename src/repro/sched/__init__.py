"""repro.sched — scheduling policy, contention-aware placement & admission.

Layer 2 of the multi-program scheduling subsystem.  Layer 1 (the simulator)
models *how* co-resident programs share one reconfigurable core —
heterogeneous quanta and weighted round-robin priorities, swept as grid
axes by `repro.core.simulator.sweep_fleet`.  This package decides *which*
programs should share a core in the first place:

  * `policy`    — named scheduling policies (uniform / weighted /
                  foreground-background) that compile down to
                  `SchedulerConfig`s, plus quantum-grid builders for the
                  sweep's quanta axis;
  * `placement` — `ContentionModel` batch-predicts per-tenant slowdowns for
                  candidate co-residency groups through `sweep_fleet`, and
                  `place_tenants` assigns T tenants to C cores with greedy
                  seeding + swap local search minimising predicted
                  worst-tenant (then mean) contention;
  * `admission` — `AdmissionController` wraps placement with an
                  admit/defer decision at a slowdown SLO (per-tenant SLO
                  weights bias the deferral order so foreground tenants
                  are protected); the serve layer
                  (`repro.serve.engine.SlotServeEngine.plan_coresidency`)
                  uses it to pick co-residents instead of taking tenant
                  order as given;
  * `online`    — `OnlineReplacer` serves an arrival/departure event
                  stream in epochs over the resumable fleet state
                  (`simulator.FleetState`), re-solving placement each
                  epoch and pricing each move as predicted contention
                  delta minus a *measured* warm-state migration penalty
                  (resume-on-cold-core probe);
                  `SlotServeEngine.serve_online` is the serving entry;
  * `topology`  — `Topology` places the cores within sockets within
                  hosts: each host is an independently (and
                  incrementally) re-solved placement domain, and moves
                  crossing a socket or host pay a LUTstructions-style
                  bitstream re-load surcharge on top of the measured
                  probe (`place_fleet` is the static per-host entry);
  * `faults`    — deterministic fault injection for the online loop: a
                  seeded `FaultPlan` schedules epoch-aligned core losses,
                  slot SEUs, bitstream flushes and reconfig stalls, which
                  the `OnlineReplacer` detects and recovers from
                  (warm-state-aware evacuation vs cold-restart vs none).
"""
from repro.sched.admission import AdmissionController, AdmissionDecision
from repro.sched.faults import (FAULT_KINDS, RECOVERY_POLICIES, FaultEvent,
                                FaultPlan)
from repro.sched.online import (RESOLVE_MODES, OnlineConfig, OnlineReplacer,
                                OnlineReport, TenantEvent)
from repro.sched.placement import (ContentionModel, Placement,
                                   PlacementConfig, fifo_placement,
                                   place_fleet, place_tenants,
                                   random_placement, score_placement)
from repro.sched.policy import PriorityPolicy, quantum_grid
from repro.sched.topology import DISTANCES, Topology

__all__ = [
    "AdmissionController", "AdmissionDecision",
    "ContentionModel", "Placement", "PlacementConfig",
    "fifo_placement", "place_fleet", "place_tenants", "random_placement",
    "score_placement",
    "OnlineConfig", "OnlineReplacer", "OnlineReport", "TenantEvent",
    "RESOLVE_MODES",
    "FAULT_KINDS", "RECOVERY_POLICIES", "FaultEvent", "FaultPlan",
    "DISTANCES", "Topology",
    "PriorityPolicy", "quantum_grid",
]
