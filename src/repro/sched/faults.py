"""Deterministic fault injection for the online serving loop.

The paper's architectural bet is that per-core FPGA state — disambiguator
residents plus the bitstream cache — persists across context switches.
That state is also exactly what a fault *destroys*: an SEU in a slot, a
failed partial reconfiguration, or a lost core each forces the re-loading
cost LUTstructions quantifies.  This module schedules those faults as
epoch-aligned events the `OnlineReplacer` detects and recovers from:

  * ``core_loss``       — a core goes down (permanent, or transient with a
                          repair delay; a repaired core may come back
                          *degraded*, with fewer usable slots — modelled by
                          `slots.lookup`'s `num_active` masking, bit-for-bit
                          an LRU cache of the smaller size);
  * ``slot_seu``        — a single-event upset corrupts chosen disambiguator
                          residents (`simulator.seu_fleet_state` surgery:
                          the implementations must be re-loaded on next
                          use);
  * ``bitstream_flush`` — the bitstream cache colds
                          (`simulator.flush_bitstream`): every future slot
                          miss re-pays the full re-load penalty;
  * ``reconfig_stall``  — the core's reconfiguration port wedges for a few
                          epochs: migration/reload attempts *to* it fail
                          transiently and retry with capped exponential
                          backoff.

Everything is deterministic: a `FaultPlan` is an explicit event tuple plus
a seed, and any randomness inside an event (which residents an SEU hits)
derives from a counter-based generator keyed on ``(seed, epoch, core)`` —
stateless, so a crash-restarted serve replays the identical storm without
carrying RNG state in its checkpoints.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["FAULT_KINDS", "RECOVERY_POLICIES", "FaultEvent", "FaultPlan"]

FAULT_KINDS = ("core_loss", "slot_seu", "bitstream_flush", "reconfig_stall")

# how the OnlineReplacer reacts to a fault storm:
#   * "none"         — no recovery: tenants on a lost core stall until it
#                      repairs (never, if the loss is permanent);
#   * "cold_restart" — restart everything: stranded tenants are evacuated,
#                      but every core's caches are flushed on any fault
#                      epoch, so the whole fleet re-pays warm-up;
#   * "warm"         — warm-state-aware: only stranded tenants move
#                      (destination chosen through the contention model,
#                      degraded cores down-weighted), surviving cores keep
#                      their warm caches.
RECOVERY_POLICIES = ("none", "cold_restart", "warm")


@dataclass(frozen=True)
class FaultEvent:
    """One epoch-aligned fault.  Only the fields of the event's `kind`
    are meaningful; the rest keep their defaults.

    core_loss:       `permanent` (never repairs) or transient with
                     `repair_epochs` delay; a transient core may come back
                     with `degraded_slots` fewer usable disambiguator
                     slots (its caches come back cold either way — the
                     region was rebuilt).
    slot_seu:        `num_hit` residents corrupted (chosen by the plan's
                     counter-based rng over the occupied entries).
    bitstream_flush: no parameters — the bs cache colds.
    reconfig_stall:  reload/migration attempts targeting the core fail
                     for `stall_epochs` epochs.
    """

    epoch: int
    kind: str
    core: int
    # core_loss
    permanent: bool = False
    repair_epochs: int = 2
    degraded_slots: int = 0
    # slot_seu
    num_hit: int = 1
    # reconfig_stall
    stall_epochs: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}, expected one of "
                f"{FAULT_KINDS}")
        if self.epoch < 0:
            raise ValueError(f"fault epoch must be >= 0, got {self.epoch}")
        if self.core < 0:
            raise ValueError(f"fault core must be >= 0, got {self.core}")
        if self.kind == "core_loss" and not self.permanent \
                and self.repair_epochs < 1:
            raise ValueError(
                f"a transient core_loss needs repair_epochs >= 1, got "
                f"{self.repair_epochs}")
        if self.degraded_slots < 0:
            raise ValueError(
                f"degraded_slots must be >= 0, got {self.degraded_slots}")
        if self.kind == "slot_seu" and self.num_hit < 1:
            raise ValueError(f"slot_seu needs num_hit >= 1, got "
                             f"{self.num_hit}")
        if self.kind == "reconfig_stall" and self.stall_epochs < 1:
            raise ValueError(
                f"reconfig_stall needs stall_epochs >= 1, got "
                f"{self.stall_epochs}")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule: explicit events plus the seed that
    drives every in-event random choice (SEU victim selection).

    `rng(event)` returns a generator keyed on ``(seed, epoch, core)`` —
    counter-based, never carried — so replaying any suffix of the plan
    (e.g. after a checkpoint restore) reproduces the identical storm.
    """

    events: tuple = ()
    seed: int = 0

    def __post_init__(self):
        evs = tuple(self.events)
        for ev in evs:
            if not isinstance(ev, FaultEvent):
                raise TypeError(
                    f"FaultPlan events must be FaultEvent, got {ev!r}")
        # deterministic application order: epoch, then core, then kind
        object.__setattr__(self, "events", tuple(sorted(
            evs, key=lambda e: (e.epoch, e.core, FAULT_KINDS.index(e.kind)))))

    def at(self, epoch: int) -> list[FaultEvent]:
        """The events injected (and detected) at `epoch`, in application
        order."""
        return [e for e in self.events if e.epoch == epoch]

    def horizon(self) -> int:
        """First epoch with no scheduled events after it."""
        return max((e.epoch for e in self.events), default=-1) + 1

    def max_core(self) -> int:
        return max((e.core for e in self.events), default=-1)

    def rng(self, event: FaultEvent) -> np.random.Generator:
        return np.random.default_rng(
            [self.seed, event.epoch, event.core,
             FAULT_KINDS.index(event.kind)])

    @classmethod
    def storm(cls, seed: int, num_epochs: int, num_cores: int, *,
              p_core_loss: float = 0.05, p_permanent: float = 0.2,
              repair_epochs: int = 2, p_degrade: float = 0.5,
              p_seu: float = 0.1, max_hit: int = 2,
              p_flush: float = 0.08, p_stall: float = 0.08,
              stall_epochs: int = 2, start_epoch: int = 1) -> "FaultPlan":
        """A seeded random storm over ``[start_epoch, num_epochs)``.

        Per (epoch, core) each fault kind fires independently with its
        probability; core losses are throttled so at least one core stays
        up at every epoch (a fully-dark fleet serves nothing, which makes
        recovery comparisons vacuous).  Same seed -> same storm.
        """
        if num_cores < 1:
            raise ValueError(f"num_cores must be >= 1, got {num_cores}")
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        down_until: dict[int, float] = {}   # core -> epoch it repairs (inf)
        for epoch in range(start_epoch, num_epochs):
            down = {c for c, until in down_until.items() if epoch < until}
            for core in range(num_cores):
                if core in down:
                    continue
                if rng.random() < p_core_loss and len(down) < num_cores - 1:
                    permanent = bool(rng.random() < p_permanent)
                    degraded = (int(rng.integers(1, 3))
                                if (not permanent
                                    and rng.random() < p_degrade) else 0)
                    events.append(FaultEvent(
                        epoch, "core_loss", core, permanent=permanent,
                        repair_epochs=repair_epochs,
                        degraded_slots=degraded))
                    down.add(core)
                    down_until[core] = (np.inf if permanent
                                        else epoch + repair_epochs)
                    continue
                if rng.random() < p_seu:
                    events.append(FaultEvent(
                        epoch, "slot_seu", core,
                        num_hit=int(rng.integers(1, max_hit + 1))))
                if rng.random() < p_flush:
                    events.append(FaultEvent(epoch, "bitstream_flush", core))
                if rng.random() < p_stall:
                    events.append(FaultEvent(
                        epoch, "reconfig_stall", core,
                        stall_epochs=stall_epochs))
        return cls(events=tuple(events), seed=seed)
