"""Admission control at a contention SLO.

The placement layer finds the least-contended assignment of tenants to
cores; admission control decides whether even that best assignment is good
enough.  `AdmissionController` places the offered tenant set, compares the
predicted worst-tenant slowdown against a service-level objective, and —
when the SLO is violated — defers the most contended tenant and re-places
the rest, iterating until the remaining set fits (or nothing does).
Deferred tenants are reported so the serve layer can queue them for a later
round instead of letting one bad co-residency blow every tenant's latency.
Per-tenant SLO weights (`decide(..., slo_weights=...)`) bias the deferral
order so foreground tenants are protected and batch tenants absorb the
contention.

This is the serving-level realisation of the ROADMAP item "wire
`estimate_fleet_contention` into serve admission control": predictions come
from the same fleet machinery, batched through
`repro.sched.placement.ContentionModel`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.sched.placement import (ContentionModel, Placement,
                                   place_tenants)

__all__ = ["AdmissionController", "AdmissionDecision"]


@dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of one admission round."""

    admitted: tuple[str, ...]          # tenant names, placement order
    deferred: tuple[str, ...]          # names deferred, worst-first
    placement: Placement | None        # placement of the admitted set
    predicted_worst: float             # nan when nothing was admitted
    slo: float
    slo_weights: dict | None = None    # per-tenant weights used (if any)

    @property
    def admitted_all(self) -> bool:
        return not self.deferred

    def core_of(self, name: str) -> int:
        """Core index an admitted tenant landed on (-1 if deferred)."""
        if self.placement is not None:
            for ci, core in enumerate(self.placement.cores):
                if name in core:
                    return ci
        return -1


class AdmissionController:
    """Admit/defer tenants so predicted worst-tenant slowdown meets an SLO.

    `slo` is the largest acceptable contention slowdown (fleet CPI over
    unpreempted solo CPI) for ANY admitted tenant — e.g. 1.5 means "no
    tenant runs more than 50% slower than it would alone on a core".
    """

    def __init__(self, *, slo: float = 1.5, num_cores: int = 2,
                 model: ContentionModel | None = None,
                 max_rounds: int = 8):
        if slo <= 0:
            raise ValueError(f"slo must be positive, got {slo}")
        if num_cores < 1:
            raise ValueError(f"num_cores must be >= 1, got {num_cores}")
        self.slo = float(slo)
        self.num_cores = num_cores
        self.model = model or ContentionModel()
        self.max_rounds = max_rounds

    def decide(self, tenants: dict[str, str],
               slo_weights: dict[str, float] | None = None
               ) -> AdmissionDecision:
        """tenants: name -> benchmark profile.  Defers greedily: while the
        best placement still violates the SLO, a victim is deferred and the
        rest are re-placed.

        `slo_weights` (optional, name -> positive weight, default 1.0)
        makes the deferral priority-aware: the victim maximises the
        *weighted violation* `predicted_slowdown / weight`, so a heavy
        foreground tenant (weight 4) tolerates 4x the contention of a unit
        batch tenant before it becomes the deferral candidate — foreground
        tenants are protected while batch tenants absorb the contention.
        The admit condition itself stays the unweighted worst-slowdown SLO
        (an admitted set must be good for everyone it serves).
        """
        weights = dict(slo_weights or {})
        for n, w in weights.items():
            if n not in tenants:
                raise ValueError(
                    f"slo_weights names unknown tenant {n!r} (offered: "
                    f"{sorted(tenants)})")
            if not w > 0:
                raise ValueError(
                    f"slo_weights must be positive, got {w!r} for {n!r}")
        work = dict(tenants)
        deferred: list[str] = []
        while work:
            pl = place_tenants(work, min(self.num_cores, len(work)),
                               self.model, max_rounds=self.max_rounds)
            if pl.worst_slowdown <= self.slo:
                admitted = tuple(n for core in pl.cores for n in core)
                return AdmissionDecision(
                    admitted=admitted, deferred=tuple(deferred),
                    placement=pl, predicted_worst=pl.worst_slowdown,
                    slo=self.slo, slo_weights=slo_weights)
            victim = max(work, key=lambda n: (
                pl.tenant_slowdown[n] / weights.get(n, 1.0), n))
            deferred.append(victim)
            del work[victim]
        return AdmissionDecision(admitted=(), deferred=tuple(deferred),
                                 placement=None,
                                 predicted_worst=math.nan, slo=self.slo,
                                 slo_weights=slo_weights)
