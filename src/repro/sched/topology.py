"""Fleet topology: reconfigurable cores within sockets within hosts.

The flat core pool the placement layer started with (PR 3/4) prices every
migration identically — fine for 3 cores on one board, wrong at
datacenter scale, where *where* a bitstream is warm decides what a move
costs.  LUTstructions (PAPERS.md) prices reconfiguration as self-loading
instruction cost, and that cost tiers naturally by distance:

  * **intra-socket** — the mover's warm state sits one reconfiguration
    port away; the only cost is the *measured* warm-resume delta the
    online layer already probes (`OnlineReplacer.migration_penalty`);
  * **cross-socket** — the destination must re-load every one of the
    mover's resident bitstreams across the socket interconnect: the
    probe cost plus `resident_tags x bs_miss_extra x
    cross_socket_reload` modelled re-load cycles;
  * **cross-host**  — the bitstreams transit the network; same model
    with the (larger) `cross_host_reload` multiplier.

`Topology` is pure geometry + the tier multipliers: core indices are
dense `[0, num_cores)`, laid out host-major then socket-major, so
`core // cores_per_socket` is the global socket and
`core // cores_per_host` the host.  `Topology.flat(n)` (one host, one
socket) reproduces the pre-topology behaviour bit-for-bit: every
distance is intra-socket and every reload multiplier is zero, which is
what keeps the historical churn/chaos anchors unchanged.

The *placement domain* — the scope inside which the per-epoch re-solve
runs its greedy + swap search — is the host: swap search inside a host
may cross sockets (and pays the tier surcharge when it does), while
cross-host moves only happen through arrival placement and fault
evacuation, mirroring how real schedulers treat rack-level migration.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["DISTANCES", "Topology"]

# near-to-far move distances the penalty model tiers by; "intra_core"
# is the degenerate src == dst case (no move, no cost)
DISTANCES = ("intra_core", "intra_socket", "cross_socket", "cross_host")


@dataclass(frozen=True)
class Topology:
    """Core geometry plus the LUTstructions re-load tier multipliers.

    `cross_socket_reload` / `cross_host_reload` scale the per-bitstream
    re-load cost (`bs_miss_extra` cycles is the intra-socket baseline the
    measured probe already charges): a cross-socket move pays an *extra*
    `resident_tags x bs_miss_extra x cross_socket_reload` cycles on top
    of the probe, a cross-host move the `cross_host_reload` variant.
    """

    num_hosts: int = 1
    sockets_per_host: int = 1
    cores_per_socket: int = 1
    cross_socket_reload: float = 4.0
    cross_host_reload: float = 16.0

    def __post_init__(self):
        for name in ("num_hosts", "sockets_per_host", "cores_per_socket"):
            if getattr(self, name) < 1:
                raise ValueError(
                    f"{name} must be >= 1, got {getattr(self, name)}")
        if self.cross_socket_reload < 0 or self.cross_host_reload < 0:
            raise ValueError(
                f"reload multipliers must be >= 0, got "
                f"cross_socket_reload={self.cross_socket_reload}, "
                f"cross_host_reload={self.cross_host_reload}")
        if self.cross_host_reload < self.cross_socket_reload:
            raise ValueError(
                f"cross_host_reload ({self.cross_host_reload}) must be >= "
                f"cross_socket_reload ({self.cross_socket_reload}) — a "
                f"network re-load cannot be cheaper than a socket one")

    # ------------------------------------------------------------------
    @classmethod
    def flat(cls, num_cores: int) -> "Topology":
        """One host, one socket, `num_cores` cores — the pre-topology
        pool.  Every move is intra-socket, every reload surcharge zero."""
        return cls(num_hosts=1, sockets_per_host=1,
                   cores_per_socket=num_cores)

    # ------------------------------------------------------------------
    @property
    def cores_per_host(self) -> int:
        return self.sockets_per_host * self.cores_per_socket

    @property
    def num_sockets(self) -> int:
        return self.num_hosts * self.sockets_per_host

    @property
    def num_cores(self) -> int:
        return self.num_hosts * self.cores_per_host

    def _check(self, core: int) -> int:
        if not 0 <= core < self.num_cores:
            raise ValueError(
                f"core {core} outside [0, {self.num_cores}) for {self}")
        return core

    def socket_of(self, core: int) -> int:
        """Global socket index of a core."""
        return self._check(core) // self.cores_per_socket

    def host_of(self, core: int) -> int:
        return self._check(core) // self.cores_per_host

    def cores_of_host(self, host: int) -> range:
        if not 0 <= host < self.num_hosts:
            raise ValueError(
                f"host {host} outside [0, {self.num_hosts})")
        lo = host * self.cores_per_host
        return range(lo, lo + self.cores_per_host)

    # ------------------------------------------------------------------
    def distance(self, src: int, dst: int) -> str:
        """Move distance tier between two cores (one of `DISTANCES`)."""
        self._check(src), self._check(dst)
        if src == dst:
            return "intra_core"
        if self.socket_of(src) == self.socket_of(dst):
            return "intra_socket"
        if self.host_of(src) == self.host_of(dst):
            return "cross_socket"
        return "cross_host"

    def reload_multiplier(self, distance: str) -> float:
        """Per-resident-bitstream re-load surcharge multiplier (on
        `bs_miss_extra`) for a move of the given distance.  Zero within
        a socket: the measured warm-resume probe already prices that
        tier."""
        if distance not in DISTANCES:
            raise ValueError(
                f"unknown distance {distance!r}, expected one of "
                f"{DISTANCES}")
        if distance in ("intra_core", "intra_socket"):
            return 0.0
        return (self.cross_socket_reload if distance == "cross_socket"
                else self.cross_host_reload)

    def geometry(self) -> tuple[int, int, int]:
        """(hosts, sockets/host, cores/socket) — the snapshot identity
        a `restore` validates against."""
        return (self.num_hosts, self.sockets_per_host,
                self.cores_per_socket)
