"""Contention-aware tenant -> core placement.

T tenants (each characterised by an instruction-mix profile, i.e. a
benchmark name from `repro.core.traces`) must share C reconfigurable
cores.  Which tenants co-reside decides how hard they fight over
disambiguator slots (paper §VI-C): two F+M-class tenants thrash a 4-slot
core, while an F+M-class tenant next to an M-only tenant barely notices
it.  This module treats that choice as an optimisation problem:

  * `ContentionModel` — batch-predicts per-tenant contention slowdowns
    (fleet CPI / unpreempted solo CPI) for candidate co-residency groups by
    running them through `repro.core.simulator.sweep_fleet` — the same
    machinery behind the Fig. 7 numbers and
    `repro.serve.engine.estimate_fleet_contention`.  Candidate groups are
    canonicalised (sorted bench multiset), cached, batched per fleet size,
    and padded to power-of-two batches so the jitted sweep compiles a
    handful of shapes, not one per call.
  * `place_tenants` — greedy seeding (most contentious tenants first, each
    onto the core that minimises the resulting group's predicted worst
    slowdown) followed by swap-based local search, minimising predicted
    worst-tenant slowdown with mean slowdown as the tie-break.
  * `fifo_placement` / `random_placement` — the baselines the benchmark
    (`benchmarks/placement_study.py`) compares against.

Solo references are unpreempted + warm-cache, so the sweep dispatcher
serves them from stack-distance passes; candidate fleets are preempted
and — since they are one-shot, warm-bitstream runs — ride the
interleave-aware fast path (`repro.core.stackdist_interleaved`), which is
what makes the greedy + swap search's many batched sweeps cheap.  The
`path` knob forces an engine for parity studies; every engine returns
bit-for-bit identical predictions.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import isa, simulator
from repro.sched.topology import Topology

__all__ = [
    "PlacementConfig", "ContentionModel", "Placement",
    "place_tenants", "place_fleet", "score_placement", "fifo_placement",
    "random_placement",
]


@dataclass(frozen=True)
class PlacementConfig:
    """Simulator knobs behind the contention predictions."""

    num_slots: int = 4
    miss_latency: int = 50
    # short quantum: frequent switching is the regime where co-residency
    # actually hurts (paper §VI-C, the 1K-vs-20K comparison) and hence where
    # placement has something to optimise.  Candidate groups span sizes
    # 1..P, so the model assumes the uniform unit-priority policy —
    # per-program priorities have no well-defined meaning across candidate
    # sizes (priority-aware admission is a ROADMAP direction).
    quantum_cycles: int = 2_000
    handler_cycles: int = 150
    trace_len: int = 12_000
    steps_per_program: int = 12_000   # total_steps = P * steps_per_program

    def scheduler(self) -> simulator.SchedulerConfig:
        return simulator.SchedulerConfig(
            quantum_cycles=self.quantum_cycles,
            handler_cycles=self.handler_cycles)


def _pad_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


class ContentionModel:
    """Batched, cached slowdown predictions for co-residency groups.

    A *group* is a multiset of benchmark names sharing one core; its
    prediction is the per-member contention slowdown vector (fleet CPI over
    unpreempted solo CPI), ordered like the sorted group tuple.  Everything
    is cached: traces per benchmark, solo CPIs (one batched unpreempted
    sweep per set of new benchmarks — stack-distance fast path), and group
    predictions (one batched preempted sweep per fleet size, padded to
    power-of-two batch shapes so repeated greedy/swap rounds reuse
    compilations).

    `scenarios` maps benchmark name -> `SlotScenario` for tenants whose
    binaries slot different opcodes (per-tenant slot taxonomies); benches
    absent from the mapping use the shared `scenario` default.  Tenant
    names resolve through `repro.workloads.resolve_trace`: Embench bench
    names and model-zoo "<arch>:<phase>" workloads are both valid, and an
    unknown profile raises a ValueError naming both sets instead of a
    KeyError from deep inside the trace synthesizer.

    `path` is handed to every underlying `sweep_fleet` call: the default
    "auto" serves solo references from the unpreempted stack-distance
    engine and preempted candidate groups from the interleaved engine;
    forcing "scan" reproduces the same predictions bit-for-bit on the
    reference machine (tests pin this).
    """

    def __init__(self, cfg: PlacementConfig | None = None,
                 scenario: isa.SlotScenario | None = None,
                 trace_seed: int = 0,
                 scenarios: dict[str, isa.SlotScenario] | None = None,
                 path: str = "auto"):
        self.cfg = cfg or PlacementConfig()
        self.scenario = scenario or isa.SCENARIO_2
        # per-tenant slot taxonomies: bench name -> SlotScenario overrides
        # the shared default (tenants compiled against different extension
        # sets disagree about which opcodes are slotted, paper §IV)
        self.scenarios = dict(scenarios or {})
        self.path = path
        self.trace_seed = trace_seed
        self._traces: dict[str, np.ndarray] = {}
        self._solo_cpi: dict[str, float] = {}
        self._solo_miss_rate: dict[str, float] = {}
        self._groups: dict[tuple[str, ...], np.ndarray] = {}
        self.sim_calls = 0          # batched sweep_fleet invocations
        self.groups_simulated = 0   # non-padding groups actually simulated

    # ------------------------------------------------------------------
    def trace(self, bench: str) -> np.ndarray:
        if bench not in self._traces:
            # repro.workloads.resolve_trace accepts Embench benches
            # (bit-for-bit the core_traces stream) and model-zoo
            # "<arch>:<phase>" workloads, and raises a ValueError naming
            # both valid sets otherwise; imported lazily so pure-Embench
            # placement never touches the model/configs stack
            from repro import workloads

            self._traces[bench] = workloads.resolve_trace(
                bench, self.cfg.trace_len, seed=self.trace_seed)
        return self._traces[bench]

    def scenario_of(self, bench: str) -> isa.SlotScenario:
        """The slot taxonomy this bench simulates under (per-tenant
        mapping first, shared default otherwise)."""
        return self.scenarios.get(bench, self.scenario)

    def _ensure_solo(self, benches) -> None:
        missing = sorted(set(benches) - self._solo_cpi.keys())
        if not missing:
            return
        # one batched unpreempted sweep per distinct taxonomy (the common
        # shared-scenario roster stays a single sweep)
        by_scen: dict[str, list[str]] = {}
        for b in missing:
            by_scen.setdefault(self.scenario_of(b).name, []).append(b)
        for _, group in sorted(by_scen.items()):
            tensor = np.stack([self.trace(b) for b in group])[:, None, :]
            # the solo window matches each fleet member's step budget so
            # cold misses amortise identically on both sides of the
            # slowdown ratio
            res = simulator.sweep_fleet(
                tensor, [self.cfg.miss_latency], self.scenario_of(group[0]),
                simulator.SchedulerConfig.no_preempt(
                    self.cfg.handler_cycles),
                slot_counts=[self.cfg.num_slots],
                total_steps=self.cfg.steps_per_program, path=self.path)
            self.sim_calls += 1
            cpi = np.asarray(res.cpi)[:, 0, 0, 0]
            miss = np.asarray(res.slot_misses)[:, 0, 0, 0]
            instr = np.asarray(res.instructions)[:, 0, 0, 0]
            for i, b in enumerate(group):
                self._solo_cpi[b] = float(cpi[i])
                self._solo_miss_rate[b] = (float(miss[i])
                                           / max(int(instr[i]), 1))

    def warm(self, benches) -> None:
        """Precompute solo references for a bench set in ONE batched sweep
        (callers with a known tenant roster should warm before querying
        per-bench metrics one at a time)."""
        self._ensure_solo(benches)

    def solo_cpi(self, bench: str) -> float:
        self._ensure_solo([bench])
        return self._solo_cpi[bench]

    def solo_miss_rate(self, bench: str) -> float:
        """Solo slot misses per instruction — the greedy seeding order."""
        self._ensure_solo([bench])
        return self._solo_miss_rate[bench]

    # ------------------------------------------------------------------
    def _cache_key(self, group, num_slots: int) -> tuple:
        """Canonical prediction-cache key: (sorted bench multiset, slot
        width).  Every lookup AND store routes through this one function
        — the PR 7 degraded-width keys special-cased the full width,
        which left two keying conventions that could drift apart: a
        permuted group priced at a degraded width must hit the same
        entry as its sorted twin, and a degraded prediction must never
        alias (or be served from) the full-width one."""
        return (tuple(sorted(group)), int(num_slots))

    def predict(self, groups, *, num_slots: int | None = None
                ) -> list[np.ndarray]:
        """Per-tenant slowdown vectors for a sequence of bench groups.

        Each group is a sequence of benchmark names (any order; the result
        vector is ordered like `tuple(sorted(group))`).  All uncached
        groups sharing a (size, per-program taxonomy) signature are
        simulated in a single `sweep_fleet` call — with no per-tenant
        scenario mapping that is exactly "one call per size".  Batches
        pad to power-of-two sizes rounded up to a multiple of the device
        count (`simulator.fleet_mesh_size`), so on multi-device hosts
        every candidate-group sweep shards evenly across the fleet mesh
        (a no-op on single-device hosts: the historical shapes are
        already multiples of 1).

        `num_slots` prices the group on a core with fewer usable slots
        (a fault-degraded core, `repro.sched.faults`): the candidate
        sweep runs at that slot count while the solo reference stays at
        full width, so a degraded core's predictions are intrinsically
        down-weighted — the extra thrashing of the smaller disambiguator
        shows up as extra slowdown.  Predictions are cached under the
        canonical `_cache_key` (group multiset, width) for every width,
        the default full width included.
        """
        ns = self.cfg.num_slots if num_slots is None else int(num_slots)
        if not 1 <= ns <= self.cfg.num_slots:
            raise ValueError(
                f"num_slots must be in [1, {self.cfg.num_slots}] (the "
                f"configured core width), got {num_slots}")
        keys = [self._cache_key(g, ns) for g in groups]
        todo: dict[tuple, list[tuple[str, ...]]] = {}
        for k, _ in dict.fromkeys(keys):   # unique, order-preserving
            if k and self._cache_key(k, ns) not in self._groups:
                sig = tuple(self.scenario_of(b).name for b in k)
                todo.setdefault((len(k), sig), []).append(k)
        ndev = simulator.fleet_mesh_size()
        for (size, _sig), ks in sorted(todo.items()):
            self._ensure_solo([b for k in ks for b in k])
            pad = -(-_pad_pow2(len(ks)) // ndev) * ndev
            batch = ks + [ks[0]] * (pad - len(ks))
            tensor = np.stack([np.stack([self.trace(b) for b in k])
                               for k in batch])
            res = simulator.sweep_fleet(
                tensor, [self.cfg.miss_latency],
                [self.scenario_of(b) for b in ks[0]],
                self.cfg.scheduler(),
                slot_counts=[ns],
                total_steps=size * self.cfg.steps_per_program,
                path=self.path)
            self.sim_calls += 1
            self.groups_simulated += len(ks)
            cpis = np.asarray(res.cpi)[:, 0, 0, :]
            instrs = np.asarray(res.instructions)[:, 0, 0, :]
            for gi, k in enumerate(ks):
                solo = np.array([self._solo_cpi[b] for b in k])
                slow = cpis[gi] / solo
                # a tenant the rotation never reached has no CPI: treat as
                # unboundedly contended, never as "free"
                self._groups[self._cache_key(k, ns)] = np.where(
                    instrs[gi] > 0, slow, np.inf)
        return [self._groups[key] if key[0] else np.zeros((0,))
                for key in keys]


# ---------------------------------------------------------------------------
# placements and their scores
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Placement:
    """An assignment of named tenants to cores, with predicted slowdowns."""

    cores: tuple[tuple[str, ...], ...]      # tenant names per core
    tenant_slowdown: dict[str, float] = field(compare=False)
    worst_slowdown: float
    mean_slowdown: float

    @property
    def objective(self) -> tuple[float, float]:
        """Lexicographic score: worst-tenant first, mean as tie-break."""
        return (self.worst_slowdown, self.mean_slowdown)


def _core_groups(cores, tenants):
    return [tuple(sorted(tenants[n] for n in core)) for core in cores]


def _tenant_slowdowns(cores, tenants, preds) -> dict[str, float]:
    out: dict[str, float] = {}
    for core, pred in zip(cores, preds):
        # prediction vectors are ordered like the sorted bench tuple; match
        # tenants to entries by sorting them the same way (ties share a
        # bench, hence a value, so the pairing is well-defined)
        for name, slow in zip(sorted(core, key=lambda n: (tenants[n], n)),
                              pred):
            out[name] = float(slow)
    return out


def score_placement(cores, tenants: dict[str, str],
                    model: ContentionModel) -> Placement:
    """Predict per-tenant slowdowns for an explicit core assignment."""
    cores = tuple(tuple(c) for c in cores if c)
    preds = model.predict(_core_groups(cores, tenants))
    per_tenant = _tenant_slowdowns(cores, tenants, preds)
    vals = np.array(list(per_tenant.values()))
    return Placement(cores=cores, tenant_slowdown=per_tenant,
                     worst_slowdown=float(vals.max()),
                     mean_slowdown=float(vals.mean()))


def _capacities(num_tenants: int, num_cores: int) -> list[int]:
    base, extra = divmod(num_tenants, num_cores)
    return [base + 1] * extra + [base] * (num_cores - extra)


def fifo_placement(names, num_cores: int) -> list[list[str]]:
    """Chunk tenants into cores in arrival order — the naive serve layer."""
    names = list(names)
    caps = _capacities(len(names), num_cores)
    cores, i = [], 0
    for c in caps:
        cores.append(names[i:i + c])
        i += c
    return cores

def random_placement(names, num_cores: int, seed: int = 0) -> list[list[str]]:
    rng = np.random.default_rng(seed)
    names = list(names)
    order = [names[i] for i in rng.permutation(len(names))]
    return fifo_placement(order, num_cores)


# ---------------------------------------------------------------------------
# greedy seeding + swap local search
# ---------------------------------------------------------------------------


def place_tenants(tenants: dict[str, str], num_cores: int,
                  model: ContentionModel | None = None, *,
                  max_rounds: int = 8) -> Placement:
    """Assign tenants to cores minimising predicted worst-tenant slowdown.

    `tenants` maps tenant name -> benchmark profile.  Core sizes are kept
    balanced (|size difference| <= 1, matching the FIFO/random baselines).
    Greedy seeding walks tenants in order of decreasing solo slot-miss rate
    (the most slot-hungry tenants get first pick) and puts each on the core
    whose resulting group predicts the best (worst, mean) objective; then
    swap-based local search exchanges tenant pairs across cores while any
    swap improves the global objective (up to `max_rounds` passes).  All
    candidate groups of a round are predicted in batched `sweep_fleet`
    calls through the `ContentionModel` cache.
    """
    if not tenants:
        raise ValueError("place_tenants needs at least one tenant")
    if num_cores < 1:
        raise ValueError(f"num_cores must be >= 1, got {num_cores}")
    model = model or ContentionModel()
    names = sorted(tenants)
    caps = _capacities(len(names), num_cores)

    # --- greedy seeding, most contentious first ---
    model.warm(tenants.values())   # one batched solo sweep, not T singletons
    order = sorted(names, key=lambda n: (-model.solo_miss_rate(tenants[n]),
                                         n))
    cores: list[list[str]] = [[] for _ in range(num_cores)]
    for n in order:
        open_cores = [ci for ci in range(num_cores)
                      if len(cores[ci]) < caps[ci]]
        cand = [tuple(sorted([tenants[m] for m in cores[ci]]
                             + [tenants[n]])) for ci in open_cores]
        preds = model.predict(cand)
        best = min(range(len(open_cores)),
                   key=lambda i: (float(np.max(preds[i])),
                                  float(np.mean(preds[i])), i))
        cores[open_cores[best]].append(n)

    # --- swap local search on the global objective ---
    current = score_placement(cores, tenants, model)
    for _ in range(max_rounds):
        moves = [(a, i, b, j)
                 for a in range(num_cores) for b in range(a + 1, num_cores)
                 for i in range(len(cores[a])) for j in range(len(cores[b]))]
        # batch-predict every post-swap group pair up front (cache absorbs
        # the duplicates across moves)
        cand_groups = []
        for a, i, b, j in moves:
            na = cores[a][:i] + cores[a][i + 1:] + [cores[b][j]]
            nb = cores[b][:j] + cores[b][j + 1:] + [cores[a][i]]
            cand_groups += [tuple(sorted(tenants[n] for n in na)),
                            tuple(sorted(tenants[n] for n in nb))]
        model.predict(cand_groups)

        best_move, best_pl = None, current
        for a, i, b, j in moves:
            trial = [list(c) for c in cores]
            trial[a][i], trial[b][j] = trial[b][j], trial[a][i]
            pl = score_placement(trial, tenants, model)
            if pl.objective < best_pl.objective:
                best_move, best_pl = (a, i, b, j), pl
        if best_move is None:
            break
        a, i, b, j = best_move
        cores[a][i], cores[b][j] = cores[b][j], cores[a][i]
        current = best_pl
    return current


def place_fleet(tenants: dict[str, str], topology: Topology,
                model: ContentionModel | None = None, *,
                max_rounds: int = 8) -> Placement:
    """Topology-aware static placement over a whole fleet.

    Partitions tenants across hosts, then runs the greedy + swap
    `place_tenants` search independently inside each host (the placement
    *domain* — swap moves may cross sockets within a host, never hosts),
    so the cost is sum-over-hosts of O(T_h^2) instead of the flat pool's
    O(T^2) swap frontier.  Tenants are dealt across hosts round-robin in
    decreasing solo slot-miss-rate order, so the slot-hungriest tenants
    spread out instead of piling onto host 0.  With `Topology.flat(C)`
    (one host) this is exactly `place_tenants(tenants, C)`.

    The returned `Placement.cores` tuple is ordered by global core index
    (host-major), empty trailing cores of a host omitted — matching how
    `score_placement` drops empty cores.
    """
    if not tenants:
        raise ValueError("place_fleet needs at least one tenant")
    model = model or ContentionModel()
    model.warm(tenants.values())   # one batched solo sweep up front
    order = sorted(tenants, key=lambda n: (-model.solo_miss_rate(tenants[n]),
                                           n))
    per_host: list[dict[str, str]] = [{} for _ in range(topology.num_hosts)]
    for i, n in enumerate(order):
        per_host[i % topology.num_hosts][n] = tenants[n]
    cores: list[tuple[str, ...]] = []
    per_tenant: dict[str, float] = {}
    for roster in per_host:
        if not roster:
            continue
        pl = place_tenants(roster,
                           min(topology.cores_per_host, len(roster)),
                           model, max_rounds=max_rounds)
        cores.extend(pl.cores)
        per_tenant.update(pl.tenant_slowdown)
    vals = np.array(list(per_tenant.values()))
    return Placement(cores=tuple(cores), tenant_slowdown=per_tenant,
                     worst_slowdown=float(vals.max()),
                     mean_slowdown=float(vals.mean()))
