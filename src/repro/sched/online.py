"""Online re-placement: epoch-structured serving with warm-state-aware
tenant migration.

The static placement layer (`repro.sched.placement`) answers "which tenants
should co-reside" once, for a fixed roster.  Real serving rosters churn:
tenants arrive and leave mid-serve, and every arrival/departure can turn a
good placement into a bad one.  This module serves a churn workload as a
sequence of *epochs* over the resumable fleet simulator
(`repro.core.simulator.FleetState`):

  * each reconfigurable core carries its disambiguator + bitstream cache
    across epochs AND across membership changes — warm state persists on
    the core, which is the paper's architectural point (§IV) and exactly
    what makes migration expensive: a tenant moved to another core leaves
    its resident slots behind;
  * each epoch the `OnlineReplacer` re-solves placement for the current
    roster through the `ContentionModel` (`place_tenants`), aligns the
    solution to the physical cores by membership overlap, and prices every
    implied move as

        net = predicted-contention-delta  -  warm-state migration penalty

    where the contention delta converts predicted slowdown changes of every
    affected tenant into cycles over the next epoch, and the migration
    penalty is *measured*, not modelled: the mover's state is resumed for a
    probe window twice — once on its current (warm) core and once on a cold
    core — and the penalty is the cycle difference (LUTstructions'
    re-loading cost as a first-class quantity);
  * policy "warm" applies only net-positive moves; the baselines are
    "never" (arrival placement is final) and "always" (apply every move the
    re-solve implies, blind to migration cost).

`benchmarks/online_churn.py` shows warm-aware re-placement matching or
beating never-migrate on worst-tenant slowdown while migrating less than
always-rebalance; `repro.serve.engine.SlotServeEngine.serve_online` wires
the loop into the serving layer.

Cost structure per epoch: every simulation the loop issues now rides the
interleave-aware stack-distance engine
(`repro.core.stackdist_interleaved`).  The re-solve and every move's
contention-delta pricing go through the `ContentionModel`'s one-shot
preempted sweeps; the epoch *advance* and the migration-penalty probes
resume explicit `FleetState`s and ride the engine's *resumable* entry
(`simulate_many(..., state=S, return_state=True)` seeds the engine from S
and materialises S' back out, bit-for-bit equal to the scan).  The
cycle-by-cycle scan only returns for caches no scan could have produced
or cold bitstream caches — in a fault-free serve, neither occurs.

Topology & fleet scale (`repro.sched.topology`): the fleet is a
`Topology` (cores within sockets within hosts, default
`Topology.flat(num_cores)` — the historical single-board pool).  Two
things tier by it:

  * **migration pricing** — `migration_penalty(name, dst)` adds the
    LUTstructions re-load surcharge on top of the measured warm-resume
    probe when the move crosses a socket or a host
    (`resident bitstreams x bs_miss_extra x tier multiplier`); within a
    socket the measured probe alone is the price, exactly as before;
  * **the per-epoch re-solve** — each *host* is a placement domain
    solved independently (`place_tenants` over the host's up cores), so
    the swap frontier is O(T_h^2) per host instead of O(T^2) over the
    fleet.  The re-solve is *incremental* by default: a domain's solved
    target assignment is cached, and only domains dirtied since the
    last epoch (arrivals, departures, applied moves, evacuations,
    faults, repairs) are re-solved — a quiet epoch at 1000 tenants
    re-prices nothing.  `resolve_mode="full"` re-solves every domain
    every epoch; both modes are bit-for-bit identical (the cache is
    pure memoisation of a deterministic solve — asserted across the
    churn/chaos streams by tests/test_fleet_scale.py and at fleet scale
    by benchmarks/fleet_scale_study.py), and `resolve_log` records
    per-epoch solved/cached domain counts and wall time.

Fault tolerance (`repro.sched.faults`): a seeded `FaultPlan` injects
epoch-aligned core losses, slot SEUs, bitstream flushes and reconfig
stalls.  The replacer detects each fault at its epoch, evacuates tenants
off lost cores as *mandatory* moves (priced for destination choice only,
never gated on net benefit; destinations under a reconfig stall are
retried with capped exponential backoff), prices degraded cores at their
reduced slot width through `ContentionModel.predict(num_slots=...)`, and
emits a structured fault log into the extended `OnlineReport`.  Cache
damage (SEU/flush) routes the next resumed segment through the scan
(the mutated state is not interleaved-seedable) until the caches
re-warm; degraded cores ride the scan with `num_active` masking until
repaired at full width.  `snapshot()`/`restore()` capture the complete
host-side serving state so a crashed serve restarts mid-trace
bit-for-bit (`run(checkpoint_every=..., save_fn=...)`).
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import simulator, slots
from repro.sched.faults import RECOVERY_POLICIES, FaultPlan
from repro.sched.placement import (ContentionModel, PlacementConfig,
                                   place_tenants)
from repro.sched.topology import Topology

__all__ = [
    "TenantEvent", "OnlineConfig", "OnlineReport", "OnlineReplacer",
    "POLICIES", "RESOLVE_MODES",
]

POLICIES = ("never", "always", "warm")
RESOLVE_MODES = ("incremental", "full")

# snapshot schema versions `OnlineReplacer.restore` understands: 1 is the
# PR-7 pre-topology layout (implicitly flat), 2 adds the topology geometry
SUPPORTED_SNAPSHOT_VERSIONS = (1, 2)


@dataclass(frozen=True)
class TenantEvent:
    """One roster change: a tenant arriving (with its bench profile) or
    departing.  Within an epoch, departures apply before arrivals."""

    epoch: int
    kind: str                 # "arrive" | "depart"
    name: str
    bench: str | None = None  # required for "arrive"

    def __post_init__(self):
        if self.kind not in ("arrive", "depart"):
            raise ValueError(
                f"event kind must be 'arrive' or 'depart', got "
                f"{self.kind!r}")
        if self.kind == "arrive" and not self.bench:
            raise ValueError(
                f"arrival of {self.name!r} needs a bench profile")
        if self.epoch < 0:
            raise ValueError(f"event epoch must be >= 0, got {self.epoch}")


@dataclass(frozen=True)
class OnlineConfig:
    """Knobs of the epoch loop.

    `epoch_steps` is the scan budget every non-empty core advances per
    epoch (its round-robin shares it between residents); `probe_steps` the
    resume window of the migration-penalty measurement.  `placement`
    carries the simulator geometry (slots, miss latency, quantum) shared
    by the epoch scans, the contention model, and the probes.

    `topology` (a `repro.sched.topology.Topology`) places the cores
    within sockets within hosts; when given, it *defines* `num_cores`.
    The default is `Topology.flat(num_cores)` — one host, one socket —
    which reproduces the pre-topology serve bit-for-bit.
    """

    num_cores: int = 2
    epoch_steps: int = 6_000
    probe_steps: int = 2_000
    # soft per-epoch migration bound: no new exchange unit starts once this
    # many tenants moved (an atomic cycle may overshoot by its length - 1)
    max_moves_per_epoch: int = 4
    bs_cache_entries: int = 64
    bs_miss_extra: int = 100
    placement: PlacementConfig = field(default_factory=PlacementConfig)
    topology: Topology | None = None

    def __post_init__(self):
        if self.topology is None:
            object.__setattr__(self, "topology",
                               Topology.flat(self.num_cores))
        elif not isinstance(self.topology, Topology):
            raise TypeError(
                f"topology must be a repro.sched.topology.Topology, got "
                f"{type(self.topology).__name__}")
        else:
            object.__setattr__(self, "num_cores", self.topology.num_cores)
        if self.num_cores < 1:
            raise ValueError(f"num_cores must be >= 1, got {self.num_cores}")
        if self.epoch_steps < 1 or self.probe_steps < 1:
            raise ValueError("epoch_steps and probe_steps must be >= 1")

    def reconfig(self) -> simulator.ReconfigConfig:
        return simulator.ReconfigConfig(
            num_slots=self.placement.num_slots,
            miss_latency=self.placement.miss_latency,
            bs_cache_entries=self.bs_cache_entries,
            bs_miss_extra=self.bs_miss_extra)


class _TenantRun:
    """Mutable service record of one tenant (cursor + cumulative counters
    survive migrations; the slot caches do not — they belong to cores)."""

    def __init__(self, name: str, bench: str, core: int):
        self.name = name
        self.bench = bench
        self.core = core               # -1: stranded (no core assigned)
        self.cursor = 0
        self.cycles = 0
        self.instrs = 0
        self.slot_misses = 0
        self.migrations = 0
        self.evacuations = 0
        # cycles of service denied while stranded on a down core: each
        # stranded epoch charges the work the tenant should have completed
        # (epoch_steps x solo CPI) as pure delay with nothing retired
        self.stall_cycles = 0.0


class _Core:
    """A physical reconfigurable core: persistent slot/bitstream caches,
    plus its fault status (up/down, usable slot width, reconfig-port
    stall horizon)."""

    def __init__(self, cfg: OnlineConfig):
        self.slot_st = slots.init(cfg.placement.num_slots)
        self.bs_st = slots.init(cfg.bs_cache_entries)
        self.up = True
        self.active_slots = cfg.placement.num_slots
        self.repair_at: int | None = None    # epoch a transient loss heals
        self.repair_degraded = 0             # slots lost after the repair
        self.stall_until = 0                 # reloads to here fail before it


@dataclass
class OnlineReport:
    """Outcome of one `OnlineReplacer.run`.

    `worst_slowdown` is the classic CPI-based contention metric (cycles
    actually spent / solo reference — blind to stranding, since a stalled
    tenant accrues no cycles); `worst_lifetime_slowdown` additionally
    charges every stranded epoch's denied service as delay, so a tenant
    parked on a dead core shows the outage it actually suffered.  In a
    fault-free serve the two coincide per tenant."""

    policy: str
    epochs: int
    migrations: int
    per_tenant: dict                   # name -> service metrics
    worst_slowdown: float
    mean_slowdown: float
    final_cores: tuple[tuple[str, ...], ...]
    moves: list                        # per-move log dicts
    epoch_log: list                    # per-epoch roster/migration rows
    recovery: str = "warm"
    evacuations: int = 0
    worst_lifetime_slowdown: float = 0.0
    fault_log: list = field(default_factory=list)


class OnlineReplacer:
    """Epoch-driven online placement over the resumable fleet simulator.

    `policy`:
      * "never"  — tenants stay where arrival placement put them;
      * "always" — apply every move the per-epoch re-solve implies;
      * "warm"   — apply a move only when its predicted contention saving
        over the next epoch exceeds its *measured* warm-state migration
        penalty (resume-on-cold-core probe).

    `faults` (a `repro.sched.faults.FaultPlan`) injects epoch-aligned
    fault events; `recovery` picks how the replacer reacts
    (`RECOVERY_POLICIES`): "warm" evacuates stranded tenants onto the
    best surviving core (a mandatory move priced for destination choice
    only), "cold_restart" additionally flushes every surviving core's
    caches on a fault epoch (the restart-everything baseline), "none"
    leaves stranded tenants stalled until their core repairs.
    """

    def __init__(self, cfg: OnlineConfig | None = None,
                 model: ContentionModel | None = None,
                 policy: str = "warm", *,
                 faults: FaultPlan | None = None,
                 recovery: str = "warm",
                 backoff_cap: int = 8,
                 resolve_mode: str = "incremental"):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}, expected one of {POLICIES}")
        if resolve_mode not in RESOLVE_MODES:
            raise ValueError(
                f"unknown resolve_mode {resolve_mode!r}, expected one of "
                f"{RESOLVE_MODES}")
        if recovery not in RECOVERY_POLICIES:
            raise ValueError(
                f"unknown recovery policy {recovery!r}, expected one of "
                f"{RECOVERY_POLICIES}")
        if faults is not None and not isinstance(faults, FaultPlan):
            raise TypeError(
                f"faults must be a repro.sched.faults.FaultPlan, got "
                f"{type(faults).__name__}")
        if backoff_cap < 1:
            raise ValueError(f"backoff_cap must be >= 1, got {backoff_cap}")
        self.cfg = cfg or OnlineConfig()
        self.model = model or ContentionModel(self.cfg.placement)
        if self.model.cfg.num_slots != self.cfg.placement.num_slots:
            raise ValueError(
                f"contention model simulates {self.model.cfg.num_slots} "
                f"slots but the online config serves "
                f"{self.cfg.placement.num_slots} — predictions would price "
                f"a different machine")
        self.policy = policy
        self.faults = faults
        self.recovery = recovery
        self.backoff_cap = backoff_cap
        self.resolve_mode = resolve_mode
        self.tenants: dict[str, _TenantRun] = {}
        self.departed: list[_TenantRun] = []
        self.cores = [_Core(self.cfg) for _ in range(self.cfg.num_cores)]
        self.migrations = 0
        self.evacuations = 0
        self.moves: list[dict] = []
        self.fault_log: list[dict] = []
        self.epoch_log: list[dict] = []
        # per-tenant reconfig-retry ledger: attempts blocked by a stalled
        # destination back off exponentially (capped) before retrying
        self._retry: dict[str, dict] = {}
        self._epoch = 0                      # next epoch run() executes
        # incremental re-solve state: per-host cached target assignments
        # (the kept swap frontier) and the set of hosts dirtied since the
        # last re-solve.  Everything starts dirty; `resolve_log` records
        # per-epoch solved/cached domain counts + wall time (telemetry
        # only — never part of the report or a snapshot, so restored
        # serves stay bit-for-bit comparable)
        self._domain_target: dict[int, dict[str, int]] = {}
        self._dirty: set[int] = set(range(self.cfg.topology.num_hosts))
        self.resolve_log: list[dict] = []

    # ------------------------------------------------------------------
    # roster bookkeeping
    # ------------------------------------------------------------------
    def _members(self, core: int) -> list[_TenantRun]:
        return sorted((t for t in self.tenants.values() if t.core == core),
                      key=lambda t: t.name)

    def _core_map(self) -> dict[int, list[_TenantRun]]:
        """core index -> name-sorted members, built in ONE O(T) pass.
        The fleet-scale hot paths (arrival candidate scoring, unit
        pricing, the per-domain re-solve) take this precomputed map
        instead of calling `_members` per core — a per-core scan made
        arrivals O(T x C) and unit pricing O(T x units), hopeless at
        1000 tenants."""
        cm: dict[int, list[_TenantRun]] = {}
        for name in sorted(self.tenants):
            t = self.tenants[name]
            cm.setdefault(t.core, []).append(t)
        return cm

    def _groups(self) -> list[tuple[str, ...]]:
        cm = self._core_map()
        return [tuple(sorted(t.bench for t in cm.get(c, [])))
                for c in range(self.cfg.num_cores)]

    def _up_cores(self) -> list[int]:
        return [ci for ci in range(self.cfg.num_cores) if self.cores[ci].up]

    def _mark_dirty(self, core: int) -> None:
        """Record that `core`'s host must be re-solved next epoch (its
        roster, up-set or current assignment changed).  Stranded tenants
        (core < 0) belong to no domain until recovery places them."""
        if core >= 0:
            self._dirty.add(self.cfg.topology.host_of(core))

    def _predict_on(self, pairs) -> list:
        """Predict each (core, group) pair's slowdowns at that core's
        usable slot width.  Full-width cores batch through one `predict`
        call (the fault-free fast path, bit-identical to the pre-fault
        code); degraded cores price at their reduced width, which is what
        down-weights them as destinations."""
        if all(self.cores[c].active_slots == self.cfg.placement.num_slots
               for c, _ in pairs):
            return self.model.predict([g for _, g in pairs])
        return [self.model.predict(
                    [g], num_slots=self.cores[c].active_slots)[0]
                for c, g in pairs]

    def _arrive(self, name: str, bench: str) -> None:
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} arrived twice")
        if any(t.name == name for t in self.departed):
            raise ValueError(
                f"tenant name {name!r} was already served and departed — "
                f"service records are keyed by name, so a returning "
                f"tenant needs a fresh name (e.g. {name!r}-2)")
        self.model.trace(bench)            # validates the bench name
        up = self._up_cores()
        if not up:
            # fully-dark fleet: the tenant strands until a core repairs
            self.tenants[name] = _TenantRun(name, bench, -1)
            return
        cm = self._core_map()
        counts = [len(cm.get(c, [])) for c in up]
        open_cores = [c for c, n in zip(up, counts) if n == min(counts)]
        # among least-loaded up cores, join the one whose resulting group
        # predicts the best (worst, mean) slowdown — greedy, no migration
        cand = [tuple(sorted([t.bench for t in cm.get(c, [])] + [bench]))
                for c in open_cores]
        preds = self._predict_on(list(zip(open_cores, cand)))
        best = min(range(len(open_cores)),
                   key=lambda i: (float(np.max(preds[i])),
                                  float(np.mean(preds[i])), i))
        self.tenants[name] = _TenantRun(name, bench, open_cores[best])
        self._mark_dirty(open_cores[best])

    def _depart(self, name: str) -> None:
        if name not in self.tenants:
            raise ValueError(f"departure of unknown tenant {name!r}")
        # the core keeps its caches — a departed tenant's residents decay
        # naturally under LRU as the survivors run; the service record is
        # archived so the final report scores every tenant ever served
        self._mark_dirty(self.tenants[name].core)
        self.departed.append(self.tenants.pop(name))

    # ------------------------------------------------------------------
    # fault injection, detection and recovery
    # ------------------------------------------------------------------
    def _apply_faults(self, epoch: int) -> bool:
        """Heal due repairs, then inject this epoch's scheduled faults.
        Returns True when any fault fired (cold_restart keys off it)."""
        for ci, core in enumerate(self.cores):
            if core.up or core.repair_at is None or epoch < core.repair_at:
                continue
            # the repaired region is rebuilt: caches come back cold, and
            # possibly narrower (masked via num_active in every later sim)
            core.up = True
            core.repair_at = None
            core.active_slots = max(
                1, self.cfg.placement.num_slots - core.repair_degraded)
            core.repair_degraded = 0
            core.slot_st = slots.init(self.cfg.placement.num_slots)
            core.bs_st = slots.init(self.cfg.bs_cache_entries)
            self._mark_dirty(ci)           # up-set changed: host re-solves
            self.fault_log.append({"epoch": epoch, "kind": "repair",
                                   "core": ci,
                                   "active_slots": core.active_slots})
        if self.faults is None:
            return False
        any_fault = False
        for ev in self.faults.at(epoch):
            core = self.cores[ev.core]
            if not core.up:
                continue        # a down core absorbs no further faults
            rec = {"epoch": epoch, "detected": epoch, "kind": ev.kind,
                   "core": ev.core}
            if ev.kind == "core_loss":
                core.up = False
                core.repair_at = (None if ev.permanent
                                  else epoch + ev.repair_epochs)
                core.repair_degraded = ev.degraded_slots
                rec["permanent"] = ev.permanent
                rec["repair_at"] = core.repair_at
                rec["stranded"] = tuple(t.name
                                        for t in self._members(ev.core))
            elif ev.kind == "slot_seu":
                tags = np.asarray(core.slot_st.tags)
                occupied = np.nonzero(tags >= 0)[0]
                hit = np.sort(self.faults.rng(ev).choice(
                    occupied, size=min(ev.num_hit, occupied.size),
                    replace=False)) if occupied.size else occupied
                rec["hit_entries"] = tuple(int(i) for i in hit)
                rec["hit_tags"] = tuple(int(tags[i]) for i in hit)
                if hit.size:
                    core.slot_st = simulator.canonical_slot_state(
                        slots.invalidate(core.slot_st, hit))
            elif ev.kind == "bitstream_flush":
                core.bs_st = slots.init(self.cfg.bs_cache_entries)
            else:                                   # reconfig_stall
                core.stall_until = max(core.stall_until,
                                       epoch + ev.stall_epochs)
                rec["stall_until"] = core.stall_until
            # conservative: any fault on the core dirties its host (a
            # core_loss changes the up-set; the rest are over-marking,
            # which only re-solves more — under-marking would break the
            # incremental == full guarantee)
            self._mark_dirty(ev.core)
            self.fault_log.append(rec)
            any_fault = True
        return any_fault

    def _attempt_move(self, name: str, dst: int, epoch: int, *,
                      why: str) -> bool:
        """Gate a reload/migration attempt on the destination's reconfig
        port.  A stalled destination fails the attempt and schedules a
        retry with capped exponential backoff; a pending backoff defers
        silently until its epoch comes up."""
        r = self._retry.get(name)
        if r is not None and epoch < r["next"]:
            return False
        if epoch < self.cores[dst].stall_until:
            retries = (r["retries"] if r is not None else 0) + 1
            delay = min(1 << (retries - 1), self.backoff_cap)
            self._retry[name] = {"retries": retries, "next": epoch + delay}
            self.fault_log.append({
                "epoch": epoch, "kind": "reconfig_retry", "tenant": name,
                "dst": dst, "why": why, "retries": retries,
                "next_attempt": epoch + delay})
            return False
        return True

    def _cold_resume_cycles(self, t: _TenantRun, dst: int) -> float:
        """Cycles of re-warming the evacuee pays on its destination,
        measured by a solo probe resumed from the destination's actual
        caches (usually cold for this tenant's tags) against the solo
        reference — the fault log's 'what did this evacuation cost'."""
        pcfg = self.cfg.placement
        core = self.cores[dst]
        st = simulator.init_fleet_state(
            1, pcfg.num_slots, self.cfg.bs_cache_entries)._replace(
                slot_st=core.slot_st, bs_st=core.bs_st,
                cursors=jnp.asarray([t.cursor], jnp.int32))
        na = (core.active_slots
              if core.active_slots < pcfg.num_slots else None)
        res = simulator.simulate_many(
            np.asarray(self.model.trace(t.bench))[None, :],
            self.cfg.reconfig(), self.model.scenario_of(t.bench),
            simulator.SchedulerConfig.no_preempt(pcfg.handler_cycles),
            total_steps=self.cfg.probe_steps, state=st, num_active=na)
        return max(0.0, float(int(res.cycles[0]))
                   - self.cfg.probe_steps * self.model.solo_cpi(t.bench))

    def _recover(self, epoch: int) -> None:
        """Evacuate stranded tenants (core lost, or never placed) onto the
        best surviving core.  Evacuations are *mandatory* moves: the
        contention model prices only the destination choice — there is no
        net-benefit gate, because the alternative is not-running."""
        if self.recovery == "none":
            return
        stranded = sorted(
            (t for t in self.tenants.values()
             if t.core < 0 or not self.cores[t.core].up),
            key=lambda t: t.name)
        up = self._up_cores()
        if not stranded or not up:
            return
        # prefer destinations whose reconfig port is not stalled; if every
        # up core is stalled, attempts go through backoff and retry later
        avail = [c for c in up
                 if epoch >= self.cores[c].stall_until] or up
        topo = self.cfg.topology
        cm = self._core_map()
        for t in stranded:
            cand = [tuple(sorted([m.bench for m in cm.get(c, [])]
                                 + [t.bench])) for c in avail]
            preds = self._predict_on(list(zip(avail, cand)))
            best = min(range(len(avail)),
                       key=lambda i: (float(np.max(preds[i])),
                                      float(np.mean(preds[i])), i))
            dst = avail[best]
            src = t.core
            if not self._attempt_move(t.name, dst, epoch,
                                      why="evacuation"):
                continue
            cold = self._cold_resume_cycles(t, dst)
            # a cross-socket/host evacuation additionally re-loads every
            # warm bitstream the tenant leaves behind (LUTstructions tier
            # surcharge); the move is mandatory so the cost lands as
            # denied-service stall, not as a gate
            reload = self.reload_cycles(t.name, dst) if src >= 0 else 0.0
            retries = self._retry.pop(t.name, {"retries": 0})["retries"]
            if src in cm:
                cm[src] = [m for m in cm[src] if m.name != t.name]
            t.core = dst
            cm.setdefault(dst, []).append(t)
            t.evacuations += 1
            t.stall_cycles += reload
            self.evacuations += 1
            self._mark_dirty(src)
            self._mark_dirty(dst)
            rec = {"epoch": epoch, "kind": "evacuation", "tenant": t.name,
                   "src": src, "dst": dst, "retries": retries,
                   "cold_resume_cycles": cold}
            if src >= 0:
                rec["distance"] = topo.distance(src, dst)
                rec["reload_cycles"] = reload
            self.fault_log.append(rec)

    # ------------------------------------------------------------------
    # epoch advance over resumable fleet state
    # ------------------------------------------------------------------
    def _advance_epoch(self) -> None:
        pcfg = self.cfg.placement
        sched = pcfg.scheduler()
        rcfg = self.cfg.reconfig()
        core_map = self._core_map()
        for ci in range(self.cfg.num_cores):
            core = self.cores[ci]
            if not core.up:
                continue                   # stranded tenants accrue stall
            members = core_map.get(ci, [])
            if not members:
                continue
            tr = np.stack([np.asarray(self.model.trace(t.bench))
                           for t in members])
            st = simulator.init_fleet_state(
                len(members), pcfg.num_slots, self.cfg.bs_cache_entries)
            # resume: the core's caches are warm from every prior epoch
            # (and from prior residents); cursors continue each tenant's
            # own stream; counters start at zero -> per-epoch deltas
            st = st._replace(
                slot_st=core.slot_st, bs_st=core.bs_st,
                cursors=jnp.asarray([t.cursor for t in members], jnp.int32))
            na = (core.active_slots
                  if core.active_slots < pcfg.num_slots else None)
            res, st = simulator.simulate_many(
                tr, rcfg,
                [self.model.scenario_of(t.bench) for t in members],
                sched, total_steps=self.cfg.epoch_steps,
                state=st, return_state=True, num_active=na)
            core.slot_st, core.bs_st = st.slot_st, st.bs_st
            cursors = np.asarray(st.cursors)
            cycles = np.asarray(res.cycles)
            instrs = np.asarray(res.instructions)
            misses = np.asarray(res.slot_misses)
            for p, t in enumerate(members):
                t.cursor = int(cursors[p])
                t.cycles += int(cycles[p])
                t.instrs += int(instrs[p])
                t.slot_misses += int(misses[p])

    # ------------------------------------------------------------------
    # warm-state migration pricing
    # ------------------------------------------------------------------
    def reload_cycles(self, name: str, dst: int) -> float:
        """LUTstructions re-load surcharge of moving `name` to `dst`:
        every one of the tenant's bitstreams warm on its *current* core
        must be re-loaded across the interconnect, at `bs_miss_extra`
        cycles each scaled by the topology's distance-tier multiplier.
        Zero within a socket (the measured probe already prices that
        tier) — so a flat topology prices every move exactly as before.
        """
        t = self.tenants[name]
        topo = self.cfg.topology
        if t.core < 0:
            return 0.0          # stranded: no warm state to leave behind
        mult = topo.reload_multiplier(topo.distance(t.core, dst))
        if mult == 0.0:
            return 0.0
        tag_row = np.asarray(self.model.scenario_of(t.bench).instr_tag)
        tags = np.unique(tag_row[np.asarray(self.model.trace(t.bench))])
        tags = tags[tags >= 0]
        if tags.size == 0:
            return 0.0
        res = slots.resident_many(self.cores[t.core].bs_st,
                                  jnp.asarray(tags, jnp.int32))
        resident = int(np.sum(np.asarray(res)))
        return float(resident * self.cfg.bs_miss_extra * mult)

    def migration_penalty(self, name: str, dst: int | None = None) -> float:
        """Cost (cycles) of restarting `name` on another core.

        The base is *measured*: the tenant's state is resumed solo for
        `probe_steps` twice — from its current core's warm caches and
        from a cold `init_fleet_state` — and the penalty is the cycle
        difference.  This is the LUTstructions quantity: how many cycles
        of reconfiguration/bitstream re-loading the destination core
        charges before the tenant is warm again.

        With a destination, the move's distance tier adds the modelled
        `reload_cycles` surcharge on top: cross-socket and cross-host
        moves must re-load the mover's resident bitstreams over the
        interconnect, which the local probe cannot see.  `dst=None` (or
        any intra-socket destination) is the bare probe, bit-identical
        to the pre-topology pricing.
        """
        t = self.tenants[name]
        pcfg = self.cfg.placement
        rcfg = self.cfg.reconfig()
        scen = self.model.scenario_of(t.bench)
        tr = np.asarray(self.model.trace(t.bench))[None, :]
        cold = simulator.init_fleet_state(
            1, pcfg.num_slots, self.cfg.bs_cache_entries)._replace(
                cursors=jnp.asarray([t.cursor], jnp.int32))
        core = self.cores[t.core]
        warm = cold._replace(slot_st=core.slot_st, bs_st=core.bs_st)
        sched = simulator.SchedulerConfig.no_preempt(pcfg.handler_cycles)
        kw = dict(total_steps=self.cfg.probe_steps, return_state=False)
        # the warm probe replays the tenant's current (possibly degraded)
        # core; the cold probe is the full-width destination baseline
        na = (core.active_slots
              if core.active_slots < pcfg.num_slots else None)
        res_c = simulator.simulate_many(tr, rcfg, scen, sched,
                                        state=cold, **kw)
        res_w = simulator.simulate_many(tr, rcfg, scen, sched,
                                        state=warm, num_active=na, **kw)
        probe = float(int(res_c.cycles[0]) - int(res_w.cycles[0]))
        if dst is None:
            return probe
        return probe + self.reload_cycles(name, dst)

    def warm_fraction(self, name: str) -> float:
        """Fraction of the tenant's slotted tag set resident on its core's
        disambiguator right now (observability for the move log)."""
        t = self.tenants[name]
        tag_row = np.asarray(self.model.scenario_of(t.bench).instr_tag)
        tags = np.unique(tag_row[np.asarray(self.model.trace(t.bench))])
        tags = tags[tags >= 0]
        if tags.size == 0:
            return 1.0
        res = slots.resident_many(self.cores[t.core].slot_st,
                                  jnp.asarray(tags, jnp.int32))
        return float(np.mean(np.asarray(res)))

    def _group_cycles(self, group: tuple[str, ...],
                      core: int | None = None) -> float:
        """Predicted cycles one epoch spends serving `group` on one core:
        per-member slowdown x solo CPI x the member's round-robin share of
        the epoch's step budget.  Pass `core` to price at that core's
        usable slot width (degraded cores predict worse, so the re-solve
        naturally steers load off them)."""
        if not group:
            return 0.0
        ns = None
        if (core is not None and self.cores[core].active_slots
                < self.cfg.placement.num_slots):
            ns = self.cores[core].active_slots
        pred = self.model.predict([group], num_slots=ns)[0]
        share = self.cfg.epoch_steps / len(group)
        solo = np.array([self.model.solo_cpi(b) for b in sorted(group)])
        return float(np.sum(pred * solo * share))

    def move_benefit(self, moves: dict[str, int],
                     core_map: dict[int, list] | None = None) -> float:
        """Predicted contention delta (cycles/epoch) of applying `moves`
        (tenant name -> destination core) atomically: old-cost minus
        new-cost summed over every affected core.  A cross-core swap must
        be priced as one unit — each leg alone transits through a
        lopsided group and would misprice the exchange.  Pass `core_map`
        (a `_core_map()` snapshot of the current membership) to avoid the
        O(tenants) rebuild per call on the rebalance hot path."""
        if core_map is None:
            core_map = self._core_map()
        affected = {self.tenants[n].core for n in moves} | set(moves.values())
        old = new = 0.0
        # ascending core order keeps the float summation order identical
        # to the historical full scan over range(num_cores)
        for ci in sorted(affected):
            if ci < 0:
                continue
            members = core_map.get(ci, [])
            cur = [t.bench for t in members]
            nxt = [t.bench for t in members
                   if t.name not in moves or moves[t.name] == ci]
            nxt += [self.tenants[n].bench for n, dst in moves.items()
                    if dst == ci and self.tenants[n].core != ci]
            old += self._group_cycles(tuple(sorted(cur)), core=ci)
            new += self._group_cycles(tuple(sorted(nxt)), core=ci)
        return old - new

    # ------------------------------------------------------------------
    # per-epoch re-solve
    # ------------------------------------------------------------------
    def _solve_domain(self, host: int,
                      core_map: dict[int, list]) -> dict[str, int]:
        """Re-solve placement for one host's roster and align the solved
        cores to the host's physical cores by membership overlap (a
        re-solve that merely permutes core labels must imply zero moves).
        Only tenants on *up* cores are re-solved: stranded tenants come
        back through the recovery path (`_recover`), never through
        rebalancing — the separation keeps the recovery-policy comparison
        honest.  Deterministic given the host's roster and up-set, which
        is what makes the incremental cache pure memoisation."""
        up = [c for c in self.cfg.topology.cores_of_host(host)
              if self.cores[c].up]
        roster = {t.name: t.bench
                  for c in up for t in core_map.get(c, [])}
        if len(roster) < 2 or not up:
            return {}
        pl = place_tenants(roster, min(len(up), len(roster)), self.model)
        solved = [set(core) for core in pl.cores]
        unassigned = set(up)
        target: dict[str, int] = {}
        current = {t.name: t.core
                   for c in up for t in core_map.get(c, [])}
        order = sorted(
            range(len(solved)),
            key=lambda si: -len(solved[si]))
        for si in order:
            best = max(unassigned, key=lambda ci: (
                sum(1 for n in solved[si] if current.get(n) == ci), -ci))
            unassigned.discard(best)
            for n in solved[si]:
                target[n] = best
        return target

    def _target_assignment(self, epoch: int | None = None) -> dict[str, int]:
        """Per-epoch re-solve: each host is an independent placement
        domain (`_solve_domain`).  In the default incremental mode only
        domains dirtied since the last re-solve run the greedy + swap
        search; clean domains reuse their cached target — bit-for-bit
        the same answer, because the domain solve is a deterministic
        function of the host's roster/up-set and every mutation of
        either marks the host dirty.  `resolve_mode="full"` re-solves
        every domain every epoch (the parity baseline)."""
        topo = self.cfg.topology
        core_map = self._core_map()
        t0 = time.perf_counter()
        dirty = (set(range(topo.num_hosts))
                 if self.resolve_mode == "full" else set(self._dirty))
        solved = 0
        target: dict[str, int] = {}
        for host in range(topo.num_hosts):
            if host in dirty:
                self._domain_target[host] = self._solve_domain(
                    host, core_map)
                solved += 1
            target.update(self._domain_target.get(host, {}))
        self._dirty.clear()
        self.resolve_log.append({
            "epoch": self._epoch if epoch is None else epoch,
            "mode": self.resolve_mode,
            "solved": solved,
            "cached": topo.num_hosts - solved,
            "seconds": time.perf_counter() - t0,
        })
        return target

    def _exchange_units(self, target: dict[str, int]) -> list[tuple]:
        """Group the target's pending moves into minimal exchange units.

        The pending moves form a permutation-like flow between cores; it
        decomposes into *chains* (a tenant moves into spare capacity) and
        *cycles* (tenants trade places — a swap is the 2-cycle).  A cycle
        must be priced and applied atomically: each leg alone transits
        through a lopsided group and would misprice the exchange."""
        pending = [(n, self.tenants[n].core, c)
                   for n, c in sorted(target.items())
                   if c != self.tenants[n].core]
        units: list[tuple] = []
        while pending:
            chain = [pending.pop(0)]
            while True:
                end = chain[-1][2]
                if end == chain[0][1]:
                    break                      # closed cycle
                nxt = next((m for m in pending if m[1] == end), None)
                if nxt is None:
                    break                      # open chain (spare capacity)
                pending.remove(nxt)
                chain.append(nxt)
            units.append(tuple(n for n, _, _ in chain))
        return units

    def rebalance(self, epoch: int) -> int:
        """One re-placement round; returns how many tenants moved."""
        if self.policy == "never":
            return 0
        target = self._target_assignment(epoch)
        if not target:
            return 0
        topo = self.cfg.topology
        units = self._exchange_units(target)
        moved = 0
        # most beneficial unit first; re-price against the *current*
        # membership before each apply (an earlier unit changes groups)
        while units and moved < self.cfg.max_moves_per_epoch:
            core_map = self._core_map()
            scored = [(self.move_benefit({n: target[n] for n in u},
                                         core_map), u)
                      for u in units]
            scored.sort(key=lambda x: (-x[0], x[1]))
            benefit, unit = scored[0]
            units.remove(unit)
            # tiered penalty: measured warm-resume probe plus the
            # distance-dependent re-load surcharge of each leg
            penalty = sum(self.migration_penalty(n, target[n])
                          for n in unit)
            net = benefit - penalty
            take = self.policy == "always" or net > 0.0
            blocked = False
            if take:
                # every leg's destination port must accept the reload;
                # stalled legs enter backoff and the unit stays put
                oks = [self._attempt_move(n, target[n], epoch,
                                          why="rebalance") for n in unit]
                if not all(oks):
                    take, blocked = False, True
            move = {
                "epoch": epoch, "tenants": unit,
                "src": tuple(self.tenants[n].core for n in unit),
                "dst": tuple(target[n] for n in unit),
                "distance": tuple(
                    topo.distance(self.tenants[n].core, target[n])
                    for n in unit),
                "benefit_cycles": benefit, "penalty_cycles": penalty,
                "net_cycles": net,
                "warm_fraction": tuple(self.warm_fraction(n)
                                       for n in unit),
                "applied": take,
            }
            if blocked:
                move["blocked"] = True
            self.moves.append(move)
            if take:
                for n in unit:
                    self._retry.pop(n, None)
                    self._mark_dirty(self.tenants[n].core)
                    self.tenants[n].core = target[n]
                    self._mark_dirty(target[n])
                    self.tenants[n].migrations += 1
                    self.migrations += 1
                    moved += 1
        return moved

    # ------------------------------------------------------------------
    def run(self, events, num_epochs: int | None = None, *,
            checkpoint_every: int = 0, save_fn=None) -> OnlineReport:
        """Serve an event stream for `num_epochs` epochs (default: last
        event epoch + 4 drain epochs).

        `checkpoint_every=k` calls ``save_fn(snapshot, epoch)`` after
        every k-th completed epoch; a replacer `restore`d from such a
        snapshot and `run` with the same arguments resumes at the next
        epoch and finishes bit-for-bit identical to the uninterrupted
        serve (the fault plan's randomness is counter-based, so the
        replayed suffix sees the identical storm)."""
        events = list(events)
        if num_epochs is None:
            num_epochs = (max((e.epoch for e in events), default=0) + 5)
        if checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0, got {checkpoint_every}")
        if checkpoint_every and save_fn is None:
            raise ValueError("checkpoint_every needs a save_fn")
        by_epoch: dict[int, list[TenantEvent]] = {}
        for e in events:
            if e.epoch >= num_epochs:
                raise ValueError(
                    f"event at epoch {e.epoch} outside the horizon "
                    f"{num_epochs}")
            by_epoch.setdefault(e.epoch, []).append(e)
        if self.faults is not None \
                and self.faults.max_core() >= self.cfg.num_cores:
            raise ValueError(
                f"fault plan targets core {self.faults.max_core()} but "
                f"the fleet has {self.cfg.num_cores} cores")
        for epoch in range(self._epoch, num_epochs):
            any_fault = self._apply_faults(epoch)
            if any_fault and self.recovery == "cold_restart":
                # restart-everything baseline: every surviving core's
                # caches are flushed, the whole fleet re-pays warm-up
                for core in self.cores:
                    if core.up:
                        core.slot_st = slots.init(
                            self.cfg.placement.num_slots)
                        core.bs_st = slots.init(self.cfg.bs_cache_entries)
                self.fault_log.append({"epoch": epoch,
                                       "kind": "cold_restart"})
            todays = by_epoch.get(epoch, [])
            for e in todays:                      # departures first
                if e.kind == "depart":
                    self._depart(e.name)
            for e in todays:
                if e.kind == "arrive":
                    self._arrive(e.name, e.bench)
            self._recover(epoch)
            moved = self.rebalance(epoch)
            self._advance_epoch()
            # denied service: a stranded tenant should have retired
            # epoch_steps instructions at its solo CPI — charge that as
            # pure stall so lifetime slowdown reflects the outage
            for t in self.tenants.values():
                if t.core < 0 or not self.cores[t.core].up:
                    t.stall_cycles += (self.cfg.epoch_steps
                                       * self.model.solo_cpi(t.bench))
            cm = self._core_map()
            row = {
                "epoch": epoch,
                "tenants": len(self.tenants),
                "moved": moved,
                "cores": tuple(tuple(t.name for t in cm.get(c, []))
                               for c in range(self.cfg.num_cores)),
            }
            if self.faults is not None:
                row["down"] = tuple(ci for ci in range(self.cfg.num_cores)
                                    if not self.cores[ci].up)
            self.epoch_log.append(row)
            self._epoch = epoch + 1
            if checkpoint_every and (epoch + 1) % checkpoint_every == 0:
                save_fn(self.snapshot(), epoch)
        return self._report(num_epochs)

    # ------------------------------------------------------------------
    # checkpoint / restore (crash-restartable serving)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Complete host-side serving state as a plain dict of numpy
        arrays and python scalars (`runtime.fault`-style): tenants with
        cursors and counters, per-core caches and fault status, retry
        ledger, and every log.  No RNG state — the fault plan's
        randomness is counter-based and replays from the plan itself."""
        def _core(c):
            return {
                "tags": np.asarray(c.slot_st.tags).copy(),
                "last_use": np.asarray(c.slot_st.last_use).copy(),
                "clock": int(c.slot_st.clock),
                "bs_tags": np.asarray(c.bs_st.tags).copy(),
                "bs_last_use": np.asarray(c.bs_st.last_use).copy(),
                "bs_clock": int(c.bs_st.clock),
                "up": c.up, "active_slots": c.active_slots,
                "repair_at": c.repair_at,
                "repair_degraded": c.repair_degraded,
                "stall_until": c.stall_until,
            }

        def _tenant(t):
            return {"name": t.name, "bench": t.bench, "core": t.core,
                    "cursor": t.cursor, "cycles": t.cycles,
                    "instrs": t.instrs, "slot_misses": t.slot_misses,
                    "migrations": t.migrations,
                    "evacuations": t.evacuations,
                    "stall_cycles": t.stall_cycles}

        return {
            "version": 2,
            "epoch": self._epoch,
            "policy": self.policy,
            "recovery": self.recovery,
            "topology": self.cfg.topology.geometry(),
            "num_cores": self.cfg.num_cores,
            "num_slots": self.cfg.placement.num_slots,
            "bs_entries": self.cfg.bs_cache_entries,
            "migrations": self.migrations,
            "evacuations": self.evacuations,
            "tenants": [_tenant(self.tenants[n])
                        for n in sorted(self.tenants)],
            "departed": [_tenant(t) for t in self.departed],
            "cores": [_core(c) for c in self.cores],
            "retry": copy.deepcopy(self._retry),
            "moves": copy.deepcopy(self.moves),
            "fault_log": copy.deepcopy(self.fault_log),
            "epoch_log": copy.deepcopy(self.epoch_log),
        }

    def restore(self, snap: dict) -> None:
        """Load a `snapshot` into this replacer; the next `run` resumes
        at the snapshot's epoch.  The replacer must be constructed with
        the same config/policy/recovery/fault plan as the one that saved
        the snapshot.  Version 1 snapshots (pre-topology) carry no
        geometry and load only onto a flat topology."""
        version = snap.get("version")
        if version not in SUPPORTED_SNAPSHOT_VERSIONS:
            raise ValueError(
                f"unknown snapshot version {version!r}; this replacer "
                f"supports versions {SUPPORTED_SNAPSHOT_VERSIONS} — a "
                f"newer writer's snapshot cannot be silently misread")
        geo = tuple(snap.get("topology", (1, 1, snap["num_cores"])))
        if geo != self.cfg.topology.geometry():
            raise ValueError(
                f"snapshot topology {geo} (hosts, sockets/host, "
                f"cores/socket) does not match this replacer's "
                f"{self.cfg.topology.geometry()}")
        for key, mine in (("policy", self.policy),
                          ("recovery", self.recovery),
                          ("num_cores", self.cfg.num_cores),
                          ("num_slots", self.cfg.placement.num_slots),
                          ("bs_entries", self.cfg.bs_cache_entries)):
            if snap[key] != mine:
                raise ValueError(
                    f"snapshot {key}={snap[key]!r} does not match this "
                    f"replacer's {mine!r}")

        def _tenant(d):
            t = _TenantRun(d["name"], d["bench"], d["core"])
            t.cursor = d["cursor"]
            t.cycles = d["cycles"]
            t.instrs = d["instrs"]
            t.slot_misses = d["slot_misses"]
            t.migrations = d["migrations"]
            t.evacuations = d["evacuations"]
            t.stall_cycles = d["stall_cycles"]
            return t

        self.tenants = {d["name"]: _tenant(d) for d in snap["tenants"]}
        self.departed = [_tenant(d) for d in snap["departed"]]
        self.cores = [_Core(self.cfg) for _ in range(self.cfg.num_cores)]
        for core, d in zip(self.cores, snap["cores"]):
            core.slot_st = slots.SlotState(
                tags=jnp.asarray(d["tags"], jnp.int32),
                last_use=jnp.asarray(d["last_use"], jnp.int32),
                clock=jnp.int32(d["clock"]))
            core.bs_st = slots.SlotState(
                tags=jnp.asarray(d["bs_tags"], jnp.int32),
                last_use=jnp.asarray(d["bs_last_use"], jnp.int32),
                clock=jnp.int32(d["bs_clock"]))
            core.up = d["up"]
            core.active_slots = d["active_slots"]
            core.repair_at = d["repair_at"]
            core.repair_degraded = d["repair_degraded"]
            core.stall_until = d["stall_until"]
        self.migrations = snap["migrations"]
        self.evacuations = snap["evacuations"]
        self._retry = copy.deepcopy(snap["retry"])
        self.moves = copy.deepcopy(snap["moves"])
        self.fault_log = copy.deepcopy(snap["fault_log"])
        self.epoch_log = copy.deepcopy(snap["epoch_log"])
        self._epoch = snap["epoch"]
        # the incremental cache never travels in a snapshot: everything
        # starts dirty, so the first resumed epoch re-solves every domain
        # — pure memoisation of a deterministic solve, so the resumed
        # serve stays bit-for-bit identical to the uninterrupted one
        self._domain_target = {}
        self._dirty = set(range(self.cfg.topology.num_hosts))

    # ------------------------------------------------------------------
    def _report(self, num_epochs: int) -> OnlineReport:
        per_tenant: dict[str, dict] = {}
        slowdowns = []
        lifetimes = []
        records = {t.name: t for t in self.departed}
        records.update(self.tenants)
        for name in sorted(records):
            t = records[name]
            if t.instrs == 0:
                per_tenant[name] = {"bench": t.bench, "instrs": 0,
                                    "scheduled": False}
                if t.stall_cycles > 0:
                    # served nothing while stranded: unbounded slowdown
                    per_tenant[name]["stall_cycles"] = t.stall_cycles
                    per_tenant[name]["lifetime_slowdown"] = float("inf")
                    lifetimes.append(float("inf"))
                continue
            cpi = t.cycles / t.instrs
            solo = self.model.solo_cpi(t.bench)
            slow = cpi / solo
            lifetime = (t.cycles + t.stall_cycles) / (t.instrs * solo)
            slowdowns.append(slow)
            lifetimes.append(lifetime)
            per_tenant[name] = {
                "bench": t.bench, "instrs": t.instrs, "cycles": t.cycles,
                "slot_misses": t.slot_misses, "cpi": cpi,
                "solo_cpi": solo,
                "slowdown": slow, "migrations": t.migrations,
                "evacuations": t.evacuations,
                "stall_cycles": t.stall_cycles,
                "lifetime_slowdown": lifetime,
                "scheduled": True,
            }
        return OnlineReport(
            policy=self.policy,
            epochs=num_epochs,
            migrations=self.migrations,
            per_tenant=per_tenant,
            worst_slowdown=float(max(slowdowns)) if slowdowns else 0.0,
            mean_slowdown=float(np.mean(slowdowns)) if slowdowns else 0.0,
            final_cores=tuple(tuple(t.name for t in self._members(c))
                              for c in range(self.cfg.num_cores)),
            moves=self.moves,
            epoch_log=self.epoch_log,
            recovery=self.recovery,
            evacuations=self.evacuations,
            worst_lifetime_slowdown=(max(lifetimes) if lifetimes else 0.0),
            fault_log=self.fault_log,
        )
