"""Online re-placement: epoch-structured serving with warm-state-aware
tenant migration.

The static placement layer (`repro.sched.placement`) answers "which tenants
should co-reside" once, for a fixed roster.  Real serving rosters churn:
tenants arrive and leave mid-serve, and every arrival/departure can turn a
good placement into a bad one.  This module serves a churn workload as a
sequence of *epochs* over the resumable fleet simulator
(`repro.core.simulator.FleetState`):

  * each reconfigurable core carries its disambiguator + bitstream cache
    across epochs AND across membership changes — warm state persists on
    the core, which is the paper's architectural point (§IV) and exactly
    what makes migration expensive: a tenant moved to another core leaves
    its resident slots behind;
  * each epoch the `OnlineReplacer` re-solves placement for the current
    roster through the `ContentionModel` (`place_tenants`), aligns the
    solution to the physical cores by membership overlap, and prices every
    implied move as

        net = predicted-contention-delta  -  warm-state migration penalty

    where the contention delta converts predicted slowdown changes of every
    affected tenant into cycles over the next epoch, and the migration
    penalty is *measured*, not modelled: the mover's state is resumed for a
    probe window twice — once on its current (warm) core and once on a cold
    core — and the penalty is the cycle difference (LUTstructions'
    re-loading cost as a first-class quantity);
  * policy "warm" applies only net-positive moves; the baselines are
    "never" (arrival placement is final) and "always" (apply every move the
    re-solve implies, blind to migration cost).

`benchmarks/online_churn.py` shows warm-aware re-placement matching or
beating never-migrate on worst-tenant slowdown while migrating less than
always-rebalance; `repro.serve.engine.SlotServeEngine.serve_online` wires
the loop into the serving layer.

Cost structure per epoch: every simulation the loop issues now rides the
interleave-aware stack-distance engine
(`repro.core.stackdist_interleaved`).  The re-solve and every move's
contention-delta pricing go through the `ContentionModel`'s one-shot
preempted sweeps; the epoch *advance* and the migration-penalty probes
resume explicit `FleetState`s and ride the engine's *resumable* entry
(`simulate_many(..., state=S, return_state=True)` seeds the engine from S
and materialises S' back out, bit-for-bit equal to the scan).  The
cycle-by-cycle scan only returns for caches no scan could have produced
or cold bitstream caches — neither occurs in this loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import simulator, slots
from repro.sched.placement import (ContentionModel, PlacementConfig,
                                   place_tenants)

__all__ = [
    "TenantEvent", "OnlineConfig", "OnlineReport", "OnlineReplacer",
    "POLICIES",
]

POLICIES = ("never", "always", "warm")


@dataclass(frozen=True)
class TenantEvent:
    """One roster change: a tenant arriving (with its bench profile) or
    departing.  Within an epoch, departures apply before arrivals."""

    epoch: int
    kind: str                 # "arrive" | "depart"
    name: str
    bench: str | None = None  # required for "arrive"

    def __post_init__(self):
        if self.kind not in ("arrive", "depart"):
            raise ValueError(
                f"event kind must be 'arrive' or 'depart', got "
                f"{self.kind!r}")
        if self.kind == "arrive" and not self.bench:
            raise ValueError(
                f"arrival of {self.name!r} needs a bench profile")
        if self.epoch < 0:
            raise ValueError(f"event epoch must be >= 0, got {self.epoch}")


@dataclass(frozen=True)
class OnlineConfig:
    """Knobs of the epoch loop.

    `epoch_steps` is the scan budget every non-empty core advances per
    epoch (its round-robin shares it between residents); `probe_steps` the
    resume window of the migration-penalty measurement.  `placement`
    carries the simulator geometry (slots, miss latency, quantum) shared
    by the epoch scans, the contention model, and the probes.
    """

    num_cores: int = 2
    epoch_steps: int = 6_000
    probe_steps: int = 2_000
    # soft per-epoch migration bound: no new exchange unit starts once this
    # many tenants moved (an atomic cycle may overshoot by its length - 1)
    max_moves_per_epoch: int = 4
    bs_cache_entries: int = 64
    bs_miss_extra: int = 100
    placement: PlacementConfig = field(default_factory=PlacementConfig)

    def __post_init__(self):
        if self.num_cores < 1:
            raise ValueError(f"num_cores must be >= 1, got {self.num_cores}")
        if self.epoch_steps < 1 or self.probe_steps < 1:
            raise ValueError("epoch_steps and probe_steps must be >= 1")

    def reconfig(self) -> simulator.ReconfigConfig:
        return simulator.ReconfigConfig(
            num_slots=self.placement.num_slots,
            miss_latency=self.placement.miss_latency,
            bs_cache_entries=self.bs_cache_entries,
            bs_miss_extra=self.bs_miss_extra)


class _TenantRun:
    """Mutable service record of one tenant (cursor + cumulative counters
    survive migrations; the slot caches do not — they belong to cores)."""

    def __init__(self, name: str, bench: str, core: int):
        self.name = name
        self.bench = bench
        self.core = core
        self.cursor = 0
        self.cycles = 0
        self.instrs = 0
        self.slot_misses = 0
        self.migrations = 0


class _Core:
    """A physical reconfigurable core: persistent slot/bitstream caches."""

    def __init__(self, cfg: OnlineConfig):
        self.slot_st = slots.init(cfg.placement.num_slots)
        self.bs_st = slots.init(cfg.bs_cache_entries)


@dataclass
class OnlineReport:
    """Outcome of one `OnlineReplacer.run`."""

    policy: str
    epochs: int
    migrations: int
    per_tenant: dict                   # name -> service metrics
    worst_slowdown: float
    mean_slowdown: float
    final_cores: tuple[tuple[str, ...], ...]
    moves: list                        # per-move log dicts
    epoch_log: list                    # per-epoch roster/migration rows


class OnlineReplacer:
    """Epoch-driven online placement over the resumable fleet simulator.

    `policy`:
      * "never"  — tenants stay where arrival placement put them;
      * "always" — apply every move the per-epoch re-solve implies;
      * "warm"   — apply a move only when its predicted contention saving
        over the next epoch exceeds its *measured* warm-state migration
        penalty (resume-on-cold-core probe).
    """

    def __init__(self, cfg: OnlineConfig | None = None,
                 model: ContentionModel | None = None,
                 policy: str = "warm"):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}, expected one of {POLICIES}")
        self.cfg = cfg or OnlineConfig()
        self.model = model or ContentionModel(self.cfg.placement)
        if self.model.cfg.num_slots != self.cfg.placement.num_slots:
            raise ValueError(
                f"contention model simulates {self.model.cfg.num_slots} "
                f"slots but the online config serves "
                f"{self.cfg.placement.num_slots} — predictions would price "
                f"a different machine")
        self.policy = policy
        self.tenants: dict[str, _TenantRun] = {}
        self.departed: list[_TenantRun] = []
        self.cores = [_Core(self.cfg) for _ in range(self.cfg.num_cores)]
        self.migrations = 0
        self.moves: list[dict] = []

    # ------------------------------------------------------------------
    # roster bookkeeping
    # ------------------------------------------------------------------
    def _members(self, core: int) -> list[_TenantRun]:
        return sorted((t for t in self.tenants.values() if t.core == core),
                      key=lambda t: t.name)

    def _groups(self) -> list[tuple[str, ...]]:
        return [tuple(sorted(t.bench for t in self._members(c)))
                for c in range(self.cfg.num_cores)]

    def _arrive(self, name: str, bench: str) -> None:
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} arrived twice")
        if any(t.name == name for t in self.departed):
            raise ValueError(
                f"tenant name {name!r} was already served and departed — "
                f"service records are keyed by name, so a returning "
                f"tenant needs a fresh name (e.g. {name!r}-2)")
        self.model.trace(bench)            # validates the bench name
        counts = [len(self._members(c)) for c in range(self.cfg.num_cores)]
        open_cores = [c for c in range(self.cfg.num_cores)
                      if counts[c] == min(counts)]
        # among least-loaded cores, join the one whose resulting group
        # predicts the best (worst, mean) slowdown — greedy, no migration
        cand = [tuple(sorted([t.bench for t in self._members(c)] + [bench]))
                for c in open_cores]
        preds = self.model.predict(cand)
        best = min(range(len(open_cores)),
                   key=lambda i: (float(np.max(preds[i])),
                                  float(np.mean(preds[i])), i))
        self.tenants[name] = _TenantRun(name, bench, open_cores[best])

    def _depart(self, name: str) -> None:
        if name not in self.tenants:
            raise ValueError(f"departure of unknown tenant {name!r}")
        # the core keeps its caches — a departed tenant's residents decay
        # naturally under LRU as the survivors run; the service record is
        # archived so the final report scores every tenant ever served
        self.departed.append(self.tenants.pop(name))

    # ------------------------------------------------------------------
    # epoch advance over resumable fleet state
    # ------------------------------------------------------------------
    def _advance_epoch(self) -> None:
        pcfg = self.cfg.placement
        sched = pcfg.scheduler()
        rcfg = self.cfg.reconfig()
        for ci in range(self.cfg.num_cores):
            members = self._members(ci)
            if not members:
                continue
            core = self.cores[ci]
            tr = np.stack([np.asarray(self.model.trace(t.bench))
                           for t in members])
            st = simulator.init_fleet_state(
                len(members), pcfg.num_slots, self.cfg.bs_cache_entries)
            # resume: the core's caches are warm from every prior epoch
            # (and from prior residents); cursors continue each tenant's
            # own stream; counters start at zero -> per-epoch deltas
            st = st._replace(
                slot_st=core.slot_st, bs_st=core.bs_st,
                cursors=jnp.asarray([t.cursor for t in members], jnp.int32))
            res, st = simulator.simulate_many(
                tr, rcfg,
                [self.model.scenario_of(t.bench) for t in members],
                sched, total_steps=self.cfg.epoch_steps,
                state=st, return_state=True)
            core.slot_st, core.bs_st = st.slot_st, st.bs_st
            cursors = np.asarray(st.cursors)
            cycles = np.asarray(res.cycles)
            instrs = np.asarray(res.instructions)
            misses = np.asarray(res.slot_misses)
            for p, t in enumerate(members):
                t.cursor = int(cursors[p])
                t.cycles += int(cycles[p])
                t.instrs += int(instrs[p])
                t.slot_misses += int(misses[p])

    # ------------------------------------------------------------------
    # warm-state migration pricing
    # ------------------------------------------------------------------
    def migration_penalty(self, name: str) -> float:
        """Measured cost (cycles) of restarting `name` on a cold core.

        Resumes the tenant's state solo for `probe_steps` twice — from its
        current core's warm caches and from a cold `init_fleet_state` —
        and returns the cycle difference.  This is the LUTstructions
        quantity: how many cycles of reconfiguration/bitstream re-loading
        the destination core charges before the tenant is warm again.
        """
        t = self.tenants[name]
        pcfg = self.cfg.placement
        rcfg = self.cfg.reconfig()
        scen = self.model.scenario_of(t.bench)
        tr = np.asarray(self.model.trace(t.bench))[None, :]
        cold = simulator.init_fleet_state(
            1, pcfg.num_slots, self.cfg.bs_cache_entries)._replace(
                cursors=jnp.asarray([t.cursor], jnp.int32))
        warm = cold._replace(slot_st=self.cores[t.core].slot_st,
                             bs_st=self.cores[t.core].bs_st)
        sched = simulator.SchedulerConfig.no_preempt(pcfg.handler_cycles)
        kw = dict(total_steps=self.cfg.probe_steps, return_state=False)
        res_c = simulator.simulate_many(tr, rcfg, scen, sched,
                                        state=cold, **kw)
        res_w = simulator.simulate_many(tr, rcfg, scen, sched,
                                        state=warm, **kw)
        return float(int(res_c.cycles[0]) - int(res_w.cycles[0]))

    def warm_fraction(self, name: str) -> float:
        """Fraction of the tenant's slotted tag set resident on its core's
        disambiguator right now (observability for the move log)."""
        t = self.tenants[name]
        tag_row = np.asarray(self.model.scenario_of(t.bench).instr_tag)
        tags = np.unique(tag_row[np.asarray(self.model.trace(t.bench))])
        tags = tags[tags >= 0]
        if tags.size == 0:
            return 1.0
        res = slots.resident_many(self.cores[t.core].slot_st,
                                  jnp.asarray(tags, jnp.int32))
        return float(np.mean(np.asarray(res)))

    def _group_cycles(self, group: tuple[str, ...]) -> float:
        """Predicted cycles one epoch spends serving `group` on one core:
        per-member slowdown x solo CPI x the member's round-robin share of
        the epoch's step budget."""
        if not group:
            return 0.0
        pred = self.model.predict([group])[0]
        share = self.cfg.epoch_steps / len(group)
        solo = np.array([self.model.solo_cpi(b) for b in sorted(group)])
        return float(np.sum(pred * solo * share))

    def move_benefit(self, moves: dict[str, int]) -> float:
        """Predicted contention delta (cycles/epoch) of applying `moves`
        (tenant name -> destination core) atomically: old-cost minus
        new-cost summed over every affected core.  A cross-core swap must
        be priced as one unit — each leg alone transits through a
        lopsided group and would misprice the exchange."""
        affected = {self.tenants[n].core for n in moves} | set(moves.values())
        old = new = 0.0
        for ci in range(self.cfg.num_cores):
            if ci not in affected:
                continue
            cur = [t.bench for t in self._members(ci)]
            nxt = [t.bench for t in self._members(ci)
                   if t.name not in moves or moves[t.name] == ci]
            nxt += [self.tenants[n].bench for n, dst in moves.items()
                    if dst == ci and self.tenants[n].core != ci]
            old += self._group_cycles(tuple(sorted(cur)))
            new += self._group_cycles(tuple(sorted(nxt)))
        return old - new

    # ------------------------------------------------------------------
    # per-epoch re-solve
    # ------------------------------------------------------------------
    def _target_assignment(self) -> dict[str, int]:
        """Re-solve placement for the current roster and align the solved
        cores to physical cores by membership overlap (a re-solve that
        merely permutes core labels must imply zero moves)."""
        roster = {t.name: t.bench for t in self.tenants.values()}
        pl = place_tenants(roster,
                           min(self.cfg.num_cores, len(roster)),
                           self.model)
        solved = [set(core) for core in pl.cores]
        unassigned = set(range(self.cfg.num_cores))
        target: dict[str, int] = {}
        current = {t.name: t.core for t in self.tenants.values()}
        order = sorted(
            range(len(solved)),
            key=lambda si: -len(solved[si]))
        for si in order:
            best = max(unassigned, key=lambda ci: (
                sum(1 for n in solved[si] if current.get(n) == ci), -ci))
            unassigned.discard(best)
            for n in solved[si]:
                target[n] = best
        return target

    def _exchange_units(self, target: dict[str, int]) -> list[tuple]:
        """Group the target's pending moves into minimal exchange units.

        The pending moves form a permutation-like flow between cores; it
        decomposes into *chains* (a tenant moves into spare capacity) and
        *cycles* (tenants trade places — a swap is the 2-cycle).  A cycle
        must be priced and applied atomically: each leg alone transits
        through a lopsided group and would misprice the exchange."""
        pending = [(n, self.tenants[n].core, c)
                   for n, c in sorted(target.items())
                   if c != self.tenants[n].core]
        units: list[tuple] = []
        while pending:
            chain = [pending.pop(0)]
            while True:
                end = chain[-1][2]
                if end == chain[0][1]:
                    break                      # closed cycle
                nxt = next((m for m in pending if m[1] == end), None)
                if nxt is None:
                    break                      # open chain (spare capacity)
                pending.remove(nxt)
                chain.append(nxt)
            units.append(tuple(n for n, _, _ in chain))
        return units

    def rebalance(self, epoch: int) -> int:
        """One re-placement round; returns how many tenants moved."""
        if self.policy == "never" or len(self.tenants) < 2:
            return 0
        target = self._target_assignment()
        units = self._exchange_units(target)
        moved = 0
        # most beneficial unit first; re-price against the *current*
        # membership before each apply (an earlier unit changes groups)
        while units and moved < self.cfg.max_moves_per_epoch:
            scored = [(self.move_benefit({n: target[n] for n in u}), u)
                      for u in units]
            scored.sort(key=lambda x: (-x[0], x[1]))
            benefit, unit = scored[0]
            units.remove(unit)
            penalty = sum(self.migration_penalty(n) for n in unit)
            net = benefit - penalty
            take = self.policy == "always" or net > 0.0
            self.moves.append({
                "epoch": epoch, "tenants": unit,
                "src": tuple(self.tenants[n].core for n in unit),
                "dst": tuple(target[n] for n in unit),
                "benefit_cycles": benefit, "penalty_cycles": penalty,
                "net_cycles": net,
                "warm_fraction": tuple(self.warm_fraction(n)
                                       for n in unit),
                "applied": take,
            })
            if take:
                for n in unit:
                    self.tenants[n].core = target[n]
                    self.tenants[n].migrations += 1
                    self.migrations += 1
                    moved += 1
        return moved

    # ------------------------------------------------------------------
    def run(self, events, num_epochs: int | None = None) -> OnlineReport:
        """Serve an event stream for `num_epochs` epochs (default: last
        event epoch + 4 drain epochs)."""
        events = list(events)
        if num_epochs is None:
            num_epochs = (max((e.epoch for e in events), default=0) + 5)
        by_epoch: dict[int, list[TenantEvent]] = {}
        for e in events:
            if e.epoch >= num_epochs:
                raise ValueError(
                    f"event at epoch {e.epoch} outside the horizon "
                    f"{num_epochs}")
            by_epoch.setdefault(e.epoch, []).append(e)
        epoch_log: list[dict] = []
        for epoch in range(num_epochs):
            todays = by_epoch.get(epoch, [])
            for e in todays:                      # departures first
                if e.kind == "depart":
                    self._depart(e.name)
            for e in todays:
                if e.kind == "arrive":
                    self._arrive(e.name, e.bench)
            moved = self.rebalance(epoch)
            self._advance_epoch()
            epoch_log.append({
                "epoch": epoch,
                "tenants": len(self.tenants),
                "moved": moved,
                "cores": tuple(tuple(t.name for t in self._members(c))
                               for c in range(self.cfg.num_cores)),
            })
        return self._report(num_epochs, epoch_log)

    def _report(self, num_epochs: int, epoch_log: list) -> OnlineReport:
        per_tenant: dict[str, dict] = {}
        slowdowns = []
        records = {t.name: t for t in self.departed}
        records.update(self.tenants)
        for name in sorted(records):
            t = records[name]
            if t.instrs == 0:
                per_tenant[name] = {"bench": t.bench, "instrs": 0,
                                    "scheduled": False}
                continue
            cpi = t.cycles / t.instrs
            slow = cpi / self.model.solo_cpi(t.bench)
            slowdowns.append(slow)
            per_tenant[name] = {
                "bench": t.bench, "instrs": t.instrs, "cycles": t.cycles,
                "slot_misses": t.slot_misses, "cpi": cpi,
                "solo_cpi": self.model.solo_cpi(t.bench),
                "slowdown": slow, "migrations": t.migrations,
                "scheduled": True,
            }
        return OnlineReport(
            policy=self.policy,
            epochs=num_epochs,
            migrations=self.migrations,
            per_tenant=per_tenant,
            worst_slowdown=float(max(slowdowns)) if slowdowns else 0.0,
            mean_slowdown=float(np.mean(slowdowns)) if slowdowns else 0.0,
            final_cores=tuple(tuple(t.name for t in self._members(c))
                              for c in range(self.cfg.num_cores)),
            moves=self.moves,
            epoch_log=epoch_log,
        )
