"""Named scheduling policies over the fleet simulator's quantum/priority
axes.

The simulator accepts raw `(quantum_cycles, priorities)` pairs
(`repro.core.simulator.SchedulerConfig`); this module gives the common
policies names and sane constructors so experiments and the serve layer
talk about *policies*, not tuples:

  * `PriorityPolicy.uniform(q)`              — the paper's round-robin;
  * `PriorityPolicy.weighted(weights, q)`    — CPU share proportional to
    integer weights (weighted round-robin, §VI-C generalised);
  * `PriorityPolicy.foreground_background()` — one latency-sensitive
    foreground program with a high weight and a long quantum, batch
    programs behind it;
  * `quantum_grid(...)`                      — builds the `quanta=` axis
    for `sweep_fleet` (scalars broadcast, vectors pass through).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.simulator import (SchedulerConfig, priority_schedule,
                                  quanta_vector)

__all__ = ["PriorityPolicy", "quantum_grid"]


@dataclass(frozen=True)
class PriorityPolicy:
    """A named (quanta, priorities) scheduling policy for a P-program fleet.

    `quanta` is a scalar or a per-program tuple; `priorities` is None
    (unit weights) or a per-program tuple of positive ints.  Use
    `.scheduler()` to compile into the simulator's `SchedulerConfig`.
    """

    name: str
    quanta: int | tuple[int, ...] = 20_000
    priorities: tuple[int, ...] | None = None
    handler_cycles: int = 150

    def scheduler(self) -> SchedulerConfig:
        return SchedulerConfig(quantum_cycles=self.quanta,
                               handler_cycles=self.handler_cycles,
                               priorities=self.priorities)

    def schedule(self, num_programs: int) -> np.ndarray:
        """The weighted round-robin turn order this policy produces."""
        return priority_schedule(self.priorities, num_programs)

    def cpu_share(self, num_programs: int) -> np.ndarray:
        """Nominal long-run CPU-time share per program.

        Each program holds the core for `priorities[p]` consecutive quanta
        of `quanta[p]` cycles per rotation, so the share is
        `w[p] * q[p] / sum(w * q)` — the quantity the weighted scan
        converges to when every program has work.
        """
        q = quanta_vector(self.quanta, num_programs).astype(np.float64)
        w = (np.ones(num_programs) if self.priorities is None
             else np.asarray(self.priorities, np.float64))
        if w.shape != (num_programs,):
            raise ValueError(
                f"priorities vector has shape {w.shape}, expected "
                f"({num_programs},)")
        return (w * q) / float(np.sum(w * q))

    # ------------------------------------------------------------------
    @classmethod
    def uniform(cls, quantum_cycles: int = 20_000,
                handler_cycles: int = 150) -> "PriorityPolicy":
        """The paper's scheduler: one quantum, unit weights."""
        return cls("uniform", quantum_cycles, None, handler_cycles)

    @classmethod
    def weighted(cls, priorities, quantum_cycles: int = 20_000,
                 handler_cycles: int = 150) -> "PriorityPolicy":
        """Weighted round-robin: share proportional to integer weights."""
        return cls("weighted", quantum_cycles, tuple(int(w) for w in
                                                     priorities),
                   handler_cycles)

    @classmethod
    def foreground_background(cls, num_programs: int,
                              fg_weight: int = 4,
                              fg_quantum: int = 40_000,
                              bg_quantum: int = 10_000,
                              handler_cycles: int = 150
                              ) -> "PriorityPolicy":
        """Program 0 is foreground (heavy weight, long quantum); the rest
        are background batch programs on short quanta."""
        if num_programs < 2:
            raise ValueError("foreground/background needs >= 2 programs")
        quanta = (fg_quantum,) + (bg_quantum,) * (num_programs - 1)
        weights = (int(fg_weight),) + (1,) * (num_programs - 1)
        return cls("foreground_background", quanta, weights, handler_cycles)


def quantum_grid(*cells, num_programs: int | None = None) -> list:
    """Normalise a mixed list of quantum cells for `sweep_fleet(quanta=...)`.

    Each cell is a scalar (shared by all programs) or a per-program
    vector.  With `num_programs` given, every cell is validated/broadcast
    to a (P,) vector up front so shape errors surface here, not inside the
    sweep.
    """
    if not cells:
        raise ValueError("quantum_grid needs at least one quantum cell")
    if num_programs is None:
        return list(cells)
    return [quanta_vector(c, num_programs) for c in cells]
