"""Train-step builder: loss -> grads -> AdamW, with full sharding plumbing.

`make_train_step` returns (step_fn, state_shardings, batch_shardings) ready
for `jax.jit(..., in_shardings=..., out_shardings=..., donate_argnums=0)`.
Gradient reduction over data axes is implicit in SPMD; optimizer states are
sharded over data (ZeRO-1) even when parameters are replicated, via a
second fsdp-forced plan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.optim import adamw
from repro.sharding.partition import ShardingPlan


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, plan: ShardingPlan,
                    microbatches: int = 1):
    """microbatches > 1 = gradient accumulation: the global batch is split
    on the batch axis and scanned, dividing activation memory by the count
    (grads accumulate in the param dtype, sharded like params)."""

    def grad_of(params, batch):
        def lf(p):
            loss, aux = transformer.loss_fn(cfg, p, batch, shd=plan)
            return loss, aux
        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(state: adamw.TrainState, batch):
        if microbatches > 1:
            split = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:]), batch)

            def acc_fn(carry, mb):
                loss_acc, g_acc = carry
                (loss, _aux), g = grad_of(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), state.params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.float32(0), g0), split)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
        else:
            (loss, _aux), grads = grad_of(state.params, batch)
        new_state, metrics = adamw.apply_updates(opt_cfg, state, grads)
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def abstract_state(cfg, opt_cfg: adamw.AdamWConfig):
    """ShapeDtypeStruct pytree of the full train state — no allocation."""
    def build():
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        return adamw.init_state(opt_cfg, params)

    return jax.eval_shape(build)


def state_shardings(cfg, plan: ShardingPlan, state_shapes):
    """Params follow the plan; m/v/master shard over data too (ZeRO-1)."""
    params_sh = plan.param_shardings(state_shapes.params)
    zero1 = dataclasses.replace(plan)  # fresh instance
    zero1.fsdp = True
    opt_sh_m = zero1.param_shardings(state_shapes.m)
    opt_sh_v = zero1.param_shardings(state_shapes.v)
    master_sh = (zero1.param_shardings(state_shapes.master)
                 if state_shapes.master is not None else None)
    return adamw.TrainState(
        step=plan.ns(jax.sharding.PartitionSpec()),
        params=params_sh, m=opt_sh_m, v=opt_sh_v, master=master_sh)


def metric_shardings(plan: ShardingPlan):
    rep = plan.ns(jax.sharding.PartitionSpec())
    return {"grad_norm": rep, "lr": rep, "loss": rep}


def jit_train_step(cfg, opt_cfg, plan, batch_specs, microbatches: int = 1):
    """Fully-sharded jitted train step + abstract inputs, used by both the
    real driver and the dry-run lower/compile path."""
    state_shapes = abstract_state(cfg, opt_cfg)
    st_sh = state_shardings(cfg, plan, state_shapes)
    batch_sh = plan.input_shardings(batch_specs)
    step = make_train_step(cfg, opt_cfg, plan, microbatches)
    jitted = jax.jit(
        step,
        in_shardings=(st_sh, batch_sh),
        out_shardings=(st_sh, metric_shardings(plan)),
        donate_argnums=(0,),
    )
    return jitted, state_shapes, st_sh
