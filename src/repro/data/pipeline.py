"""Deterministic synthetic LM data pipeline, sharded host feed + prefetch.

Production shape: each host materialises only its addressable shard of the
global batch (`jax.make_array_from_callback`), tokens are a deterministic
counter-hash stream (reproducible across restarts — resuming at step k
regenerates exactly the batch the failed run would have seen, which is what
the fault-tolerance tests assert), and an N-deep prefetch queue overlaps
host generation with device compute.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass

import jax
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _hash_tokens(cfg: DataConfig, step: int, rows: np.ndarray) -> np.ndarray:
    """Deterministic pseudo-token block for (step, global row ids)."""
    # splitmix64-style mixing — stable across platforms, no RNG state;
    # uint64 wraparound is the point, so silence the overflow warning
    with np.errstate(over="ignore"):
        x = (rows[:, None].astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
             + np.arange(cfg.seq_len, dtype=np.uint64)[None, :]
             + np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9)
             + np.uint64(cfg.seed) * np.uint64(0x94D049BB133111EB))
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
    return (x % np.uint64(cfg.vocab)).astype(np.int32)


def global_batch_at(cfg: DataConfig, step: int) -> np.ndarray:
    """The full (global_batch, seq_len) token block for a step (tests)."""
    return _hash_tokens(cfg, step, np.arange(cfg.global_batch))


def make_batch(cfg: DataConfig, step: int, sharding) -> jax.Array:
    """Build the sharded global array, materialising per-device shards only."""
    def cb(index):
        rows = np.arange(cfg.global_batch)[index[0]]
        return _hash_tokens(cfg, step, rows)[:, index[1]]

    return jax.make_array_from_callback(
        (cfg.global_batch, cfg.seq_len), sharding, cb)


class Prefetcher:
    """Background thread keeping `depth` batches ready on device."""

    def __init__(self, cfg: DataConfig, sharding, start_step: int = 0,
                 depth: int = 2):
        self.cfg = cfg
        self.sharding = sharding
        self.depth = depth
        self._queue: collections.deque = collections.deque()
        self._next = start_step
        self._lock = threading.Lock()
        self._fill()

    def _fill(self):
        while len(self._queue) < self.depth:
            self._queue.append(
                (self._next, make_batch(self.cfg, self._next, self.sharding)))
            self._next += 1

    def get(self) -> tuple[int, jax.Array]:
        with self._lock:
            step, batch = self._queue.popleft()
            self._fill()
            return step, batch
