"""RecurrentGemma / Griffin blocks: RG-LRU recurrence + local attention.

Recurrent block (Griffin):
    u     = x @ W_x            (lru width)
    u_c   = causal depthwise conv1d(u, width 4)
    r_t   = sigmoid(u_c * w_r + b_r)          (per-channel gates — the
    i_t   = sigmoid(u_c * w_i + b_i)           block-diagonal gates of the
    a_t   = exp(-c * softplus(lam) * r_t)      paper reduced to diagonal)
    h_t   = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_c_t)
    out   = (h * gelu(x @ W_gate)) @ W_out

The recurrence is a first-order linear scan => `jax.lax.associative_scan`
(log-depth, fully parallel) for train/prefill — this is what makes the
524288-token `long_500k` cell tractable — and a single fused step for
decode.  The Pallas kernel (`repro.kernels.rglru_scan`) implements the
chunked sequential-grid variant of the same computation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

C_RGLRU = 8.0


def init_rec_block(key, cfg):
    d, w = cfg.d_model, cfg.lru_width
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        "wx": jax.random.normal(ks[0], (d, w), dt) * s,
        "wgate": jax.random.normal(ks[1], (d, w), dt) * s,
        "wout": jax.random.normal(ks[2], (w, d), dt) * w ** -0.5,
        "conv": jax.random.normal(ks[3], (cfg.conv_width, w), dt) * 0.1,
        "w_r": jnp.zeros((w,), jnp.float32),
        "b_r": jnp.zeros((w,), jnp.float32),
        "w_i": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        # lam init so a ~ uniform(0.9, 0.999) at r=0.5 — standard LRU init
        "lam": jnp.linspace(2.0, 6.0, w, dtype=jnp.float32),
    }


def _conv1d_causal(u, kernel, state=None):
    """Depthwise causal conv.  u: (B,T,W); kernel: (cw,W);
    state: (B,cw-1,W) trailing inputs of the previous segment."""
    cw = kernel.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(ext[:, i:i + u.shape[1], :] * kernel[i] for i in range(cw))
    return out, ext[:, -(cw - 1):, :].astype(jnp.float32)


def _gates(p, u_c):
    uf = u_c.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_r"] + p["b_r"])
    i = jax.nn.sigmoid(uf * p["w_i"] + p["b_i"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r      # <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def _assoc(a, b, h0):
    b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_scan(p, u_c, h0, chunk: int = 512):
    """Parallel linear recurrence h_t = a_t h_{t-1} + b_t.

    Chunked: an outer `lax.scan` carries h across chunks; inside each chunk
    a log-depth `associative_scan` parallelises.  The chunk body is
    checkpointed so the backward stores only the (B,W) chunk carries —
    full-sequence associative_scan would store log(T) full-width levels
    (and blow both compile time and HBM at T=524288)."""
    bsz, t, w = u_c.shape
    a, b = _gates(p, u_c)
    if t <= chunk or t % chunk != 0:
        h = _assoc(a, b, h0)
        return h, h[:, -1, :]
    nc = t // chunk

    def body(h, ab):
        ac, bc = ab
        hc = _assoc(ac, bc, h)
        return hc[:, -1, :], hc

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    ar = a.reshape(bsz, nc, chunk, w).transpose(1, 0, 2, 3)
    br = b.reshape(bsz, nc, chunk, w).transpose(1, 0, 2, 3)
    h_last, hs = jax.lax.scan(body, h0, (ar, br))
    h = hs.transpose(1, 0, 2, 3).reshape(bsz, t, w)
    return h, h_last


def rglru_step(p, u_c1, h0):
    """Single decode step.  u_c1: (B,1,W); h0: (B,W)."""
    a, b = _gates(p, u_c1)
    h = a[:, 0] * h0 + b[:, 0]
    return h[:, None, :], h


def rec_block(p, x, state, cfg):
    """Full Griffin recurrent block.  state: {"h": (B,W), "conv": (B,cw-1,W)}
    Returns (out, new_state)."""
    u = x @ p["wx"]
    u_c, conv_state = _conv1d_causal(u, p["conv"],
                                     state["conv"] if state else None)
    h0 = state["h"] if state else jnp.zeros(
        (x.shape[0], cfg.lru_width), jnp.float32)
    if x.shape[1] == 1:
        h, h_last = rglru_step(p, u_c, h0)
    else:
        h, h_last = rglru_scan(p, u_c, h0)
    gate = jax.nn.gelu(x @ p["wgate"])
    out = (h.astype(x.dtype) * gate) @ p["wout"]
    return out, {"h": h_last, "conv": conv_state}


def init_rec_state(cfg, batch: int):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width),
                          jnp.float32),
    }
