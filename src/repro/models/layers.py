"""Shared model layers: norms, RoPE/M-RoPE, MLPs, flash attention.

Design rules (framework-wide):
  * all matmuls run in the config dtype (bf16 on TPU), all reductions
    (softmax, norm statistics) accumulate in f32;
  * attention never materialises an O(T^2) score tensor: the pure-JAX path
    is a `lax.scan` over KV blocks carrying (m, l, acc) flash statistics —
    this is also the compile-memory guarantee behind the 32k dry-run cells;
  * a sliding `window` reduces the scanned KV range to the causal band.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_rmsnorm(d: int):
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL's 3D M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., T, H, Dh); positions: broadcastable to (..., T)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., T, Dh/2)
    ang = ang[..., None, :]                                 # (..., T, 1, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: tuple[int, ...]):
    """Qwen2-VL multimodal RoPE: positions3 (..., T, 3) = (t, h, w) ids;
    the head_dim/2 frequency bands are split into `sections` (t|h|w)."""
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)  # (Dh/2,)
    # pick which of the three position streams drives each frequency band
    sel = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(jnp.asarray(sel, jnp.int32)[None, None, :],
                         positions3.shape[:-1] + (dh // 2,)),
        axis=-1)                                            # (..., T, Dh/2)
    ang = (pos * freqs)[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    if cfg.mlp in ("swiglu", "gelu_glu"):
        return {
            "wi": jax.random.normal(k1, (d, f), dt) * s_in,
            "wg": jax.random.normal(k2, (d, f), dt) * s_in,
            "wo": jax.random.normal(k3, (f, d), dt) * s_out,
        }
    return {
        "wi": jax.random.normal(k1, (d, f), dt) * s_in,
        "wo": jax.random.normal(k2, (f, d), dt) * s_out,
    }


def apply_mlp(p, x, cfg):
    if cfg.mlp in ("swiglu", "gelu_glu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# flash attention (pure-JAX oracle path; Pallas kernel in repro.kernels)
# ---------------------------------------------------------------------------

class _FlashCarry(NamedTuple):
    m: jnp.ndarray    # (B, G, Tq) running max
    l: jnp.ndarray    # (B, G, Tq) running sum
    acc: jnp.ndarray  # (B, G, Tq, Dh) running value accum (f32)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block: int = 512, q_offset: int = 0,
                    kv_len: jnp.ndarray | None = None,
                    kv_start: jnp.ndarray | None = None):
    """Memory-bounded multi-head attention.

    q: (B, Tq, H, Dh);  k/v: (B, Tk, K, Dh) with H = K * q_per_kv.
    Scans KV blocks carrying flash statistics; peak live memory is
    O(B*H*Tq*(Dh + block)) regardless of Tk.  `q_offset` is the absolute
    position of q[0] (decode / chunked prefill).  `window`>0 masks keys
    older than `window` positions.  `kv_len` (B,) masks invalid cache tail.
    """
    b, tq, h, dh = q.shape
    _, tk, kh, _ = k.shape
    g = h // kh  # query heads per kv head
    scale = dh ** -0.5

    qr = (q * scale).reshape(b, tq, kh, g, dh).astype(jnp.float32)
    qpos = q_offset + jnp.arange(tq)

    nblk = -(-tk // block)
    pad = nblk * block - tk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(b, nblk, block, kh, dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nblk, block, kh, dh).transpose(1, 0, 2, 3, 4)

    init = _FlashCarry(
        m=jnp.full((b, tq, kh, g), NEG_INF, jnp.float32),
        l=jnp.zeros((b, tq, kh, g), jnp.float32),
        acc=jnp.zeros((b, tq, kh, g, dh), jnp.float32),
    )

    def step(carry, inp):
        blk_idx, kblk, vblk = inp
        kpos = blk_idx * block + jnp.arange(block)
        # scores: (B, Tq, K, G, block)
        s = jnp.einsum("btkgd,bskd->btkgs", qr, kblk.astype(jnp.float32))
        mask = jnp.ones((tq, block), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        mask &= (kpos < tk)[None, :]
        mask = mask[None]
        if kv_len is not None:
            mask = mask & (kpos[None, None, :] < kv_len[:, None, None])
        if kv_start is not None:
            mask = mask & (kpos[None, None, :] >= kv_start[:, None, None])
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(carry.m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(carry.m - m_new)
        l_new = carry.l * corr + p.sum(-1)
        acc_new = carry.acc * corr[..., None] + jnp.einsum(
            "btkgs,bskd->btkgd", p, vblk.astype(jnp.float32))
        return _FlashCarry(m_new, l_new, acc_new), None

    # checkpoint: the scan backward recomputes per-block scores instead of
    # storing the O(Tq x block) probability tensors for every block
    step_ckpt = jax.checkpoint(
        step, policy=jax.checkpoint_policies.nothing_saveable)
    carry, _ = jax.lax.scan(step_ckpt, init, (jnp.arange(nblk), kb, vb))
    out = carry.acc / jnp.maximum(carry.l[..., None], 1e-30)
    return out.reshape(b, tq, h, dh).astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                  kv_len=None):
    """Naive O(T^2) oracle (tests only)."""
    b, tq, h, dh = q.shape
    _, tk, kh, _ = k.shape
    g = h // kh
    qr = q.reshape(b, tq, kh, g, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("btkgd,bskd->btkgs", qr, k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(tq)
    kpos = jnp.arange(tk)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    mask = mask[None]
    if kv_len is not None:
        mask = mask & (kpos[None, None, :] < kv_len[:, None, None])
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(b, tq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": jax.random.normal(ks[0], (d, h * dh), dt) * d ** -0.5,
        "wk": jax.random.normal(ks[1], (d, kh * dh), dt) * d ** -0.5,
        "wv": jax.random.normal(ks[2], (d, kh * dh), dt) * d ** -0.5,
        "wo": jax.random.normal(ks[3], (h * dh, d), dt) * (h * dh) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((kh * dh,), dt)
        p["bv"] = jnp.zeros((kh * dh,), dt)
    return p


def qkv(p, x, cfg, positions):
    """Project + position-encode. positions: (B,T) ids or (B,T,3) for mrope."""
    b, t, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v
