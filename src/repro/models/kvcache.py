"""KV caches: full (global attention) and circular-window (local attention),
plus the flash-decode combine for sequence-sharded caches.

Decode memory layout (DESIGN.md §5): the full cache is sharded
(batch -> data, seq -> model).  One decode step must (a) write the new K/V
into whichever model-shard owns position `pos` and (b) attend over all
shards.  Both happen inside one `shard_map`: each shard computes partial
flash statistics (m, l, o) over its sequence chunk and the shards merge via
a logsumexp-weighted `psum` — the collective is O(B*H*Dh), never O(S).

The circular window cache (RecurrentGemma local attention) is only
`window` long, so it stays replicated across `model`; no collective at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def init_full_cache(cfg, batch: int, length: int):
    kh, dh = cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    return {"k": jnp.zeros((batch, length, kh, dh), dt),
            "v": jnp.zeros((batch, length, kh, dh), dt)}


def init_window_cache(cfg, batch: int):
    kh, dh, w = cfg.num_kv_heads, cfg.head_dim, cfg.window
    dt = jnp.dtype(cfg.dtype)
    return {"k": jnp.zeros((batch, w, kh, dh), dt),
            "v": jnp.zeros((batch, w, kh, dh), dt)}


def _write_slot(buf, new, idx):
    """buf: (B,S,K,dh); new: (B,K,dh); idx: (B,) — one-slot write per batch
    row, tolerant of out-of-range idx (writes the existing value back)."""
    s = buf.shape[1]
    idx_c = jnp.clip(idx, 0, s - 1)
    in_range = (idx >= 0) & (idx < s)

    def one(b, n, i, ok):
        cur = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0)
        val = jnp.where(ok, n[None], cur)
        return jax.lax.dynamic_update_slice_in_dim(b, val, i, axis=0)

    return jax.vmap(one)(buf, new, idx_c, in_range)


# ---------------------------------------------------------------------------
# single-device decode attention (oracle + smoke path)
# ---------------------------------------------------------------------------

def decode_attention_local(q, cache, k_new, v_new, pos, cfg):
    """q: (B,1,H,dh); cache k/v: (B,S,K,dh); pos: (B,) absolute position of
    the new token.  Returns (out (B,1,H,dh), new cache)."""
    b, _, h, dh = q.shape
    s = cache["k"].shape[1]
    kh = cfg.num_kv_heads
    g = h // kh
    ck = _write_slot(cache["k"], k_new[:, 0], pos)
    cv = _write_slot(cache["v"], v_new[:, 0], pos)
    qr = (q[:, 0].reshape(b, kh, g, dh) * dh ** -0.5).astype(jnp.float32)
    sc = jnp.einsum("bkgd,bskd->bkgs", qr, ck.astype(jnp.float32))
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, cv.astype(jnp.float32))
    return o.reshape(b, 1, h, dh).astype(q.dtype), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# sharded flash-decode (seq-sharded cache, psum combine)
# ---------------------------------------------------------------------------

def _scatter_token(buf, new, pos):
    """buf: (B,S,K,dh); new: (B,1,K,dh); pos: (B,).  An HLO scatter — GSPMD
    partitions it in place on the (data, model)-sharded cache and the
    donated buffer aliases (no full-cache copy, unlike in-shard_map
    updates)."""
    b = buf.shape[0]
    idx = jnp.stack([jnp.arange(b, dtype=pos.dtype), pos], axis=1)
    return jax.lax.scatter(
        buf, idx, new[:, 0],
        jax.lax.ScatterDimensionNumbers(
            update_window_dims=(1, 2),
            inserted_window_dims=(0, 1),
            scatter_dims_to_operand_dims=(0, 1)),
        indices_are_sorted=True, unique_indices=True)


def decode_attention_sharded(q, cache, k_new, v_new, pos, cfg, mesh,
                             data_axes=("data",), model_axis="model"):
    b_spec = data_axes if len(data_axes) > 1 else data_axes[0]
    cache_spec = P(b_spec, model_axis, None, None)
    q_spec = P(b_spec, None, None, None)
    kh = cfg.num_kv_heads

    # cache write OUTSIDE shard_map: scatter partitions in place
    ck_all = _scatter_token(cache["k"], k_new, pos)
    cv_all = _scatter_token(cache["v"], v_new, pos)

    def body(qs, ck, cv, ps):
        b, _, h, dh = qs.shape
        s_loc = ck.shape[1]
        g = h // kh
        shard = jax.lax.axis_index(model_axis)
        lo = shard * s_loc
        qr = (qs[:, 0].reshape(b, kh, g, dh) * dh ** -0.5).astype(jnp.float32)
        sc = jnp.einsum("bkgd,bskd->bkgs", qr, ck.astype(jnp.float32))
        valid = (lo + jnp.arange(s_loc))[None, :] <= ps[:, None]
        sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
        # partial flash statistics + logsumexp-weighted combine
        m_loc = sc.max(-1)                                   # (B,K,G)
        p = jnp.exp(sc - m_loc[..., None])
        l_loc = p.sum(-1)
        o_loc = jnp.einsum("bkgs,bskd->bkgd", p, cv.astype(jnp.float32))
        m_glob = jax.lax.pmax(m_loc, model_axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, model_axis)
        o_glob = jax.lax.psum(o_loc * corr[..., None], model_axis)
        o = o_glob / jnp.maximum(l_glob[..., None], 1e-30)
        return o.reshape(b, 1, h, dh).astype(qs.dtype)

    o = shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, P(b_spec)),
        out_specs=q_spec,
        check_vma=False,
    )(q, ck_all, cv_all, pos)
    return o, {"k": ck_all, "v": cv_all}


def decode_attention(q, cache, k_new, v_new, pos, cfg, mesh=None,
                     data_axes=("data",)):
    if mesh is None:
        return decode_attention_local(q, cache, k_new, v_new, pos, cfg)
    return decode_attention_sharded(q, cache, k_new, v_new, pos, cfg, mesh,
                                    data_axes)


# ---------------------------------------------------------------------------
# circular window cache (local attention decode)
# ---------------------------------------------------------------------------

def window_decode_attention(q, cache, k_new, v_new, pos, cfg):
    """Rolling-buffer local attention; buffer slot = abs_pos % window."""
    b, _, h, dh = q.shape
    w = cfg.window
    kh = cfg.num_kv_heads
    g = h // kh
    slot = pos % w
    ck = _write_slot(cache["k"], k_new[:, 0], slot)
    cv = _write_slot(cache["v"], v_new[:, 0], slot)
    # absolute position held by each slot after the write
    sl = jnp.arange(w)[None, :]
    abs_pos = pos[:, None] - ((pos[:, None] - sl) % w)
    valid = abs_pos >= 0  # window recency is implied by the buffer size
    qr = (q[:, 0].reshape(b, kh, g, dh) * dh ** -0.5).astype(jnp.float32)
    sc = jnp.einsum("bkgd,bskd->bkgs", qr, ck.astype(jnp.float32))
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, cv.astype(jnp.float32))
    return o.reshape(b, 1, h, dh).astype(q.dtype), {"k": ck, "v": cv}
