"""Mixture-of-Experts layer with expert parallelism over the `model` axis.

Placement: experts are statically sharded over `model` (E/tp per device,
stacked leading axis).  Activations at MoE entry are replicated across
`model` (the TP convention used by the attention path), so dispatch needs
NO all-to-all: every shard gathers the tokens routed to *its* experts into a
capacity buffer, runs its expert matmuls, scatter-adds its partial output
and the shard partials merge in the same `psum` that TP-MLP would need
anyway.  Token order is deterministic (first-come capacity, paper-faithful
"first-served slots").

The paper hook: the per-layer expert load vector (`aux["expert_load"]`) is
the opcode-access set of `repro.core.expert_slots` — the serving engine
feeds it to the disambiguator to track slot residency and fill traffic.

The gather/scatter index machinery is mirrored 1:1 by the Pallas dispatch
kernel (`repro.kernels.moe_dispatch`); `moe_apply_dense` is its oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * d ** -0.5,
        "wi": jax.random.normal(ks[1], (e, d, f), dt) * d ** -0.5,
        "wg": jax.random.normal(ks[2], (e, d, f), dt) * d ** -0.5,
        "wo": jax.random.normal(ks[3], (e, f, d), dt) * f ** -0.5,
    }


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def route(x2d: jnp.ndarray, router_w: jnp.ndarray, cfg,
          router_bias: jnp.ndarray | None = None):
    """x2d: (N, D) -> expert ids (N,k), gates (N,k) f32.

    router_bias (E,) implements *slot-hit routing* (DESIGN.md §2): the
    serving engine biases selection toward slot-resident experts; gates are
    renormalised from the UNBIASED logits so mixture weights stay faithful
    to the learned router."""
    logits = (x2d.astype(jnp.float32) @ router_w)
    sel = logits if router_bias is None else logits + router_bias
    _, ids = jax.lax.top_k(sel, cfg.top_k)
    orig = jnp.take_along_axis(logits, ids, axis=-1)
    gates = jax.nn.softmax(orig, axis=-1)
    return ids, gates


def _dispatch_indices(ids: jnp.ndarray, n_experts: int, capacity: int):
    """First-come positions within each expert's capacity buffer.

    ids: (N, k) -> (pos (N,k) int32, kept (N,k) bool).
    """
    n, k = ids.shape
    flat = ids.reshape(-1)                                   # (N*k,)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    kept = pos < capacity
    return pos.reshape(n, k), kept.reshape(n, k)


def _expert_ffn(buf, wi, wg, wo, cfg):
    """buf: (E?, C, D) through stacked experts."""
    if cfg.mlp in ("swiglu", "gelu_glu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wi))
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _gather_compute_scatter(x2d, ids, gates, pos, kept, wi, wg, wo, cfg,
                            e_lo: int, e_local: int, capacity: int):
    """Dispatch the tokens routed to experts [e_lo, e_lo+e_local) and return
    this shard's partial output (N, D)."""
    n, d = x2d.shape
    k = ids.shape[1]
    local = (ids >= e_lo) & (ids < e_lo + e_local) & kept    # (N,k)
    e_loc = jnp.where(local, ids - e_lo, 0)
    p_loc = jnp.where(local, pos, 0)
    w = local.astype(x2d.dtype)

    buf = jnp.zeros((e_local, capacity, d), x2d.dtype)
    xk = jnp.broadcast_to(x2d[:, None, :], (n, k, d)) * w[..., None]
    buf = buf.at[e_loc.reshape(-1), p_loc.reshape(-1)].add(
        xk.reshape(n * k, d))

    out_buf = _expert_ffn(buf, wi, wg, wo, cfg)              # (E_loc, C, D)

    y = out_buf[e_loc.reshape(-1), p_loc.reshape(-1)].reshape(n, k, d)
    y = y * (gates.astype(x2d.dtype) * w)[..., None]
    return y.sum(axis=1)


def moe_apply_dense(p, x, cfg, router_bias=None):
    """Single-device reference path (smoke tests / kernel oracle)."""
    b, t, d = x.shape
    x2d = x.reshape(-1, d)
    cap = _capacity(x2d.shape[0], cfg)
    ids, gates = route(x2d, p["router"], cfg, router_bias)
    pos, kept = _dispatch_indices(ids, cfg.num_experts, cap)
    y = _gather_compute_scatter(
        x2d, ids, gates, pos, kept, p["wi"], p["wg"], p["wo"], cfg,
        0, cfg.num_experts, cap)
    load = jnp.zeros((cfg.num_experts,), jnp.int32).at[ids.reshape(-1)].add(
        kept.reshape(-1).astype(jnp.int32))
    return y.reshape(b, t, d), {"expert_load": load}


MOE_TOKEN_CHUNK = 16_384


def moe_apply_sharded(p, x, cfg, mesh, data_axes=("data",),
                      model_axis="model", router_bias=None):
    """Expert-parallel path: experts sharded over `model`, x replicated
    over `model` and sharded over data axes on batch.

    Tokens are processed in chunks of MOE_TOKEN_CHUNK inside a lax.scan so
    the dispatch transients (one-hot cumsum, gathered (N,k,D) buffers)
    never scale with the full B*T token count — this is what keeps the
    400B-class train_4k cells inside HBM."""
    tp = mesh.shape[model_axis]
    e_local = cfg.num_experts // tp
    dp = P(data_axes if len(data_axes) > 1 else data_axes[0])
    x_spec = P(dp[0], None, None)
    w_spec = P(model_axis, None, None)

    def body(router_w, wi, wg, wo, xs):
        b, t, d = xs.shape
        x2d = xs.reshape(-1, d)
        n = x2d.shape[0]
        shard = jax.lax.axis_index(model_axis)
        e_lo = shard * e_local

        def one_chunk(xc):
            cap = _capacity(xc.shape[0], cfg)
            ids, gates = route(xc, router_w, cfg, router_bias)
            pos, kept = _dispatch_indices(ids, cfg.num_experts, cap)
            y = _gather_compute_scatter(
                xc, ids, gates, pos, kept, wi, wg, wo, cfg,
                e_lo, e_local, cap)
            load = jnp.zeros((cfg.num_experts,), jnp.int32).at[
                ids.reshape(-1)].add(kept.reshape(-1).astype(jnp.int32))
            return y, load

        if n > MOE_TOKEN_CHUNK and n % MOE_TOKEN_CHUNK == 0:
            nc = n // MOE_TOKEN_CHUNK
            xr = x2d.reshape(nc, MOE_TOKEN_CHUNK, d)
            chunk_fn = jax.checkpoint(
                one_chunk, policy=jax.checkpoint_policies.nothing_saveable)
            y, load = jax.lax.map(chunk_fn, xr)
            y = y.reshape(n, d)
            load = load.sum(axis=0)
        else:
            y, load = one_chunk(x2d)
        y = jax.lax.psum(y, model_axis)
        load = jax.lax.psum(load, data_axes)  # global per-layer expert load
        return y.reshape(b, t, d), load

    y, load = shard_map(
        body, mesh=mesh,
        in_specs=(P(), w_spec, w_spec, w_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p["router"], p["wi"], p["wg"], p["wo"], x)
    return y, {"expert_load": load}


def moe_apply(p, x, cfg, mesh=None, data_axes=("data",),
              router_bias=None):
    if mesh is None:
        return moe_apply_dense(p, x, cfg, router_bias)
    return moe_apply_sharded(p, x, cfg, mesh, data_axes,
                             router_bias=router_bias)
