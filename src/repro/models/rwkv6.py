"""RWKV6 "Finch" blocks: data-dependent decay linear attention + channel mix.

Time-mix recurrence (per head, key dim N):
    wkv_t = sum_{s<t} diag(prod_{j=s+1}^{t-1} w_j) k_s v_s^T + diag(u) k_t v_t^T
    o_t   = r_t @ wkv_t ;   S_{t+1} = diag(w_t) S_t + k_t v_t^T
with per-channel data-dependent decay w_t = exp(-exp(d_t)).

Two equivalent implementations:
  * `recurrence_scan`  — per-token `lax.scan`; the oracle and the decode step;
  * `recurrence_chunked` — chunkwise-parallel form whose intra-chunk decay
    matrix is built in *log space* (exponents are always <= 0, so it is
    numerically stable without the 1/cumprod overflow of the naive GLA
    form).  This is the train/prefill path and the shape mirrored by the
    Pallas kernel (`repro.kernels.rwkv6_scan`).

The ddlerp token-shift LoRAs of the reference implementation are kept in
reduced form (single low-rank delta per projection stream).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LORA_RANK = 32


def init_rwkv_block(key, cfg):
    d = cfg.d_model
    n = cfg.head_dim
    h = d // n
    f = cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 16)
    s = d ** -0.5

    def mat(k, shape, scale):
        return jax.random.normal(k, shape, dt) * scale

    return {
        # --- time mix ---
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),   # r,k,v,w,g shift mixes
        "lora_a": mat(ks[0], (d, LORA_RANK), s),
        "lora_b": mat(ks[1], (LORA_RANK, 5 * d), LORA_RANK ** -0.5) * 0.1,
        "wr": mat(ks[2], (d, d), s),
        "wk": mat(ks[3], (d, d), s),
        "wv": mat(ks[4], (d, d), s),
        "wg": mat(ks[5], (d, d), s),
        "w0": jnp.zeros((d,), jnp.float32) + 0.5,    # decay bias
        "u": jax.random.normal(ks[6], (h, n), jnp.float32) * 0.1,  # bonus
        "ln_o": jnp.ones((h, n), jnp.float32),       # per-head groupnorm
        "ln_o_b": jnp.zeros((h, n), jnp.float32),
        "wo": mat(ks[7], (d, d), s),
        # --- channel mix ---
        "mu_cm": 0.5 * jnp.ones((2, d), jnp.float32),  # k,r shift mixes
        "ck": mat(ks[8], (d, f), s),
        "cv": mat(ks[9], (f, d), f ** -0.5),
        "cr": mat(ks[10], (d, d), s),
    }


def _token_shift(x, x_prev):
    """x: (B,T,D); x_prev: (B,D) last token of previous segment."""
    prev = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return prev - x  # RWKV convention: xx = shifted - x


def time_mix_inputs(p, x, x_prev, cfg):
    """Returns per-stream mixed inputs and the decay/gate tensors."""
    b, t, d = x.shape
    n = cfg.head_dim
    h = d // n
    xx = _token_shift(x, x_prev)
    lora = jnp.tanh((x + xx * p["mu"][0]).astype(jnp.float32)
                    @ p["lora_a"].astype(jnp.float32))
    delta = (lora @ p["lora_b"].astype(jnp.float32)).reshape(b, t, 5, d)
    mixed = x[:, :, None, :] + xx[:, :, None, :] * \
        (p["mu"][None, None].astype(x.dtype) + delta.astype(x.dtype))
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = (xr @ p["wr"]).reshape(b, t, h, n)
    k = (xk @ p["wk"]).reshape(b, t, h, n)
    v = (xv @ p["wv"]).reshape(b, t, h, n)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent per-channel decay, in log space:
    #   w = exp(-exp(d))  =>  log w = -exp(d)
    d_t = p["w0"].astype(jnp.float32) + \
        (xw.astype(jnp.float32) @ p["lora_a"].astype(jnp.float32)
         @ p["lora_b"].astype(jnp.float32)[:, :d]) * 0.1
    logw = -jnp.exp(d_t).reshape(b, t, h, n)  # <= 0
    return r, k, v, logw, g


def recurrence_scan(r, k, v, logw, u, state0):
    """Per-token oracle/decode path.  r,k,v,logw: (B,T,H,N) ; u: (H,N);
    state0: (B,H,N,N) keyed [key_dim, value_dim]."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,N,N)
        att = s + (u[None] * kt)[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, att)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, w))
    state, out = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return out.transpose(1, 0, 2, 3), state  # (B,T,H,N), (B,H,N,N)


def recurrence_chunked(r, k, v, logw, u, state0, chunk: int = 64):
    """Chunkwise-parallel path (matmul-heavy, MXU-friendly).

    Stability: every exponent is a *difference of log-decay cumsums* with
    the later index minuend, hence <= 0; no 1/cumprod appears anywhere.
    """
    b, t, h, n = r.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    rs = (a.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4).astype(
        jnp.float32) for a in (r, k, v, logw))
    rc, kc, vc, lwc = rs

    def per_chunk(state, inp):
        rt, kt, vt, lw = inp                      # (B,C,H,N)
        cl = jnp.cumsum(lw, axis=1)               # inclusive logdecay cumsum
        cl_prev = cl - lw                         # exclusive (cl_{t-1})
        # inter-chunk: o_t += (r_t * exp(cl_{t-1})) @ S
        r_dec = rt * jnp.exp(cl_prev)
        o = jnp.einsum("bchk,bhkv->bchv", r_dec, state)
        # intra-chunk: A[t,s] = sum_n r[t,n] k[s,n] exp(cl_{t-1,n}-cl_{s,n})
        # (strictly lower-triangular) + diagonal bonus u
        decay = jnp.exp(jnp.clip(
            cl_prev[:, :, None] - cl[:, None, :], -60.0, 0.0))  # (B,Ct,Cs,H,N)
        a = jnp.einsum("bthn,bshn,btshn->btsh", rt, kt, decay)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
        a = a * tri[None, :, :, None]
        o = o + jnp.einsum("btsh,bshv->bthv", a, vt)
        o = o + jnp.einsum("bthn,bthn,bthv->bthv",
                           rt, u[None, None] * kt, vt)
        # state update: S' = diag(exp(cl_C)) S + sum_s k_s exp(cl_C-cl_s) v_s^T
        cl_last = cl[:, -1:, :, :]                # (B,1,H,N)
        k_dec = kt * jnp.exp(cl_last - cl)
        state = jnp.exp(cl_last[:, 0])[..., None] * state + \
            jnp.einsum("bchk,bchv->bhkv", k_dec, vt)
        return state, o

    # checkpoint: the scan backward must not store the (B,C,C,H,N) decay
    # tensor per chunk — recompute it; only the (B,H,N,N) carries persist
    per_chunk_ckpt = jax.checkpoint(
        per_chunk, policy=jax.checkpoint_policies.nothing_saveable)
    state, o = jax.lax.scan(per_chunk_ckpt, state0.astype(jnp.float32),
                            (rc, kc, vc, lwc))
    return o.transpose(1, 0, 2, 3, 4).reshape(b, t, h, n), state


def _head_groupnorm(o, scale, bias, eps=64e-5):
    of = o.astype(jnp.float32)
    mu = of.mean(-1, keepdims=True)
    var = of.var(-1, keepdims=True)
    return (of - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def time_mix(p, x, x_prev, state0, cfg, chunk=64, use_chunked=True):
    """Full RWKV6 attention replacement.  Returns (out, x_last, state)."""
    b, t, d = x.shape
    r, k, v, logw, g = time_mix_inputs(p, x, x_prev, cfg)
    if use_chunked and t % chunk == 0 and t > 1:
        o, state = recurrence_chunked(r, k, v, logw, p["u"], state0, chunk)
    else:
        o, state = recurrence_scan(r, k, v, logw, p["u"], state0)
    o = _head_groupnorm(o, p["ln_o"], p["ln_o_b"])
    o = o.reshape(b, t, d).astype(x.dtype) * g
    return o @ p["wo"], x[:, -1, :], state


def channel_mix(p, x, x_prev):
    """RWKV6 FFN.  Returns (out, x_last)."""
    xx = _token_shift(x, x_prev)
    xk = x + xx * p["mu_cm"][0].astype(x.dtype)
    xr = x + xx * p["mu_cm"][1].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"]), x[:, -1, :]


def init_rwkv_state(cfg, batch: int):
    d, n = cfg.d_model, cfg.head_dim
    h = d // n
    return {
        "s": jnp.zeros((batch, h, n, n), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), jnp.float32),
        "shift_cm": jnp.zeros((batch, d), jnp.float32),
    }
