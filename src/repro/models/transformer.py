"""Decoder-LM assembly for all 10 assigned architectures.

An architecture compiles to *segments*: a tuple of block types repeated N
times, with parameters stacked over the repeat axis and executed under
`lax.scan` (small HLO, bounded compile time even at 80 layers) with
per-layer rematerialisation.

    dense/vlm/audio:  [(("attn",), L)]
    llama4 (moe/2):   [(("attn", "moe"), L/2)]
    arctic (moe+res): [(("moe",), L)]
    rwkv6:            [(("rwkv",), L)]
    recurrentgemma:   [(("rec","rec","lattn"), 12), (("rec","rec"), 1)]

Three execution modes share the block code:
    train   — full sequence, no cache;
    prefill — full sequence, emits per-layer cache (stacked by scan);
    decode  — one token, consumes + re-emits cache (scan xs/ys).

Sharding is injected via a duck-typed `shd` context (repro.sharding): the
model only *tags* tensors (`shd.act(x, kind)`); the partition plan decides
layouts.  `shd=None` (CPU tests) is a no-op.  MoE and sharded decode
attention additionally use `shd.mesh` for their `shard_map` sections.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import kvcache, layers, moe, rglru, rwkv6


# ---------------------------------------------------------------------------
# segment plan
# ---------------------------------------------------------------------------

def segments(cfg) -> list[tuple[tuple[str, ...], int]]:
    L = cfg.num_layers
    if cfg.ssm == "rwkv6":
        return [(("rwkv",), L)]
    if cfg.pattern:
        plen = len(cfg.pattern)
        body = tuple("lattn" if t == "attn" else t for t in cfg.pattern)
        segs = [(body, L // plen)]
        tail = L % plen
        if tail:
            segs.append((body[:tail], 1))
        return segs
    if cfg.is_moe:
        if cfg.moe_every == 1:
            return [(("moe",), L)]
        pat = tuple("attn" if i < cfg.moe_every - 1 else "moe"
                    for i in range(cfg.moe_every))
        return [(pat, L // cfg.moe_every)]
    return [(("attn",), L)]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(btype: str, key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"ln1": layers.init_rmsnorm(d), "ln2": layers.init_rmsnorm(d)}
    if btype in ("attn", "lattn"):
        p["attn"] = layers.init_attention(ks[0], cfg)
        p["mlp"] = layers.init_mlp(ks[1], cfg)
    elif btype == "moe":
        p["attn"] = layers.init_attention(ks[0], cfg)
        p["moe"] = moe.init_moe(ks[1], cfg)
        if cfg.dense_ff_residual:
            p["dense"] = layers.init_mlp(ks[2], cfg, cfg.dense_ff_residual)
    elif btype == "rwkv":
        p.update(rwkv6.init_rwkv_block(ks[0], cfg))
    elif btype == "rec":
        p["rec"] = rglru.init_rec_block(ks[0], cfg)
        p["mlp"] = layers.init_mlp(ks[1], cfg)
    else:
        raise ValueError(btype)
    return p


def init_params(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, len(segments(cfg)) + 2)
    params: dict[str, Any] = {}
    if cfg.embed_inputs:
        params["embed"] = jax.random.normal(
            keys[0], (cfg.vocab, cfg.d_model), dt) * cfg.d_model ** -0.5
    segs = []
    for i, (types, n) in enumerate(segments(cfg)):
        seg_keys = jax.random.split(keys[i + 1], n)

        def init_one(k, types=types):
            sub = jax.random.split(k, len(types))
            return [_init_block(t, sk, cfg) for t, sk in zip(types, sub)]

        segs.append(jax.vmap(init_one)(seg_keys))
    params["segments"] = segs
    params["final_norm"] = layers.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab), dt) * cfg.d_model ** -0.5
    return params


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def _init_block_cache(btype, cfg, batch, length):
    if btype in ("attn", "moe"):
        return kvcache.init_full_cache(cfg, batch, length)
    if btype == "lattn":
        return kvcache.init_window_cache(cfg, batch)
    if btype == "rwkv":
        return rwkv6.init_rwkv_state(cfg, batch)
    if btype == "rec":
        return rglru.init_rec_state(cfg, batch)
    raise ValueError(btype)


def init_cache(cfg, batch: int, length: int):
    """Decode cache for a max context of `length` tokens."""
    out = []
    for types, n in segments(cfg):
        def one(_, types=types):
            return [_init_block_cache(t, cfg, batch, length) for t in types]
        out.append(jax.vmap(one)(jnp.arange(n)))
    return out


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

class Ctx(NamedTuple):
    cfg: Any
    mode: str                    # train | prefill | decode
    positions: Any               # (B,T) ids, (B,T,3) mrope, or (B,) decode
    shd: Any = None              # sharding context or None
    router_bias: Any = None      # (E,) slot-hit routing bias (serving)

    @property
    def mesh(self):
        return getattr(self.shd, "mesh", None)

    @property
    def data_axes(self):
        return getattr(self.shd, "data_axes", ("data",))

    def act(self, x, kind):
        return self.shd.act(x, kind) if self.shd is not None else x


def _attention(p, x, cache, ctx, window: int):
    cfg = ctx.cfg
    b, t, _ = x.shape
    h = layers.rmsnorm(x, p["ln1"])
    h = ctx.act(h, "attn_in")
    pos = ctx.positions
    if ctx.mode == "decode":
        rope_pos = pos[:, None] if cfg.pos == "rope" else \
            jnp.broadcast_to(pos[:, None, None], (b, 1, 3))
    else:
        rope_pos = pos
    q, k, v = layers.qkv(p["attn"], h, cfg, rope_pos)
    q = ctx.act(q, "q_heads")
    if ctx.mode == "decode":
        if window:
            o, new_cache = kvcache.window_decode_attention(
                q, cache, k, v, pos, cfg)
        else:
            o, new_cache = kvcache.decode_attention(
                q, cache, k, v, pos, cfg, ctx.mesh, ctx.data_axes)
    else:
        k = ctx.act(k, "kv_heads")
        v = ctx.act(v, "kv_heads")
        kq, vq = k, v
        if (ctx.shd is not None and ctx.shd.strategy == "heads"
                and cfg.q_per_kv > 1):
            # GQA under head-TP: the (H -> kh, g) reshape inside flash
            # attention cannot stay sharded when kh < tp, so expand K/V to
            # one head per query head *before* the kernel; the expanded
            # tensors shard over H exactly like Q (per-device bytes equal
            # replicated KV, so this costs no HBM).
            kq = ctx.act(_expand_kv(k, cfg.q_per_kv), "q_heads")
            vq = ctx.act(_expand_kv(v, cfg.q_per_kv), "q_heads")
        if window:
            o = _local_attention(q, kq, vq, window)
        else:
            o = layers.flash_attention(q, kq, vq, causal=True)
        new_cache = None
        if ctx.mode == "prefill":
            new_cache = _prefill_cache(cfg, k, v, window)
    o = o.reshape(b, t, -1)
    o = ctx.act(o, "attn_out")
    return ctx.act(o @ p["attn"]["wo"], "hidden"), new_cache


def _expand_kv(k, g):
    b, t, kh, dh = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, kh, g, dh))
    return k.reshape(b, t, kh * g, dh)


def _local_attention(q, k, v, window):
    """Exact sliding-window attention via the two-chunk trick."""
    b, t, h, dh = q.shape
    if t <= window:
        return layers.flash_attention(q, k, v, causal=True, window=window,
                                      block=min(t, 1024))
    assert t % window == 0, (t, window)
    nc = t // window
    kh = k.shape[2]
    qc = q.reshape(b, nc, window, h, dh)
    kc = k.reshape(b, nc, window, kh, dh)
    vc = v.reshape(b, nc, window, kh, dh)
    # prepend each chunk's predecessor (zeros for the first)
    kprev = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([kprev, kc], axis=2).reshape(
        b * nc, 2 * window, kh, dh)
    v2 = jnp.concatenate([vprev, vc], axis=2).reshape(
        b * nc, 2 * window, kh, dh)
    q2 = qc.reshape(b * nc, window, h, dh)
    # chunk 0 has a zero-padded predecessor: mask its leading window
    kv_start = jnp.where(
        (jnp.arange(b * nc) % nc) == 0, window, 0).astype(jnp.int32)
    o = layers.flash_attention(q2, k2, v2, causal=True, window=window,
                               q_offset=window, kv_start=kv_start,
                               block=min(2 * window, 1024))
    return o.reshape(b, t, h, dh)


def _prefill_cache(cfg, k, v, window):
    """Arrange prefill K/V as a decode-ready cache."""
    if not window:
        return {"k": k, "v": v}
    b, t, kh, dh = k.shape
    w = cfg.window
    if t >= w:
        # last `window` tokens at their circular slots
        tail_k, tail_v = k[:, t - w:], v[:, t - w:]
        slots = (jnp.arange(t - w, t) % w)
        order = jnp.argsort(slots)
        return {"k": tail_k[:, order], "v": tail_v[:, order]}
    pad = w - t
    return {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}


def _mlp_sub(p, x, ctx, name="mlp"):
    cfg = ctx.cfg
    h = layers.rmsnorm(x, p["ln2"])
    h = ctx.act(h, "mlp_in")
    out = layers.apply_mlp(p[name], h, cfg) if name == "mlp" else None
    return ctx.act(out, "hidden")


def apply_block(btype, p, x, cache, ctx):
    cfg = ctx.cfg
    aux = {}
    if btype in ("attn", "lattn"):
        window = cfg.window if btype == "lattn" else 0
        o, new_cache = _attention(p, x, cache, ctx, window)
        x = x + o
        h = layers.rmsnorm(x, p["ln2"])
        h = ctx.act(h, "mlp_in")
        x = x + ctx.act(layers.apply_mlp(p["mlp"], h, cfg), "hidden")
    elif btype == "moe":
        o, new_cache = _attention(p, x, cache, ctx, 0)
        x = x + o
        h = layers.rmsnorm(x, p["ln2"])
        h = ctx.act(h, "mlp_in")
        mo, aux = moe.moe_apply(p["moe"], h, cfg, ctx.mesh, ctx.data_axes,
                                router_bias=ctx.router_bias)
        if cfg.dense_ff_residual:
            mo = mo + layers.apply_mlp(p["dense"], h, cfg)
        x = x + ctx.act(mo, "hidden")
    elif btype == "rwkv":
        st = cache if cache is not None else rwkv6.init_rwkv_state(
            cfg, x.shape[0])
        h = layers.rmsnorm(x, p["ln1"])
        o, x_last_tm, s_new = rwkv6.time_mix(
            p, h, st["shift_tm"].astype(x.dtype), st["s"], cfg,
            use_chunked=(ctx.mode != "decode"))
        x = x + ctx.act(o, "hidden")
        h2 = layers.rmsnorm(x, p["ln2"])
        o2, x_last_cm = rwkv6.channel_mix(
            p, h2, st["shift_cm"].astype(x.dtype))
        x = x + ctx.act(o2, "hidden")
        new_cache = {"s": s_new,
                     "shift_tm": x_last_tm.astype(jnp.float32),
                     "shift_cm": x_last_cm.astype(jnp.float32)}
    elif btype == "rec":
        st = cache if cache is not None else rglru.init_rec_state(
            cfg, x.shape[0])
        h = layers.rmsnorm(x, p["ln1"])
        o, new_cache = rglru.rec_block(p["rec"], h, st, cfg)
        x = x + ctx.act(o, "hidden")
        h2 = layers.rmsnorm(x, p["ln2"])
        x = x + ctx.act(layers.apply_mlp(p["mlp"], h2, cfg), "hidden")
    else:
        raise ValueError(btype)
    if ctx.mode == "train":
        new_cache = 0  # uniform scan ys placeholder
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# segment scan
# ---------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat == "full"
              else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def run_segments(params, x, caches, ctx):
    """caches: None (train/prefill) or list matching segments."""
    cfg = ctx.cfg
    all_caches, all_aux = [], []
    for si, (types, n) in enumerate(segments(cfg)):
        seg_params = params["segments"][si]
        seg_cache = caches[si] if caches is not None else None

        def body(xc, xs, types=types):
            p_list = xs[0]
            c_list = xs[1] if len(xs) > 1 else [None] * len(types)
            ncs, auxes = [], []
            for j, bt in enumerate(types):
                xc, nc, aux = apply_block(bt, p_list[j], xc, c_list[j], ctx)
                ncs.append(nc)
                auxes.append(aux)
            return xc, (ncs, auxes)

        body = _remat(body, cfg)
        xs = (seg_params,) if seg_cache is None else (seg_params, seg_cache)
        x, (ncs, auxes) = jax.lax.scan(lambda c, s: body(c, s), x, xs)
        all_caches.append(ncs)
        all_aux.append(auxes)
    return x, all_caches, all_aux


# ---------------------------------------------------------------------------
# top level: forward / loss / prefill / decode
# ---------------------------------------------------------------------------

def _embed_in(cfg, params, batch, ctx):
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    return ctx.act(x, "hidden")


def _positions_for(cfg, batch, t):
    if cfg.pos == "mrope":
        return batch["positions"]
    b = (batch["tokens"] if "tokens" in batch else batch["embeds"]).shape[0]
    return jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))


def _logits(cfg, params, x, ctx):
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return ctx.act(x @ head, "logits")


def forward(cfg, params, batch, shd=None, mode="train"):
    t = (batch["tokens"] if "tokens" in batch else batch["embeds"]).shape[1]
    ctx = Ctx(cfg=cfg, mode=mode, positions=_positions_for(cfg, batch, t),
              shd=shd)
    x = _embed_in(cfg, params, batch, ctx)
    x, caches, aux = run_segments(params, x, None, ctx)
    x = layers.rmsnorm(x, params["final_norm"])
    return x, caches, aux, ctx


def loss_fn(cfg, params, batch, shd=None):
    """Next-token cross entropy (mean over tokens); returns (loss, aux)."""
    x, _, aux, ctx = forward(cfg, params, batch, shd)
    tgt = batch["tokens"] if cfg.embed_inputs else batch["labels"]
    # shift by padding (keeps T divisible for the chunked scan); the final
    # position gets weight 0
    targets = jnp.pad(tgt[:, 1:], ((0, 0), (0, 1)))
    weights = jnp.ones(targets.shape, jnp.float32).at[:, -1].set(0.0)

    def xent(xc, tc, wc):
        logits = _logits(cfg, params, xc, ctx).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return ((logz - gold) * wc).sum()

    n_tok = targets.shape[0] * (targets.shape[1] - 1)
    if cfg.loss_chunk and x.shape[1] % cfg.loss_chunk == 0:
        nc = x.shape[1] // cfg.loss_chunk
        xc = x.reshape(x.shape[0], nc, cfg.loss_chunk, -1).transpose(
            1, 0, 2, 3)
        tc = targets.reshape(targets.shape[0], nc, -1).transpose(1, 0, 2)
        wc = weights.reshape(weights.shape[0], nc, -1).transpose(1, 0, 2)
        # checkpoint: the scan's backward must NOT store per-chunk f32
        # logits (that would be the full (B,T,V) we are chunking to avoid)
        chunk_loss = jax.checkpoint(
            lambda a, b, c: xent(a, b, c),
            policy=jax.checkpoint_policies.nothing_saveable)
        total = jax.lax.scan(
            lambda acc, abw: (acc + chunk_loss(*abw), None), jnp.float32(0),
            (xc, tc, wc))[0]
    else:
        total = xent(x, targets, weights)
    loss = total / n_tok
    lb = [a.get("lb_loss") for seg in aux for a in seg
          if isinstance(a, dict) and a.get("lb_loss") is not None]
    if lb:
        loss = loss + 0.01 * sum(jnp.mean(l) for l in lb)
    return loss, aux


def prefill(cfg, params, batch, shd=None):
    """Returns (last-token logits, decode-ready cache, aux)."""
    x, caches, aux, ctx = forward(cfg, params, batch, shd, mode="prefill")
    x = x[:, -1:]
    return _logits(cfg, params, x, ctx), caches, aux


def decode_step(cfg, params, batch, cache, shd=None):
    """One token for every sequence.  batch: tokens/embeds (B,1,...) +
    positions (B,).  Returns (logits (B,1,V), new cache, aux)."""
    ctx = Ctx(cfg=cfg, mode="decode", positions=batch["positions"], shd=shd,
              router_bias=batch.get("router_bias"))
    x = _embed_in(cfg, params, batch, ctx)
    x, caches, aux = run_segments(params, x, cache, ctx)
    x = layers.rmsnorm(x, params["final_norm"])
    return _logits(cfg, params, x, ctx), caches, aux
