"""Version-compatibility shims for the pinned jax (0.4.37).

The repo pins jax 0.4.37 (see pyproject.toml); newer jax moved several
APIs that this tree uses.  Every module that needs a moved symbol imports
it from here so the resolution logic lives in exactly one place:

  * ``shard_map`` — top-level ``jax.shard_map`` only exists on jax >= 0.6;
    on the pinned version it lives at ``jax.experimental.shard_map`` (and
    spells the replication-check kwarg ``check_rep``, not ``check_vma``).
  * ``keystr`` — the ``simple``/``separator`` kwargs are newer than the pin.

Keep this module dependency-light: it is imported at the bottom of the
import graph (core, kernels, models, optim, sharding all route through
it), so it must never import any other ``repro`` module.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: promoted to the top-level namespace
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pinned 0.4.x: still experimental
    from jax.experimental.shard_map import shard_map as _shard_map

import functools as _functools
import inspect as _inspect

_SHARD_MAP_PARAMS = frozenset(
    _inspect.signature(_shard_map).parameters)


@_functools.wraps(_shard_map)
def shard_map(f, *args, **kwargs):
    """`shard_map` accepting both kwarg spellings of replication checking.

    jax >= 0.6 renamed ``check_rep`` to ``check_vma``; callers here use the
    new spelling, which this wrapper translates for the pinned 0.4.37
    (and vice versa on newer jax, should someone pass the old one).
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, *args, **kwargs)

def keystr(path, *, simple: bool = False, separator: str = "") -> str:
    """``jax.tree_util.keystr`` with the ``simple``/``separator`` kwargs.

    Newer jax grew ``keystr(path, simple=True, separator="/")``; the pinned
    0.4.37 only accepts the bare path.  The simple form strips the
    ``DictKey``/``GetAttrKey``/``SequenceKey`` punctuation down to the raw
    key names, which is what the sharding rules match against.
    """
    try:
        return jax.tree_util.keystr(path, simple=simple, separator=separator)
    except TypeError:
        pass
    if not simple:
        return jax.tree_util.keystr(path)

    def _name(k):
        for attr in ("key", "name", "idx"):
            if hasattr(k, attr):
                return str(getattr(k, attr))
        return str(k)

    return separator.join(_name(k) for k in path)


__all__ = ["shard_map", "keystr"]
