"""Instruction disambiguator — a functional, jittable fully-associative cache.

Paper §IV, Fig. 2: the disambiguator is a small fully-associative L0 cache
whose tags are instruction opcodes (plus function fields).  On a hit it
multiplexes the operands to the slot holding the implementation; on a miss it
requests the bitstream from the bitstream cache and reconfigures the LRU
victim slot, paying a (technology-dependent) reconfiguration latency.

This module gives exact LRU semantics as a pure function over a small state
pytree, so the same machinery runs

  * inside the cycle-approximate core simulator (`lax.scan` over a trace),
  * batched over experiment configurations (`vmap`),
  * per-device inside `shard_map` for the TPU expert-slot runtime
    (`repro.core.expert_slots`).

State is intentionally tiny (two int32 vectors + a scalar clock) so it can
live in registers/SMEM when embedded in kernels.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)


class SlotState(NamedTuple):
    """Disambiguator state.

    tags:     (S,) int32 — tag resident in each slot, -1 when empty.
    last_use: (S,) int32 — LRU clock value of the slot's last touch.
    clock:    ()   int32 — monotonically increasing use counter.
    """

    tags: jnp.ndarray
    last_use: jnp.ndarray
    clock: jnp.ndarray


def init(num_slots: int) -> SlotState:
    return SlotState(
        tags=jnp.full((num_slots,), EMPTY, dtype=jnp.int32),
        last_use=jnp.zeros((num_slots,), dtype=jnp.int32),
        clock=jnp.int32(0),
    )


class LookupResult(NamedTuple):
    state: SlotState
    hit: jnp.ndarray          # () bool — tag was resident (or unslotted)
    slot: jnp.ndarray         # () int32 — slot serving the tag (-1 unslotted)
    evicted_tag: jnp.ndarray  # () int32 — tag displaced on a fill, else -1


def _access(state: SlotState, tag: jnp.ndarray,
            num_active: jnp.ndarray | None = None):
    """Shared LRU core: hit-test + victim fill, one implementation.

    Returns (new_state, hit, slot, unslotted, victim) so both the full
    `lookup` (which also reports the evicted tag) and the lean fused
    fleet-scan path (`lookup_fused`, which only needs state + hit) build on
    exactly the same eviction logic and can never drift apart.
    """
    tag = jnp.asarray(tag, jnp.int32)
    unslotted = tag < 0

    matches = state.tags == tag
    if num_active is not None:
        in_active = (jnp.arange(state.tags.shape[0], dtype=jnp.int32)
                     < jnp.asarray(num_active, jnp.int32))
        matches = matches & in_active
    hit_any = jnp.any(matches) & ~unslotted
    hit_slot = jnp.argmax(matches).astype(jnp.int32)

    # LRU victim: prefer empty slots (their last_use is forced to int32 min)
    empties = state.tags == EMPTY
    use_key = jnp.where(empties, jnp.iinfo(jnp.int32).min, state.last_use)
    if num_active is not None:
        use_key = jnp.where(in_active, use_key, jnp.iinfo(jnp.int32).max)
    victim = jnp.argmin(use_key).astype(jnp.int32)

    slot = jnp.where(hit_any, hit_slot, victim)

    clock = state.clock + 1
    do_touch = ~unslotted
    new_tags = jnp.where(
        do_touch & ~hit_any,
        state.tags.at[slot].set(tag),
        state.tags,
    )
    new_last = jnp.where(
        do_touch,
        state.last_use.at[slot].set(clock),
        state.last_use,
    )
    new_state = SlotState(tags=new_tags, last_use=new_last, clock=clock)
    return new_state, hit_any | unslotted, slot, unslotted, victim


def lookup(state: SlotState, tag: jnp.ndarray,
           num_active: jnp.ndarray | None = None) -> LookupResult:
    """Access `tag`; fill the LRU victim on a miss.  tag == -1 is unslotted
    (a hardwired base instruction) and leaves the state untouched but still
    reports hit=True so callers charge no reconfiguration latency.

    `num_active` (optional, traced) restricts the cache to the first
    `num_active` slots: inactive slots never match and are never victims,
    which makes the state behave exactly like an LRU cache of that size.
    This turns the slot *count* — normally a static shape — into a sweepable
    runtime value: allocate the max size once, `vmap` over `num_active`.
    """
    tag = jnp.asarray(tag, jnp.int32)
    new_state, hit, slot, unslotted, victim = _access(state, tag, num_active)
    # a miss that filled an empty slot displaced nothing: tags[victim] is
    # already EMPTY in that case, so no extra guard is needed
    evicted = jnp.where(hit | unslotted, EMPTY, state.tags[victim])
    return LookupResult(
        state=new_state,
        hit=hit,
        slot=jnp.where(unslotted, EMPTY, slot),
        evicted_tag=evicted,
    )


def lookup_fused(slot_state: SlotState, bs_state: SlotState,
                 tag: jnp.ndarray,
                 num_active: jnp.ndarray | None = None):
    """One fused disambiguator + bitstream-cache access — the fleet scan's
    hot pair (paper §IV: a disambiguator miss fetches the bitstream through
    the bitstream cache; a miss there goes to the unified L2).

    Semantically identical to

        res = lookup(slot_state, tag, num_active)
        bs  = lookup(bs_state, where(res.hit, EMPTY, tag))

    but skips the victim-reporting outputs neither cache consumer uses, so
    the per-step state update inside `lax.scan` stays minimal.  Returns
    (slot_state, bs_state, hit, bs_hit).
    """
    tag = jnp.asarray(tag, jnp.int32)
    slot_state, hit, _, _, _ = _access(slot_state, tag, num_active)
    bs_state, bs_hit, _, _, _ = _access(
        bs_state, jnp.where(hit, EMPTY, tag))
    return slot_state, bs_state, hit, bs_hit


def lookup_batch(state: SlotState, tags: jnp.ndarray,
                 num_active: jnp.ndarray | None = None
                 ) -> tuple[SlotState, jnp.ndarray]:
    """Sequentially access a vector of tags; returns (state, hits bool vector).

    A thin `lax.scan` over `lookup` — used by the expert-slot runtime where a
    token block touches a sequence of expert ids on one device.  `num_active`
    masks the pool down exactly like `lookup`'s, so the expert-slot runtime
    can sweep pool sizes over one max-size state the same way the simulator
    sweeps disambiguator sizes.
    """

    def step(st, tag):
        r = lookup(st, tag, num_active)
        return r.state, r.hit

    return jax.lax.scan(step, state, tags)


def invalidate(state: SlotState, idx) -> SlotState:
    """SEU surgery: kill the residents at entry indices `idx`.

    The hit entries become empty (tag -1, last_use 0) exactly as if they
    had never been filled; the clock and every surviving resident are
    untouched, so the survivors keep their relative LRU order.  This is
    the fault-injection primitive behind `simulator.seu_fleet_state` —
    a single-event upset corrupts a slot's configuration bits, so its
    implementation must be re-loaded (and re-pays the reconfiguration
    latency) on next use.
    """
    idx = jnp.asarray(idx, jnp.int32).reshape(-1)
    return SlotState(tags=state.tags.at[idx].set(EMPTY),
                     last_use=state.last_use.at[idx].set(0),
                     clock=state.clock)


def occupancy(state: SlotState) -> jnp.ndarray:
    return jnp.sum(state.tags != EMPTY)


def resident(state: SlotState, tag: jnp.ndarray) -> jnp.ndarray:
    """Non-mutating residency probe (no LRU touch)."""
    return jnp.any(state.tags == jnp.asarray(tag, jnp.int32)) & (tag >= 0)


def resident_many(state: SlotState, tags: jnp.ndarray) -> jnp.ndarray:
    """Vectorized `resident`: (T,) bool residency per probed tag, no LRU
    touch.  Used by the online re-placement layer to measure how much of a
    tenant's slotted working set is still warm in a core's disambiguator
    (the fraction a migration to a cold core would have to re-fault)."""
    tags = jnp.asarray(tags, jnp.int32)
    return jnp.any(state.tags[None, :] == tags[:, None], axis=1) & (tags >= 0)
