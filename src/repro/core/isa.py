"""RISC-V RV32IMF instruction taxonomy used by the paper's evaluation.

The paper (§V-D) partitions the "M" and "F" extension instructions into
reconfigurable-slot *groups* by logic similarity:

  M: {mul, mulh, mulhsu, mulhu} | {div, divu} | {rem, remu}          (3 groups)
  F: {fadd.s, fsub.s} | {fmul.s} | {fdiv.s} |
     {fsgnj.s, fsgnjn.s, fsgnjx.s, fmin.s, fmax.s, fle.s, flt.s, feq.s} |
     {fsqrt.s} | {fcvt.w.s, fcvt.wu.s, fcvt.s.w, fcvt.s.wu} |
     {fmadd.s, fmsub.s, fnmsub.s, fnmadd.s}                           (7 groups)

Three granularity scenarios map instructions onto disambiguator tags:

  scenario 1: tag = instruction id   (8 slots)
  scenario 2: tag = group id         (4 slots)   <- the paper's main scenario
  scenario 3: tag = extension id     (1 slot)

Base RV32I instructions are hardwired and never occupy a slot (tag = -1).

Cycle costs follow §V-A of the paper: base/simple-F ops are 1 cycle, "M" ops
are 4 cycles, F arithmetic units are 6-stage pipelines, and fused
multiply-add chains two of them (12 cycles).

When an extension is absent from a binary's compile target, its instructions
are replaced by ABI soft routines (libgcc/libgcc-soft-float equivalents).
Soft-float cost depends on whether "M" is available in hardware, because
soft-float multiplies dominate; this is exactly why the paper observes
RV32IF ~ RV32IMF for `minver` while RV32IM still beats RV32I on float-heavy
code.  The expansion constants below are calibrated, documented estimates of
dynamic instruction counts of the corresponding libgcc routines.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field

import numpy as np

# ---------------------------------------------------------------------------
# Extensions
# ---------------------------------------------------------------------------


class Ext(enum.IntEnum):
    BASE = 0  # RV32I — hardwired, never slotted
    M = 1
    F = 2


# ---------------------------------------------------------------------------
# Instructions (dynamic-trace alphabet)
# ---------------------------------------------------------------------------

# name -> (extension, group name, hardware cycles)
_INSTRUCTION_TABLE = [
    # --- base marker (represents *any* RV32I instruction in traces) ---
    ("base", Ext.BASE, "base", 1),
    # --- M ---
    ("mul", Ext.M, "mul", 4),
    ("mulh", Ext.M, "mul", 4),
    ("mulhsu", Ext.M, "mul", 4),
    ("mulhu", Ext.M, "mul", 4),
    ("div", Ext.M, "div", 4),
    ("divu", Ext.M, "div", 4),
    ("rem", Ext.M, "rem", 4),
    ("remu", Ext.M, "rem", 4),
    # --- F ---
    ("fadd.s", Ext.F, "fadd", 6),
    ("fsub.s", Ext.F, "fadd", 6),
    ("fmul.s", Ext.F, "fmul", 6),
    ("fdiv.s", Ext.F, "fdiv", 6),
    ("fsqrt.s", Ext.F, "fsqrt", 6),
    ("fsgnj.s", Ext.F, "fcmp", 1),
    ("fsgnjn.s", Ext.F, "fcmp", 1),
    ("fsgnjx.s", Ext.F, "fcmp", 1),
    ("fmin.s", Ext.F, "fcmp", 1),
    ("fmax.s", Ext.F, "fcmp", 1),
    ("fle.s", Ext.F, "fcmp", 1),
    ("flt.s", Ext.F, "fcmp", 1),
    ("feq.s", Ext.F, "fcmp", 1),
    ("fcvt.w.s", Ext.F, "fcvt", 6),
    ("fcvt.wu.s", Ext.F, "fcvt", 6),
    ("fcvt.s.w", Ext.F, "fcvt", 6),
    ("fcvt.s.wu", Ext.F, "fcvt", 6),
    ("fmadd.s", Ext.F, "fma", 12),
    ("fmsub.s", Ext.F, "fma", 12),
    ("fnmsub.s", Ext.F, "fma", 12),
    ("fnmadd.s", Ext.F, "fma", 12),
]

NAMES = [t[0] for t in _INSTRUCTION_TABLE]
NUM_INSTRUCTIONS = len(_INSTRUCTION_TABLE)
INSTR_ID = {name: i for i, name in enumerate(NAMES)}

# group taxonomy (paper §V-D scenario 2) — "base" is group 0 and unslotted
GROUP_NAMES = [
    "base",
    "mul", "div", "rem",
    "fadd", "fmul", "fdiv", "fcmp", "fsqrt", "fcvt", "fma",
]
GROUP_ID = {g: i for i, g in enumerate(GROUP_NAMES)}
NUM_GROUPS = len(GROUP_NAMES)
M_GROUPS = ("mul", "div", "rem")
F_GROUPS = ("fadd", "fmul", "fdiv", "fcmp", "fsqrt", "fcvt", "fma")

# per-instruction static arrays (indexed by instruction id)
INSTR_EXT = np.array([int(t[1]) for t in _INSTRUCTION_TABLE], dtype=np.int32)
INSTR_GROUP = np.array(
    [GROUP_ID[t[2]] for t in _INSTRUCTION_TABLE], dtype=np.int32
)
INSTR_HW_CYCLES = np.array([t[3] for t in _INSTRUCTION_TABLE], dtype=np.int32)

GROUP_EXT = np.zeros(NUM_GROUPS, dtype=np.int32)
for _n, _e, _g, _c in _INSTRUCTION_TABLE:
    GROUP_EXT[GROUP_ID[_g]] = int(_e)

# representative hardware cost per *group* (used by the analytic fig-4 model)
GROUP_HW_CYCLES = np.zeros(NUM_GROUPS, dtype=np.float64)
for _g in GROUP_NAMES:
    _ids = [i for i in range(NUM_INSTRUCTIONS) if INSTR_GROUP[i] == GROUP_ID[_g]]
    GROUP_HW_CYCLES[GROUP_ID[_g]] = float(np.mean(INSTR_HW_CYCLES[_ids]))


# ---------------------------------------------------------------------------
# ABI soft-routine expansion model
# ---------------------------------------------------------------------------
# Dynamic cycles consumed when the instruction's extension is NOT in the
# compile target.  Two columns: the soft routine running on an RV32I machine
# (integer mul/div themselves emulated) and on an RV32IM machine (hardware
# integer mul/div available to the float emulation).  Base instructions are
# never expanded.  Values are calibrated dynamic-instruction estimates for
# libgcc's __mulsi3/__divsi3 and the RV32 soft-float routines; see
# EXPERIMENTS.md §Fig4 for the calibration against the paper's numbers.

# group -> cycles of the soft routine on RV32I
SOFT_COST_ON_I = {
    "mul": 38.0,    # __mulsi3: shift-add loop with early exit — index/address
                    # math has small operands, so the dynamic average is far
                    # below the 32-iteration worst case
    "div": 80.0,    # __udivsi3/__divsi3 restoring division
    "rem": 80.0,
    "fadd": 100.0,  # unpack, align, add, normalise, round, pack
    "fmul": 250.0,  # mantissa 32x32->64 via soft mul dominates
    "fdiv": 600.0,  # iterative mantissa divide (soft mul per step)
    "fcmp": 30.0,
    "fsqrt": 900.0, # newton iterations, each with soft mul
    "fcvt": 40.0,
    "fma": 360.0,   # soft fmul + soft fadd (+rounding glue)
}
# group -> cycles of the soft routine on RV32IM (hardware mul/div available)
SOFT_COST_ON_M = {
    "mul": 4.0,     # not expanded — hardware
    "div": 4.0,
    "rem": 4.0,
    "fadd": 60.0,   # alignment/normalisation logic unchanged
    "fmul": 58.0,   # one hardware mulhu + glue
    "fdiv": 150.0,
    "fcmp": 22.0,
    "fsqrt": 320.0,
    "fcvt": 28.0,
    "fma": 125.0,
}

SOFT_ON_I = np.ones(NUM_GROUPS, dtype=np.float64)
SOFT_ON_M = np.ones(NUM_GROUPS, dtype=np.float64)
for _g, _v in SOFT_COST_ON_I.items():
    SOFT_ON_I[GROUP_ID[_g]] = _v
for _g, _v in SOFT_COST_ON_M.items():
    SOFT_ON_M[GROUP_ID[_g]] = _v


# ---------------------------------------------------------------------------
# Compile targets ("specs")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Spec:
    """A compile target / hardware capability set (e.g. RV32IMF)."""

    name: str
    has_m: bool
    has_f: bool

    def group_cost(self) -> np.ndarray:
        """Per-group dynamic cycles under this spec (hardwired machine).

        Used for the fixed-ISA baselines of Fig. 4: no slots, no
        reconfiguration — extension present => hardware cycles, absent =>
        ABI soft-routine cycles.
        """
        cost = GROUP_HW_CYCLES.copy()
        for g in M_GROUPS:
            if not self.has_m:
                cost[GROUP_ID[g]] = SOFT_ON_I[GROUP_ID[g]]
        for g in F_GROUPS:
            if not self.has_f:
                src = SOFT_ON_M if self.has_m else SOFT_ON_I
                cost[GROUP_ID[g]] = src[GROUP_ID[g]]
        return cost


RV32I = Spec("RV32I", has_m=False, has_f=False)
RV32IM = Spec("RV32IM", has_m=True, has_f=False)
RV32IF = Spec("RV32IF", has_m=False, has_f=True)
RV32IMF = Spec("RV32IMF", has_m=True, has_f=True)
SPECS = {s.name: s for s in (RV32I, RV32IM, RV32IF, RV32IMF)}


# ---------------------------------------------------------------------------
# Slot-granularity scenarios (paper §V-D)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SlotScenario:
    """Maps every instruction id to a disambiguator tag; -1 = unslotted."""

    name: str
    num_slots: int
    instr_tag: np.ndarray = field(repr=False)  # (NUM_INSTRUCTIONS,) int32

    @property
    def num_tags(self) -> int:
        return int(self.instr_tag.max()) + 1


def _scenario_tags(level: str) -> np.ndarray:
    tags = np.full(NUM_INSTRUCTIONS, -1, dtype=np.int32)
    if level == "instruction":
        nxt = 0
        for i in range(NUM_INSTRUCTIONS):
            if INSTR_EXT[i] != Ext.BASE:
                tags[i] = nxt
                nxt += 1
    elif level == "group":
        # group ids start at 1 ("base" is 0); shift to dense 0..9
        for i in range(NUM_INSTRUCTIONS):
            if INSTR_EXT[i] != Ext.BASE:
                tags[i] = INSTR_GROUP[i] - 1
    elif level == "extension":
        for i in range(NUM_INSTRUCTIONS):
            if INSTR_EXT[i] == Ext.M:
                tags[i] = 0
            elif INSTR_EXT[i] == Ext.F:
                tags[i] = 1
    else:
        raise ValueError(level)
    return tags


def make_scenario(level: str, num_slots: int, name: str | None = None) -> SlotScenario:
    return SlotScenario(
        name=name or f"{num_slots}slot/{level}",
        num_slots=num_slots,
        instr_tag=_scenario_tags(level),
    )


# the three scenarios of §V-D
SCENARIO_1 = make_scenario("instruction", 8, "S1: 8 slots, 1/instr")
SCENARIO_2 = make_scenario("group", 4, "S2: 4 slots, 1/group")
SCENARIO_3 = make_scenario("extension", 1, "S3: 1 slot, 1/ext")

# fig-7 slot-count variations of scenario 2
SCENARIO_2_2SLOT = make_scenario("group", 2, "S2v: 2 slots, 1/group")
SCENARIO_2_8SLOT = make_scenario("group", 8, "S2v: 8 slots, 1/group")

SCENARIOS = {
    "s1": SCENARIO_1,
    "s2": SCENARIO_2,
    "s3": SCENARIO_3,
    "s2_2slot": SCENARIO_2_2SLOT,
    "s2_8slot": SCENARIO_2_8SLOT,
}
