"""Bitstream cache model (paper §IV, Fig. 1).

The paper adds a third L1 cache — the *bitstream cache* — beside the
instruction and data caches.  It is separate so its geometry can differ
("wider blocks to facilitate the increased data width to carry bitstreams").
The paper's evaluation folds its latency into the abstract miss-latency
constant; this module keeps an explicit sizing model so that

  * the simulator's two-level cost (disambiguator miss -> bitstream-cache
    hit/miss) has physically grounded defaults, and
  * the TPU adaptation (`repro.core.expert_slots`) can derive slot-fill
    times from *bytes moved / bandwidth* instead of abstract cycles.

Sizing grounding: a small reconfigurable region able to host one RISC-V
instruction group (a pipelined FP adder, say ~500-2000 LUTs) needs a partial
bitstream of roughly 30-200 KB on today's 7-series-class fabrics; a
wide-block cache line of 64-256 B then needs hundreds of beats per fill,
which is exactly why the paper calls for faster, smaller-region
reconfiguration technologies.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class BitstreamCacheConfig:
    """Geometry + timing of the L1 bitstream cache."""

    entries: int = 16              # bitstreams resident (fully associative)
    bitstream_bytes: int = 64 * 1024   # per instruction-group bitstream
    block_bytes: int = 256         # wide cache block (vs 64B I/D lines)
    fill_cycles_per_block: int = 2  # from unified L2
    config_port_bytes_per_cycle: int = 1024  # fabric configuration port bw

    @property
    def reconfig_cycles(self) -> int:
        """Cycles to push a resident bitstream into a slot (the paper's
        'fast reconfiguration technology' knob).  64KB @ 1KB/cycle = 64."""
        return max(1, self.bitstream_bytes // self.config_port_bytes_per_cycle)

    @property
    def fill_cycles(self) -> int:
        """Cycles to bring a bitstream into the cache from L2 on a miss."""
        blocks = -(-self.bitstream_bytes // self.block_bytes)
        return blocks * self.fill_cycles_per_block

    def miss_latency(self, bs_hit: bool) -> int:
        """End-to-end disambiguator-miss cost."""
        return self.reconfig_cycles + (0 if bs_hit else self.fill_cycles)


# Presets spanning the paper's 10/50/250-cycle study range:
FUTURE_FAST = BitstreamCacheConfig(
    bitstream_bytes=8 * 1024, config_port_bytes_per_cycle=1024)   # ~8 cycles
NEAR_TERM = BitstreamCacheConfig(
    bitstream_bytes=48 * 1024, config_port_bytes_per_cycle=1024)  # ~47 cycles
PARTIAL_RECONFIG = BitstreamCacheConfig(
    bitstream_bytes=256 * 1024, config_port_bytes_per_cycle=1024)  # ~256 cycles
