"""Single-pass LRU stack-distance engine (Mattson et al., 1970).

LRU has the *stack-inclusion* property: the contents of an S-slot LRU cache
are always a subset of an (S+1)-slot one, so one pass over a tag stream
yields exact hit/miss counts for EVERY cache size at once.  An access whose
stack distance (number of distinct slotted tags touched since the previous
access to the same tag) is `d` hits in any cache of more than `d` slots and
misses in every smaller one; first-touch accesses miss at all sizes.

The fleet simulator's sweep grid (`repro.core.simulator.sweep_fleet`)
brute-forces exactly this axis with one `lax.scan` per {slot count x miss
latency} lane.  Whenever a run is

  * **unpreempted** — the round-robin quantum is unreachable, so only
    program 0 is ever scheduled and its trace order is independent of the
    per-step costs (and hence of the miss latency), and
  * **warm-bitstream** — the bitstream cache holds at least as many entries
    as there are distinct tags, so it never evicts and each tag misses it
    exactly once: on its compulsory (first-touch) disambiguator miss,

the whole grid collapses into post-processing of one distance profile:

    slot_misses(S) = cold + #{accesses with distance >= S}
    bs_misses      = cold                    (== distinct slotted tags)
    cycles(S, L)   = sum(hw[instr]) + slot_misses(S) * L
                     + bs_misses * bs_miss_extra

with no handler cycles and zero switches.  All arithmetic is int32, like
the scan it replaces, so eligible results are bit-for-bit identical
(`simulator` guards eligibility so no int32 accumulator can overflow).

The distance computation itself is vectorised rather than scanned: a
(steps, num_tags) last-occurrence matrix built with `lax.cummax` gives each
access's previous-occurrence cursor, and the stack distance is a row-wise
count of tags touched more recently — O(steps * num_tags) elementwise work
with no sequential dependency beyond the cummax, which is far faster than
stepping an LRU state machine.

This module is deliberately generic: it knows nothing about the RISC-V
alphabet.  Callers pass the per-opcode tag and cost tables
(`repro.core.simulator` passes `isa.INSTR_HW_CYCLES`).

Preempted runs cannot use this collapse — their merged access order is
cost-dependent, hence grid-cell-dependent — but they are not scan-only:
`repro.core.stackdist_interleaved` replays each cell's own interleaving
at scheduler-window granularity with the same cummax distance pass.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "DistanceProfile", "SweepGrid",
    "distance_profile", "misses_for_counts", "cycles_grid",
    "sweep_unpreempted", "lanes_unpreempted",
]


class DistanceProfile(NamedTuple):
    """Everything the affine cycle reconstruction needs, per tag stream."""

    hist: jnp.ndarray         # (num_tags,) int32 — hist[d] = reuse accesses
                              # at finite stack distance d
    cold: jnp.ndarray         # () int32 — first-touch accesses; equals the
                              # number of distinct slotted tags in the stream
    base_cycles: jnp.ndarray  # () int32 — sum of per-instruction hw cycles
    steps: jnp.ndarray        # () int32 — stream length (== instructions)


class SweepGrid(NamedTuple):
    """Reconstructed counters over a {slot count x miss latency} grid."""

    cycles: jnp.ndarray       # (..., K, L) int32
    slot_misses: jnp.ndarray  # (..., K) int32 — latency-independent
    bs_misses: jnp.ndarray    # (...,) int32 — size- and latency-independent


def _profile_one(tags: jnp.ndarray, costs: jnp.ndarray,
                 num_tags: int) -> DistanceProfile:
    """(N,) tag stream (-1 = unslotted) + (N,) hw costs -> DistanceProfile."""
    n = tags.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    tag_ids = jnp.arange(num_tags, dtype=jnp.int32)
    # last_pos[i, u] = last position j <= i with tags[j] == u, else -1
    occurrence = jnp.where(tags[:, None] == tag_ids[None, :],
                           idx[:, None], jnp.int32(-1))
    last_pos = jax.lax.cummax(occurrence, axis=0)
    # shift to *strictly before i*: the state the access at i observes
    prev = jnp.concatenate(
        [jnp.full((1, num_tags), -1, jnp.int32), last_pos[:-1]], axis=0)

    slotted = tags >= 0
    safe = jnp.clip(tags, 0)  # clamp -1 so the gather below stays in-bounds
    prev_self = jnp.take_along_axis(prev, safe[:, None], axis=1)[:, 0]
    cold = slotted & (prev_self < 0)
    # distinct tags touched after my previous occurrence (excludes myself:
    # prev[i, tags[i]] == prev_self, never strictly greater)
    dist = jnp.sum(prev > prev_self[:, None], axis=1).astype(jnp.int32)

    bucket = jnp.where(slotted & ~cold, dist, jnp.int32(num_tags))
    hist = jnp.bincount(bucket, length=num_tags + 1)[:num_tags]
    return DistanceProfile(
        hist=hist.astype(jnp.int32),
        cold=jnp.sum(cold).astype(jnp.int32),
        base_cycles=jnp.sum(costs).astype(jnp.int32),
        steps=jnp.int32(n),
    )


@functools.partial(jax.jit, static_argnames=("num_tags",))
def distance_profile(tags: jnp.ndarray, costs: jnp.ndarray,
                     num_tags: int) -> DistanceProfile:
    """Profile one (N,) tag/cost stream.  num_tags must cover max(tags)+1."""
    return _profile_one(jnp.asarray(tags, jnp.int32),
                        jnp.asarray(costs, jnp.int32), num_tags)


def misses_for_counts(profile: DistanceProfile,
                      slot_counts: jnp.ndarray) -> jnp.ndarray:
    """(K,) exact LRU miss counts, one per requested slot count."""
    num_tags = profile.hist.shape[0]
    # tail[s] = reuse accesses with distance >= s; tail[num_tags] = 0
    tail = jnp.concatenate(
        [jnp.cumsum(profile.hist[::-1])[::-1].astype(jnp.int32),
         jnp.zeros((1,), jnp.int32)])
    counts = jnp.clip(jnp.asarray(slot_counts, jnp.int32), 0, num_tags)
    return profile.cold + tail[counts]


def cycles_grid(profile: DistanceProfile, slot_counts: jnp.ndarray,
                miss_latencies: jnp.ndarray,
                bs_miss_extra) -> SweepGrid:
    """Affine reconstruction over the full {slot count x latency} grid."""
    misses = misses_for_counts(profile, slot_counts)          # (K,)
    lats = jnp.asarray(miss_latencies, jnp.int32)             # (L,)
    cycles = (profile.base_cycles
              + misses[:, None] * lats[None, :]
              + profile.cold * jnp.int32(bs_miss_extra))      # (K, L)
    return SweepGrid(cycles=cycles, slot_misses=misses, bs_misses=profile.cold)


def _stream(traces: jnp.ndarray, instr_tag: jnp.ndarray,
            instr_costs: jnp.ndarray, total_steps: int):
    """Unroll (…, N) instruction traces into (…, total_steps) tag/cost
    streams, wrapping the cursor exactly like the scan path does."""
    idx = jnp.remainder(jnp.arange(total_steps, dtype=jnp.int32),
                        traces.shape[-1])
    stream = traces[..., idx]
    return (jnp.asarray(instr_tag, jnp.int32)[stream],
            jnp.asarray(instr_costs, jnp.int32)[stream])


@functools.partial(jax.jit, static_argnames=("num_tags", "total_steps"))
def sweep_unpreempted(traces: jnp.ndarray, instr_tag: jnp.ndarray,
                      instr_costs: jnp.ndarray, slot_counts: jnp.ndarray,
                      miss_latencies: jnp.ndarray, bs_miss_extra, *,
                      num_tags: int, total_steps: int) -> SweepGrid:
    """Solo-program sweep: (B, N) traces -> SweepGrid with (B, K, L) cycles.

    One distance profile per trace — independent of BOTH grid axes — then
    the whole {slot count x latency} grid reconstructs affinely.
    """
    tags, costs = _stream(jnp.asarray(traces, jnp.int32), instr_tag,
                          instr_costs, total_steps)
    profiles = jax.vmap(
        functools.partial(_profile_one, num_tags=num_tags))(tags, costs)
    return jax.vmap(
        lambda p: cycles_grid(p, slot_counts, miss_latencies,
                              bs_miss_extra))(profiles)


@functools.partial(jax.jit, static_argnames=("num_tags", "total_steps"))
def lanes_unpreempted(traces: jnp.ndarray, instr_tag: jnp.ndarray,
                      instr_costs: jnp.ndarray, num_slots: jnp.ndarray,
                      miss_latencies: jnp.ndarray, bs_miss_extra, *,
                      num_tags: int, total_steps: int):
    """Paired (trace, latency) lanes at one slot count — the
    `simulate_single_batch` shape.  Returns (cycles, slot_misses, bs_misses),
    each (B,) int32."""
    tags, costs = _stream(jnp.asarray(traces, jnp.int32), instr_tag,
                          instr_costs, total_steps)
    profiles = jax.vmap(
        functools.partial(_profile_one, num_tags=num_tags))(tags, costs)
    misses = jax.vmap(
        lambda p: misses_for_counts(p, jnp.reshape(num_slots, (1,)))[0]
    )(profiles)
    lats = jnp.asarray(miss_latencies, jnp.int32).reshape(-1)
    cycles = (profiles.base_cycles + misses * lats
              + profiles.cold * jnp.int32(bs_miss_extra))
    return cycles, misses, profiles.cold
