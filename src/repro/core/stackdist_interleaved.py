"""Interleave-aware LRU stack-distance engine for *preempted* fleets.

The unpreempted engine (`repro.core.stackdist`) collapses the whole
{slot count x miss latency} grid into post-processing of one distance
profile, but it is only exact when the scheduler never fires.  Under
preemption that collapse is impossible in principle: the round-robin
quantum is counted in *cycles*, a slot miss burns more of the quantum
than a hit, and how often an access misses depends on the slot count and
miss latency — so the context-switch points, and with them the merged
access order itself, differ per grid cell.  No single merged tag stream
can serve the whole grid.

What *can* be shared is the mathematics.  This module keeps Mattson's
argument — an access to a shared exact-LRU disambiguator hits at slot
count S iff its stack distance in the **merged** (interleaved) stream is
below S, where the stack distance is the number of distinct slotted tags
touched since the access's previous occurrence, regardless of which
program touched them — and drops the sequential granularity from *steps*
to *scheduler windows*.  Per grid cell the engine carries the merged
stream's per-tag last-occurrence vector plus the scheduler state
(per-program cursors, priority-schedule cursor, cycles burnt in the open
quantum) and each `lax.while_loop` iteration commits one window of the
scheduled program's upcoming accesses:

  1. gather a static-size window of the scheduled program's next `W`
     accesses (the trace cursor wraps exactly like the scan's);
  2. one `cummax` pass over the (W, num_tags) occurrence matrix — seeded
     with the carried last-occurrence vector — yields every window
     access's stack distance in the merged stream (the same trick as
     `stackdist._profile_one`, shifted to a non-empty initial state);
  3. distances give misses (miss iff first touch or distance >= S),
     misses give per-access cycle costs, the running cost sum gives the
     quantum-expiry point; the window commits up to that point (or the
     whole window when the quantum survives it — the carried
     quantum-cycle counter resumes it next iteration), last-occurrence /
     cursors / counters advance, and an expiry pays the context-switch
     handler and rotates the weighted round-robin schedule.

The loop runs until `total_steps` accesses committed.  Its trip count is
~ total_steps / W plus one extra iteration per context switch — two to
three orders of magnitude below the per-step scan's trip count — while
every inner operation is a wide vector op over the window: the same
sequential-depth-for-parallel-work trade that bought the unpreempted
path its ~40x, now available in the preempted regime the serving stack
(placement search, online re-placement pricing) actually lives in.

Exactness needs the **warm bitstream cache** precondition for the same
reason the unpreempted path does: warm (entries >= distinct tags across
*every* program's tag table — the disambiguator and bitstream cache are
shared, so tag streams merge) means the bitstream cache never evicts, a
bitstream miss happens exactly on each tag's first (cold) touch in the
merged stream, and the bitstream axis decouples from the slot-count
axis.  Cold bitstream caches stay on the scan (preempted) or take the
stacked pass of `repro.core.stackdist_cold` (unpreempted).  All
arithmetic is int32 like the scan, so eligible results are bit-for-bit
identical (`repro.core.simulator.interleaved_eligible` guards warmth and
int32 overflow; parity is enforced by
tests/test_stackdist_interleaved.py).

**Resumable runs** (`resume_preempted`): a cell can also start from a
scan `FleetState` instead of a cold stream.  The seed translates cache
contents into the engine's coordinates — every tag gets a *virtual*
last-occurrence position in a block `[0, num_tags)` placed below all
segment positions: evicted-but-bitstream-resident tags take the bottom
of the block (any access to them must re-fault: with a full
disambiguator their stack distance is >= every slot count, and they are
not cold, so no bitstream miss is charged), disambiguator residents sit
above them ordered by LRU `last_use`, untouched tags stay -1 (their
first touch is still the compulsory cold+bitstream miss).  Segment
accesses then occupy positions `num_tags + step`, so one cummax pass
recovers exactly the stack distances a seeded LRU cache would produce.
The open quantum (`q_cycles`), scheduler cursor, per-program trace
cursors and cumulative counters seed the carry directly.  To come back
*out*, the cell additionally tracks each tag's last slot-miss position
(`last_miss_pos`, the bitstream cache's own LRU clock input), which —
together with `last_pos` — is enough to rebuild a `FleetState`
bit-for-bit in canonical slot order (`repro.core.simulator` owns the
translation in `_seed_carry` / `_state_from_final`).

The window size `W` is a pure performance knob, not a correctness
parameter: a quantum larger than the window simply spans several
iterations via the carried quantum-cycle counter.  Like its sibling,
this module is deliberately generic — it knows nothing about the RISC-V
alphabet; callers pass the per-opcode tag and cost tables.

**Kernel dispatch** (`use_kernel`): both entry points accept a knob that
routes the window pass through the fused Pallas kernel
(`repro.kernels.window_distance`) instead of the jnp body above — the
whole per-cell loop runs on-chip with the per-tag `last_pos` vector
resident in VMEM/registers and the (W, num_tags) occurrence matrices
never materialised in HBM.  `None` defers to the session default
(`window_distance.resolve`: compiled Pallas on GPU/TPU, the jnp body on
CPU); `'kernel'`/True forces the kernel (interpret mode off-accelerator);
`'interpret'` forces `pl.pallas_call(..., interpret=True)` — the CPU
parity path CI proves bit-for-bit; `'jnp'`/False forces the always-
available jnp fallback.  Every mode returns bit-identical results
(tests/test_window_kernel.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import window_distance

__all__ = ["CellCarry", "InterleavedGrid", "resume_preempted",
           "sweep_preempted"]


class InterleavedGrid(NamedTuple):
    """Per-cell fleet counters over a {quantum x fleet x slots x latency}
    grid — the scan's `FleetResult` fields with (Q, B, K, L, ...) axes."""

    cycles: jnp.ndarray        # (Q, B, K, L, P) int32, incl. handler
    instructions: jnp.ndarray  # (Q, B, K, L, P) int32
    slot_misses: jnp.ndarray   # (Q, B, K, L, P) int32
    bs_misses: jnp.ndarray     # (Q, B, K, L, P) int32
    switches: jnp.ndarray      # (Q, B, K, L) int32


class CellCarry(NamedTuple):
    """One cell's loop carry — also the seed/result type of the resumable
    entry.  Counters are cumulative, so a seeded run keeps accumulating
    on top of the seed's values exactly like a resumed scan would.
    `last_miss_pos` is live only when the cell materialises a resumable
    state (`None` otherwise — an empty pytree node, so the one-shot
    sweep's compiled carry is unchanged); seeds always pass -1s for it
    (segment-local: only misses *since the seed* can move bitstream LRU
    order, earlier order is recovered from the seed state itself)."""

    last_pos: jnp.ndarray       # (num_tags,) merged-stream last occurrence
    last_miss_pos: jnp.ndarray  # (num_tags,) last slot-miss occurrence
    cursors: jnp.ndarray        # (P,) per-program trace cursor
    sched_idx: jnp.ndarray      # () cursor into the priority schedule
    steps_done: jnp.ndarray     # () committed accesses (merged position)
    q_cycles: jnp.ndarray       # () cycles burnt in the open quantum
    cycles: jnp.ndarray         # (P,) attributed cycles (incl. handler)
    instrs: jnp.ndarray         # (P,)
    misses: jnp.ndarray         # (P,) disambiguator misses
    bs_misses: jnp.ndarray      # (P,) bitstream-cache misses
    switches: jnp.ndarray       # () context switches


def _simulate_cell(ptags, pcosts, num_active, miss_latency, quanta,
                   schedule, handler, bs_miss_extra, num_tags: int,
                   total_steps: int, window: int,
                   seed: CellCarry | None = None,
                   materialise: bool = False):
    """One grid cell: (P, N) pre-gathered tag/cost streams -> counters.

    Mirrors `simulator._fleet_step_fn`'s cost model exactly, one window
    per iteration instead of one access per scan step.  `num_active`,
    `miss_latency` and `quanta` are the cell's coordinates; `schedule`
    is the weighted round-robin turn order shared by the whole grid.

    With a `seed` the cell resumes mid-run: segment positions shift up by
    `num_tags` so the seed's virtual per-tag positions in `[0, num_tags)`
    sit below every new access (see module docstring).  With
    `materialise` (static) the carry additionally tracks per-tag last
    slot-miss positions and the full final carry is returned instead of
    the counter tuple.
    """
    num_progs, trace_len = ptags.shape
    tag_ids = jnp.arange(num_tags, dtype=jnp.int32)
    warange = jnp.arange(window, dtype=jnp.int32)
    sched_len = schedule.shape[0]
    # seeded runs place segment accesses above the seed's virtual block
    pos_base = num_tags if seed is not None else 0

    def cond(c: CellCarry):
        return c.steps_done < total_steps

    def body(c: CellCarry) -> CellCarry:
        p = schedule[c.sched_idx]
        idx = jnp.remainder(c.cursors[p] + warange, trace_len)
        w_tags = jnp.take(ptags[p], idx)
        w_hw = jnp.take(pcosts[p], idx)
        slotted = w_tags >= 0

        # merged-stream stack distances for the whole window in one pass:
        # occ/cummax give each tag's last occurrence at-or-before every
        # window row; shifting by one row and flooring with the carried
        # last_pos yields the state each access observes
        pos = c.steps_done + warange
        if pos_base:
            pos = jnp.int32(pos_base) + pos
        match = w_tags[:, None] == tag_ids[None, :]
        occ = jnp.where(match, pos[:, None], jnp.int32(-1))
        cm = jax.lax.cummax(occ, axis=0)
        prev = jnp.concatenate(
            [c.last_pos[None, :],
             jnp.maximum(cm[:-1], c.last_pos[None, :])], axis=0)
        safe = jnp.clip(w_tags, 0)   # clamp -1 so the gather stays in-bounds
        prev_self = jnp.take_along_axis(prev, safe[:, None], axis=1)[:, 0]
        cold = slotted & (prev_self < 0)
        dist = jnp.sum(prev > prev_self[:, None], axis=1).astype(jnp.int32)
        miss = slotted & (cold | (dist >= num_active))

        # scan cost model: hw + miss latency + (warm bitstream cache ->
        # bitstream miss exactly on the cold touch)
        cost = (w_hw + jnp.where(miss, miss_latency, 0)
                + jnp.where(cold, bs_miss_extra, 0)).astype(jnp.int32)
        cum = c.q_cycles + jnp.cumsum(cost)
        expire = cum >= quanta[p]
        any_exp = jnp.any(expire)
        # first expiring access executes, then the switch fires — exactly
        # the scan's `q = q_cycles + cost; do_switch = q >= quantum`
        n_exp = jnp.where(any_exp,
                          jnp.argmax(expire).astype(jnp.int32) + 1,
                          jnp.int32(window))
        remaining = (total_steps - c.steps_done).astype(jnp.int32)
        n = jnp.minimum(n_exp, remaining)
        do_switch = any_exp & (n_exp <= remaining)

        committed = jnp.take(cm, n - 1, axis=0)   # per-tag last occ <= n-1
        if materialise:
            cm_miss = jax.lax.cummax(
                jnp.where(match & miss[:, None], pos[:, None],
                          jnp.int32(-1)), axis=0)
            last_miss_pos = jnp.maximum(c.last_miss_pos,
                                        jnp.take(cm_miss, n - 1, axis=0))
        else:
            last_miss_pos = c.last_miss_pos
        end_cum = jnp.take(cum, n - 1)
        run_cycles = (end_cum - c.q_cycles
                      + jnp.where(do_switch, handler, 0).astype(jnp.int32))
        in_run = warange < n
        return CellCarry(
            last_pos=jnp.maximum(c.last_pos, committed),
            last_miss_pos=last_miss_pos,
            cursors=c.cursors.at[p].add(n),
            sched_idx=jnp.where(do_switch,
                                (c.sched_idx + 1) % sched_len,
                                c.sched_idx),
            steps_done=c.steps_done + n,
            q_cycles=jnp.where(do_switch, 0, end_cum).astype(jnp.int32),
            cycles=c.cycles.at[p].add(run_cycles),
            instrs=c.instrs.at[p].add(n),
            misses=c.misses.at[p].add(
                jnp.sum(miss & in_run).astype(jnp.int32)),
            bs_misses=c.bs_misses.at[p].add(
                jnp.sum(cold & in_run).astype(jnp.int32)),
            switches=c.switches + do_switch.astype(jnp.int32),
        )

    if seed is None:
        zeros_p = jnp.zeros((num_progs,), jnp.int32)
        init = CellCarry(
            last_pos=jnp.full((num_tags,), -1, jnp.int32),
            last_miss_pos=(jnp.full((num_tags,), -1, jnp.int32)
                           if materialise else None),
            cursors=zeros_p, sched_idx=jnp.int32(0), steps_done=jnp.int32(0),
            q_cycles=jnp.int32(0), cycles=zeros_p, instrs=zeros_p,
            misses=zeros_p, bs_misses=zeros_p, switches=jnp.int32(0))
    else:
        init = seed._replace(
            last_miss_pos=jnp.full((num_tags,), -1, jnp.int32),
            steps_done=jnp.int32(0))
    final = jax.lax.while_loop(cond, body, init)
    if materialise:
        return final
    return (final.cycles, final.instrs, final.misses, final.bs_misses,
            final.switches)


@functools.partial(jax.jit,
                   static_argnames=("num_tags", "total_steps", "window",
                                    "kernel", "interpret"))
def _resume_impl(fleet, tag_table, instr_costs, num_active, miss_latency,
                 quanta, schedule, handler, bs_miss_extra,
                 seed: CellCarry, *, num_tags: int, total_steps: int,
                 window: int, kernel: bool, interpret: bool) -> CellCarry:
    table = jnp.asarray(tag_table, jnp.int32)
    costs = jnp.asarray(instr_costs, jnp.int32)
    fleet = jnp.asarray(fleet, jnp.int32)
    ptags = jnp.take_along_axis(table, fleet, axis=1)
    pcosts = costs[fleet]
    if kernel:
        kseed = (seed.last_pos, seed.cursors, seed.sched_idx,
                 seed.q_cycles, seed.cycles, seed.instrs, seed.misses,
                 seed.bs_misses, seed.switches)
        return CellCarry(*window_distance.window_cell(
            ptags, pcosts, num_active, miss_latency, quanta, schedule,
            handler, bs_miss_extra, seed=kseed, num_tags=num_tags,
            total_steps=total_steps, window=window, materialise=True,
            interpret=interpret))
    return _simulate_cell(ptags, pcosts,
                          jnp.asarray(num_active, jnp.int32),
                          jnp.asarray(miss_latency, jnp.int32),
                          jnp.asarray(quanta, jnp.int32),
                          jnp.asarray(schedule, jnp.int32),
                          jnp.asarray(handler, jnp.int32),
                          jnp.asarray(bs_miss_extra, jnp.int32),
                          num_tags, total_steps, window,
                          seed=seed, materialise=True)


def resume_preempted(fleet: jnp.ndarray, tag_table: jnp.ndarray,
                     instr_costs: jnp.ndarray, num_active, miss_latency,
                     quanta: jnp.ndarray, schedule: jnp.ndarray, handler,
                     bs_miss_extra, seed: CellCarry, *, num_tags: int,
                     total_steps: int, window: int,
                     use_kernel=None) -> CellCarry:
    """One resumable cell: (P, N) traces + engine-coordinate seed ->
    final `CellCarry` (cumulative counters plus the per-tag occurrence
    vectors `repro.core.simulator._state_from_final` turns back into a
    `FleetState`).  The seed is built by `simulator._seed_carry`; its
    `last_miss_pos`/`steps_done` fields are ignored (reset to -1/0).
    `use_kernel` picks the window-pass implementation (module
    docstring); every mode is bit-for-bit identical."""
    kernel, interpret = window_distance.resolve(use_kernel)
    return _resume_impl(fleet, tag_table, instr_costs, num_active,
                        miss_latency, quanta, schedule, handler,
                        bs_miss_extra, seed, num_tags=num_tags,
                        total_steps=total_steps, window=window,
                        kernel=kernel, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("num_tags", "total_steps", "window",
                                    "kernel", "interpret"))
def _sweep_impl(fleets, tag_table, instr_costs, slot_counts,
                miss_latencies, quanta, schedule, handler, bs_miss_extra,
                *, num_tags: int, total_steps: int, window: int,
                kernel: bool, interpret: bool) -> InterleavedGrid:
    table = jnp.asarray(tag_table, jnp.int32)
    costs = jnp.asarray(instr_costs, jnp.int32)
    fleets = jnp.asarray(fleets, jnp.int32)
    # hoist the per-access dependent double gather out of the loop, like
    # the scan path does: (B, P, N) tag and hw-cost streams
    ptags = jax.vmap(lambda f: jnp.take_along_axis(table, f, axis=1))(fleets)
    pcosts = costs[fleets]
    if kernel:
        return InterleavedGrid(*window_distance.window_grid(
            ptags, pcosts, slot_counts, miss_latencies, quanta, schedule,
            handler, bs_miss_extra, num_tags=num_tags,
            total_steps=total_steps, window=window, interpret=interpret))

    def one(pt, pc, s, lat, qv):
        return _simulate_cell(pt, pc, s, lat, qv, schedule,
                              jnp.asarray(handler, jnp.int32),
                              jnp.asarray(bs_miss_extra, jnp.int32),
                              num_tags, total_steps, window)

    f = jax.vmap(one, in_axes=(None, None, None, 0, None))   # latency axis
    f = jax.vmap(f, in_axes=(None, None, 0, None, None))     # slot-count
    f = jax.vmap(f, in_axes=(0, 0, None, None, None))        # fleet axis
    f = jax.vmap(f, in_axes=(None, None, None, None, 0))     # quantum axis
    return InterleavedGrid(*f(ptags, pcosts,
                              jnp.asarray(slot_counts, jnp.int32),
                              jnp.asarray(miss_latencies, jnp.int32),
                              jnp.asarray(quanta, jnp.int32)))


def sweep_preempted(fleets: jnp.ndarray, tag_table: jnp.ndarray,
                    instr_costs: jnp.ndarray, slot_counts: jnp.ndarray,
                    miss_latencies: jnp.ndarray, quanta: jnp.ndarray,
                    schedule: jnp.ndarray, handler, bs_miss_extra, *,
                    num_tags: int, total_steps: int, window: int,
                    use_kernel=None) -> InterleavedGrid:
    """Preempted-fleet sweep: (B, P, N) traces -> InterleavedGrid.

    `tag_table` is the (P, num_opcodes) per-program instr->tag table,
    `instr_costs` the shared (num_opcodes,) hw-cycle table, `quanta` the
    (Q, P) swept per-program quantum grid, `schedule` the weighted
    round-robin turn order.  Every {quantum x fleet x slot count x miss
    latency} cell runs its own interleaving (the switch points are
    cost-dependent, see module docstring); cells are independent, so the
    grid is a vmap^4 over one cell engine — or, under `use_kernel` (see
    module docstring), one fused Pallas kernel whose grid is the cell
    grid — axis order matching the scan's `simulator._sweep_fleet`.
    """
    kernel, interpret = window_distance.resolve(use_kernel)
    return _sweep_impl(fleets, tag_table, instr_costs, slot_counts,
                       miss_latencies, quanta, schedule, handler,
                       bs_miss_extra, num_tags=num_tags,
                       total_steps=total_steps, window=window,
                       kernel=kernel, interpret=interpret)
