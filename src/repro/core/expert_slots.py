"""Slot-resident experts — the paper's architecture mapped onto TPU serving.

Mapping (DESIGN.md §2): an MoE expert's weight block is the *bitstream*, HBM
is the *bitstream cache*, a per-device pool of S fast-resident experts is the
*reconfigurable slot* array, and the router's expert id is the *opcode*.  The
disambiguator becomes a block-granular exact-LRU residency tracker: a token
block "executes" a set of expert ids; ids not resident trigger a slot fill
whose cost is bytes/bandwidth (the reconfiguration latency analogue).

Beyond-paper knob: *slot-hit routing* biases the router's logits toward
resident experts (within a quality margin), trading routing fidelity for
fill traffic — the serving engine measures both sides of that trade.

Everything is functional over small state pytrees so it runs per-device
under `shard_map`/`vmap` and inside jitted decode steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ExpertSlotConfig:
    num_experts: int
    slots_per_device: int
    expert_bytes: int                      # "bitstream" size
    fill_bandwidth: float = 100e9          # bytes/s budgeted for slot fills
                                           # (~1/8 of v5e HBM bw, DMA stream)
    hit_bias: float = 0.0                  # slot-hit routing logit bias
    hit_margin: float = float("inf")       # only reroute if within margin of
                                           # the argmax logit

    @property
    def fill_seconds(self) -> float:
        return self.expert_bytes / self.fill_bandwidth


class ExpertSlotState(NamedTuple):
    """Block-granular exact LRU over expert ids.

    Rather than tracking slot indices, we track per-expert recency; the
    resident set is then "the S most recently used experts", which is
    exactly LRU and needs no slot permutation bookkeeping.
    """

    last_use: jnp.ndarray  # (E,) int32; 0 = never used
    resident: jnp.ndarray  # (E,) bool
    clock: jnp.ndarray     # () int32


def init_state(cfg: ExpertSlotConfig) -> ExpertSlotState:
    return ExpertSlotState(
        last_use=jnp.zeros((cfg.num_experts,), jnp.int32),
        resident=jnp.zeros((cfg.num_experts,), bool),
        clock=jnp.int32(0),
    )


class BlockStats(NamedTuple):
    accessed: jnp.ndarray       # () int32 — distinct experts touched
    misses: jnp.ndarray         # () int32 — slot fills triggered
    fill_seconds: jnp.ndarray   # () f32  — modelled reconfiguration time
    hit_rate: jnp.ndarray       # () f32


def access_block(state: ExpertSlotState, expert_ids: jnp.ndarray,
                 cfg: ExpertSlotConfig,
                 valid: jnp.ndarray | None = None
                 ) -> tuple[ExpertSlotState, BlockStats]:
    """Charge one token block's expert accesses against the slot pool.

    expert_ids: (T,) int32 routed ids (pad with any id + valid=False).
    """
    e = cfg.num_experts
    if valid is None:
        valid = jnp.ones(expert_ids.shape, bool)
    accessed = jnp.zeros((e,), bool).at[expert_ids].max(valid)

    misses = jnp.sum(accessed & ~state.resident).astype(jnp.int32)
    n_accessed = jnp.sum(accessed).astype(jnp.int32)

    clock = state.clock + 1
    last_use = jnp.where(accessed, clock, state.last_use)
    # resident set = S most-recently-used experts (exact block-LRU);
    # never-used experts (last_use == 0) are not resident.
    s = min(cfg.slots_per_device, e)
    thresh = jax.lax.top_k(last_use, s)[0][-1]
    resident = (last_use >= jnp.maximum(thresh, 1)) & (last_use > 0)
    # tie-break: cap residency at S by preferring lower ids among the
    # threshold cohort (deterministic, matches hardware priority encoders)
    over = jnp.cumsum((last_use == thresh) & resident) + \
        jnp.sum(resident & (last_use > thresh))
    resident = resident & jnp.where(last_use == thresh, over <= s, True)

    stats = BlockStats(
        accessed=n_accessed,
        misses=misses,
        fill_seconds=(misses * cfg.expert_bytes / cfg.fill_bandwidth
                      ).astype(jnp.float32),
        hit_rate=jnp.where(
            n_accessed > 0,
            1.0 - misses / jnp.maximum(n_accessed, 1), 1.0
        ).astype(jnp.float32),
    )
    return ExpertSlotState(last_use, resident, clock), stats


def slot_hit_routing(gate_logits: jnp.ndarray, state: ExpertSlotState,
                     cfg: ExpertSlotConfig, k: int = 1
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bias routing toward resident experts (beyond-paper optimisation).

    gate_logits: (T, E).  Returns (expert_ids (T,k), gates (T,k)).
    A resident expert's logit gets +hit_bias, but only experts whose
    *unbiased* logit is within `hit_margin` of the per-token max are
    eligible for the boost — bounding the routing-quality loss.
    """
    unbiased_max = jnp.max(gate_logits, axis=-1, keepdims=True)
    eligible = gate_logits >= (unbiased_max - cfg.hit_margin)
    boost = jnp.where(eligible & state.resident[None, :], cfg.hit_bias, 0.0)
    biased = gate_logits + boost
    gates, ids = jax.lax.top_k(biased, k)
    # gate values are re-normalised from the *unbiased* distribution so the
    # mixture weights stay faithful to the learned router
    orig = jnp.take_along_axis(gate_logits, ids, axis=-1)
    gates = jax.nn.softmax(orig, axis=-1)
    return ids, gates


def resident_expert_ids(state: ExpertSlotState, slots: int) -> jnp.ndarray:
    """(S,) ids of resident experts (padded with -1), for fill scheduling."""
    score = jnp.where(state.resident, state.last_use, -1)
    top, ids = jax.lax.top_k(score, slots)
    return jnp.where(top >= 0, ids, -1)
