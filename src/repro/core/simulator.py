"""Cycle-approximate simulator of the FPGA-extended reconfigurable core.

Mirrors the paper's methodology (§V): the softcore supports all RV32IMF
instructions; the instruction disambiguator acts as an L0 cache over
reconfigurable slots and *adds latency* on slot misses, abstracting the
reconfiguration technology behind a configurable miss-latency constant
(10 / 50 / 250 cycles studied).  Two execution modes:

  * fixed-ISA machines (RV32I/IM/IF/IMF baselines of Fig. 4) — analytic:
    absent extensions expand to ABI soft routines; no slots, no misses;
  * the reconfigurable core (Fig. 6/7) — `lax.scan` over a synthesised
    instruction trace with exact-LRU disambiguator + bitstream-cache state.

Multi-programming (Fig. 7) adds a FreeRTOS-style round-robin scheduler with
a cycle quantum and a context-switch handler cost; slot state deliberately
persists across switches (the architecture's whole point — shared extensions
stay resident, §IV).  The scheduler runs over arbitrary fleets of P programs
(`simulate_many`), each with its own slot taxonomy (per-program tag tables),
and `sweep_fleet` crosses {fleets x slot counts x miss latencies} in one
jitted vmap^3 — slot counts sweep dynamically by masking a max-size
disambiguator.  The paper's pair experiments are the P=2 special case.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa, slots
from repro.core.traces import Mix, analytic_cpi  # re-export for callers

__all__ = [
    "ReconfigConfig", "SchedulerConfig", "SimResult", "PairResult",
    "FleetResult", "fleet_tag_table",
    "simulate_single", "simulate_single_batch",
    "simulate_many", "sweep_fleet",
    "simulate_pair", "simulate_pair_batch",
    "analytic_cpi", "fixed_pair_cpi", "fixed_fleet_cpi",
]


@dataclass(frozen=True)
class ReconfigConfig:
    """Reconfigurable-core parameters (paper §V-A, §V-D)."""

    num_slots: int
    miss_latency: int          # disambiguator-miss cycles (reconfig incl.)
    bs_cache_entries: int = 64  # bitstream-cache entries (>= tags: warm mode)
    bs_miss_extra: int = 100    # added cycles when the bitstream cache misses


# quantum no run can reach: larger than any reachable cycle count, yet far
# enough below int32 overflow that the q_cycles accumulator stays safe.
# Use it (via SchedulerConfig.no_preempt()) for solo/unpreempted runs.
NO_PREEMPT_QUANTUM = 1 << 30


@dataclass(frozen=True)
class SchedulerConfig:
    """Round-robin OS scheduler model (paper §V-B, §VI-C)."""

    quantum_cycles: int = 20_000
    handler_cycles: int = 150   # timer-interrupt + context-switch routine
                                # (incl. the 32 FP registers added in §V-B)

    @classmethod
    def no_preempt(cls, handler_cycles: int = 150) -> "SchedulerConfig":
        """A scheduler that never fires — for solo-program references."""
        return cls(quantum_cycles=NO_PREEMPT_QUANTUM,
                   handler_cycles=handler_cycles)


class SimResult(NamedTuple):
    cycles: jnp.ndarray
    instructions: jnp.ndarray
    slot_misses: jnp.ndarray
    bs_misses: jnp.ndarray

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


class PairResult(NamedTuple):
    cycles: jnp.ndarray        # (P,) attributed cycles (incl. handler)
    instructions: jnp.ndarray  # (P,)
    slot_misses: jnp.ndarray   # (P,)
    switches: jnp.ndarray      # () context switches

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


# ---------------------------------------------------------------------------
# Single-program reconfigurable core
# ---------------------------------------------------------------------------


def _simulate_single(trace, instr_tag, miss_latency, num_slots: int,
                     bs_entries: int, bs_miss_extra):
    """P=1 special case of the fleet scan: one program, never preempted.

    One cost model lives in `_fleet_step_fn`; the single-program path is a
    wrapper so disambiguator/bitstream accounting cannot drift between the
    Fig. 6 (single) and Fig. 7 (multi-program) experiments.
    """
    r = _simulate_fleet_impl(
        trace[None, :], instr_tag[None, :], miss_latency,
        jnp.int32(num_slots), jnp.int32(NO_PREEMPT_QUANTUM), jnp.int32(0),
        num_slots, bs_entries, bs_miss_extra, trace.shape[0])
    return SimResult(r.cycles[0], r.instructions[0], r.slot_misses[0],
                     r.bs_misses[0])


_simulate_single_jit = functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries"))(_simulate_single)


def simulate_single(trace: np.ndarray, cfg: ReconfigConfig,
                    scenario: isa.SlotScenario) -> SimResult:
    return _simulate_single_jit(
        jnp.asarray(trace, jnp.int32),
        jnp.asarray(scenario.instr_tag, jnp.int32),
        jnp.int32(cfg.miss_latency), num_slots=cfg.num_slots,
        bs_entries=cfg.bs_cache_entries,
        bs_miss_extra=jnp.int32(cfg.bs_miss_extra))


def simulate_single_batch(traces: np.ndarray, miss_latencies: np.ndarray,
                          cfg: ReconfigConfig,
                          scenario: isa.SlotScenario) -> SimResult:
    """vmap over (trace, miss latency) lanes with a shared scenario."""
    tag = jnp.asarray(scenario.instr_tag, jnp.int32)
    fn = jax.vmap(
        lambda t, L: _simulate_single_jit(
            t, tag, L, num_slots=cfg.num_slots,
            bs_entries=cfg.bs_cache_entries,
            bs_miss_extra=jnp.int32(cfg.bs_miss_extra)))
    return fn(jnp.asarray(traces, jnp.int32),
              jnp.asarray(miss_latencies, jnp.int32))


# ---------------------------------------------------------------------------
# Multi-program (round-robin scheduler): the N-program fleet simulator
# ---------------------------------------------------------------------------


class FleetResult(NamedTuple):
    """Per-program counters of an N-program fleet run.

    Leading axes are whatever grid the caller swept (fleets / slot counts /
    miss latencies); the trailing axis is the program index within a fleet.
    """

    cycles: jnp.ndarray        # (..., P) attributed cycles (incl. handler)
    instructions: jnp.ndarray  # (..., P)
    slot_misses: jnp.ndarray   # (..., P)
    bs_misses: jnp.ndarray     # (..., P)
    switches: jnp.ndarray      # (...)  context switches

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


def fleet_tag_table(scenarios, num_programs: int) -> np.ndarray:
    """(P, NUM_INSTRUCTIONS) per-program disambiguator-tag table.

    `scenarios` is either one `SlotScenario` shared by every program or a
    sequence of `num_programs` of them — per-program tables let an FM-class
    and an M-class program disagree about which opcodes are slotted (their
    binaries were compiled against different extension sets, paper §IV).
    """
    if isinstance(scenarios, isa.SlotScenario):
        return np.stack([scenarios.instr_tag] * num_programs)
    scenarios = list(scenarios)
    if len(scenarios) != num_programs:
        raise ValueError(
            f"{len(scenarios)} scenarios for {num_programs} programs")
    return np.stack([s.instr_tag for s in scenarios])


def _fleet_step_fn(traces, tags, hw, miss_latency, active_slots, quantum,
                   handler, bs_miss_extra):
    """Round-robin step over a (P, N) trace tensor with per-program tags."""
    num_progs, trace_len = traces.shape

    def step(c, _):
        p = c["active"]
        ins = traces[p, jnp.remainder(c["cursors"][p], trace_len)]
        tag = tags[p, ins]
        res = slots.lookup(c["slot_st"], tag, active_slots)
        # on a disambiguator miss the bitstream is fetched through the
        # bitstream cache; a miss there goes to the unified L2 (extra cost)
        bs_res = slots.lookup(
            c["bs_st"], jnp.where(res.hit, jnp.int32(-1), tag))
        cost = hw[ins]
        cost = cost + jnp.where(res.hit, 0, miss_latency).astype(jnp.int32)
        cost = cost + jnp.where(res.hit | bs_res.hit, 0,
                                bs_miss_extra).astype(jnp.int32)

        q = c["q_cycles"] + cost
        do_switch = q >= quantum
        # the outgoing program pays the interrupt-handler cycles, mirroring
        # the paper's observation that short quanta inflate all runtimes
        cost_p = cost + jnp.where(do_switch, handler, 0).astype(jnp.int32)

        # slot/bitstream state deliberately persists across the switch —
        # shared extensions stay resident (the architecture's point, §IV)
        return {
            "slot_st": res.state,
            "bs_st": bs_res.state,
            "cursors": c["cursors"].at[p].add(1),
            "active": jnp.where(do_switch, (p + 1) % num_progs, p),
            "q_cycles": jnp.where(do_switch, 0, q),
            "cycles": c["cycles"].at[p].add(cost_p),
            "instrs": c["instrs"].at[p].add(1),
            "misses": c["misses"].at[p].add((~res.hit).astype(jnp.int32)),
            "bs_misses": c["bs_misses"].at[p].add(
                (~(res.hit | bs_res.hit)).astype(jnp.int32)),
            "switches": c["switches"] + do_switch.astype(jnp.int32),
        }, None

    return step


def _simulate_fleet_impl(traces, tag_table, miss_latency, active_slots,
                         quantum, handler, num_slots: int, bs_entries: int,
                         bs_miss_extra, total_steps: int) -> FleetResult:
    """(P, N) traces + (P, num_opcodes) tags -> per-program FleetResult.

    `num_slots` is the *allocated* (static) disambiguator size;
    `active_slots` (traced) masks it down so slot count is a sweep axis.
    """
    hw = jnp.asarray(isa.INSTR_HW_CYCLES, jnp.int32)
    tags = jnp.asarray(tag_table, jnp.int32)
    num_progs = traces.shape[0]

    init = {
        "slot_st": slots.init(num_slots),
        "bs_st": slots.init(bs_entries),
        "cursors": jnp.zeros((num_progs,), jnp.int32),
        "active": jnp.int32(0),
        "q_cycles": jnp.int32(0),
        "cycles": jnp.zeros((num_progs,), jnp.int32),
        "instrs": jnp.zeros((num_progs,), jnp.int32),
        "misses": jnp.zeros((num_progs,), jnp.int32),
        "bs_misses": jnp.zeros((num_progs,), jnp.int32),
        "switches": jnp.int32(0),
    }
    step = _fleet_step_fn(traces, tags, hw, miss_latency, active_slots,
                          quantum, handler, bs_miss_extra)
    final, _ = jax.lax.scan(step, init, None, length=total_steps)
    return FleetResult(final["cycles"], final["instrs"], final["misses"],
                       final["bs_misses"], final["switches"])


_simulate_fleet = functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps"))(
        _simulate_fleet_impl)


def simulate_many(traces: np.ndarray, cfg: ReconfigConfig,
                  scenarios, sched: SchedulerConfig,
                  total_steps: int = 400_000) -> FleetResult:
    """Round-robin fleet of P programs sharing one reconfigurable core.

    traces: (P, N) int32 instruction ids; `scenarios` is one shared
    `SlotScenario` or a length-P sequence (per-program slot taxonomies).
    """
    traces = jnp.asarray(traces, jnp.int32)
    table = fleet_tag_table(scenarios, traces.shape[0])
    return _simulate_fleet(
        traces, table, jnp.int32(cfg.miss_latency),
        jnp.int32(cfg.num_slots), jnp.int32(sched.quantum_cycles),
        jnp.int32(sched.handler_cycles), cfg.num_slots,
        cfg.bs_cache_entries, jnp.int32(cfg.bs_miss_extra), total_steps)


@functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps"))
def _sweep_fleet(fleets, tag_table, miss_latencies, slot_counts, quantum,
                 handler, num_slots: int, bs_entries: int, bs_miss_extra,
                 total_steps: int) -> FleetResult:
    def one(t, s, lat):
        return _simulate_fleet_impl(
            t, tag_table, lat, s, quantum, handler, num_slots, bs_entries,
            bs_miss_extra, total_steps)

    f = jax.vmap(one, in_axes=(None, None, 0))   # miss-latency axis
    f = jax.vmap(f, in_axes=(None, 0, None))     # slot-count axis
    f = jax.vmap(f, in_axes=(0, None, None))     # fleet axis
    return f(fleets, slot_counts, miss_latencies)


def sweep_fleet(fleets: np.ndarray, miss_latencies, scenarios,
                sched: SchedulerConfig, *, slot_counts,
                bs_cache_entries: int = 64, bs_miss_extra: int = 100,
                total_steps: int = 400_000) -> FleetResult:
    """One jitted call over the {fleets x slot counts x miss latencies} grid.

    fleets: (B, P, N) int32 traces.  Slot counts are swept by masking one
    max-size disambiguator (`slots.lookup`'s `num_active`), so the whole
    grid — including the slot-count axis, normally a static shape — runs as
    a single compiled `vmap^3`.  Result axes: (B, K_slots, L_lat, P).
    """
    fleets = jnp.asarray(fleets, jnp.int32)
    table = fleet_tag_table(scenarios, fleets.shape[1])
    counts = jnp.asarray(slot_counts, jnp.int32).reshape(-1)
    lats = jnp.asarray(miss_latencies, jnp.int32).reshape(-1)
    s_max = int(np.max(np.asarray(slot_counts)))
    return _sweep_fleet(
        fleets, table, lats, counts, jnp.int32(sched.quantum_cycles),
        jnp.int32(sched.handler_cycles), s_max, bs_cache_entries,
        jnp.int32(bs_miss_extra), total_steps)


# --- pair path: the P=2 special case, kept as thin wrappers so the Fig. 7
# --- numbers stay reproducible bit-for-bit through the fleet machinery


def simulate_pair(traces: np.ndarray, cfg: ReconfigConfig,
                  scenario: isa.SlotScenario, sched: SchedulerConfig,
                  total_steps: int = 400_000) -> PairResult:
    r = simulate_many(traces, cfg, scenario, sched, total_steps)
    return PairResult(r.cycles, r.instructions, r.slot_misses, r.switches)


def simulate_pair_batch(traces: np.ndarray, cfg: ReconfigConfig,
                        scenario: isa.SlotScenario, sched: SchedulerConfig,
                        total_steps: int = 400_000) -> PairResult:
    """traces: (B, P, N) — one-cell sweep over the pair lanes."""
    r = sweep_fleet(
        jnp.asarray(traces, jnp.int32), [cfg.miss_latency], scenario, sched,
        slot_counts=[cfg.num_slots], bs_cache_entries=cfg.bs_cache_entries,
        bs_miss_extra=cfg.bs_miss_extra, total_steps=total_steps)
    # squeeze the singleton slot-count / latency axes -> (B, P) like before
    return PairResult(r.cycles[:, 0, 0], r.instructions[:, 0, 0],
                      r.slot_misses[:, 0, 0], r.switches[:, 0, 0])


# ---------------------------------------------------------------------------
# Fixed-ISA analytic helpers (Fig. 4 baselines; pair variant for Fig. 7)
# ---------------------------------------------------------------------------


def fixed_fleet_cpi(mix: Mix, spec: isa.Spec, sched: SchedulerConfig) -> float:
    """CPI of a fixed-ISA machine inside a round-robin fleet (any P).

    The handler executes `handler_cycles` of base instructions once per
    quantum; amortised per original instruction that is
    handler * CPI / quantum — independent of how many programs share the
    core, since every program pays it once per own quantum.
    """
    cpi = analytic_cpi(mix, spec)
    return cpi * (1.0 + sched.handler_cycles / sched.quantum_cycles)


# historical name from the pair-only simulator; the formula never depended
# on the fleet size, so the P=2 name is just an alias now
fixed_pair_cpi = fixed_fleet_cpi
