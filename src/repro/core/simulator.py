"""Cycle-approximate simulator of the FPGA-extended reconfigurable core.

Mirrors the paper's methodology (§V): the softcore supports all RV32IMF
instructions; the instruction disambiguator acts as an L0 cache over
reconfigurable slots and *adds latency* on slot misses, abstracting the
reconfiguration technology behind a configurable miss-latency constant
(10 / 50 / 250 cycles studied).  Two execution modes:

  * fixed-ISA machines (RV32I/IM/IF/IMF baselines of Fig. 4) — analytic:
    absent extensions expand to ABI soft routines; no slots, no misses;
  * the reconfigurable core (Fig. 6/7) — `lax.scan` over a synthesised
    instruction trace with exact-LRU disambiguator + bitstream-cache state.

Multi-programming (Fig. 7) adds a FreeRTOS-style round-robin scheduler with
a cycle quantum and a context-switch handler cost; slot state deliberately
persists across switches (the architecture's whole point — shared extensions
stay resident, §IV).  The scheduler runs over arbitrary fleets of P programs
(`simulate_many`), each with its own slot taxonomy (per-program tag tables),
heterogeneous per-program quanta, and integer priority weights (weighted
round-robin — see `SchedulerConfig`; the uniform unit-priority case is the
paper's scheduler, bit-for-bit).  `sweep_fleet` crosses {quanta x fleets x
slot counts x miss latencies} in one jitted vmap^4 — slot counts sweep
dynamically by masking a max-size disambiguator, quanta by vmapping the
per-program quantum vector.  The paper's pair experiments are the P=2
special case; the scheduling-policy axes feed `repro.sched`'s
contention-aware placement and admission control.

Four execution paths serve the sweep entry points (`sweep_fleet`,
`simulate_many`, `simulate_single`, `simulate_single_batch`); a dispatcher
picks per call:

  * **stack-distance fast path** (`repro.core.stackdist`): one Mattson pass
    per trace yields exact miss counts for every slot count at once, and
    cycles reconstruct affinely per miss latency — the {slot count x
    latency} grid collapses into post-processing.  Exact (bit-for-bit equal
    to the scan) iff the run is *unpreempted* (the quantum exceeds any
    reachable cycle count, so only program 0 runs and trace order is
    latency-independent) and the bitstream cache is *warm* (entries >=
    distinct tags, so it never evicts).  `stackdist_eligible` encodes both
    rules plus the no-overflow guard.
  * **interleaved fast path** (`repro.core.stackdist_interleaved`): the
    preempted generalisation.  Switch points depend on per-access costs
    (the quantum is counted in cycles), so the merged access order differs
    per {slot count x latency x quantum} cell and the grid cannot collapse;
    instead each cell replays its interleaving at *scheduler-window*
    granularity — one vectorized Mattson cummax pass per window, a
    `lax.while_loop` whose trip count is ~steps/window + one per context
    switch instead of one per step.  Exact (bit-for-bit) iff the bitstream
    cache is warm over the FLEET's merged tag set and no int32 accumulator
    can overflow (`interleaved_eligible`); ~15x over the optimized scan on
    preempted fig6-style grids (BENCH_sweep.json).  The engine is also
    *resumable*: a scan-shaped `FleetState` seeds it (cache contents map
    to virtual merged-stream positions, the open quantum / scheduler
    cursor / counters seed the loop carry) and a `FleetState`
    materialises back out, bit-for-bit equal to the scan's.
  * **stacked cold-bitstream path** (`repro.core.stackdist_cold`): for
    *unpreempted* runs whose bitstream cache is undersized, the
    disambiguator's miss subsequence is itself an LRU reference stream, so
    a second per-slot-count Mattson pass over it yields exact bitstream
    hit/miss counts for every `bs_cache_entries` at once —
    `stackdist_cold_eligible` drops the warmth condition entirely
    (`sweep_bitstream` exposes the full capacity x penalty grid in one
    call).
  * **`lax.scan` path**: the general cycle-by-cycle round-robin machine —
    the reference semantics, and the fallback for the one remaining
    stronghold: preempted runs with a cold bitstream cache (plus
    hand-crafted `FleetState`s no engine can seed from).  Its hot loop
    pre-gathers the per-program (tag, hw-cost) streams once per call
    (instead of a dependent double gather per step), fuses the
    disambiguator + bitstream lookups into one state update
    (`slots.lookup_fused`), and unrolls the scan body (`scan_unroll`).

Callers can force a path with
`path="scan"|"stackdist"|"stackdist_cold"|"interleaved"` (parity tests
do); the default `"auto"` routes unpreempted eligible sweeps through
stack distance (warm) or the stacked cold pass, and preempted eligible
sweeps — one-shot or resumed — through the interleaved engine.

The scan's carry is an explicit, resumable value (`FleetState`):
`simulate_many(..., state=S, return_state=True)` runs N steps from S and
returns (results, S'), with the one-shot run being the
`S = init_fleet_state(...)` special case — split-at-any-step resume is
bit-for-bit equal to the unsplit run.  This is what lets the online
serving layer (`repro.sched.online`) carry warm slot/bitstream caches
across epochs and price tenant migration by resuming a tenant on a cold
core.  Resumed segments ride the interleaved engine whenever it is
exact for them (`interleaved_eligible` + a seedable state); every
returned `FleetState` is in *canonical* form — residents sorted by LRU
clock into a prefix — so states are comparable across engines (canonical
form is behaviour-preserving: exact-LRU eviction depends only on the
resident (tag, last_use) set, never on physical slot order).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import (isa, slots, stackdist, stackdist_cold,
                        stackdist_interleaved)
from repro.core.traces import Mix, analytic_cpi  # re-export for callers

__all__ = [
    "ReconfigConfig", "SchedulerConfig", "SimResult", "PairResult",
    "FleetResult", "FleetState", "init_fleet_state",
    "fleet_tag_table", "stackdist_eligible", "stackdist_cold_eligible",
    "interleaved_eligible",
    "quanta_vector", "priority_schedule",
    "simulate_single", "simulate_single_batch",
    "simulate_many", "sweep_fleet", "sweep_bitstream",
    "simulate_pair", "simulate_pair_batch",
    "analytic_cpi", "fixed_pair_cpi", "fixed_fleet_cpi",
]

# default lax.scan unroll for the cycle-by-cycle path — exposed so callers
# (and benchmarks/perf_sweep.py, which sweeps it) can tune per backend
# without changing results (integer state updates are exact).  Tuned on CPU:
# un-vmapped scans gain ~10% at unroll=4, but the vmap^3 sweep loses badly
# to the duplicated loop body, so the shared default stays 1; accelerators
# with per-step dispatch overhead are where larger unrolls pay off.
SCAN_UNROLL = 1

# default scheduler-window size of the interleaved fast path — a pure
# performance knob (a quantum larger than the window spans several
# iterations via the carried quantum-cycle counter; results are identical
# for any window >= 1).  Backend-aware: the recorded window sweep
# (BENCH_sweep.json, preempted_grid.*.window_sweep_s) shows 256 beating
# 512 on every CPU preempted grid (P=2..4), so CPU defaults to 256;
# accelerators keep 512 — wider windows amortise kernel dispatch and the
# per-iteration gather there, and no recorded sweep argues for less.
_INTERLEAVE_WINDOW_BY_BACKEND = {"cpu": 256}


def _default_interleave_window() -> int:
    return _INTERLEAVE_WINDOW_BY_BACKEND.get(jax.default_backend(), 512)


INTERLEAVE_WINDOW = _default_interleave_window()


@dataclass(frozen=True)
class ReconfigConfig:
    """Reconfigurable-core parameters (paper §V-A, §V-D)."""

    num_slots: int
    miss_latency: int          # disambiguator-miss cycles (reconfig incl.)
    bs_cache_entries: int = 64  # bitstream-cache entries (>= tags: warm mode)
    bs_miss_extra: int = 100    # added cycles when the bitstream cache misses


# quantum no run can reach: larger than any reachable cycle count, yet far
# enough below int32 overflow that the q_cycles accumulator stays safe.
# Use it (via SchedulerConfig.no_preempt()) for solo/unpreempted runs.
NO_PREEMPT_QUANTUM = 1 << 30


@dataclass(frozen=True)
class SchedulerConfig:
    """Round-robin OS scheduler model (paper §V-B, §VI-C).

    Beyond the paper's single uniform quantum, the scheduler supports

      * **heterogeneous quanta** — `quantum_cycles` may be a length-P tuple
        giving each program its own timer quantum, and
      * **priority weights** — `priorities` (length-P positive ints) turn
        the plain round-robin into a weighted one: program p takes
        `priorities[p]` consecutive quanta per rotation, so CPU share is
        proportional to the weight.  The timer interrupt (and its
        `handler_cycles`) still fires at every quantum expiry, including
        back-to-back quanta of the same program.

    A scalar `quantum_cycles` with `priorities=None` is exactly the paper's
    uniform round-robin and reproduces it bit-for-bit.
    """

    quantum_cycles: int | tuple[int, ...] = 20_000
    handler_cycles: int = 150   # timer-interrupt + context-switch routine
                                # (incl. the 32 FP registers added in §V-B)
    priorities: tuple[int, ...] | None = None

    @classmethod
    def no_preempt(cls, handler_cycles: int = 150) -> "SchedulerConfig":
        """A scheduler that never fires — for solo-program references."""
        return cls(quantum_cycles=NO_PREEMPT_QUANTUM,
                   handler_cycles=handler_cycles)

    def quanta(self, num_programs: int) -> np.ndarray:
        """(P,) int32 per-program quantum vector (scalars broadcast)."""
        return quanta_vector(self.quantum_cycles, num_programs)

    def schedule(self, num_programs: int) -> np.ndarray:
        """The weighted round-robin turn order (see `priority_schedule`)."""
        return priority_schedule(self.priorities, num_programs)


def quanta_vector(quantum_cycles, num_programs: int) -> np.ndarray:
    """Normalise a scalar-or-vector quantum spec to a (P,) int32 vector."""
    q = np.asarray(quantum_cycles, dtype=np.int64)
    if q.ndim == 0:
        q = np.full((num_programs,), int(q), np.int64)
    if q.shape != (num_programs,):
        raise ValueError(
            f"quantum_cycles vector has shape {q.shape}, expected "
            f"({num_programs},) for a fleet of P={num_programs} programs")
    if np.any(q <= 0):
        raise ValueError(f"quantum_cycles must be positive, got {q.tolist()}")
    return q.astype(np.int32)


def priority_schedule(priorities, num_programs: int) -> np.ndarray:
    """Weighted round-robin turn order as a flat program-index sequence.

    `priorities=None` (or all-ones) is the plain rotation `[0, 1, .., P-1]`;
    weights `(2, 1)` yield `[0, 0, 1]`: program 0 takes two consecutive
    quanta per rotation.  The scan holds a cursor into this (static-length)
    sequence, so the weighted policy costs one extra gather per step and the
    uniform case stays bit-for-bit identical to the historical rotation.
    """
    if priorities is None:
        return np.arange(num_programs, dtype=np.int32)
    pr = np.asarray(priorities, dtype=np.int64)
    if pr.shape != (num_programs,):
        raise ValueError(
            f"priorities vector has shape {pr.shape}, expected "
            f"({num_programs},) for a fleet of P={num_programs} programs")
    if np.any(pr <= 0):
        raise ValueError(f"priorities must be positive ints, got "
                         f"{pr.tolist()}")
    return np.repeat(np.arange(num_programs, dtype=np.int32),
                     pr).astype(np.int32)


class SimResult(NamedTuple):
    cycles: jnp.ndarray
    instructions: jnp.ndarray
    slot_misses: jnp.ndarray
    bs_misses: jnp.ndarray

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


class PairResult(NamedTuple):
    cycles: jnp.ndarray        # (P,) attributed cycles (incl. handler)
    instructions: jnp.ndarray  # (P,)
    slot_misses: jnp.ndarray   # (P,)
    switches: jnp.ndarray      # () context switches

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


# ---------------------------------------------------------------------------
# Single-program reconfigurable core
# ---------------------------------------------------------------------------


def stackdist_eligible(tag_row, *, quantum_cycles, bs_entries: int,
                       max_miss_latency: int, bs_miss_extra: int,
                       total_steps: int) -> bool:
    """True iff the *unpreempted* stack-distance fast path is exact.

    This predicate gates `repro.core.stackdist` — the engine that collapses
    the whole {slot count x latency} grid into one distance profile.  That
    collapse needs the merged access order to be grid-independent, which
    only holds when program 0 runs alone, so the quantum must be provably
    unreachable; preempted runs are NOT served by this engine, but they are
    no longer scan-only either — `interleaved_eligible` gates the
    interleave-aware engine (`repro.core.stackdist_interleaved`) that
    replays each grid cell's own switch points at window granularity.

    Three conditions (see module docstring and `repro.core.stackdist`):

    1. warm bitstream cache: `bs_entries` covers every distinct tag of the
       scheduled program (`tag_row` is program 0's instr->tag table), so the
       bitstream cache never evicts and each tag misses it exactly once;
    2. unpreempted: the quantum is the NO_PREEMPT sentinel or beyond, so
       trace order is latency-independent and no handler cycles accrue;
    3. no-overflow guard: even the worst-case per-step cost summed over
       `total_steps` stays below the quantum — the scan's q_cycles
       accumulator can provably never fire a switch (and int32 stays safe).

    `quantum_cycles` may be a scalar, a per-program vector, or a whole
    swept quantum grid: with heterogeneous quanta a run is unpreempted only
    when EVERY program's quantum is unreachable, so eligibility is judged
    on the minimum over all entries.
    """
    num_tags = int(np.max(tag_row)) + 1
    warm = bs_entries >= num_tags
    worst_step = (int(np.max(isa.INSTR_HW_CYCLES)) + int(max_miss_latency)
                  + int(bs_miss_extra))
    min_quantum = int(np.min(np.asarray(quantum_cycles)))
    unpreempted = (min_quantum >= NO_PREEMPT_QUANTUM
                   and total_steps * worst_step < min_quantum)
    return warm and unpreempted


def stackdist_cold_eligible(*, quantum_cycles, max_miss_latency: int,
                            bs_miss_extra: int, total_steps: int) -> bool:
    """True iff the stacked cold-bitstream pass is exact for this run.

    Gates `repro.core.stackdist_cold`: `stackdist_eligible`'s unpreempted
    + no-overflow conditions with the warm-bitstream-cache condition
    *dropped* — the second Mattson pass over the disambiguator's miss
    subsequence serves ANY bitstream capacity exactly, so an undersized
    (cold) bitstream cache no longer forces the scan as long as the run
    is unpreempted (preempted + cold remains the scan's last stronghold:
    there the miss subsequence itself is switch-point-dependent per grid
    cell AND the bitstream axis feeds back into the switch points).
    """
    worst_step = (int(np.max(isa.INSTR_HW_CYCLES)) + int(max_miss_latency)
                  + int(bs_miss_extra))
    min_quantum = int(np.min(np.asarray(quantum_cycles)))
    return (min_quantum >= NO_PREEMPT_QUANTUM
            and total_steps * worst_step < min_quantum)


def interleaved_eligible(tag_table, *, bs_entries: int, miss_latencies,
                         bs_miss_extra: int, handler_cycles: int,
                         total_steps: int) -> bool:
    """True iff the interleave-aware fast path is *exact* for this run.

    Gates `repro.core.stackdist_interleaved`, which serves preempted (and
    mixed preempted/unpreempted) one-shot runs.  Unlike
    `stackdist_eligible` there is no quantum condition at all: every grid
    cell replays its own switch points, so any quantum — uniform,
    per-program, swept, even unreachable — is exact.  What remains:

    1. warm bitstream cache over the *fleet*: `bs_entries` covers the
       merged tag alphabet (`tag_table` is the (P, num_opcodes) per-program
       table; the caches are shared, so the union matters — a fleet whose
       second program slots more opcodes than its first can be cold even
       when program 0 alone would be warm).  Warm means a bitstream miss
       happens exactly on each tag's first touch in the merged stream,
       decoupling the bitstream axis from the slot-count axis;
    2. non-negative costs: latencies / bitstream penalty / handler >= 0,
       so the in-window cycle accumulation is monotone;
    3. no-overflow guard: worst-case per-access cost plus a handler every
       access, summed over `total_steps`, stays inside int32 — the same
       accumulators the scan uses.

    Resumed (`state=`) runs are eligible too: the engine seeds from a
    `FleetState` (see `repro.core.stackdist_interleaved.resume_preempted`)
    provided the state is scan-shaped (`_seedable_fleet_state`: prefix
    packing, distinct LRU clocks, slot residents covered by the bitstream
    cache) and the seed's counters leave int32 headroom for the segment —
    `simulate_many` checks both on top of this predicate and falls back
    to the scan for hand-crafted states that fail them.
    """
    num_tags = int(np.max(tag_table)) + 1
    warm = bs_entries >= num_tags
    lats = np.asarray(miss_latencies)
    nonneg = (int(np.min(lats)) >= 0 and int(bs_miss_extra) >= 0
              and int(handler_cycles) >= 0)
    worst_step = (int(np.max(isa.INSTR_HW_CYCLES)) + int(np.max(lats))
                  + int(bs_miss_extra) + int(handler_cycles))
    no_overflow = total_steps * worst_step < np.iinfo(np.int32).max
    return warm and nonneg and no_overflow


# auto-dispatch heuristics for the interleaved engine (forcing
# path="interleaved" only requires exactness, i.e. `interleaved_eligible`):
# below this minimum quantum a cell switches every handful of accesses and
# the window engine degenerates toward one iteration per scheduler run,
# losing its sequential-depth advantage over the scan
_INTERLEAVED_AUTO_MIN_QUANTUM = 256
# per-iteration transient footprint bound: window x num_tags x grid cells
# per fleet (the fleet axis is chunked separately, see
# _sweep_fleet_interleaved)
_INTERLEAVED_CHUNK_ELEMS = 16_000_000
# fleet batches are padded up to a multiple of this before hitting the
# interleaved sweep, so batch-size churn (contention-model pricing calls
# with B = 1..8) reuses one compiled shape; padded rows are replays of
# fleet 0 and are sliced off the result
_INTERLEAVED_BATCH_BUCKET = 4


def _interleaved_window(quanta_grid, total_steps: int,
                        window: int | None) -> int:
    """Static window size: the tuned default, shrunk to the next power of
    two covering the largest quantum (tiny quanta expire within tiny
    windows) and never beyond the run length."""
    if window is None:
        q = int(np.max(np.asarray(quanta_grid)))
        window = min(INTERLEAVE_WINDOW, 1 << max(0, (q - 1)).bit_length())
    return max(1, min(int(window), total_steps))


def _interleaved_auto_ok(quanta_grid, grid_cells: int, num_tags: int,
                         total_steps: int, window: int | None) -> bool:
    w = _interleaved_window(quanta_grid, total_steps, window)
    return (int(np.min(np.asarray(quanta_grid)))
            >= _INTERLEAVED_AUTO_MIN_QUANTUM
            and w * max(num_tags, 1) * grid_cells
            <= _INTERLEAVED_CHUNK_ELEMS)


def _check_single_path(path: str, eligible: bool,
                       cold_ok: bool = False) -> str:
    """Path validation for the single-program entry points, which dispatch
    between the unpreempted stack-distance engines (warm / stacked-cold)
    and the scan."""
    if path == "interleaved":
        raise ValueError(
            "interleaved path is not served by the single-program entry "
            "points (a solo run is never preempted; the unpreempted "
            "stack-distance engine already collapses its grid) — use "
            "simulate_many or sweep_fleet to force it")
    return _check_path(path, eligible, cold_ok=cold_ok)


def _check_path(path: str, stackdist_ok: bool, interleaved_ok: bool = False,
                interleaved_auto: bool = False,
                cold_ok: bool = False) -> str:
    if path not in ("auto", "stackdist", "stackdist_cold", "interleaved",
                    "scan"):
        raise ValueError(f"unknown path {path!r}")
    if path == "stackdist" and not stackdist_ok:
        raise ValueError(
            "stack-distance path requires an unpreempted run with a warm "
            "bitstream cache (see simulator.stackdist_eligible)")
    if path == "stackdist_cold" and not cold_ok:
        raise ValueError(
            "stacked cold-bitstream path requires an unpreempted run with "
            "int32-safe costs (see simulator.stackdist_cold_eligible)")
    if path == "interleaved" and not interleaved_ok:
        raise ValueError(
            "interleaved path requires a one-shot run with a warm "
            "bitstream cache over the fleet's merged tag set and "
            "non-negative int32-safe costs (see "
            "simulator.interleaved_eligible)")
    if path == "auto":
        path = ("stackdist" if stackdist_ok
                else "stackdist_cold" if cold_ok
                else "interleaved" if interleaved_ok and interleaved_auto
                else "scan")
    return path


def _simulate_single(trace, instr_tag, miss_latency, num_slots: int,
                     bs_entries: int, bs_miss_extra):
    """P=1 special case of the fleet scan: one program, never preempted.

    One cost model lives in `_fleet_step_fn`; the single-program path is a
    wrapper so disambiguator/bitstream accounting cannot drift between the
    Fig. 6 (single) and Fig. 7 (multi-program) experiments.
    """
    r, _ = _simulate_fleet_impl(
        trace[None, :], instr_tag[None, :], miss_latency,
        jnp.int32(num_slots),
        jnp.full((1,), NO_PREEMPT_QUANTUM, jnp.int32),
        jnp.zeros((1,), jnp.int32), jnp.int32(0),
        num_slots, bs_entries, bs_miss_extra, trace.shape[0])
    return SimResult(r.cycles[0], r.instructions[0], r.slot_misses[0],
                     r.bs_misses[0])


_simulate_single_jit = functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries"))(_simulate_single)


def _single_eligible(cfg: ReconfigConfig, scenario: isa.SlotScenario,
                     max_miss_latency: int, total_steps: int) -> bool:
    return stackdist_eligible(
        scenario.instr_tag, quantum_cycles=NO_PREEMPT_QUANTUM,
        bs_entries=cfg.bs_cache_entries, max_miss_latency=max_miss_latency,
        bs_miss_extra=cfg.bs_miss_extra, total_steps=total_steps)


def _single_cold_eligible(cfg: ReconfigConfig, max_miss_latency: int,
                          total_steps: int) -> bool:
    return stackdist_cold_eligible(
        quantum_cycles=NO_PREEMPT_QUANTUM, max_miss_latency=max_miss_latency,
        bs_miss_extra=cfg.bs_miss_extra, total_steps=total_steps)


def simulate_single(trace: np.ndarray, cfg: ReconfigConfig,
                    scenario: isa.SlotScenario,
                    path: str = "auto") -> SimResult:
    trace = jnp.asarray(trace, jnp.int32)
    eligible = _single_eligible(cfg, scenario, cfg.miss_latency,
                                trace.shape[0])
    cold_ok = _single_cold_eligible(cfg, cfg.miss_latency, trace.shape[0])
    chosen = _check_single_path(path, eligible, cold_ok)
    if chosen == "stackdist":
        cycles, misses, bs = stackdist.lanes_unpreempted(
            trace[None, :], scenario.instr_tag, isa.INSTR_HW_CYCLES,
            jnp.int32(cfg.num_slots), jnp.asarray([cfg.miss_latency]),
            jnp.int32(cfg.bs_miss_extra),
            num_tags=max(scenario.num_tags, 1), total_steps=trace.shape[0])
        return SimResult(cycles[0], jnp.int32(trace.shape[0]), misses[0],
                         bs[0])
    if chosen == "stackdist_cold":
        cycles, misses, bs = stackdist_cold.lanes_cold(
            trace[None, :], scenario.instr_tag, isa.INSTR_HW_CYCLES,
            jnp.int32(cfg.num_slots), jnp.asarray([cfg.miss_latency]),
            jnp.int32(cfg.bs_cache_entries), jnp.int32(cfg.bs_miss_extra),
            num_tags=max(scenario.num_tags, 1), total_steps=trace.shape[0])
        return SimResult(cycles[0], jnp.int32(trace.shape[0]), misses[0],
                         bs[0])
    return _simulate_single_jit(
        trace,
        jnp.asarray(scenario.instr_tag, jnp.int32),
        jnp.int32(cfg.miss_latency), num_slots=cfg.num_slots,
        bs_entries=cfg.bs_cache_entries,
        bs_miss_extra=jnp.int32(cfg.bs_miss_extra))


def simulate_single_batch(traces: np.ndarray, miss_latencies: np.ndarray,
                          cfg: ReconfigConfig,
                          scenario: isa.SlotScenario,
                          path: str = "auto") -> SimResult:
    """vmap over (trace, miss latency) lanes with a shared scenario.

    Eligible lanes (a single program is never preempted) route through one
    stack-distance profile per lane — warm bitstream caches take the plain
    pass, cold ones the stacked pass — instead of one `lax.scan` per
    lane."""
    traces = jnp.asarray(traces, jnp.int32)
    lats = jnp.asarray(miss_latencies, jnp.int32)
    max_lat = int(np.max(np.asarray(miss_latencies)))
    eligible = _single_eligible(cfg, scenario, max_lat, traces.shape[-1])
    cold_ok = _single_cold_eligible(cfg, max_lat, traces.shape[-1])
    chosen = _check_single_path(path, eligible, cold_ok)
    if chosen in ("stackdist", "stackdist_cold"):
        chunk = _stackdist_chunk(traces.shape[-1],
                                 max(scenario.num_tags, 1))
        if chosen == "stackdist":
            def lanes(tr, la):
                return stackdist.lanes_unpreempted(
                    tr, scenario.instr_tag, isa.INSTR_HW_CYCLES,
                    jnp.int32(cfg.num_slots), la,
                    jnp.int32(cfg.bs_miss_extra),
                    num_tags=max(scenario.num_tags, 1),
                    total_steps=traces.shape[-1])
        else:
            def lanes(tr, la):
                return stackdist_cold.lanes_cold(
                    tr, scenario.instr_tag, isa.INSTR_HW_CYCLES,
                    jnp.int32(cfg.num_slots), la,
                    jnp.int32(cfg.bs_cache_entries),
                    jnp.int32(cfg.bs_miss_extra),
                    num_tags=max(scenario.num_tags, 1),
                    total_steps=traces.shape[-1])
        outs = [lanes(traces[i:i + chunk], lats[i:i + chunk])
                for i in range(0, traces.shape[0], chunk)]
        cycles, misses, bs = (jnp.concatenate(x) for x in zip(*outs))
        instrs = jnp.full(cycles.shape, traces.shape[-1], jnp.int32)
        return SimResult(cycles, instrs, misses, bs)
    tag = jnp.asarray(scenario.instr_tag, jnp.int32)
    fn = jax.vmap(
        lambda t, L: _simulate_single_jit(
            t, tag, L, num_slots=cfg.num_slots,
            bs_entries=cfg.bs_cache_entries,
            bs_miss_extra=jnp.int32(cfg.bs_miss_extra)))
    return fn(traces, lats)


# ---------------------------------------------------------------------------
# Multi-program (round-robin scheduler): the N-program fleet simulator
# ---------------------------------------------------------------------------


class FleetResult(NamedTuple):
    """Per-program counters of an N-program fleet run.

    Leading axes are whatever grid the caller swept (fleets / slot counts /
    miss latencies); the trailing axis is the program index within a fleet.
    """

    cycles: jnp.ndarray        # (..., P) attributed cycles (incl. handler)
    instructions: jnp.ndarray  # (..., P)
    slot_misses: jnp.ndarray   # (..., P)
    bs_misses: jnp.ndarray     # (..., P)
    switches: jnp.ndarray      # (...)  context switches

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


class FleetState(NamedTuple):
    """The fleet scan's carry as an explicit, resumable value.

    `simulate_many` is "run N steps from state S -> (results, S')": the
    one-shot run is the `S = init_fleet_state(...)` special case, and
    feeding S' back in continues the simulation bit-for-bit — a run split
    at any step boundary equals the unsplit run exactly (cache contents,
    LRU clocks, scheduler cursor and all counters are part of the state).

    Counters (`cycles` .. `switches`) are *cumulative since the state was
    initialised*, so a resumed segment's `FleetResult` reports run totals;
    zero them (`reset_counters`) to measure one segment in isolation.
    The slot/bitstream caches are the warm state the paper's architecture
    preserves across context switches (§IV) — `repro.sched.online` carries
    them across serving epochs and prices tenant migration by resuming a
    tenant's state on a cold core.
    """

    slot_st: slots.SlotState   # disambiguator (shared by the fleet)
    bs_st: slots.SlotState     # bitstream cache
    cursors: jnp.ndarray       # (P,) per-program trace cursor
    sched_idx: jnp.ndarray     # () cursor into the priority schedule
    q_cycles: jnp.ndarray      # () cycles burnt in the current quantum
    cycles: jnp.ndarray        # (P,) attributed cycles (incl. handler)
    instrs: jnp.ndarray        # (P,)
    misses: jnp.ndarray        # (P,) disambiguator misses
    bs_misses: jnp.ndarray     # (P,) bitstream-cache misses
    switches: jnp.ndarray      # () context switches

    @property
    def num_programs(self) -> int:
        return self.cursors.shape[0]

    def result(self) -> "FleetResult":
        """The cumulative counters viewed as a FleetResult."""
        return FleetResult(self.cycles, self.instrs, self.misses,
                           self.bs_misses, self.switches)

    def reset_counters(self) -> "FleetState":
        """Zero the counters, keeping caches/cursors — the next segment's
        FleetResult then reports that segment alone."""
        z = jnp.zeros_like
        return self._replace(cycles=z(self.cycles), instrs=z(self.instrs),
                             misses=z(self.misses),
                             bs_misses=z(self.bs_misses),
                             switches=z(self.switches))


def init_fleet_state(num_programs: int, num_slots: int,
                     bs_entries: int = 64) -> FleetState:
    """Cold-start state for a fleet of P programs (empty caches, step 0)."""
    if num_programs < 1:
        raise ValueError(f"num_programs must be >= 1, got {num_programs}")
    return FleetState(
        slot_st=slots.init(num_slots),
        bs_st=slots.init(bs_entries),
        cursors=jnp.zeros((num_programs,), jnp.int32),
        sched_idx=jnp.int32(0),
        q_cycles=jnp.int32(0),
        cycles=jnp.zeros((num_programs,), jnp.int32),
        instrs=jnp.zeros((num_programs,), jnp.int32),
        misses=jnp.zeros((num_programs,), jnp.int32),
        bs_misses=jnp.zeros((num_programs,), jnp.int32),
        switches=jnp.int32(0),
    )


def _check_fleet_state(state: FleetState, num_programs: int,
                       num_slots: int, bs_entries: int) -> None:
    if state.cursors.shape != (num_programs,):
        raise ValueError(
            f"FleetState carries {state.cursors.shape[0]} program cursors, "
            f"but the traces describe a fleet of P={num_programs} programs")
    if state.slot_st.tags.shape[0] != num_slots:
        raise ValueError(
            f"FleetState disambiguator has {state.slot_st.tags.shape[0]} "
            f"slots, but the config allocates num_slots={num_slots} — "
            f"resume must use the same slot geometry it was initialised "
            f"with")
    if state.bs_st.tags.shape[0] != bs_entries:
        raise ValueError(
            f"FleetState bitstream cache has {state.bs_st.tags.shape[0]} "
            f"entries, but the config allocates "
            f"bs_cache_entries={bs_entries}")


def fleet_tag_table(scenarios, num_programs: int) -> np.ndarray:
    """(P, NUM_INSTRUCTIONS) per-program disambiguator-tag table.

    `scenarios` is either one `SlotScenario` shared by every program or a
    sequence of `num_programs` of them — per-program tables let an FM-class
    and an M-class program disagree about which opcodes are slotted (their
    binaries were compiled against different extension sets, paper §IV).
    """
    if isinstance(scenarios, isa.SlotScenario):
        scenarios = [scenarios] * num_programs
    else:
        scenarios = list(scenarios)
    if len(scenarios) != num_programs:
        raise ValueError(
            f"got {len(scenarios)} slot scenarios for a fleet of "
            f"P={num_programs} programs — pass one SlotScenario to share, "
            f"or exactly one per program")
    for i, s in enumerate(scenarios):
        tag = np.asarray(s.instr_tag)
        if tag.shape != (isa.NUM_INSTRUCTIONS,):
            raise ValueError(
                f"scenario {i} ({getattr(s, 'name', s)!r}) has instr_tag "
                f"shape {tag.shape}, expected ({isa.NUM_INSTRUCTIONS},)")
    return np.stack([s.instr_tag for s in scenarios])


# ---------------------------------------------------------------------------
# FleetState <-> interleaved-engine translation (the resumable fast path)
# ---------------------------------------------------------------------------


def canonical_slot_state(st: slots.SlotState) -> slots.SlotState:
    """Behaviour-preserving canonical arrangement of one cache: residents
    sorted by LRU clock (`last_use`) ascending into a prefix, empty
    entries (tag -1, last_use 0) as the suffix, clock untouched.

    Exact-LRU behaviour depends only on the resident (tag, last_use) set —
    hits are membership tests, the victim is argmin(last_use) with empties
    preferred, fills take the first empty — never on physical entry order
    (`slots._access`).  Ties in `last_use` (impossible in real scan
    states, whose filled clocks are distinct) keep their original relative
    order (stable sort), preserving the scan's lowest-index-victim
    tiebreak.  Fault surgery (`seu_fleet_state`, `degrade_fleet_state`)
    re-canonicalises after punching holes so a mutated cache is
    prefix-packed again.
    """
    tags = np.asarray(st.tags)
    lu = np.asarray(st.last_use)
    filled = tags >= 0
    k = int(filled.sum())
    order = np.argsort(lu[filled], kind="stable")
    t = np.full(tags.shape, -1, np.int32)
    u = np.zeros(lu.shape, np.int32)
    t[:k] = tags[filled][order]
    u[:k] = lu[filled][order]
    return slots.SlotState(tags=jnp.asarray(t), last_use=jnp.asarray(u),
                           clock=st.clock)


def _canonical_state(state: FleetState) -> FleetState:
    """Behaviour-preserving canonical cache arrangement of a whole
    `FleetState` (see `canonical_slot_state`).  Canonicalising every
    returned `FleetState` makes states comparable across engines: the
    interleaved engine recovers the resident *sets* and clocks exactly
    but not the scan's incidental fill order, so both report this shared
    normal form.
    """
    return state._replace(slot_st=canonical_slot_state(state.slot_st),
                          bs_st=canonical_slot_state(state.bs_st))


# ---------------------------------------------------------------------------
# fault surgery: the state mutations a fleet's fault events inflict
# ---------------------------------------------------------------------------


def seu_fleet_state(state: FleetState, slot_indices) -> FleetState:
    """A single-event upset corrupts the disambiguator entries at
    `slot_indices`: their residents are invalidated (the configuration
    bits are garbage, so the implementation must be re-loaded on next
    use) and the cache is re-canonicalised so survivors pack a prefix.

    The result is usually NOT seedable by the interleaved resume — a
    partially-filled disambiguator next to a fuller bitstream cache is a
    geometry no uninterrupted LRU run reaches (`_seedable_fleet_state`)
    — so the next resumed segment falls back to the cycle-by-cycle scan;
    once that segment refills the disambiguator, subsequent segments ride
    the engine again.
    """
    idx = np.asarray(slot_indices, np.int64).reshape(-1)
    n = np.asarray(state.slot_st.tags).shape[0]
    if idx.size and (idx.min() < 0 or idx.max() >= n):
        raise ValueError(
            f"SEU slot indices {idx.tolist()} out of range for a "
            f"{n}-slot disambiguator")
    return state._replace(slot_st=canonical_slot_state(
        slots.invalidate(state.slot_st, idx)))


def flush_bitstream(state: FleetState) -> FleetState:
    """A failed partial reconfiguration (or scrub) colds the bitstream
    cache; the configured slots keep running, but every future
    disambiguator miss re-pays the full bitstream re-load penalty
    (`bs_miss_extra` — the LUTstructions cost made real).

    Slot residents no longer covered by the bitstream cache make the
    state unseedable by the interleaved resume, so the next resumed
    segment rides the scan until the bitstream cache re-warms.
    """
    bs_entries = np.asarray(state.bs_st.tags).shape[0]
    return state._replace(bs_st=slots.init(bs_entries))


def degrade_fleet_state(state: FleetState, num_active: int) -> FleetState:
    """Shrink a fleet state to a core that came back with only
    `num_active` usable disambiguator slots: the `num_active`
    most-recently-used residents survive (packed canonically into the
    active prefix), everything else is invalidated.

    The result is the state contract of `simulate_many(...,
    num_active=k)`: masking (`slots.lookup`'s `num_active`) makes
    inactive slots inert — never matched, never victims — so a masked
    run over a state whose residents all sit inside the active prefix is
    bit-for-bit an LRU cache of the smaller size (the degraded-core
    equivalence property, pinned by tests/test_faults.py).
    """
    n = np.asarray(state.slot_st.tags).shape[0]
    if not 1 <= num_active <= n:
        raise ValueError(
            f"num_active must be in [1, {n}], got {num_active}")
    st = canonical_slot_state(state.slot_st)
    tags = np.asarray(st.tags)
    filled = int((tags >= 0).sum())
    if filled > num_active:
        # canonical order is LRU-ascending: the dead slots take the
        # least-recently-used residents (prefix entries)
        st = canonical_slot_state(
            slots.invalidate(st, np.arange(filled - num_active)))
    return state._replace(slot_st=st)


def _seedable_fleet_state(state: FleetState, num_tags: int,
                          worst_step: int, total_steps: int) -> bool:
    """True iff the interleaved engine can seed from this `FleetState`.

    Any state an actual scan produced qualifies; the conditions only
    exclude hand-crafted states whose cache geometry no LRU run can reach
    (those silently fall back to the scan under `path="auto"`):

      * both caches prefix-packed with distinct resident tags in
        `[0, num_tags)` and distinct LRU clocks no later than the cache
        clock (scan fills always pack a prefix, clocks are unique);
      * slot residents all bitstream-resident, and a non-full
        disambiguator implies identical resident sets (no eviction can
        have happened before the cache filled) — this is what lets the
        seed order evicted tags below residents without knowing the
        true eviction history;
      * int32 headroom: the seed's counters/cursors/clocks plus a
        worst-case segment stay below int32 (the scan tolerates silent
        wraparound only in the sense that nothing guards it; the engine
        refuses to seed rather than diverge).
    """
    def cache(st: slots.SlotState):
        tags = np.asarray(st.tags)
        lu = np.asarray(st.last_use).astype(np.int64)
        filled = tags >= 0
        k = int(filled.sum())
        if not (np.all(tags[:k] >= 0) and np.all(tags[k:] < 0)):
            return None
        res = tags[:k]
        if k and (int(res.max()) >= num_tags
                  or len(np.unique(res)) != k
                  or len(np.unique(lu[:k])) != k
                  or int(lu[:k].max()) > int(st.clock)
                  or int(lu[:k].min()) < 0):
            return None
        return res

    slot_res = cache(state.slot_st)
    bs_res = cache(state.bs_st)
    if slot_res is None or bs_res is None:
        return False
    if not np.isin(slot_res, bs_res).all():
        return False
    full = slot_res.size == np.asarray(state.slot_st.tags).size
    if not full and slot_res.size != bs_res.size:
        return False
    lim = np.iinfo(np.int32).max
    top = max(int(state.q_cycles), int(state.switches),
              *(int(np.max(np.asarray(x))) for x in
                (state.cycles, state.instrs, state.misses, state.bs_misses)))
    return (top + total_steps * worst_step < lim
            and int(np.max(np.asarray(state.cursors))) + total_steps < lim
            and int(state.slot_st.clock) + total_steps < lim
            and int(state.bs_st.clock) + total_steps < lim)


def _seed_carry(state: FleetState,
                num_tags: int) -> stackdist_interleaved.CellCarry:
    """Translate a (seedable) `FleetState` into engine coordinates.

    Cache contents become the virtual per-tag position block `[0,
    num_tags)` below all segment positions: evicted-but-bitstream-resident
    tags at the bottom (their next access must re-fault at every slot
    count — the disambiguator is provably full whenever they exist — and
    they are not cold), disambiguator residents above them ordered by LRU
    clock, untouched tags -1.  Scheduler state and counters seed the
    carry verbatim.
    """
    slot_tags = np.asarray(state.slot_st.tags)
    slot_lu = np.asarray(state.slot_st.last_use).astype(np.int64)
    bs_tags = np.asarray(state.bs_st.tags)
    filled = slot_tags >= 0
    residents = slot_tags[filled][np.argsort(slot_lu[filled])]
    evicted = np.setdiff1d(bs_tags[bs_tags >= 0], residents)
    last_pos = np.full((num_tags,), -1, np.int32)
    last_pos[evicted] = np.arange(evicted.size, dtype=np.int32)
    last_pos[residents] = evicted.size + np.arange(residents.size,
                                                   dtype=np.int32)
    return stackdist_interleaved.CellCarry(
        last_pos=jnp.asarray(last_pos),
        last_miss_pos=jnp.full((num_tags,), -1, jnp.int32),
        cursors=state.cursors, sched_idx=state.sched_idx,
        steps_done=jnp.int32(0), q_cycles=state.q_cycles,
        cycles=state.cycles, instrs=state.instrs, misses=state.misses,
        bs_misses=state.bs_misses, switches=state.switches)


def _state_from_final(final: stackdist_interleaved.CellCarry,
                      seed_state: FleetState, num_slots: int,
                      bs_entries: int, num_tags: int,
                      total_steps: int) -> FleetState:
    """Rebuild the canonical `FleetState` from the engine's final carry.

    Both cache clocks advance by exactly one per access (the bitstream
    clock ticks on every `lookup_fused` step too, tag -1 or hit or not),
    so clock' = seed clock + steps.  A touched tag's LRU clock is the
    scan clock value of its last access — seed clock plus its 1-based
    segment step index, i.e. `last_pos - num_tags + 1` — and untouched
    tags keep their seed clock; the bitstream cache is touched exactly on
    slot misses, so its clocks come from `last_miss_pos` the same way.
    Residency: the disambiguator holds the `num_slots` most recent
    distinct tags of the merged stream (seed block included), the warm
    bitstream cache holds every tag ever present.  Entries pack in
    canonical order (`_canonical_state`'s normal form) directly.
    """
    offset = num_tags
    last_pos = np.asarray(final.last_pos, dtype=np.int64)
    last_miss = np.asarray(final.last_miss_pos, dtype=np.int64)
    seed_slot_clock = int(seed_state.slot_st.clock)
    seed_bs_clock = int(seed_state.bs_st.clock)

    def lu_map(st: slots.SlotState) -> np.ndarray:
        m = np.zeros((num_tags,), np.int64)
        tags = np.asarray(st.tags)
        f = tags >= 0
        m[tags[f]] = np.asarray(st.last_use, np.int64)[f]
        return m

    slot_lu = np.where(last_pos >= offset,
                       seed_slot_clock + (last_pos - offset) + 1,
                       lu_map(seed_state.slot_st))
    bs_lu = np.where(last_miss >= 0,
                     seed_bs_clock + (last_miss - offset) + 1,
                     lu_map(seed_state.bs_st))
    present = np.nonzero(last_pos >= 0)[0]
    by_recency = present[np.argsort(last_pos[present])]
    slot_res = by_recency[-num_slots:]   # ascending position = ascending lu
    bs_res = present[np.argsort(bs_lu[present])]

    def pack(res: np.ndarray, lu: np.ndarray, size: int,
             clock: int) -> slots.SlotState:
        t = np.full((size,), -1, np.int32)
        u = np.zeros((size,), np.int32)
        t[:res.size] = res
        u[:res.size] = lu[res].astype(np.int32)
        return slots.SlotState(tags=jnp.asarray(t), last_use=jnp.asarray(u),
                               clock=jnp.int32(clock))

    return FleetState(
        slot_st=pack(slot_res, slot_lu, num_slots,
                     seed_slot_clock + total_steps),
        bs_st=pack(bs_res, bs_lu, bs_entries, seed_bs_clock + total_steps),
        cursors=final.cursors, sched_idx=final.sched_idx,
        q_cycles=final.q_cycles, cycles=final.cycles, instrs=final.instrs,
        misses=final.misses, bs_misses=final.bs_misses,
        switches=final.switches)


def _engine_num_tags(table: np.ndarray, state: FleetState | None) -> int:
    """Static tag-alphabet size for the interleaved engine: the fleet's
    table plus any *stale* resident tags a carried state may hold from
    scenarios no longer in the fleet — stale residents still occupy real
    LRU stack positions, so the engine must model them."""
    nt = int(np.max(table)) + 1
    if state is not None:
        for st in (state.slot_st, state.bs_st):
            t = np.asarray(st.tags)
            if t.size and int(t.max()) >= 0:
                nt = max(nt, int(t.max()) + 1)
    return max(nt, 1)


def _resume_fleet_interleaved(traces, table, cfg: ReconfigConfig, quanta,
                              schedule, handler, seed_state: FleetState,
                              total_steps: int, num_tags: int,
                              use_kernel=None):
    """Run one resumable interleaved cell from a `FleetState` seed ->
    (FleetResult, final CellCarry)."""
    w = _interleaved_window(quanta, total_steps, None)
    final = stackdist_interleaved.resume_preempted(
        traces, jnp.asarray(table, jnp.int32), isa.INSTR_HW_CYCLES,
        jnp.int32(cfg.num_slots), jnp.int32(cfg.miss_latency),
        jnp.asarray(quanta, jnp.int32), jnp.asarray(schedule, jnp.int32),
        jnp.int32(handler), jnp.int32(cfg.bs_miss_extra),
        _seed_carry(seed_state, num_tags),
        num_tags=num_tags, total_steps=total_steps, window=w,
        use_kernel=use_kernel)
    res = FleetResult(final.cycles, final.instrs, final.misses,
                      final.bs_misses, final.switches)
    return res, final


def _fleet_step_fn(ptags, pcosts, miss_latency, active_slots, quanta,
                   schedule, handler, bs_miss_extra):
    """Round-robin step over precomputed per-program (tag, cost) streams.

    `ptags`/`pcosts` are the (P, N) gathers `tags[p, traces[p, i]]` /
    `hw[traces[p, i]]` hoisted out of the step: the hot loop does two
    independent stream loads instead of a dependent double gather per cycle,
    and one fused disambiguator+bitstream update (`slots.lookup_fused`).

    `quanta` is the (P,) per-program quantum vector and `schedule` the
    weighted round-robin turn order (`priority_schedule`): the scan walks a
    cursor through `schedule` instead of incrementing the program index, so
    priority weights are one extra gather per step.  With uniform quanta
    and unit priorities this reduces exactly to the historical rotation.
    """
    trace_len = ptags.shape[1]
    sched_len = schedule.shape[0]

    def step(c: FleetState, _):
        p = schedule[c.sched_idx]
        i = jnp.remainder(c.cursors[p], trace_len)
        tag = ptags[p, i]
        # on a disambiguator miss the bitstream is fetched through the
        # bitstream cache; a miss there goes to the unified L2 (extra cost)
        slot_st, bs_st, hit, bs_hit = slots.lookup_fused(
            c.slot_st, c.bs_st, tag, active_slots)
        cost = pcosts[p, i]
        cost = cost + jnp.where(hit, 0, miss_latency).astype(jnp.int32)
        cost = cost + jnp.where(hit | bs_hit, 0,
                                bs_miss_extra).astype(jnp.int32)

        q = c.q_cycles + cost
        do_switch = q >= quanta[p]
        # the outgoing program pays the interrupt-handler cycles, mirroring
        # the paper's observation that short quanta inflate all runtimes
        cost_p = cost + jnp.where(do_switch, handler, 0).astype(jnp.int32)

        # slot/bitstream state deliberately persists across the switch —
        # shared extensions stay resident (the architecture's point, §IV)
        return FleetState(
            slot_st=slot_st,
            bs_st=bs_st,
            cursors=c.cursors.at[p].add(1),
            sched_idx=jnp.where(do_switch,
                                (c.sched_idx + 1) % sched_len,
                                c.sched_idx),
            q_cycles=jnp.where(do_switch, 0, q),
            cycles=c.cycles.at[p].add(cost_p),
            instrs=c.instrs.at[p].add(1),
            misses=c.misses.at[p].add((~hit).astype(jnp.int32)),
            bs_misses=c.bs_misses.at[p].add(
                (~(hit | bs_hit)).astype(jnp.int32)),
            switches=c.switches + do_switch.astype(jnp.int32),
        ), None

    return step


def _simulate_fleet_impl(traces, tag_table, miss_latency, active_slots,
                         quanta, schedule, handler, num_slots: int,
                         bs_entries: int, bs_miss_extra, total_steps: int,
                         scan_unroll: int = SCAN_UNROLL,
                         state: FleetState | None = None
                         ) -> tuple[FleetResult, FleetState]:
    """(P, N) traces + (P, num_opcodes) tags -> (FleetResult, FleetState).

    `num_slots` is the *allocated* (static) disambiguator size;
    `active_slots` (traced) masks it down so slot count is a sweep axis.
    `quanta` is the (P,) per-program quantum vector; `schedule` the
    weighted round-robin turn order (see `priority_schedule`).  `state`
    resumes the scan from a prior carry (None = cold init); the returned
    state carries the run's full warm state for further resumption.
    """
    hw = jnp.asarray(isa.INSTR_HW_CYCLES, jnp.int32)
    tags = jnp.asarray(tag_table, jnp.int32)
    num_progs = traces.shape[0]
    # hoist the per-step dependent double gather: precompute the per-program
    # tag and hw-cost streams once (the instruction id itself is only ever
    # used through these two tables)
    ptags = jnp.take_along_axis(tags, traces, axis=1)
    pcosts = hw[traces]

    init = (init_fleet_state(num_progs, num_slots, bs_entries)
            if state is None else state)
    step = _fleet_step_fn(ptags, pcosts, miss_latency, active_slots,
                          quanta, schedule, handler, bs_miss_extra)
    final, _ = jax.lax.scan(step, init, None, length=total_steps,
                            unroll=scan_unroll)
    return final.result(), final


_simulate_fleet = functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps",
                              "scan_unroll"))(_simulate_fleet_impl)


def simulate_many(traces: np.ndarray, cfg: ReconfigConfig,
                  scenarios, sched: SchedulerConfig,
                  total_steps: int = 400_000,
                  scan_unroll: int = SCAN_UNROLL, *,
                  state: FleetState | None = None,
                  return_state: bool = False,
                  num_active: int | None = None,
                  path: str = "auto",
                  use_kernel=None):
    """Round-robin fleet of P programs sharing one reconfigurable core.

    traces: (P, N) int32 instruction ids; `scenarios` is one shared
    `SlotScenario` or a length-P sequence (per-program slot taxonomies).
    `sched` may carry per-program quanta and/or priority weights
    (`SchedulerConfig`); the uniform unit-priority case reproduces the
    paper's round-robin bit-for-bit.

    The scan carry is an explicit value: `state` resumes a prior run's
    `FleetState` (None = cold start), and `return_state=True` additionally
    returns the final state, making the call "run `total_steps` from S ->
    (results, S')".  A run split at any step boundary reproduces the
    one-shot run bit-for-bit (counters are cumulative in the state).

    Dispatch: calls with a warm bitstream cache — one-shot, resumed
    (`state=`), or `return_state=True` — route through the
    interleave-aware fast path (`repro.core.stackdist_interleaved`),
    preempted or not, and are bit-for-bit equal to the scan: the engine
    seeds from the `FleetState` (a one-shot `return_state` run seeds from
    the cold init state) and materialises the final state back out in
    canonical form.  Hand-crafted states no scan could produce
    (`_seedable_fleet_state`), cold bitstream caches, and sub-threshold
    quanta fall back to the cycle-by-cycle scan, whose returned states
    are canonicalised too (`_canonical_state` — behaviour-preserving, so
    resumes and state comparisons never see which engine ran).
    `path="scan"|"interleaved"` forces an engine ("interleaved" raises
    on ineligible or unseedable runs); `use_kernel` picks the
    interleaved engine's window-pass implementation (jnp body or the
    fused Pallas kernel — `repro.kernels.window_distance.resolve`),
    bit-for-bit identical either way.

    `num_active` masks the disambiguator down to its first `num_active`
    slots (a degraded core that came back with fewer usable slots —
    `slots.lookup`'s masking, bit-for-bit an LRU cache of that size).
    Masked runs ride the scan: the interleaved engine seeds full-geometry
    caches only, so `path="interleaved"` raises.  A resumed masked run
    requires every resident inside the active prefix
    (`degrade_fleet_state` produces exactly that), otherwise the inert
    masked residents would be re-sorted into live slots on
    canonicalisation.
    """
    traces = jnp.asarray(traces, jnp.int32)
    if traces.ndim != 2:
        raise ValueError(
            f"simulate_many expects (P, N) traces, got shape "
            f"{tuple(traces.shape)}")
    num_progs = traces.shape[0]
    table = fleet_tag_table(scenarios, num_progs)
    schedule = sched.schedule(num_progs)
    if path not in ("auto", "scan", "interleaved"):
        raise ValueError(
            f"unknown path {path!r} — simulate_many accepts "
            f"'auto'|'scan'|'interleaved' (solo unpreempted runs take the "
            f"stack-distance engine through simulate_single/sweep_fleet)")
    quanta = sched.quanta(num_progs)
    active = cfg.num_slots if num_active is None else int(num_active)
    if not 1 <= active <= cfg.num_slots:
        raise ValueError(
            f"num_active must be in [1, {cfg.num_slots}] "
            f"(the allocated slot count), got {num_active}")
    masked = active < cfg.num_slots
    if masked and path == "interleaved":
        raise ValueError(
            "a masked (degraded) disambiguator rides the scan — the "
            "interleaved engine seeds full-geometry caches only; use "
            "path='auto' or 'scan'")
    if state is not None:
        _check_fleet_state(state, num_progs, cfg.num_slots,
                           cfg.bs_cache_entries)
        if masked and bool(np.any(
                np.asarray(state.slot_st.tags)[active:] >= 0)):
            raise ValueError(
                f"num_active={active} masks slots the state still "
                f"populates — apply simulator.degrade_fleet_state first "
                f"so the dead slots hold no residents")
        if int(state.sched_idx) >= schedule.shape[0]:
            raise ValueError(
                f"FleetState scheduler cursor {int(state.sched_idx)} is "
                f"out of range for a priority schedule of length "
                f"{schedule.shape[0]} — resume must use a SchedulerConfig "
                f"whose priority weights produce a schedule at least as "
                f"long as the one the state was built under")
    eligible = interleaved_eligible(
        table, bs_entries=cfg.bs_cache_entries,
        miss_latencies=[cfg.miss_latency], bs_miss_extra=cfg.bs_miss_extra,
        handler_cycles=sched.handler_cycles, total_steps=total_steps)
    if state is None and not return_state:
        # one-shot result-only: no state to seed or materialise
        if path == "interleaved" and not eligible:
            raise ValueError(
                "interleaved path requires a warm bitstream cache over the "
                "fleet's merged tag set and non-negative int32-safe costs "
                "(see simulator.interleaved_eligible)")
        if path == "interleaved" or (
                path == "auto" and not masked and eligible
                and _interleaved_auto_ok(
                    quanta[None, :], 1, int(np.max(table)) + 1, total_steps,
                    None)):
            res = _sweep_fleet_interleaved(
                traces[None], table,
                jnp.asarray([cfg.miss_latency], jnp.int32),
                jnp.asarray([cfg.num_slots], jnp.int32), quanta[None, :],
                schedule, sched.handler_cycles, cfg.bs_miss_extra,
                total_steps, None, use_kernel)
            return FleetResult(*(x[0, 0, 0, 0] for x in res))
    else:
        # state-carrying: seed the resumable engine from the given state
        # (or the cold init state for one-shot return_state runs)
        seed_state = state if state is not None else init_fleet_state(
            num_progs, cfg.num_slots, cfg.bs_cache_entries)
        num_tags = _engine_num_tags(table, seed_state)
        worst_step = (int(np.max(isa.INSTR_HW_CYCLES))
                      + int(cfg.miss_latency) + int(cfg.bs_miss_extra)
                      + int(sched.handler_cycles))
        resumable = (not masked and eligible
                     and cfg.bs_cache_entries >= num_tags
                     and _seedable_fleet_state(seed_state, num_tags,
                                               worst_step, total_steps))
        if path == "interleaved" and not resumable:
            raise ValueError(
                "interleaved path requires a warm bitstream cache over the "
                "fleet's merged tag set, non-negative int32-safe costs, "
                "and a scan-shaped FleetState seed with int32 headroom "
                "(see simulator.interleaved_eligible and "
                "simulator._seedable_fleet_state)")
        if path == "interleaved" or (
                path == "auto" and resumable and _interleaved_auto_ok(
                    quanta[None, :], 1, num_tags, total_steps, None)):
            res, final = _resume_fleet_interleaved(
                traces, table, cfg, quanta, schedule, sched.handler_cycles,
                seed_state, total_steps, num_tags, use_kernel)
            if not return_state:
                return res
            return res, _state_from_final(final, seed_state, cfg.num_slots,
                                          cfg.bs_cache_entries, num_tags,
                                          total_steps)
    res, final = _simulate_fleet(
        traces, table, jnp.int32(cfg.miss_latency),
        jnp.int32(active),
        jnp.asarray(quanta),
        jnp.asarray(schedule),
        jnp.int32(sched.handler_cycles), cfg.num_slots,
        cfg.bs_cache_entries, jnp.int32(cfg.bs_miss_extra), total_steps,
        scan_unroll, state)
    return (res, _canonical_state(final)) if return_state else res


@functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps",
                              "scan_unroll"))
def _sweep_fleet(fleets, tag_table, miss_latencies, slot_counts, quanta,
                 schedule, handler, num_slots: int, bs_entries: int,
                 bs_miss_extra, total_steps: int,
                 scan_unroll: int) -> FleetResult:
    def one(t, s, lat, qv):
        return _simulate_fleet_impl(
            t, tag_table, lat, s, qv, schedule, handler, num_slots,
            bs_entries, bs_miss_extra, total_steps, scan_unroll)[0]

    f = jax.vmap(one, in_axes=(None, None, 0, None))   # miss-latency axis
    f = jax.vmap(f, in_axes=(None, 0, None, None))     # slot-count axis
    f = jax.vmap(f, in_axes=(0, None, None, None))     # fleet axis
    f = jax.vmap(f, in_axes=(None, None, None, 0))     # quantum axis
    return f(fleets, slot_counts, miss_latencies, quanta)


# the distance profile materializes (total_steps, num_tags)-shaped int32
# temporaries per batched lane; cap chunk_size * total_steps * num_tags so
# the fast path's transient footprint stays bounded (~64 MB per temporary,
# a few alive at once) no matter how many fleets an eligible sweep batches
# or how fine the tag taxonomy is
_STACKDIST_CHUNK_ELEMS = 16_000_000


def _stackdist_chunk(total_steps: int, num_tags: int) -> int:
    return max(1, _STACKDIST_CHUNK_ELEMS
               // max(total_steps * max(num_tags, 1), 1))


def _sweep_fleet_stackdist(fleets, table, lats, counts, bs_miss_extra,
                           total_steps: int) -> FleetResult:
    """Assemble the scan-shaped FleetResult from one stack-distance pass.

    Only valid for eligible (unpreempted) runs: program 0 executes every
    step, programs 1..P-1 never get scheduled (their counters are zero in
    the scan too), and no switch ever fires.  The fleet axis is processed
    in memory-bounded chunks (at most two compiled shapes: full + tail).
    """
    num_progs = fleets.shape[1]
    num_tags = max(int(np.max(np.asarray(table[0]))) + 1, 1)
    chunk = _stackdist_chunk(total_steps, num_tags)
    grids = [
        stackdist.sweep_unpreempted(
            fleets[i:i + chunk, 0, :], table[0], isa.INSTR_HW_CYCLES,
            counts, lats, jnp.int32(bs_miss_extra), num_tags=num_tags,
            total_steps=total_steps)
        for i in range(0, fleets.shape[0], chunk)]
    cycles = jnp.concatenate([g.cycles for g in grids])
    slot_misses = jnp.concatenate([g.slot_misses for g in grids])
    bs_misses = jnp.concatenate([g.bs_misses for g in grids])
    b, k, l = cycles.shape
    zeros = jnp.zeros((b, k, l, num_progs), jnp.int32)
    return FleetResult(
        cycles=zeros.at[..., 0].set(cycles),
        instructions=zeros.at[..., 0].set(jnp.int32(total_steps)),
        slot_misses=zeros.at[..., 0].set(slot_misses[:, :, None]),
        bs_misses=zeros.at[..., 0].set(bs_misses[:, None, None]),
        switches=jnp.zeros((b, k, l), jnp.int32),
    )


def _sweep_fleet_stackdist_cold(fleets, table, lats, counts, bs_entries,
                                bs_miss_extra,
                                total_steps: int) -> FleetResult:
    """Assemble the scan-shaped FleetResult from the stacked cold pass.

    Same unpreempted contract as `_sweep_fleet_stackdist` (program 0 only,
    no switches), but the bitstream-miss count now varies with the slot
    count — the cold cache sees a different miss stream per S — so the
    `bs_misses` field broadcasts over latencies only.  The per-slot-count
    second pass multiplies the transient footprint by K, so the fleet
    chunking divides by it.
    """
    num_progs = fleets.shape[1]
    num_tags = max(int(np.max(np.asarray(table[0]))) + 1, 1)
    chunk = max(1, _stackdist_chunk(total_steps, num_tags)
                // max(int(counts.shape[0]), 1))
    grids = [
        stackdist_cold.sweep_cold(
            fleets[i:i + chunk, 0, :], table[0], isa.INSTR_HW_CYCLES,
            counts, lats, jnp.asarray([bs_entries], jnp.int32),
            jnp.asarray([bs_miss_extra], jnp.int32), num_tags=num_tags,
            total_steps=total_steps)
        for i in range(0, fleets.shape[0], chunk)]
    cycles = jnp.concatenate([g.cycles[:, :, :, 0, 0] for g in grids])
    slot_misses = jnp.concatenate([g.slot_misses for g in grids])
    bs_misses = jnp.concatenate([g.bs_misses[:, :, 0] for g in grids])
    b, k, l = cycles.shape
    zeros = jnp.zeros((b, k, l, num_progs), jnp.int32)
    return FleetResult(
        cycles=zeros.at[..., 0].set(cycles),
        instructions=zeros.at[..., 0].set(jnp.int32(total_steps)),
        slot_misses=zeros.at[..., 0].set(slot_misses[:, :, None]),
        bs_misses=zeros.at[..., 0].set(bs_misses[:, :, None]),
        switches=jnp.zeros((b, k, l), jnp.int32),
    )


def _fleet_mesh():
    """1-D device mesh over the fleet axis, or None on single-device
    hosts (the mesh path must be a no-op there: every BENCH anchor is
    recorded single-device and stays byte-identical)."""
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    return jax.sharding.Mesh(np.array(devs), ("fleet",))


def fleet_mesh_size() -> int:
    """Devices the interleaved sweep shards its fleet axis over (1 on
    single-device hosts).  Batch-building callers (the contention
    model's candidate-group sweeps) round their batch shapes to a
    multiple of this so every shard is full and the padded shape is
    reused across calls."""
    mesh = _fleet_mesh()
    return int(mesh.devices.size) if mesh is not None else 1


def _mesh_sweep_preempted(mesh, part, table, counts, lats, quanta_grid,
                          schedule, handler, bs_miss_extra, num_tags: int,
                          total_steps: int, w: int, use_kernel):
    """Shard one padded fleet chunk across the device mesh: each device
    runs the interleaved sweep (jnp or Pallas-kernel window pass alike)
    over its fleet shard; grid/scalar operands replicate via closure.
    Results concatenate along the fleet axis, so this is bit-identical
    to the single-device call on the same chunk."""
    spec = jax.sharding.PartitionSpec

    def shard(pt):
        return stackdist_interleaved.sweep_preempted(
            pt, table, isa.INSTR_HW_CYCLES, counts, lats,
            jnp.asarray(quanta_grid, jnp.int32),
            jnp.asarray(schedule, jnp.int32), jnp.int32(handler),
            jnp.int32(bs_miss_extra), num_tags=num_tags,
            total_steps=total_steps, window=w, use_kernel=use_kernel)

    out_specs = stackdist_interleaved.InterleavedGrid(
        *([spec(None, "fleet")] * 5))
    return compat.shard_map(shard, mesh=mesh, in_specs=(spec("fleet"),),
                            out_specs=out_specs, check_rep=False)(part)


def _sweep_fleet_interleaved(fleets, table, lats, counts, quanta_grid,
                             schedule, handler, bs_miss_extra,
                             total_steps: int, window: int | None,
                             use_kernel=None) -> FleetResult:
    """Serve the full (Q, B, K, L) grid from the interleave-aware engine.

    Each cell replays its own switch points (they are cost-dependent), so
    nothing broadcasts — but the sequential depth per cell is scheduler
    windows, not steps.  The fleet axis is processed in memory-bounded
    chunks, mirroring `_sweep_fleet_stackdist`, and padded up to a bucket
    size so repeat callers with varying batch sizes (the contention
    model's candidate sweeps price groups in batches of 1..8) hit one
    compiled shape instead of one per batch size — compiling this sweep
    costs seconds, replaying a few padded cells costs milliseconds.  On
    multi-device hosts each chunk's fleet axis additionally shards
    across a 1-D device mesh (`compat.shard_map`) — cells are
    independent, so sharding the batch is exact; padding rounds up to
    the device count and padded rows are sliced off as before.
    `use_kernel` picks the window-pass implementation
    (`repro.kernels.window_distance.resolve`).
    """
    num_tags = max(int(np.max(np.asarray(table))) + 1, 1)
    w = _interleaved_window(quanta_grid, total_steps, window)
    cells = quanta_grid.shape[0] * counts.shape[0] * lats.shape[0]
    chunk = max(1, _INTERLEAVED_CHUNK_ELEMS // max(w * num_tags * cells, 1))
    b_total = fleets.shape[0]
    mesh = _fleet_mesh()
    ndev = mesh.devices.size if mesh is not None else 1
    grids = []
    for i in range(0, b_total, chunk):
        part = jnp.asarray(fleets[i:i + chunk])
        if b_total > chunk:
            target = chunk          # tail rides the full-chunk shape
        else:
            target = min(-(-b_total // _INTERLEAVED_BATCH_BUCKET)
                         * _INTERLEAVED_BATCH_BUCKET, chunk)
        target = -(-target // ndev) * ndev   # mesh: divisible fleet shards
        pad = target - part.shape[0]
        if pad > 0:
            part = jnp.concatenate(
                [part, jnp.broadcast_to(part[:1],
                                        (pad,) + part.shape[1:])], axis=0)
        if mesh is not None:
            grids.append(_mesh_sweep_preempted(
                mesh, part, table, counts, lats, quanta_grid, schedule,
                handler, bs_miss_extra, num_tags, total_steps, w,
                use_kernel))
        else:
            grids.append(stackdist_interleaved.sweep_preempted(
                part, table, isa.INSTR_HW_CYCLES, counts, lats,
                jnp.asarray(quanta_grid, jnp.int32),
                jnp.asarray(schedule, jnp.int32), jnp.int32(handler),
                jnp.int32(bs_miss_extra), num_tags=num_tags,
                total_steps=total_steps, window=w, use_kernel=use_kernel))
    return FleetResult(*(jnp.concatenate([g[f] for g in grids],
                                         axis=1)[:, :b_total]
                         for f in range(5)))


def sweep_fleet(fleets: np.ndarray, miss_latencies, scenarios,
                sched: SchedulerConfig, *, slot_counts, quanta=None,
                bs_cache_entries: int = 64, bs_miss_extra: int = 100,
                total_steps: int = 400_000, path: str = "auto",
                scan_unroll: int = SCAN_UNROLL,
                interleave_window: int | None = None,
                use_kernel=None) -> FleetResult:
    """One call over the {quanta x fleets x slot counts x miss latencies}
    grid.

    fleets: (B, P, N) int32 traces.  Result axes: (B, K_slots, L_lat, P) —
    or, when `quanta` is given, (Q, B, K_slots, L_lat, P) with the swept
    quantum axis outermost.  Each `quanta` entry is a scalar (shared by
    every program) or a length-P vector of per-program quanta; `quanta=None`
    keeps the historical 3-axis grid at `sched.quantum_cycles`.  Priority
    weights (`sched.priorities`) apply to every cell of the grid.

    Dispatch (see module docstring): grids unpreempted at EVERY quantum
    cell with a warm bitstream cache (`stackdist_eligible`) collapse the
    K x L grid into one stack-distance pass per fleet (quantum cells are
    then identical by construction and broadcast); unpreempted grids with
    a COLD bitstream cache take the stacked pass
    (`stackdist_cold_eligible` / `repro.core.stackdist_cold`) instead of
    the scan; preempted or mixed grids with a fleet-warm bitstream cache
    (`interleaved_eligible`) replay every cell's own interleaving at
    scheduler-window granularity (`repro.core.stackdist_interleaved`;
    `interleave_window` overrides the tuned backend-aware window size and
    `use_kernel` the window-pass implementation — jnp body or fused
    Pallas kernel, see `repro.kernels.window_distance.resolve` — results
    identical for any value of either); everything else — now only preempted runs
    with cold bitstream caches — runs the jitted vmap^4 of `lax.scan`s,
    where slot counts sweep by masking one max-size disambiguator
    (`slots.lookup`'s `num_active`).  `path` forces a specific engine
    ("stackdist"/"stackdist_cold"/"interleaved" raise if the grid is
    ineligible); all engines return bit-for-bit identical results on
    eligible grids.
    """
    fleets = jnp.asarray(fleets, jnp.int32)
    if fleets.ndim != 3:
        raise ValueError(
            f"sweep_fleet expects (B, P, N) fleet traces, got shape "
            f"{tuple(fleets.shape)}")
    num_progs = fleets.shape[1]
    table = fleet_tag_table(scenarios, num_progs)
    counts = jnp.asarray(slot_counts, jnp.int32).reshape(-1)
    lats = jnp.asarray(miss_latencies, jnp.int32).reshape(-1)
    if quanta is None:
        quanta_grid = sched.quanta(num_progs)[None, :]          # (1, P)
    else:
        if np.isscalar(quanta) or getattr(quanta, "ndim", None) == 0:
            raise ValueError(
                f"quanta must be a sequence of quantum cells (scalars or "
                f"per-program vectors), got bare scalar {quanta!r} — pass "
                f"quanta=[{quanta!r}] for a single-cell axis")
        quanta = list(quanta)
        if not quanta:
            raise ValueError("quanta needs at least one quantum cell")
        quanta_grid = np.stack([quanta_vector(q, num_progs) for q in quanta])
    eligible = stackdist_eligible(
        table[0], quantum_cycles=quanta_grid,
        bs_entries=bs_cache_entries,
        max_miss_latency=int(np.max(np.asarray(miss_latencies))),
        bs_miss_extra=bs_miss_extra, total_steps=total_steps)
    inter_eligible = interleaved_eligible(
        table, bs_entries=bs_cache_entries, miss_latencies=lats,
        bs_miss_extra=bs_miss_extra, handler_cycles=sched.handler_cycles,
        total_steps=total_steps)
    inter_auto = _interleaved_auto_ok(
        quanta_grid, quanta_grid.shape[0] * counts.shape[0] * lats.shape[0],
        int(np.max(table)) + 1, total_steps, interleave_window)
    cold_eligible = stackdist_cold_eligible(
        quantum_cycles=quanta_grid,
        max_miss_latency=int(np.max(np.asarray(miss_latencies))),
        bs_miss_extra=bs_miss_extra, total_steps=total_steps)
    chosen = _check_path(path, eligible, inter_eligible, inter_auto,
                         cold_eligible)
    if chosen in ("stackdist", "stackdist_cold"):
        if chosen == "stackdist":
            res = _sweep_fleet_stackdist(fleets, table, lats, counts,
                                         bs_miss_extra, total_steps)
        else:
            res = _sweep_fleet_stackdist_cold(
                fleets, table, lats, counts, bs_cache_entries,
                bs_miss_extra, total_steps)
        if quanta is None:
            return res
        # every quantum cell is unpreempted, so cells are identical:
        # broadcast the one reconstructed grid over the quantum axis
        q = quanta_grid.shape[0]
        return FleetResult(*(jnp.broadcast_to(x[None], (q,) + x.shape)
                             for x in res))
    if chosen == "interleaved":
        res = _sweep_fleet_interleaved(
            fleets, table, lats, counts, quanta_grid,
            sched.schedule(num_progs), sched.handler_cycles, bs_miss_extra,
            total_steps, interleave_window, use_kernel)
        if quanta is None:
            return FleetResult(*(x[0] for x in res))
        return res
    s_max = int(np.max(np.asarray(slot_counts)))
    res = _sweep_fleet(
        fleets, table, lats, counts, jnp.asarray(quanta_grid),
        jnp.asarray(sched.schedule(num_progs)),
        jnp.int32(sched.handler_cycles), s_max, bs_cache_entries,
        jnp.int32(bs_miss_extra), total_steps, scan_unroll)
    if quanta is None:
        return FleetResult(*(x[0] for x in res))
    return res


def sweep_bitstream(traces: np.ndarray, scenario: isa.SlotScenario, *,
                    slot_counts, miss_latencies, bs_entries, bs_miss_extras,
                    total_steps: int,
                    path: str = "auto") -> stackdist_cold.ColdGrid:
    """Solo-program sweep over the full reconfiguration-cost design space:
    {slot count x miss latency x bitstream capacity x bitstream penalty}.

    traces: (B, N) int32 solo instruction traces, run unpreempted.
    Returns a `stackdist_cold.ColdGrid` with (B, K, L, E, X) cycles,
    (B, K) slot misses and (B, K, E) bitstream misses — the axes
    `benchmarks/bitstream_study.py` studies, in one call.

    Dispatch: eligible runs (`stackdist_cold_eligible` — unpreempted is
    by construction here, so only the int32 guard matters) take the
    stacked Mattson pass, one profile per (trace, slot count) serving the
    whole capacity x penalty sub-grid; `path="scan"` forces one
    cycle-by-cycle run per grid cell (the parity reference).
    """
    traces = jnp.asarray(traces, jnp.int32)
    if traces.ndim != 2:
        raise ValueError(
            f"sweep_bitstream expects (B, N) solo traces, got shape "
            f"{tuple(traces.shape)}")
    counts = np.asarray(slot_counts, np.int32).reshape(-1)
    lats = np.asarray(miss_latencies, np.int32).reshape(-1)
    caps = np.asarray(bs_entries, np.int32).reshape(-1)
    extras = np.asarray(bs_miss_extras, np.int32).reshape(-1)
    cold_ok = stackdist_cold_eligible(
        quantum_cycles=NO_PREEMPT_QUANTUM,
        max_miss_latency=int(np.max(lats)),
        bs_miss_extra=int(np.max(extras)), total_steps=total_steps)
    if path not in ("auto", "stackdist_cold", "scan"):
        raise ValueError(
            f"unknown path {path!r} — sweep_bitstream accepts "
            f"'auto'|'stackdist_cold'|'scan'")
    if path == "stackdist_cold" and not cold_ok:
        raise ValueError(
            "stacked cold-bitstream path requires an unpreempted run with "
            "int32-safe costs (see simulator.stackdist_cold_eligible)")
    if path != "scan" and cold_ok:
        return stackdist_cold.sweep_cold(
            traces, scenario.instr_tag, isa.INSTR_HW_CYCLES,
            jnp.asarray(counts), jnp.asarray(lats), jnp.asarray(caps),
            jnp.asarray(extras), num_tags=max(scenario.num_tags, 1),
            total_steps=total_steps)
    # reference fallback: one scan per cell (slot/bitstream misses do not
    # depend on the latency/penalty axes in an unpreempted run, so the
    # counter fields come from the first L x X cell)
    b = traces.shape[0]
    shape = (b, counts.size, lats.size, caps.size, extras.size)
    cycles = np.zeros(shape, np.int32)
    slot_misses = np.zeros(shape[:2], np.int32)
    bs_misses = np.zeros((b, counts.size, caps.size), np.int32)
    for i in range(b):
        stream = traces[i][jnp.remainder(
            jnp.arange(total_steps, dtype=jnp.int32), traces.shape[-1])]
        for k, s in enumerate(counts):
            for e, cap in enumerate(caps):
                for l, lat in enumerate(lats):
                    for x, pen in enumerate(extras):
                        r = simulate_single(
                            stream,
                            ReconfigConfig(num_slots=int(s),
                                           miss_latency=int(lat),
                                           bs_cache_entries=int(cap),
                                           bs_miss_extra=int(pen)),
                            scenario, path="scan")
                        cycles[i, k, l, e, x] = int(r.cycles)
                        slot_misses[i, k] = int(r.slot_misses)
                        bs_misses[i, k, e] = int(r.bs_misses)
    return stackdist_cold.ColdGrid(cycles=jnp.asarray(cycles),
                                   slot_misses=jnp.asarray(slot_misses),
                                   bs_misses=jnp.asarray(bs_misses))


# --- pair path: the P=2 special case, kept as thin wrappers so the Fig. 7
# --- numbers stay reproducible bit-for-bit through the fleet machinery


def simulate_pair(traces: np.ndarray, cfg: ReconfigConfig,
                  scenario: isa.SlotScenario, sched: SchedulerConfig,
                  total_steps: int = 400_000) -> PairResult:
    r = simulate_many(traces, cfg, scenario, sched, total_steps)
    return PairResult(r.cycles, r.instructions, r.slot_misses, r.switches)


def simulate_pair_batch(traces: np.ndarray, cfg: ReconfigConfig,
                        scenario: isa.SlotScenario, sched: SchedulerConfig,
                        total_steps: int = 400_000) -> PairResult:
    """traces: (B, P, N) — one-cell sweep over the pair lanes."""
    r = sweep_fleet(
        jnp.asarray(traces, jnp.int32), [cfg.miss_latency], scenario, sched,
        slot_counts=[cfg.num_slots], bs_cache_entries=cfg.bs_cache_entries,
        bs_miss_extra=cfg.bs_miss_extra, total_steps=total_steps)
    # squeeze the singleton slot-count / latency axes -> (B, P) like before
    return PairResult(r.cycles[:, 0, 0], r.instructions[:, 0, 0],
                      r.slot_misses[:, 0, 0], r.switches[:, 0, 0])


# ---------------------------------------------------------------------------
# Fixed-ISA analytic helpers (Fig. 4 baselines; pair variant for Fig. 7)
# ---------------------------------------------------------------------------


def fixed_fleet_cpi(mix: Mix, spec: isa.Spec, sched: SchedulerConfig,
                    program_index: int = 0) -> float:
    """CPI of a fixed-ISA machine inside a round-robin fleet (any P).

    The handler executes `handler_cycles` of base instructions once per
    quantum; amortised per original instruction that is
    handler * CPI / quantum — independent of how many programs share the
    core, since every program pays it once per own quantum.  Priority
    weights don't change CPI either (they change wall-clock share, not
    per-instruction cost).  With heterogeneous quanta, pass the program's
    index so its own quantum amortises the handler.
    """
    cpi = analytic_cpi(mix, spec)
    q = np.asarray(sched.quantum_cycles).reshape(-1)
    quantum = int(q[program_index if q.size > 1 else 0])
    return cpi * (1.0 + sched.handler_cycles / quantum)


# historical name from the pair-only simulator; the formula never depended
# on the fleet size, so the P=2 name is just an alias now
fixed_pair_cpi = fixed_fleet_cpi
