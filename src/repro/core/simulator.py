"""Cycle-approximate simulator of the FPGA-extended reconfigurable core.

Mirrors the paper's methodology (§V): the softcore supports all RV32IMF
instructions; the instruction disambiguator acts as an L0 cache over
reconfigurable slots and *adds latency* on slot misses, abstracting the
reconfiguration technology behind a configurable miss-latency constant
(10 / 50 / 250 cycles studied).  Two execution modes:

  * fixed-ISA machines (RV32I/IM/IF/IMF baselines of Fig. 4) — analytic:
    absent extensions expand to ABI soft routines; no slots, no misses;
  * the reconfigurable core (Fig. 6/7) — `lax.scan` over a synthesised
    instruction trace with exact-LRU disambiguator + bitstream-cache state.

Multi-programming (Fig. 7) adds a FreeRTOS-style round-robin scheduler with
a cycle quantum and a context-switch handler cost; slot state deliberately
persists across switches (the architecture's whole point — shared extensions
stay resident, §IV).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa, slots
from repro.core.traces import Mix, analytic_cpi  # re-export for callers

__all__ = [
    "ReconfigConfig", "SchedulerConfig", "SimResult", "PairResult",
    "simulate_single", "simulate_single_batch",
    "simulate_pair", "simulate_pair_batch",
    "analytic_cpi", "fixed_pair_cpi",
]


@dataclass(frozen=True)
class ReconfigConfig:
    """Reconfigurable-core parameters (paper §V-A, §V-D)."""

    num_slots: int
    miss_latency: int          # disambiguator-miss cycles (reconfig incl.)
    bs_cache_entries: int = 64  # bitstream-cache entries (>= tags: warm mode)
    bs_miss_extra: int = 100    # added cycles when the bitstream cache misses


@dataclass(frozen=True)
class SchedulerConfig:
    """Round-robin OS scheduler model (paper §V-B, §VI-C)."""

    quantum_cycles: int = 20_000
    handler_cycles: int = 150   # timer-interrupt + context-switch routine
                                # (incl. the 32 FP registers added in §V-B)


class SimResult(NamedTuple):
    cycles: jnp.ndarray
    instructions: jnp.ndarray
    slot_misses: jnp.ndarray
    bs_misses: jnp.ndarray

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


class PairResult(NamedTuple):
    cycles: jnp.ndarray        # (P,) attributed cycles (incl. handler)
    instructions: jnp.ndarray  # (P,)
    slot_misses: jnp.ndarray   # (P,)
    switches: jnp.ndarray      # () context switches

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


# ---------------------------------------------------------------------------
# Single-program reconfigurable core
# ---------------------------------------------------------------------------


def _step_tables(instr_tag: np.ndarray):
    hw = jnp.asarray(isa.INSTR_HW_CYCLES, jnp.int32)
    tags = jnp.asarray(instr_tag, jnp.int32)
    return hw, tags


@functools.partial(jax.jit, static_argnames=("num_slots", "bs_entries"))
def _simulate_single(trace, instr_tag, miss_latency, num_slots: int,
                     bs_entries: int, bs_miss_extra):
    hw, tags = _step_tables(instr_tag)
    init = (
        slots.init(num_slots),
        slots.init(bs_entries),
        jnp.int32(0),  # cycles
        jnp.int32(0),  # slot misses
        jnp.int32(0),  # bitstream-cache misses
    )

    def step(carry, ins):
        slot_st, bs_st, cycles, miss, bsmiss = carry
        tag = tags[ins]
        res = slots.lookup(slot_st, tag)
        # on a disambiguator miss the bitstream is fetched through the
        # bitstream cache; a miss there goes to the unified L2 (extra cost)
        bs_res = slots.lookup(bs_st, jnp.where(res.hit, jnp.int32(-1), tag))
        cost = hw[ins]
        cost = cost + jnp.where(res.hit, 0, miss_latency).astype(jnp.int32)
        cost = cost + jnp.where(res.hit | bs_res.hit, 0,
                                bs_miss_extra).astype(jnp.int32)
        return (
            res.state, bs_res.state, cycles + cost,
            miss + (~res.hit).astype(jnp.int32),
            bsmiss + (~(res.hit | bs_res.hit)).astype(jnp.int32),
        ), None

    (slot_st, bs_st, cycles, miss, bsmiss), _ = jax.lax.scan(step, init, trace)
    n = jnp.int32(trace.shape[0])
    return SimResult(cycles, n, miss, bsmiss)


def simulate_single(trace: np.ndarray, cfg: ReconfigConfig,
                    scenario: isa.SlotScenario) -> SimResult:
    return _simulate_single(
        jnp.asarray(trace, jnp.int32), scenario.instr_tag,
        jnp.int32(cfg.miss_latency), cfg.num_slots,
        cfg.bs_cache_entries, jnp.int32(cfg.bs_miss_extra))


def simulate_single_batch(traces: np.ndarray, miss_latencies: np.ndarray,
                          cfg: ReconfigConfig,
                          scenario: isa.SlotScenario) -> SimResult:
    """vmap over (trace, miss latency) lanes with a shared scenario."""
    fn = jax.vmap(
        lambda t, L: _simulate_single(
            t, scenario.instr_tag, L, cfg.num_slots,
            cfg.bs_cache_entries, jnp.int32(cfg.bs_miss_extra)))
    return fn(jnp.asarray(traces, jnp.int32),
              jnp.asarray(miss_latencies, jnp.int32))


# ---------------------------------------------------------------------------
# Multi-program (round-robin scheduler)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps"))
def _simulate_pair(traces, instr_tag, miss_latency, quantum, handler,
                   num_slots: int, bs_entries: int, bs_miss_extra,
                   total_steps: int):
    hw, tags = _step_tables(instr_tag)
    num_progs, trace_len = traces.shape

    class Carry(NamedTuple):
        slot_st: slots.SlotState
        bs_st: slots.SlotState
        cursors: jnp.ndarray   # (P,)
        active: jnp.ndarray    # ()
        q_cycles: jnp.ndarray  # ()
        cycles: jnp.ndarray    # (P,)
        instrs: jnp.ndarray    # (P,)
        misses: jnp.ndarray    # (P,)
        switches: jnp.ndarray  # ()

    init = Carry(
        slots.init(num_slots), slots.init(bs_entries),
        jnp.zeros((num_progs,), jnp.int32), jnp.int32(0), jnp.int32(0),
        jnp.zeros((num_progs,), jnp.int32),
        jnp.zeros((num_progs,), jnp.int32),
        jnp.zeros((num_progs,), jnp.int32),
        jnp.int32(0),
    )

    def step(c: Carry, _):
        p = c.active
        ins = traces[p, jnp.remainder(c.cursors[p], trace_len)]
        tag = tags[ins]
        res = slots.lookup(c.slot_st, tag)
        bs_res = slots.lookup(
            c.bs_st, jnp.where(res.hit, jnp.int32(-1), tag))
        cost = hw[ins]
        cost = cost + jnp.where(res.hit, 0, miss_latency).astype(jnp.int32)
        cost = cost + jnp.where(res.hit | bs_res.hit, 0,
                                bs_miss_extra).astype(jnp.int32)

        q = c.q_cycles + cost
        do_switch = q >= quantum
        # the outgoing program pays the interrupt-handler cycles, mirroring
        # the paper's observation that short quanta inflate all runtimes
        cost_p = cost + jnp.where(do_switch, handler, 0).astype(jnp.int32)

        return Carry(
            slot_st=res.state,
            bs_st=bs_res.state,
            cursors=c.cursors.at[p].add(1),
            active=jnp.where(do_switch, (p + 1) % num_progs, p),
            q_cycles=jnp.where(do_switch, 0, q),
            cycles=c.cycles.at[p].add(cost_p),
            instrs=c.instrs.at[p].add(1),
            misses=c.misses.at[p].add((~res.hit).astype(jnp.int32)),
            switches=c.switches + do_switch.astype(jnp.int32),
        ), None

    final, _ = jax.lax.scan(step, init, None, length=total_steps)
    return PairResult(final.cycles, final.instrs, final.misses,
                      final.switches)


def simulate_pair(traces: np.ndarray, cfg: ReconfigConfig,
                  scenario: isa.SlotScenario, sched: SchedulerConfig,
                  total_steps: int = 400_000) -> PairResult:
    return _simulate_pair(
        jnp.asarray(traces, jnp.int32), scenario.instr_tag,
        jnp.int32(cfg.miss_latency), jnp.int32(sched.quantum_cycles),
        jnp.int32(sched.handler_cycles), cfg.num_slots,
        cfg.bs_cache_entries, jnp.int32(cfg.bs_miss_extra), total_steps)


def simulate_pair_batch(traces: np.ndarray, cfg: ReconfigConfig,
                        scenario: isa.SlotScenario, sched: SchedulerConfig,
                        total_steps: int = 400_000) -> PairResult:
    """traces: (B, P, N) — vmap over pair lanes."""
    fn = jax.vmap(
        lambda t: _simulate_pair(
            t, scenario.instr_tag, jnp.int32(cfg.miss_latency),
            jnp.int32(sched.quantum_cycles), jnp.int32(sched.handler_cycles),
            cfg.num_slots, cfg.bs_cache_entries,
            jnp.int32(cfg.bs_miss_extra), total_steps))
    return fn(jnp.asarray(traces, jnp.int32))


# ---------------------------------------------------------------------------
# Fixed-ISA analytic helpers (Fig. 4 baselines; pair variant for Fig. 7)
# ---------------------------------------------------------------------------


def fixed_pair_cpi(mix: Mix, spec: isa.Spec, sched: SchedulerConfig) -> float:
    """CPI of a fixed-ISA machine inside a round-robin pair.

    The handler executes `handler_cycles` of base instructions once per
    quantum; amortised per original instruction that is
    handler * CPI / quantum.
    """
    cpi = analytic_cpi(mix, spec)
    return cpi * (1.0 + sched.handler_cycles / sched.quantum_cycles)
