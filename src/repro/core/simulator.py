"""Cycle-approximate simulator of the FPGA-extended reconfigurable core.

Mirrors the paper's methodology (§V): the softcore supports all RV32IMF
instructions; the instruction disambiguator acts as an L0 cache over
reconfigurable slots and *adds latency* on slot misses, abstracting the
reconfiguration technology behind a configurable miss-latency constant
(10 / 50 / 250 cycles studied).  Two execution modes:

  * fixed-ISA machines (RV32I/IM/IF/IMF baselines of Fig. 4) — analytic:
    absent extensions expand to ABI soft routines; no slots, no misses;
  * the reconfigurable core (Fig. 6/7) — `lax.scan` over a synthesised
    instruction trace with exact-LRU disambiguator + bitstream-cache state.

Multi-programming (Fig. 7) adds a FreeRTOS-style round-robin scheduler with
a cycle quantum and a context-switch handler cost; slot state deliberately
persists across switches (the architecture's whole point — shared extensions
stay resident, §IV).  The scheduler runs over arbitrary fleets of P programs
(`simulate_many`), each with its own slot taxonomy (per-program tag tables),
and `sweep_fleet` crosses {fleets x slot counts x miss latencies} in one
jitted vmap^3 — slot counts sweep dynamically by masking a max-size
disambiguator.  The paper's pair experiments are the P=2 special case.

Two execution paths serve the sweep entry points (`sweep_fleet`,
`simulate_single`, `simulate_single_batch`); a dispatcher picks per call:

  * **stack-distance fast path** (`repro.core.stackdist`): one Mattson pass
    per trace yields exact miss counts for every slot count at once, and
    cycles reconstruct affinely per miss latency — the {slot count x
    latency} grid collapses into post-processing.  Exact (bit-for-bit equal
    to the scan) iff the run is *unpreempted* (the quantum exceeds any
    reachable cycle count, so only program 0 runs and trace order is
    latency-independent) and the bitstream cache is *warm* (entries >=
    distinct tags, so it never evicts).  `stackdist_eligible` encodes both
    rules plus the no-overflow guard.
  * **`lax.scan` path**: the general cycle-by-cycle round-robin machine,
    used for preempted fleets and cold bitstream caches.  Its hot loop
    pre-gathers the per-program (tag, hw-cost) streams once per call
    (instead of a dependent double gather per step), fuses the
    disambiguator + bitstream lookups into one state update
    (`slots.lookup_fused`), and unrolls the scan body (`scan_unroll`).

Callers can force a path with `path="scan"`/`"stackdist"` (parity tests do);
the default `"auto"` routes eligible sweeps through stack distance.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa, slots, stackdist
from repro.core.traces import Mix, analytic_cpi  # re-export for callers

__all__ = [
    "ReconfigConfig", "SchedulerConfig", "SimResult", "PairResult",
    "FleetResult", "fleet_tag_table", "stackdist_eligible",
    "simulate_single", "simulate_single_batch",
    "simulate_many", "sweep_fleet",
    "simulate_pair", "simulate_pair_batch",
    "analytic_cpi", "fixed_pair_cpi", "fixed_fleet_cpi",
]

# default lax.scan unroll for the cycle-by-cycle path — exposed so callers
# (and benchmarks/perf_sweep.py, which sweeps it) can tune per backend
# without changing results (integer state updates are exact).  Tuned on CPU:
# un-vmapped scans gain ~10% at unroll=4, but the vmap^3 sweep loses badly
# to the duplicated loop body, so the shared default stays 1; accelerators
# with per-step dispatch overhead are where larger unrolls pay off.
SCAN_UNROLL = 1


@dataclass(frozen=True)
class ReconfigConfig:
    """Reconfigurable-core parameters (paper §V-A, §V-D)."""

    num_slots: int
    miss_latency: int          # disambiguator-miss cycles (reconfig incl.)
    bs_cache_entries: int = 64  # bitstream-cache entries (>= tags: warm mode)
    bs_miss_extra: int = 100    # added cycles when the bitstream cache misses


# quantum no run can reach: larger than any reachable cycle count, yet far
# enough below int32 overflow that the q_cycles accumulator stays safe.
# Use it (via SchedulerConfig.no_preempt()) for solo/unpreempted runs.
NO_PREEMPT_QUANTUM = 1 << 30


@dataclass(frozen=True)
class SchedulerConfig:
    """Round-robin OS scheduler model (paper §V-B, §VI-C)."""

    quantum_cycles: int = 20_000
    handler_cycles: int = 150   # timer-interrupt + context-switch routine
                                # (incl. the 32 FP registers added in §V-B)

    @classmethod
    def no_preempt(cls, handler_cycles: int = 150) -> "SchedulerConfig":
        """A scheduler that never fires — for solo-program references."""
        return cls(quantum_cycles=NO_PREEMPT_QUANTUM,
                   handler_cycles=handler_cycles)


class SimResult(NamedTuple):
    cycles: jnp.ndarray
    instructions: jnp.ndarray
    slot_misses: jnp.ndarray
    bs_misses: jnp.ndarray

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


class PairResult(NamedTuple):
    cycles: jnp.ndarray        # (P,) attributed cycles (incl. handler)
    instructions: jnp.ndarray  # (P,)
    slot_misses: jnp.ndarray   # (P,)
    switches: jnp.ndarray      # () context switches

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


# ---------------------------------------------------------------------------
# Single-program reconfigurable core
# ---------------------------------------------------------------------------


def stackdist_eligible(tag_row, *, quantum_cycles: int, bs_entries: int,
                       max_miss_latency: int, bs_miss_extra: int,
                       total_steps: int) -> bool:
    """True iff the stack-distance fast path is *exact* for this run.

    Three conditions (see module docstring and `repro.core.stackdist`):

    1. warm bitstream cache: `bs_entries` covers every distinct tag of the
       scheduled program (`tag_row` is program 0's instr->tag table), so the
       bitstream cache never evicts and each tag misses it exactly once;
    2. unpreempted: the quantum is the NO_PREEMPT sentinel or beyond, so
       trace order is latency-independent and no handler cycles accrue;
    3. no-overflow guard: even the worst-case per-step cost summed over
       `total_steps` stays below the quantum — the scan's q_cycles
       accumulator can provably never fire a switch (and int32 stays safe).
    """
    num_tags = int(np.max(tag_row)) + 1
    warm = bs_entries >= num_tags
    worst_step = (int(np.max(isa.INSTR_HW_CYCLES)) + int(max_miss_latency)
                  + int(bs_miss_extra))
    unpreempted = (quantum_cycles >= NO_PREEMPT_QUANTUM
                   and total_steps * worst_step < quantum_cycles)
    return warm and unpreempted


def _check_path(path: str, eligible: bool) -> str:
    if path not in ("auto", "stackdist", "scan"):
        raise ValueError(f"unknown path {path!r}")
    if path == "stackdist" and not eligible:
        raise ValueError(
            "stack-distance path requires an unpreempted run with a warm "
            "bitstream cache (see simulator.stackdist_eligible)")
    if path == "auto":
        path = "stackdist" if eligible else "scan"
    return path


def _simulate_single(trace, instr_tag, miss_latency, num_slots: int,
                     bs_entries: int, bs_miss_extra):
    """P=1 special case of the fleet scan: one program, never preempted.

    One cost model lives in `_fleet_step_fn`; the single-program path is a
    wrapper so disambiguator/bitstream accounting cannot drift between the
    Fig. 6 (single) and Fig. 7 (multi-program) experiments.
    """
    r = _simulate_fleet_impl(
        trace[None, :], instr_tag[None, :], miss_latency,
        jnp.int32(num_slots), jnp.int32(NO_PREEMPT_QUANTUM), jnp.int32(0),
        num_slots, bs_entries, bs_miss_extra, trace.shape[0])
    return SimResult(r.cycles[0], r.instructions[0], r.slot_misses[0],
                     r.bs_misses[0])


_simulate_single_jit = functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries"))(_simulate_single)


def _single_eligible(cfg: ReconfigConfig, scenario: isa.SlotScenario,
                     max_miss_latency: int, total_steps: int) -> bool:
    return stackdist_eligible(
        scenario.instr_tag, quantum_cycles=NO_PREEMPT_QUANTUM,
        bs_entries=cfg.bs_cache_entries, max_miss_latency=max_miss_latency,
        bs_miss_extra=cfg.bs_miss_extra, total_steps=total_steps)


def simulate_single(trace: np.ndarray, cfg: ReconfigConfig,
                    scenario: isa.SlotScenario,
                    path: str = "auto") -> SimResult:
    trace = jnp.asarray(trace, jnp.int32)
    eligible = _single_eligible(cfg, scenario, cfg.miss_latency,
                                trace.shape[0])
    if _check_path(path, eligible) == "stackdist":
        cycles, misses, bs = stackdist.lanes_unpreempted(
            trace[None, :], scenario.instr_tag, isa.INSTR_HW_CYCLES,
            jnp.int32(cfg.num_slots), jnp.asarray([cfg.miss_latency]),
            jnp.int32(cfg.bs_miss_extra),
            num_tags=max(scenario.num_tags, 1), total_steps=trace.shape[0])
        return SimResult(cycles[0], jnp.int32(trace.shape[0]), misses[0],
                         bs[0])
    return _simulate_single_jit(
        trace,
        jnp.asarray(scenario.instr_tag, jnp.int32),
        jnp.int32(cfg.miss_latency), num_slots=cfg.num_slots,
        bs_entries=cfg.bs_cache_entries,
        bs_miss_extra=jnp.int32(cfg.bs_miss_extra))


def simulate_single_batch(traces: np.ndarray, miss_latencies: np.ndarray,
                          cfg: ReconfigConfig,
                          scenario: isa.SlotScenario,
                          path: str = "auto") -> SimResult:
    """vmap over (trace, miss latency) lanes with a shared scenario.

    Eligible lanes (warm bitstream cache — a single program is never
    preempted) route through one stack-distance profile per lane instead of
    one `lax.scan` per lane."""
    traces = jnp.asarray(traces, jnp.int32)
    lats = jnp.asarray(miss_latencies, jnp.int32)
    eligible = _single_eligible(cfg, scenario,
                                int(np.max(np.asarray(miss_latencies))),
                                traces.shape[-1])
    if _check_path(path, eligible) == "stackdist":
        chunk = _stackdist_chunk(traces.shape[-1],
                                 max(scenario.num_tags, 1))
        outs = [
            stackdist.lanes_unpreempted(
                traces[i:i + chunk], scenario.instr_tag,
                isa.INSTR_HW_CYCLES, jnp.int32(cfg.num_slots),
                lats[i:i + chunk], jnp.int32(cfg.bs_miss_extra),
                num_tags=max(scenario.num_tags, 1),
                total_steps=traces.shape[-1])
            for i in range(0, traces.shape[0], chunk)]
        cycles, misses, bs = (jnp.concatenate(x) for x in zip(*outs))
        instrs = jnp.full(cycles.shape, traces.shape[-1], jnp.int32)
        return SimResult(cycles, instrs, misses, bs)
    tag = jnp.asarray(scenario.instr_tag, jnp.int32)
    fn = jax.vmap(
        lambda t, L: _simulate_single_jit(
            t, tag, L, num_slots=cfg.num_slots,
            bs_entries=cfg.bs_cache_entries,
            bs_miss_extra=jnp.int32(cfg.bs_miss_extra)))
    return fn(traces, lats)


# ---------------------------------------------------------------------------
# Multi-program (round-robin scheduler): the N-program fleet simulator
# ---------------------------------------------------------------------------


class FleetResult(NamedTuple):
    """Per-program counters of an N-program fleet run.

    Leading axes are whatever grid the caller swept (fleets / slot counts /
    miss latencies); the trailing axis is the program index within a fleet.
    """

    cycles: jnp.ndarray        # (..., P) attributed cycles (incl. handler)
    instructions: jnp.ndarray  # (..., P)
    slot_misses: jnp.ndarray   # (..., P)
    bs_misses: jnp.ndarray     # (..., P)
    switches: jnp.ndarray      # (...)  context switches

    @property
    def cpi(self):
        return self.cycles / jnp.maximum(self.instructions, 1)


def fleet_tag_table(scenarios, num_programs: int) -> np.ndarray:
    """(P, NUM_INSTRUCTIONS) per-program disambiguator-tag table.

    `scenarios` is either one `SlotScenario` shared by every program or a
    sequence of `num_programs` of them — per-program tables let an FM-class
    and an M-class program disagree about which opcodes are slotted (their
    binaries were compiled against different extension sets, paper §IV).
    """
    if isinstance(scenarios, isa.SlotScenario):
        return np.stack([scenarios.instr_tag] * num_programs)
    scenarios = list(scenarios)
    if len(scenarios) != num_programs:
        raise ValueError(
            f"{len(scenarios)} scenarios for {num_programs} programs")
    return np.stack([s.instr_tag for s in scenarios])


def _fleet_step_fn(ptags, pcosts, miss_latency, active_slots, quantum,
                   handler, bs_miss_extra):
    """Round-robin step over precomputed per-program (tag, cost) streams.

    `ptags`/`pcosts` are the (P, N) gathers `tags[p, traces[p, i]]` /
    `hw[traces[p, i]]` hoisted out of the step: the hot loop does two
    independent stream loads instead of a dependent double gather per cycle,
    and one fused disambiguator+bitstream update (`slots.lookup_fused`).
    """
    num_progs, trace_len = ptags.shape

    def step(c, _):
        p = c["active"]
        i = jnp.remainder(c["cursors"][p], trace_len)
        tag = ptags[p, i]
        # on a disambiguator miss the bitstream is fetched through the
        # bitstream cache; a miss there goes to the unified L2 (extra cost)
        slot_st, bs_st, hit, bs_hit = slots.lookup_fused(
            c["slot_st"], c["bs_st"], tag, active_slots)
        cost = pcosts[p, i]
        cost = cost + jnp.where(hit, 0, miss_latency).astype(jnp.int32)
        cost = cost + jnp.where(hit | bs_hit, 0,
                                bs_miss_extra).astype(jnp.int32)

        q = c["q_cycles"] + cost
        do_switch = q >= quantum
        # the outgoing program pays the interrupt-handler cycles, mirroring
        # the paper's observation that short quanta inflate all runtimes
        cost_p = cost + jnp.where(do_switch, handler, 0).astype(jnp.int32)

        # slot/bitstream state deliberately persists across the switch —
        # shared extensions stay resident (the architecture's point, §IV)
        return {
            "slot_st": slot_st,
            "bs_st": bs_st,
            "cursors": c["cursors"].at[p].add(1),
            "active": jnp.where(do_switch, (p + 1) % num_progs, p),
            "q_cycles": jnp.where(do_switch, 0, q),
            "cycles": c["cycles"].at[p].add(cost_p),
            "instrs": c["instrs"].at[p].add(1),
            "misses": c["misses"].at[p].add((~hit).astype(jnp.int32)),
            "bs_misses": c["bs_misses"].at[p].add(
                (~(hit | bs_hit)).astype(jnp.int32)),
            "switches": c["switches"] + do_switch.astype(jnp.int32),
        }, None

    return step


def _simulate_fleet_impl(traces, tag_table, miss_latency, active_slots,
                         quantum, handler, num_slots: int, bs_entries: int,
                         bs_miss_extra, total_steps: int,
                         scan_unroll: int = SCAN_UNROLL) -> FleetResult:
    """(P, N) traces + (P, num_opcodes) tags -> per-program FleetResult.

    `num_slots` is the *allocated* (static) disambiguator size;
    `active_slots` (traced) masks it down so slot count is a sweep axis.
    """
    hw = jnp.asarray(isa.INSTR_HW_CYCLES, jnp.int32)
    tags = jnp.asarray(tag_table, jnp.int32)
    num_progs = traces.shape[0]
    # hoist the per-step dependent double gather: precompute the per-program
    # tag and hw-cost streams once (the instruction id itself is only ever
    # used through these two tables)
    ptags = jnp.take_along_axis(tags, traces, axis=1)
    pcosts = hw[traces]

    init = {
        "slot_st": slots.init(num_slots),
        "bs_st": slots.init(bs_entries),
        "cursors": jnp.zeros((num_progs,), jnp.int32),
        "active": jnp.int32(0),
        "q_cycles": jnp.int32(0),
        "cycles": jnp.zeros((num_progs,), jnp.int32),
        "instrs": jnp.zeros((num_progs,), jnp.int32),
        "misses": jnp.zeros((num_progs,), jnp.int32),
        "bs_misses": jnp.zeros((num_progs,), jnp.int32),
        "switches": jnp.int32(0),
    }
    step = _fleet_step_fn(ptags, pcosts, miss_latency, active_slots,
                          quantum, handler, bs_miss_extra)
    final, _ = jax.lax.scan(step, init, None, length=total_steps,
                            unroll=scan_unroll)
    return FleetResult(final["cycles"], final["instrs"], final["misses"],
                       final["bs_misses"], final["switches"])


_simulate_fleet = functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps",
                              "scan_unroll"))(_simulate_fleet_impl)


def simulate_many(traces: np.ndarray, cfg: ReconfigConfig,
                  scenarios, sched: SchedulerConfig,
                  total_steps: int = 400_000,
                  scan_unroll: int = SCAN_UNROLL) -> FleetResult:
    """Round-robin fleet of P programs sharing one reconfigurable core.

    traces: (P, N) int32 instruction ids; `scenarios` is one shared
    `SlotScenario` or a length-P sequence (per-program slot taxonomies).
    """
    traces = jnp.asarray(traces, jnp.int32)
    table = fleet_tag_table(scenarios, traces.shape[0])
    return _simulate_fleet(
        traces, table, jnp.int32(cfg.miss_latency),
        jnp.int32(cfg.num_slots), jnp.int32(sched.quantum_cycles),
        jnp.int32(sched.handler_cycles), cfg.num_slots,
        cfg.bs_cache_entries, jnp.int32(cfg.bs_miss_extra), total_steps,
        scan_unroll)


@functools.partial(
    jax.jit, static_argnames=("num_slots", "bs_entries", "total_steps",
                              "scan_unroll"))
def _sweep_fleet(fleets, tag_table, miss_latencies, slot_counts, quantum,
                 handler, num_slots: int, bs_entries: int, bs_miss_extra,
                 total_steps: int, scan_unroll: int) -> FleetResult:
    def one(t, s, lat):
        return _simulate_fleet_impl(
            t, tag_table, lat, s, quantum, handler, num_slots, bs_entries,
            bs_miss_extra, total_steps, scan_unroll)

    f = jax.vmap(one, in_axes=(None, None, 0))   # miss-latency axis
    f = jax.vmap(f, in_axes=(None, 0, None))     # slot-count axis
    f = jax.vmap(f, in_axes=(0, None, None))     # fleet axis
    return f(fleets, slot_counts, miss_latencies)


# the distance profile materializes (total_steps, num_tags)-shaped int32
# temporaries per batched lane; cap chunk_size * total_steps * num_tags so
# the fast path's transient footprint stays bounded (~64 MB per temporary,
# a few alive at once) no matter how many fleets an eligible sweep batches
# or how fine the tag taxonomy is
_STACKDIST_CHUNK_ELEMS = 16_000_000


def _stackdist_chunk(total_steps: int, num_tags: int) -> int:
    return max(1, _STACKDIST_CHUNK_ELEMS
               // max(total_steps * max(num_tags, 1), 1))


def _sweep_fleet_stackdist(fleets, table, lats, counts, bs_miss_extra,
                           total_steps: int) -> FleetResult:
    """Assemble the scan-shaped FleetResult from one stack-distance pass.

    Only valid for eligible (unpreempted) runs: program 0 executes every
    step, programs 1..P-1 never get scheduled (their counters are zero in
    the scan too), and no switch ever fires.  The fleet axis is processed
    in memory-bounded chunks (at most two compiled shapes: full + tail).
    """
    num_progs = fleets.shape[1]
    num_tags = max(int(np.max(np.asarray(table[0]))) + 1, 1)
    chunk = _stackdist_chunk(total_steps, num_tags)
    grids = [
        stackdist.sweep_unpreempted(
            fleets[i:i + chunk, 0, :], table[0], isa.INSTR_HW_CYCLES,
            counts, lats, jnp.int32(bs_miss_extra), num_tags=num_tags,
            total_steps=total_steps)
        for i in range(0, fleets.shape[0], chunk)]
    cycles = jnp.concatenate([g.cycles for g in grids])
    slot_misses = jnp.concatenate([g.slot_misses for g in grids])
    bs_misses = jnp.concatenate([g.bs_misses for g in grids])
    b, k, l = cycles.shape
    zeros = jnp.zeros((b, k, l, num_progs), jnp.int32)
    return FleetResult(
        cycles=zeros.at[..., 0].set(cycles),
        instructions=zeros.at[..., 0].set(jnp.int32(total_steps)),
        slot_misses=zeros.at[..., 0].set(slot_misses[:, :, None]),
        bs_misses=zeros.at[..., 0].set(bs_misses[:, None, None]),
        switches=jnp.zeros((b, k, l), jnp.int32),
    )


def sweep_fleet(fleets: np.ndarray, miss_latencies, scenarios,
                sched: SchedulerConfig, *, slot_counts,
                bs_cache_entries: int = 64, bs_miss_extra: int = 100,
                total_steps: int = 400_000, path: str = "auto",
                scan_unroll: int = SCAN_UNROLL) -> FleetResult:
    """One call over the {fleets x slot counts x miss latencies} grid.

    fleets: (B, P, N) int32 traces.  Result axes: (B, K_slots, L_lat, P).

    Dispatch (see module docstring): eligible grids — unpreempted, warm
    bitstream cache (`stackdist_eligible`) — collapse the K x L grid into
    one stack-distance pass per fleet; everything else runs the jitted
    vmap^3 of `lax.scan`s, where slot counts sweep by masking one max-size
    disambiguator (`slots.lookup`'s `num_active`).  `path` forces a
    specific engine ("stackdist" raises if the grid is ineligible);
    both return bit-for-bit identical results on eligible grids.
    """
    fleets = jnp.asarray(fleets, jnp.int32)
    table = fleet_tag_table(scenarios, fleets.shape[1])
    counts = jnp.asarray(slot_counts, jnp.int32).reshape(-1)
    lats = jnp.asarray(miss_latencies, jnp.int32).reshape(-1)
    eligible = stackdist_eligible(
        table[0], quantum_cycles=sched.quantum_cycles,
        bs_entries=bs_cache_entries,
        max_miss_latency=int(np.max(np.asarray(miss_latencies))),
        bs_miss_extra=bs_miss_extra, total_steps=total_steps)
    if _check_path(path, eligible) == "stackdist":
        return _sweep_fleet_stackdist(fleets, table, lats, counts,
                                      bs_miss_extra, total_steps)
    s_max = int(np.max(np.asarray(slot_counts)))
    return _sweep_fleet(
        fleets, table, lats, counts, jnp.int32(sched.quantum_cycles),
        jnp.int32(sched.handler_cycles), s_max, bs_cache_entries,
        jnp.int32(bs_miss_extra), total_steps, scan_unroll)


# --- pair path: the P=2 special case, kept as thin wrappers so the Fig. 7
# --- numbers stay reproducible bit-for-bit through the fleet machinery


def simulate_pair(traces: np.ndarray, cfg: ReconfigConfig,
                  scenario: isa.SlotScenario, sched: SchedulerConfig,
                  total_steps: int = 400_000) -> PairResult:
    r = simulate_many(traces, cfg, scenario, sched, total_steps)
    return PairResult(r.cycles, r.instructions, r.slot_misses, r.switches)


def simulate_pair_batch(traces: np.ndarray, cfg: ReconfigConfig,
                        scenario: isa.SlotScenario, sched: SchedulerConfig,
                        total_steps: int = 400_000) -> PairResult:
    """traces: (B, P, N) — one-cell sweep over the pair lanes."""
    r = sweep_fleet(
        jnp.asarray(traces, jnp.int32), [cfg.miss_latency], scenario, sched,
        slot_counts=[cfg.num_slots], bs_cache_entries=cfg.bs_cache_entries,
        bs_miss_extra=cfg.bs_miss_extra, total_steps=total_steps)
    # squeeze the singleton slot-count / latency axes -> (B, P) like before
    return PairResult(r.cycles[:, 0, 0], r.instructions[:, 0, 0],
                      r.slot_misses[:, 0, 0], r.switches[:, 0, 0])


# ---------------------------------------------------------------------------
# Fixed-ISA analytic helpers (Fig. 4 baselines; pair variant for Fig. 7)
# ---------------------------------------------------------------------------


def fixed_fleet_cpi(mix: Mix, spec: isa.Spec, sched: SchedulerConfig) -> float:
    """CPI of a fixed-ISA machine inside a round-robin fleet (any P).

    The handler executes `handler_cycles` of base instructions once per
    quantum; amortised per original instruction that is
    handler * CPI / quantum — independent of how many programs share the
    core, since every program pays it once per own quantum.
    """
    cpi = analytic_cpi(mix, spec)
    return cpi * (1.0 + sched.handler_cycles / sched.quantum_cycles)


# historical name from the pair-only simulator; the formula never depended
# on the fleet size, so the P=2 name is just an alias now
fixed_pair_cpi = fixed_fleet_cpi
