"""Multi-program experiment construction (paper §VI-C).

The paper pairs benchmarks under FreeRTOS's round-robin scheduler:

  * C(5,2) = 10 pairs among the five "improved by F and M" benchmarks, and
  * 5 x 8 = 40 pairs of one FM-class with one M-only-class benchmark,

for 50 combinations total; pairs that do not compete for slots (M-only with
M-only, or anything with an insensitive benchmark) are omitted, because every
granularity scenario fits the whole "M" extension.

`SchedulerConfig` itself lives in `repro.core.simulator`; this module builds
the pair set and the per-pair trace tensors.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core import traces
from repro.core.simulator import SchedulerConfig  # noqa: F401  (re-export)


def make_pairs() -> list[tuple[str, str]]:
    """The paper's 50 benchmark combinations (§VI-C)."""
    fm = traces.FM_BENCHES
    m = traces.M_BENCHES
    pairs = list(itertools.combinations(fm, 2))          # 10
    pairs += [(a, b) for a in fm for b in m]             # 40
    assert len(pairs) == 50
    return pairs


def fm_fm_pairs() -> list[tuple[str, str]]:
    return list(itertools.combinations(traces.FM_BENCHES, 2))


def fm_m_pairs() -> list[tuple[str, str]]:
    return [(a, b) for a in traces.FM_BENCHES for b in traces.M_BENCHES]


def pair_traces(pairs: list[tuple[str, str]], length: int = 150_000,
                seed: int = 0) -> np.ndarray:
    """(B, 2, N) int32 trace tensor for `simulate_pair_batch`.

    Traces are cached per benchmark (they are deterministic per seed).
    """
    cache: dict[str, np.ndarray] = {}

    def get(name: str) -> np.ndarray:
        if name not in cache:
            cache[name] = traces.build_trace(name, length, seed)
        return cache[name]

    return np.stack([np.stack([get(a), get(b)]) for a, b in pairs])
