"""Multi-program experiment construction (paper §VI-C, generalised to fleets).

The paper pairs benchmarks under FreeRTOS's round-robin scheduler:

  * C(5,2) = 10 pairs among the five "improved by F and M" benchmarks, and
  * 5 x 8 = 40 pairs of one FM-class with one M-only-class benchmark,

for 50 combinations total; pairs that do not compete for slots (M-only with
M-only, or anything with an insensitive benchmark) are omitted, because every
granularity scenario fits the whole "M" extension.

`make_fleets(k)` extends the same construction to k-way mixes: C(5,k)
all-FM fleets plus C(5,k-1) x 8 fleets with one M-only member — slot
competition is guaranteed because every fleet carries at least k-1 FM
working sets.  `make_pairs()` is exactly `make_fleets(2)`.

`SchedulerConfig` itself lives in `repro.core.simulator`; this module builds
the fleet sets and the (B, P, N) trace tensors for
`repro.core.simulator.sweep_fleet` / `simulate_pair_batch`.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core import traces
from repro.core.simulator import SchedulerConfig  # noqa: F401  (re-export)


def make_fleets(k: int, fm: list[str] | None = None,
                m: list[str] | None = None) -> list[tuple[str, ...]]:
    """All slot-competing k-way benchmark fleets (k >= 2).

    C(|FM|, k) all-FM fleets, then C(|FM|, k-1) x |M| fleets of FM-class
    programs joined by one M-only program — |fleets| = C(|FM|, k) +
    C(|FM|, k-1) * |M|, and every fleet carries at least k-1 FM working
    sets, which is what guarantees slot competition.  For k=2 with the
    default pools this is the paper's 50 combinations in their original
    order.  `fm`/`m` override the benchmark pools (property tests and
    custom tenant studies); programs never repeat within a fleet.
    """
    if k < 2:
        raise ValueError(f"fleets need at least 2 programs, got k={k}")
    fm = traces.FM_BENCHES if fm is None else list(fm)
    m = traces.M_BENCHES if m is None else list(m)
    if k - 1 > len(fm):
        raise ValueError(
            f"k={k} fleets need at least k-1={k - 1} FM-class benchmarks, "
            f"pool has {len(fm)}")
    fleets = list(itertools.combinations(fm, k))
    fleets += [c + (b,) for c in itertools.combinations(fm, k - 1)
               for b in m]
    return fleets


def make_pairs() -> list[tuple[str, str]]:
    """The paper's 50 benchmark combinations (§VI-C) — the P=2 fleet set."""
    pairs = make_fleets(2)
    assert len(pairs) == 50
    return pairs


def fm_fm_pairs() -> list[tuple[str, str]]:
    return list(itertools.combinations(traces.FM_BENCHES, 2))


def fm_m_pairs() -> list[tuple[str, str]]:
    return [(a, b) for a in traces.FM_BENCHES for b in traces.M_BENCHES]


def fleet_traces(fleets: list[tuple[str, ...]], length: int = 150_000,
                 seed: int = 0) -> np.ndarray:
    """(B, P, N) int32 trace tensor for `sweep_fleet`.

    Every fleet must have the same size P.  Traces are cached per benchmark
    (they are deterministic per seed).
    """
    sizes = {len(f) for f in fleets}
    if len(sizes) != 1:
        raise ValueError(f"mixed fleet sizes {sorted(sizes)}")
    cache: dict[str, np.ndarray] = {}

    def get(name: str) -> np.ndarray:
        if name not in cache:
            cache[name] = traces.build_trace(name, length, seed)
        return cache[name]

    return np.stack([np.stack([get(n) for n in fleet]) for fleet in fleets])


def pair_traces(pairs: list[tuple[str, str]], length: int = 150_000,
                seed: int = 0) -> np.ndarray:
    """(B, 2, N) trace tensor — the P=2 special case of `fleet_traces`."""
    return fleet_traces(pairs, length, seed)
