"""Stacked Mattson pass: exact cold-bitstream sweeps for unpreempted runs.

The unpreempted engine (`repro.core.stackdist`) needs a *warm* bitstream
cache: warmth makes bitstream misses coincide with cold touches, so the
bitstream term is a slot-count-independent constant and the whole
{slot count x miss latency} grid reconstructs affinely from one distance
profile.  A cold (undersized) bitstream cache breaks that — which
entries it evicts depends on the slot-miss sequence, which depends on
the slot count — and until now such runs fell back to the per-access
`lax.scan`.

They do not need to.  For an unpreempted run, the *access order* is
fixed (no context switches), so at each slot count ``S`` the
disambiguator's miss subsequence — the only accesses that touch the
bitstream cache — is itself a fully determined LRU reference stream.
Stack one more Mattson pass on top of it:

  1. the first pass gives every access's stack distance ``dist`` in the
     tag stream, hence the slot-miss indicator per slot count
     (``miss_S = slotted & (cold | dist >= S)``);
  2. masking the occurrence matrix down to miss positions and running a
     second cummax gives each miss's stack distance *within the miss
     subsequence* — exactly the bitstream cache's LRU stack distance,
     since the bitstream cache sees precisely the miss stream;
  3. a distance histogram per slot count then answers every bitstream
     capacity ``E`` at once:

         bs_misses(S, E) = cold + #{reuse misses with dist2 >= E}
         cycles(S, L, E, X) = base + slot_misses(S) * L
                                   + bs_misses(S, E) * X

     (``cold`` is capacity-independent: a tag's first touch is always
     both a slot miss and a compulsory bitstream miss, so the bitstream
     cold count equals the slot cold count at every S and E).

All arithmetic is int32 like the scan, so results are bit-for-bit
identical whenever the run is unpreempted and overflow-safe
(`repro.core.simulator.stackdist_cold_eligible` guards both; parity is
pinned by tests/test_resume_fastpath.py).  This turns e.g.
`benchmarks/bitstream_study.py`'s capacity x penalty grid — previously
one full scan per cell — into a single jitted call.

Like its siblings, this module is deliberately generic: it knows nothing
about the RISC-V alphabet; callers pass per-opcode tag/cost tables.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.stackdist import _stream

__all__ = ["ColdGrid", "lanes_cold", "sweep_cold"]


class ColdGrid(NamedTuple):
    """Exact counters over the {slot count x latency x bitstream capacity
    x bitstream penalty} grid of one unpreempted run."""

    cycles: jnp.ndarray       # (..., K, L, E, X) int32
    slot_misses: jnp.ndarray  # (..., K) int32
    bs_misses: jnp.ndarray    # (..., K, E) int32


def _cold_one(tags: jnp.ndarray, costs: jnp.ndarray,
              slot_counts: jnp.ndarray, miss_latencies: jnp.ndarray,
              bs_entries: jnp.ndarray, bs_miss_extras: jnp.ndarray,
              num_tags: int) -> ColdGrid:
    """(N,) tag stream (-1 = unslotted) + (N,) hw costs -> ColdGrid."""
    n = tags.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    tag_ids = jnp.arange(num_tags, dtype=jnp.int32)
    match = tags[:, None] == tag_ids[None, :]
    occurrence = jnp.where(match, idx[:, None], jnp.int32(-1))
    last_pos = jax.lax.cummax(occurrence, axis=0)
    prev = jnp.concatenate(
        [jnp.full((1, num_tags), -1, jnp.int32), last_pos[:-1]], axis=0)
    slotted = tags >= 0
    safe = jnp.clip(tags, 0)   # clamp -1 so the gather stays in-bounds
    prev_self = jnp.take_along_axis(prev, safe[:, None], axis=1)[:, 0]
    cold = slotted & (prev_self < 0)
    dist = jnp.sum(prev > prev_self[:, None], axis=1).astype(jnp.int32)

    def per_count(s):
        # the miss subsequence at slot count s, re-profiled as its own
        # LRU reference stream (the bitstream cache sees exactly it)
        miss = slotted & (cold | (dist >= s))
        cm2 = jax.lax.cummax(
            jnp.where(match & miss[:, None], idx[:, None], jnp.int32(-1)),
            axis=0)
        prev2 = jnp.concatenate(
            [jnp.full((1, num_tags), -1, jnp.int32), cm2[:-1]], axis=0)
        prev2_self = jnp.take_along_axis(prev2, safe[:, None], axis=1)[:, 0]
        dist2 = jnp.sum(prev2 > prev2_self[:, None], axis=1).astype(jnp.int32)
        reuse = miss & (prev2_self >= 0)
        bucket = jnp.where(reuse, dist2, jnp.int32(num_tags))
        hist2 = jnp.bincount(bucket, length=num_tags + 1)[:num_tags]
        return jnp.sum(miss).astype(jnp.int32), hist2.astype(jnp.int32)

    slot_misses, hist2 = jax.vmap(per_count)(
        jnp.asarray(slot_counts, jnp.int32))        # (K,), (K, num_tags)
    cold_count = jnp.sum(cold).astype(jnp.int32)
    base = jnp.sum(costs).astype(jnp.int32)

    # tail2[s, e] = reuse misses at slot count s with dist2 >= e
    tail2 = jnp.concatenate(
        [jnp.cumsum(hist2[:, ::-1], axis=1)[:, ::-1].astype(jnp.int32),
         jnp.zeros((hist2.shape[0], 1), jnp.int32)], axis=1)
    caps = jnp.clip(jnp.asarray(bs_entries, jnp.int32), 0, num_tags)
    bs_misses = cold_count + tail2[:, caps]          # (K, E)
    lats = jnp.asarray(miss_latencies, jnp.int32)
    extras = jnp.asarray(bs_miss_extras, jnp.int32)
    cycles = (base
              + slot_misses[:, None, None, None] * lats[None, :, None, None]
              + bs_misses[:, None, :, None] * extras[None, None, None, :])
    return ColdGrid(cycles=cycles, slot_misses=slot_misses,
                    bs_misses=bs_misses)


@functools.partial(jax.jit, static_argnames=("num_tags", "total_steps"))
def sweep_cold(traces: jnp.ndarray, instr_tag: jnp.ndarray,
               instr_costs: jnp.ndarray, slot_counts: jnp.ndarray,
               miss_latencies: jnp.ndarray, bs_entries: jnp.ndarray,
               bs_miss_extras: jnp.ndarray, *, num_tags: int,
               total_steps: int) -> ColdGrid:
    """Solo-program sweep: (B, N) traces -> ColdGrid with (B, K, L, E, X)
    cycles.  One stacked profile per (trace, slot count) pair serves the
    whole latency x capacity x penalty sub-grid affinely."""
    tags, costs = _stream(jnp.asarray(traces, jnp.int32), instr_tag,
                          instr_costs, total_steps)
    return jax.vmap(
        lambda t, c: _cold_one(t, c, slot_counts, miss_latencies,
                               bs_entries, bs_miss_extras, num_tags)
    )(tags, costs)


@functools.partial(jax.jit, static_argnames=("num_tags", "total_steps"))
def lanes_cold(traces: jnp.ndarray, instr_tag: jnp.ndarray,
               instr_costs: jnp.ndarray, num_slots, miss_latencies,
               bs_entries, bs_miss_extra, *, num_tags: int,
               total_steps: int):
    """Paired (trace, latency) lanes at one slot count / capacity /
    penalty — the `simulate_single_batch` shape.  Returns
    (cycles, slot_misses, bs_misses), each (B,) int32."""
    tags, costs = _stream(jnp.asarray(traces, jnp.int32), instr_tag,
                          instr_costs, total_steps)
    lats = jnp.asarray(miss_latencies, jnp.int32).reshape(-1)

    def one(t, c, lat):
        g = _cold_one(t, c, jnp.reshape(num_slots, (1,)),
                      jnp.reshape(lat, (1,)), jnp.reshape(bs_entries, (1,)),
                      jnp.reshape(bs_miss_extra, (1,)), num_tags)
        return g.cycles[0, 0, 0, 0], g.slot_misses[0], g.bs_misses[0, 0]

    return jax.vmap(one)(tags, costs, lats)
