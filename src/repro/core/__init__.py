"""The paper's primary contribution: the FPGA-extended modified Harvard
architecture, as (a) a faithful cycle-approximate simulation stack
(isa/traces/slots/simulator/scheduler/bitstream) and (b) its TPU-native
adaptation, slot-resident expert serving (expert_slots).  See DESIGN.md §2.
"""
from repro.core import (  # noqa: F401
    bitstream, expert_slots, isa, scheduler, simulator, slots, stackdist,
    stackdist_interleaved, traces,
)
