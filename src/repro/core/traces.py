"""Embench-calibrated workload model and dynamic-trace synthesis.

The paper evaluates an adapted Embench 1.0 suite (22 benchmarks, §V-C) on a
RISC-V softcore.  Embench itself cannot execute in this environment, so we
model each benchmark as

  * a *dynamic instruction mix*: fractions of M-class / F-class operations
    (with per-group weights), solved so the analytic fixed-ISA model
    (`repro.core.simulator.analytic_cpi`) reproduces the paper's published
    speedups (Fig. 4/5) exactly where stated and plausible class-consistent
    values elsewhere — every number of the latter kind is marked
    `synthesized=True` below and called out in EXPERIMENTS.md;
  * a *loop structure* used to synthesise instruction-level traces for the
    slot simulator: a repeating superblock with (a) hot F-group runs, (b)
    interleaved index/address `mul` events inside the hot loop, and (c)
    periodic "cold" group events (pivot divisions, conversions, compares),
    which is what produces the three miss regimes the paper measures across
    its slot-granularity scenarios (§V-D, Fig. 6).

Traces are synthesised with a seeded numpy Generator at *instruction*
granularity over the `repro.core.isa` alphabet, then consumed by jitted
`lax.scan` simulators.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core import isa

# ---------------------------------------------------------------------------
# Benchmark catalogue
# ---------------------------------------------------------------------------

FM_CLASS = "improved_by_F_and_M"
M_CLASS = "improved_by_M"
INSENSITIVE = "insensitive"


@dataclass(frozen=True)
class BenchSpec:
    name: str
    cls: str
    # calibration targets: speedup of RV32IM / RV32IF over RV32I.
    target_speedup_m: float
    target_speedup_f: float
    # nominal RV32IMF runtime in Mcycles (Fig. 4 bar magnitude)
    imf_mcycles: float
    # per-extension group weight vectors (normalised inside solve_mix)
    w_m: dict = field(default_factory=lambda: {"mul": 1.0})
    w_f: dict = field(default_factory=dict)
    # loop-structure knobs for trace synthesis
    hot_f_groups: tuple = ()       # groups kept hot in the inner loop
    cold_event_period: int = 64    # instrs between cold-group events
    f_run_len: int = 4             # contiguous F ops per burst
    mul_period: int = 12           # instrs between index-mul events (FM cls)
    sporadic: bool = False         # F usage is spread out (st, wikisort)
    # if set, pin the M-op dynamic fraction (index/RNG integer mul rate seen
    # in the compiled loop) and let the RV32IM speedup emerge from the model
    x_m_fixed: float | None = None
    # whether targets are invented (not stated in the paper text)
    synthesized: bool = True


def _b(*args, **kw) -> BenchSpec:
    return BenchSpec(*args, **kw)


# The five FM-class benchmarks (paper Fig. 5).  minver's 27.5x "F" speedup and
# wikisort's 2.9x collective RV32IMF speedup are stated in the text; the rest
# are class-consistent synthesized targets.
_FM = [
    _b("minver", FM_CLASS, 0.0, 27.5, 77.0,
       w_m={"mul": 0.9, "div": 0.1},
       w_f={"fadd": 0.28, "fmul": 0.33, "fdiv": 0.20, "fcmp": 0.09,
            "fcvt": 0.04, "fma": 0.06},
       hot_f_groups=("fadd", "fmul"), cold_event_period=56,
       f_run_len=4, mul_period=11, x_m_fixed=0.006, synthesized=False),
    _b("wikisort", FM_CLASS, 2.0, 1.55, 180.0,
       w_m={"mul": 0.85, "div": 0.15},
       w_f={"fcmp": 0.55, "fadd": 0.27, "fmul": 0.18},
       hot_f_groups=("fcmp", "fadd"), cold_event_period=90,
       f_run_len=1, mul_period=14, sporadic=True, synthesized=False),
    _b("st", FM_CLASS, 0.0, 4.0, 120.0,
       w_m={"mul": 1.0},
       w_f={"fadd": 0.45, "fmul": 0.35, "fdiv": 0.05, "fsqrt": 0.02,
            "fcmp": 0.05, "fcvt": 0.08},
       hot_f_groups=("fadd", "fmul"), cold_event_period=70,
       f_run_len=1, mul_period=13, sporadic=True, x_m_fixed=0.090),
    _b("nbody", FM_CLASS, 0.0, 4.5, 310.0,
       w_m={"mul": 1.0},
       w_f={"fadd": 0.35, "fmul": 0.38, "fdiv": 0.08, "fsqrt": 0.05,
            "fma": 0.14},
       hot_f_groups=("fadd", "fmul"), cold_event_period=60,
       f_run_len=1, mul_period=12, x_m_fixed=0.085),
    _b("cubic", FM_CLASS, 0.0, 5.0, 90.0,
       w_m={"mul": 0.95, "div": 0.05},
       w_f={"fadd": 0.30, "fmul": 0.33, "fdiv": 0.15, "fcvt": 0.05,
            "fma": 0.10, "fsqrt": 0.07},
       hot_f_groups=("fadd", "fmul"), cold_event_period=80,
       f_run_len=1, mul_period=12, x_m_fixed=0.090),
]

# Eight M-only benchmarks; matmult-int's 4.6x is stated in the text.
_M = [
    _b("matmult-int", M_CLASS, 4.6, 1.0, 150.0,
       w_m={"mul": 1.0}, f_run_len=1, mul_period=8, synthesized=False),
    _b("crc32", M_CLASS, 1.35, 1.0, 30.0, w_m={"mul": 1.0}, mul_period=24),
    _b("qrduino", M_CLASS, 1.8, 1.0, 70.0,
       w_m={"mul": 0.8, "rem": 0.2}, mul_period=16),
    _b("primecount", M_CLASS, 2.1, 1.0, 250.0,
       w_m={"div": 0.45, "rem": 0.45, "mul": 0.10}, mul_period=14),
    _b("ud", M_CLASS, 2.4, 1.0, 45.0,
       w_m={"mul": 0.75, "div": 0.25}, mul_period=12),
    _b("aha-mont64", M_CLASS, 3.0, 1.0, 160.0,
       w_m={"mul": 1.0}, mul_period=9),
    _b("tarfind", M_CLASS, 1.5, 1.0, 60.0, w_m={"mul": 1.0}, mul_period=22),
    _b("edn", M_CLASS, 3.4, 1.0, 110.0, w_m={"mul": 1.0}, mul_period=9),
]

# Nine insensitive benchmarks (control-heavy; negligible M/F usage).
_INS = [
    _b(n, INSENSITIVE, 1.0, 1.0, mc, w_m={"mul": 1.0}, mul_period=400)
    for n, mc in [
        ("md5sum", 25.0), ("huffbench", 95.0), ("nettle-aes", 140.0),
        ("nettle-sha256", 85.0), ("nsichneu", 55.0), ("picojpeg", 210.0),
        ("sglib-combined", 130.0), ("slre", 75.0), ("statemate", 20.0),
    ]
]

BENCHES: dict[str, BenchSpec] = {b.name: b for b in _FM + _M + _INS}
FM_BENCHES = [b.name for b in _FM]
M_BENCHES = [b.name for b in _M]
INSENSITIVE_BENCHES = [b.name for b in _INS]

assert len(BENCHES) == 22


# ---------------------------------------------------------------------------
# Mix solving (fixed-ISA analytic model -> paper Fig. 4 targets)
# ---------------------------------------------------------------------------


def _group_vec(weights: dict) -> np.ndarray:
    v = np.zeros(isa.NUM_GROUPS)
    total = sum(weights.values())
    for g, w in weights.items():
        v[isa.GROUP_ID[g]] = w / total
    return v


@dataclass(frozen=True)
class Mix:
    """Solved dynamic instruction mix: fraction per isa group (sums to 1)."""

    bench: str
    frac: np.ndarray  # (NUM_GROUPS,) fractions over groups; [0] is base

    @property
    def x_m(self) -> float:
        return float(sum(self.frac[isa.GROUP_ID[g]] for g in isa.M_GROUPS))

    @property
    def x_f(self) -> float:
        return float(sum(self.frac[isa.GROUP_ID[g]] for g in isa.F_GROUPS))


def analytic_cpi(mix: Mix, spec: isa.Spec) -> float:
    """Cycles per (original RV32IMF) instruction under a fixed-ISA machine."""
    return float(mix.frac @ spec.group_cost())


def solve_mix(bench: BenchSpec) -> Mix:
    """Solve (x_m, x_f) so RV32IM/RV32IF speedups over RV32I hit the targets.

    Linear system: with per-extension aggregate costs a_* (M groups) and b_*
    (F groups) under each spec,
        T_I  = 1 + x_m (a_I - 1) + x_f (b_I - 1)
        T_IM = 1 + x_m (a_M - 1) + x_f (b_M - 1)
        T_IF = 1 + x_m (a_I - 1) + x_f (b_F - 1)
    and s_m T_IM = T_I,  s_f T_IF = T_I.
    """
    wm = _group_vec(bench.w_m)
    wf = _group_vec(bench.w_f) if bench.w_f else np.zeros(isa.NUM_GROUPS)

    def agg(vec, cost):
        s = vec.sum()
        return float(vec @ cost) / s if s else 1.0

    a_i = agg(wm, isa.SOFT_ON_I)
    a_m = agg(wm, isa.GROUP_HW_CYCLES)
    b_i = agg(wf, isa.SOFT_ON_I)
    b_m = agg(wf, isa.SOFT_ON_M)
    b_f = agg(wf, isa.GROUP_HW_CYCLES)

    s_m, s_f = bench.target_speedup_m, bench.target_speedup_f
    if not bench.w_f:  # M-only / insensitive: x_f = 0, closed form
        if s_m <= 1.0:
            x_m = 0.003 if bench.cls == INSENSITIVE else 0.0
        else:
            x_m = (s_m - 1.0) / ((a_i - 1.0) - s_m * (a_m - 1.0))
        x_f = 0.0
    elif bench.x_m_fixed is not None:
        # pin x_m to the compiled loop's integer-mul rate; solve x_f so the
        # RV32IF speedup hits s_f; the RV32IM speedup then *emerges*
        x_m = bench.x_m_fixed
        x_f = ((s_f - 1.0) * (1.0 + x_m * (a_i - 1.0))
               / ((b_i - 1.0) - s_f * (b_f - 1.0)))
    else:
        # rows: [T_I - s_m T_IM = 0], [T_I - s_f T_IF = 0]
        mat = np.array([
            [(a_i - 1.0) - s_m * (a_m - 1.0), (b_i - 1.0) - s_m * (b_m - 1.0)],
            [(a_i - 1.0) * (1.0 - s_f), (b_i - 1.0) - s_f * (b_f - 1.0)],
        ])
        rhs = np.array([s_m - 1.0, s_f - 1.0])
        x_m, x_f = np.linalg.solve(mat, rhs)
    x_m = float(np.clip(x_m, 0.0, 0.45))
    x_f = float(np.clip(x_f, 0.0, 0.45))

    frac = wm * x_m + wf * x_f
    frac[isa.GROUP_ID["base"]] = 1.0 - x_m - x_f
    return Mix(bench=bench.name, frac=frac)


MIXES: dict[str, Mix] = {}


def mix_of(name: str) -> Mix:
    if name not in MIXES:
        MIXES[name] = solve_mix(BENCHES[name])
    return MIXES[name]


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------

# instruction alternatives per group, cycled to create instruction-level
# (scenario-1) tag variety matching shared-logic reality (§V-D)
_GROUP_MEMBERS = {
    "mul": ["mul", "mulhu"],
    "div": ["div", "divu"],
    "rem": ["rem", "remu"],
    "fadd": ["fadd.s", "fsub.s"],
    "fmul": ["fmul.s"],
    "fdiv": ["fdiv.s"],
    "fcmp": ["flt.s", "fle.s"],
    "fsqrt": ["fsqrt.s"],
    "fcvt": ["fcvt.s.w"],
    "fma": ["fmadd.s", "fmsub.s"],
}


def build_trace(name: str, length: int = 200_000, seed: int = 0) -> np.ndarray:
    """Synthesise an instruction-id trace (int32, values in isa alphabet).

    Structure per superblock (~cold_event_period instrs):
      base filler | hot-F runs (f_run_len) | interleaved mul events
      (every mul_period) | one cold-group event.
    Counts are scaled so the stationary mix matches `mix_of(name)`.
    """
    bench = BENCHES[name]
    mix = mix_of(name)
    return paint_trace(
        mix.frac, length=length, seed_key=f"{name}:{seed}",
        hot_f_groups=bench.hot_f_groups,
        cold_event_period=bench.cold_event_period,
        f_run_len=bench.f_run_len, sporadic=bench.sporadic)


def paint_trace(frac: np.ndarray, *, length: int, seed_key: str,
                hot_f_groups: tuple = (), cold_event_period: int = 64,
                f_run_len: int = 4, sporadic: bool = False) -> np.ndarray:
    """Paint a (NUM_GROUPS,) stationary mix onto an instruction-id trace.

    This is the loop-structure painter behind `build_trace`, exposed so
    other mix sources (the model-zoo lowering in `repro.workloads`) share
    the exact same process-deterministic contract: the numpy Generator is
    seeded from ``crc32(seed_key)`` — crc32, not ``hash()``, because str
    hashing is PYTHONHASHSEED-randomised and traces must be identical
    across processes (golden pins, PR-over-PR benchmarks).
    """
    rng = np.random.default_rng(zlib.crc32(seed_key.encode()))

    sb_len = max(int(cold_event_period), 24)
    hot = [g for g in hot_f_groups if frac[isa.GROUP_ID[g]] > 0]
    cold = [g for g in isa.F_GROUPS
            if g not in hot and frac[isa.GROUP_ID[g]] > 0]
    m_present = [g for g in isa.M_GROUPS if frac[isa.GROUP_ID[g]] > 0]

    member_cycler = {g: 0 for g in _GROUP_MEMBERS}

    def run_of(g: str, count: int) -> list[int]:
        # one member per *event* (a compiled loop body reuses the same
        # instruction); the member rotates between events, which is what
        # gives scenario 1 its instruction-level tag variety
        members = _GROUP_MEMBERS[g]
        m = members[member_cycler[g] % len(members)]
        member_cycler[g] += 1
        return [isa.INSTR_ID[m]] * count

    base_id = isa.INSTR_ID["base"]
    # fractional-count accumulators preserve the exact stationary mix even
    # when per-superblock counts round to zero
    acc = {g: 0.0 for g in hot + cold + m_present}

    trace: list[int] = []
    cold_idx = 0
    while len(trace) < length:
        # hot/M groups drain their accumulator every superblock; cold groups
        # accumulate and drain only when they are the rotor (below), which
        # both preserves the exact per-group mix and produces the paper's
        # spaced capacity-miss events
        for g in acc:
            acc[g] += frac[isa.GROUP_ID[g]] * sb_len
        counts = {}
        for g in hot + m_present:
            counts[g] = int(acc[g])
            acc[g] -= counts[g]

        # --- assemble op runs: hot-F bursts, index-mul singles, cold event ---
        items: list[list[int]] = []
        run = max(1, f_run_len)
        hot_runs: list[list[int]] = []
        for g in hot:
            c = counts[g]
            while c > 0:
                take = min(run, c)
                hot_runs.append(run_of(g, take))
                c -= take
        rng.shuffle(hot_runs)
        m_singles = []
        for g in m_present:
            m_singles.extend(run_of(g, 1) for _ in range(counts[g]))
        # interleave: each mul event lands between two F bursts, maximising
        # the M<->F alternation the paper's scenario-3 numbers imply
        hi, mi = 0, 0
        while hi < len(hot_runs) or mi < len(m_singles):
            if hi < len(hot_runs):
                items.append(hot_runs[hi]); hi += 1
            if mi < len(m_singles):
                items.append(m_singles[mi]); mi += 1
        # one rotating cold group per superblock keeps distinct cold tags
        # spaced in time (the paper's capacity misses)
        if cold:
            g = cold[cold_idx % len(cold)]
            cold_idx += 1
            pending = int(acc[g])
            if pending:
                acc[g] -= pending
                items.append(run_of(g, pending))

        # --- paint onto a fixed-length canvas: base filler fills the gaps ---
        n_ops = sum(len(it) for it in items)
        body_len = max(sb_len, n_ops + len(items) + 1)
        n_base = body_len - n_ops
        n_gaps = len(items) + 1
        if sporadic:
            # ops cluster at the head; a long base tail separates clusters
            tail = int(n_base * 0.6)
            inner = n_base - tail
        else:
            tail = 0
            inner = n_base
        gaps = np.full(n_gaps, inner // n_gaps, dtype=np.int64)
        gaps[: inner % n_gaps] += 1
        if n_gaps > 2:  # jitter, keeping the total exact
            j = rng.integers(0, 2, size=n_gaps - 1)
            gaps[:-1] += j - np.roll(j, 1) * 0  # +0/1 then rebalance below
            excess = gaps.sum() - inner
            gaps[-1] -= excess
            if gaps[-1] < 0:
                gaps[0] += gaps[-1]
                gaps[-1] = 0
        body: list[int] = []
        for i, it in enumerate(items):
            body.extend([base_id] * int(gaps[i]))
            body.extend(it)
        body.extend([base_id] * int(gaps[-1]))
        body.extend([base_id] * tail)
        trace.extend(body)

    return np.asarray(trace[:length], dtype=np.int32)


def trace_mix(trace: np.ndarray) -> np.ndarray:
    """Empirical per-group fraction of a trace (for validation)."""
    groups = isa.INSTR_GROUP[trace]
    return np.bincount(groups, minlength=isa.NUM_GROUPS) / len(trace)


def rescale_bench(name: str, **overrides) -> BenchSpec:
    """Utility for calibration sweeps."""
    return replace(BENCHES[name], **overrides)
