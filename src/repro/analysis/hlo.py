"""HLO-text analysis for the roofline: FLOPs, bytes and collective traffic
with correct `while`-loop (lax.scan) accounting.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE regardless
of trip count — useless for layer-scanned models (80x undercount).  This
module re-derives the three roofline numerators by walking the optimized
HLO computation graph:

  * per computation: dot FLOPs (2 * out_elems * contraction), elementwise
    FLOPs (1/output element of compute instructions), collective wire
    bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute operand bytes), and an HBM-traffic proxy
    (operand + result bytes of every non-plumbing instruction — i.e.
    post-fusion boundaries, the standard fusion-level traffic model);
  * call graph roll-up: `fusion`/`call`/`conditional` add callee cost,
    `while` adds trip_count * body + trip_count * condition, with the trip
    count read from the loop-condition's comparison constant (scans lower
    to 0..N counters; unknown conditions conservatively count once).

Shapes in post-SPMD HLO are per-device, so all results are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_PLUMBING = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "iota", "after-all", "custom-call"}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """Total (elements, bytes) over every array shape in the string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Instr:
    name: str
    result: str          # result type string
    op: str
    rest: str            # operands + attrs (raw)
    operands: list = field(default_factory=list)
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # instr name -> result str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                # parameters: name: shape pairs in the header
                for pm in re.finditer(r"([\w.\-]+):\s*(\(?[^,()]*(?:\([^)]*"
                                      r"\))?[^,()]*)", m.group(2)):
                    cur.shapes[pm.group(1)] = pm.group(2)
                continue
            if line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        root, name, result, op, rest = m.groups()
        operands = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
        inst = Instr(name=name, result=result, op=op, rest=rest,
                     operands=operands, is_root=bool(root))
        cur.instrs.append(inst)
        cur.shapes[name] = result
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan conditions compare the counter against a constant."""
    consts = {}
    for inst in cond.instrs:
        if inst.op == "constant":
            cm = re.search(r"constant\((-?\d+)\)", "constant(" + inst.rest)
            if cm:
                consts[inst.name] = int(cm.group(1))
    best = None
    for inst in cond.instrs:
        if inst.op in ("compare", "fusion") or "compare" in inst.rest:
            for opnd in inst.operands:
                if opnd in consts:
                    best = max(best or 0, consts[opnd])
    if best is None and consts:
        best = max(consts.values())
    return best if best and best > 0 else 1


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_elems, _ = _shape_elems_bytes(inst.result)
    contraction = 1
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.rest)
    if cm and inst.operands:
        lhs_shape = comp.shapes.get(inst.operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contraction *= dims[int(idx)]
    return 2.0 * out_elems * contraction


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: dict = field(default_factory=dict)

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_counts.items():
            d = self.coll_counts.setdefault(k, {"count": 0, "bytes": 0})
            d["count"] += v["count"]
            d["bytes"] += v["bytes"]
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    {n: {"count": v["count"] * k, "bytes": v["bytes"] * k}
                     for n, v in self.coll_counts.items()})


_SLICING = ("dynamic-slice", "gather", "slice")


def _fusion_bytes(comp: Computation) -> float:
    """HBM traffic of one fused computation: each parameter is read once at
    its largest interior use (window-sized when every use is a slice), and
    the root result is written once.  Interior intermediates stay in
    registers/VMEM.  This is what makes scan-stacked params/caches cost one
    layer's bytes per trip instead of the whole (L, ...) stack."""
    params = {i.name for i in comp.instrs if i.op == "parameter"}
    read: dict[str, float] = {}
    root_bytes = 0.0
    users: dict[str, list] = {}
    for inst in comp.instrs:
        for o in inst.operands:
            users.setdefault(o, []).append(inst)
    # convert/bitcast/copy are transparent: XLA:CPU's bf16 normalization
    # wraps whole buffers in converts that a TPU compile (native bf16,
    # aliased in-place updates) never materialises
    TRANSPARENT = ("convert", "bitcast", "copy")

    def consumers(name):
        out = []
        frontier = [name]
        seen = set()
        while frontier:
            n = frontier.pop()
            for inst in users.get(n, []):
                if inst.name in seen:
                    continue
                seen.add(inst.name)
                if inst.op in TRANSPARENT:
                    frontier.append(inst.name)
                else:
                    out.append((n, inst))
        return out

    for p in params:
        best = 0.0
        for via, inst in consumers(p):
            _, out_b = _shape_elems_bytes(inst.result)
            if inst.op in _SLICING:
                size = float(out_b)           # window-sized read
            elif inst.op == "dynamic-update-slice" and \
                    via == inst.operands[0]:
                # aliased buffer: window write only (size of the update)
                upd = inst.operands[1] if len(inst.operands) > 1 else None
                size = float(_shape_elems_bytes(
                    comp.shapes.get(upd, ""))[1]) if upd else float(out_b)
            else:
                size = float(_shape_elems_bytes(comp.shapes.get(p, ""))[1])
            best = max(best, size)
        if users.get(p) and not consumers(p):
            # param feeds only transparent ops ending at the root
            best = float(_shape_elems_bytes(comp.shapes.get(p, ""))[1])
        read[p] = best
    # root result writes; aliased in-place roots (dynamic-update-slice /
    # scatter) write only their window; multi-output fusions root at a
    # tuple whose elements are handled individually
    by_name = {i.name: i for i in comp.instrs}

    def write_bytes(inst, depth=0) -> float:
        if depth > 8:
            return float(_shape_elems_bytes(inst.result)[1])
        if inst.op == "tuple":
            return sum(write_bytes(by_name[o], depth + 1)
                       for o in inst.operands if o in by_name)
        if inst.op in TRANSPARENT and inst.operands and \
                inst.operands[0] in by_name:
            return write_bytes(by_name[inst.operands[0]], depth + 1)
        if inst.op in ("dynamic-update-slice", "scatter") and \
                len(inst.operands) > 1:
            upd = inst.operands[1]
            return float(_shape_elems_bytes(comp.shapes.get(upd, ""))[1])
        return float(_shape_elems_bytes(inst.result)[1])

    root = next((i for i in comp.instrs if i.is_root), None)
    if root is None:
        for inst in reversed(comp.instrs):
            if inst.op != "parameter":
                root = inst
                break
    root_bytes = write_bytes(root) if root is not None else 0.0
    return sum(read.values()) + root_bytes


def _comp_cost(comp: Computation, comps, memo) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Cost()  # cycle guard
    total = Cost()
    for inst in comp.instrs:
        op = inst.op
        base = op.replace("-start", "").replace("-done", "")
        if op.endswith("-done"):
            continue
        # sub-computation roll-up
        called = []
        for attr, mult_kind in (("calls", "call"), ("body", "body"),
                                ("condition", "cond"),
                                ("branch_computations", "call"),
                                ("to_apply", "call")):
            am = re.search(attr + r"=\{?%?([\w.\-]+(?:, *%[\w.\-]+)*)\}?",
                           inst.rest)
            if am:
                for cname in re.findall(r"[\w.\-]+", am.group(1)):
                    if cname in comps:
                        called.append((mult_kind, cname))
        if op == "while":
            body = next((c for k, c in called if k == "body"), None)
            cond = next((c for k, c in called if k == "cond"), None)
            trips = _trip_count(comps[cond]) if cond else 1
            if body:
                total += _comp_cost(comps[body], comps, memo).scaled(trips)
            if cond:
                total += _comp_cost(comps[cond], comps, memo).scaled(trips)
            continue
        for _, cname in called:
            sub = _comp_cost(comps[cname], comps, memo)
            if op == "fusion":
                # fused interiors never materialise: keep FLOPs and
                # collectives; replace byte traffic with the fusion model
                # (per-parameter max read size — window-sized when consumed
                # via slicing — plus the root result write)
                sub = Cost(sub.flops, _fusion_bytes(comps[cname]),
                           sub.coll_bytes, sub.coll_counts)
            total += sub

        if base in _COLLECTIVES:
            _, nbytes = _shape_elems_bytes(inst.result)
            if base == "all-reduce" and op.endswith("-start"):
                nbytes //= 2  # (in, out) tuple on async start
            total += Cost(0.0, nbytes, nbytes,
                          {base: {"count": 1, "bytes": nbytes}})
            continue
        if base == "dot" or base == "convolution":
            total += Cost(_dot_flops(inst, comp), 0.0)
        elif base not in _PLUMBING and not called:
            out_elems, _ = _shape_elems_bytes(inst.result)
            total += Cost(float(out_elems), 0.0)
        # HBM-traffic proxy: results + operands of non-plumbing instrs.
        # Slicing ops only touch their window, not the whole operand —
        # critical for scan-stacked params/caches (a dynamic-slice of the
        # (L, ...) stack reads one layer, not L layers).
        if base == "fusion":
            continue  # traffic handled via _fusion_bytes above
        if base not in _PLUMBING or base == "custom-call":
            _, out_b = _shape_elems_bytes(inst.result)
            if base in ("dynamic-slice", "gather", "slice", "reshape",
                        "transpose", "broadcast", "copy", "convert",
                        "reduce"):
                opnd_b = out_b  # window/stream-sized read
                if base in ("reshape", "transpose", "copy", "convert"):
                    opnd_b = out_b
                if base == "reduce":
                    opnd_b = 0
                    for o in inst.operands:
                        if o in comp.shapes:
                            opnd_b += _shape_elems_bytes(comp.shapes[o])[1]
            elif base in ("dynamic-update-slice", "scatter"):
                # read update + write window; the big buffer aliases
                upd_b = 0
                if len(inst.operands) >= 2:
                    o = inst.operands[1]
                    if o in comp.shapes:
                        upd_b = _shape_elems_bytes(comp.shapes[o])[1]
                total += Cost(0.0, 2.0 * upd_b)
                continue
            else:
                opnd_b = 0
                for o in inst.operands:
                    if o in comp.shapes:
                        opnd_b += _shape_elems_bytes(comp.shapes[o])[1]
            total += Cost(0.0, out_b + opnd_b)
    memo[comp.name] = total
    return total


def _entry_name(text: str, comps: dict[str, Computation]) -> str:
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER.match(line[len("ENTRY"):].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    return entry


def analyze_module(text: str) -> dict:
    """Per-device {flops, bytes, collective_bytes, collectives} with scan
    trip counts applied."""
    comps = parse_module(text)
    entry = _entry_name(text, comps)
    cost = _comp_cost(comps[entry], comps, {})
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.coll_bytes,
        "collectives": cost.coll_counts,
    }


# ---------------------------------------------------------------------------
# executed-op histogram (the workloads layer's per-op accounting source)
# ---------------------------------------------------------------------------

_FLOAT_DTYPES = {"f16", "bf16", "f32", "f64", "c64", "c128"}


def _dtype_class(shape_str: str) -> str:
    """'f' for float/complex results, 'i' for integer/pred ones."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "i"
    return "f" if m.group(1) in _FLOAT_DTYPES else "i"


def _comp_hist(comp: Computation, comps, memo) -> dict[str, float]:
    """Executed-op histogram of one computation: ``"op:dtypeclass"`` ->
    output-element count (``"dot:f"`` / ``"convolution:f"`` -> FLOPs),
    rolled up through the call graph with `while` trip multipliers —
    the same traversal as `_comp_cost`, but keeping per-opcode identity
    instead of collapsing everything into three roofline numerators."""
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = {}  # cycle guard
    total: dict[str, float] = {}

    def acc(d: dict, k: float = 1.0) -> None:
        for key, v in d.items():
            total[key] = total.get(key, 0.0) + v * k

    for inst in comp.instrs:
        op = inst.op
        base = op.replace("-start", "").replace("-done", "")
        if op.endswith("-done"):
            continue
        called = []
        for attr, mult_kind in (("calls", "call"), ("body", "body"),
                                ("condition", "cond"),
                                ("branch_computations", "call"),
                                ("to_apply", "call")):
            am = re.search(attr + r"=\{?%?([\w.\-]+(?:, *%[\w.\-]+)*)\}?",
                           inst.rest)
            if am:
                for cname in re.findall(r"[\w.\-]+", am.group(1)):
                    if cname in comps:
                        called.append((mult_kind, cname))
        if op == "while":
            body = next((c for k, c in called if k == "body"), None)
            cond = next((c for k, c in called if k == "cond"), None)
            trips = _trip_count(comps[cond]) if cond else 1
            if body:
                acc(_comp_hist(comps[body], comps, memo), trips)
            if cond:
                acc(_comp_hist(comps[cond], comps, memo), trips)
            continue
        for _, cname in called:
            # fused/called interiors execute element-for-element
            acc(_comp_hist(comps[cname], comps, memo))
        if called or base in _COLLECTIVES or base in _PLUMBING:
            continue
        if base in ("dot", "convolution"):
            total["dot:f"] = total.get("dot:f", 0.0) + _dot_flops(inst, comp)
            continue
        if base in ("compare", "select", "reduce", "reduce-window"):
            # result dtype lies (compare -> pred, reduce collapses); judge
            # by the first operand, and charge reductions per input element
            opnd = comp.shapes.get(inst.operands[0], "") if inst.operands \
                else inst.result
            cls = _dtype_class(opnd)
            if base in ("reduce", "reduce-window"):
                n = float(_shape_elems_bytes(opnd)[0])
            else:
                n = float(_shape_elems_bytes(inst.result)[0])
        else:
            cls = _dtype_class(inst.result)
            n = float(_shape_elems_bytes(inst.result)[0])
        key = f"{base}:{cls}"
        total[key] = total.get(key, 0.0) + n
    memo[comp.name] = total
    return total


def op_histogram(text: str) -> dict[str, float]:
    """Executed-op histogram of a compiled module.

    Keys are ``"{hlo_op}:{f|i}"`` (float vs integer/pred class); values are
    executed output elements — except ``"dot:f"``, which carries FLOPs so
    callers can convert contractions into fused multiply-add counts.  While
    bodies are multiplied by their trip count, exactly like
    `analyze_module`, so layer-scanned models report per-layer ops L times.
    """
    comps = parse_module(text)
    return dict(_comp_hist(comps[_entry_name(text, comps)], comps, {}))


# ---------------------------------------------------------------------------
# legacy helpers (kept for tests / quick greps)
# ---------------------------------------------------------------------------

def xla_cost_analysis(compiled) -> dict:
    """Normalised view of ``Compiled.cost_analysis()`` across jax versions.

    Older jax (including the pinned 0.4.37) returns a per-device *list* of
    property dicts; newer jax returns a single flat dict.  Callers always
    want one flat mapping — for a per-device list we take device 0 (SPMD
    programs are identical across devices).

    Backends are allowed to ship without cost analysis (PJRT plugins often
    stub it out, returning nothing or raising).  The workloads layer
    (`repro.workloads`) builds instruction mixes on top of this call, so a
    missing/empty analysis raises a `ValueError` naming the backend instead
    of surfacing as a bare `KeyError`/`AttributeError`/`None` deep inside
    the mix pipeline.
    """
    backend = getattr(compiled, "platform", None)
    if backend is None:
        try:
            import jax
            backend = jax.default_backend()
        except Exception:  # noqa: BLE001 — name *something* in the error
            backend = "<unknown>"
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # noqa: BLE001
        raise ValueError(
            f"Compiled.cost_analysis() is unavailable on backend "
            f"{backend!r} ({type(e).__name__}: {e}) — this backend cannot "
            f"drive HLO cost accounting (repro.analysis.hlo / "
            f"repro.workloads)") from e
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not ca:
        raise ValueError(
            f"Compiled.cost_analysis() returned no properties on backend "
            f"{backend!r} — this backend cannot drive HLO cost accounting "
            f"(repro.analysis.hlo / repro.workloads)")
    return dict(ca)


def collective_stats(hlo_text: str) -> dict:
    res = analyze_module(hlo_text)
    out = dict(res["collectives"])
    out["total_bytes"] = res["collective_bytes"]
    return out


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   *, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, link_bw: float = 50e9) -> dict:
    """Terms in seconds, all PER-DEVICE (post-SPMD shapes are per-chip)."""
    compute = flops / peak_flops
    memory = hbm_bytes / hbm_bw
    collective = coll_bytes / link_bw
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
    }
