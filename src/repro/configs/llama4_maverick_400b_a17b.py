"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H (GQA kv=8) expert ff8192
v202048, MoE 128e top-1, MoE on alternating layers (=> ~400B total / ~17B
active).  Early-fusion multimodality is a frontend concern and out of the
backbone scope. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    num_experts=128, top_k=1, moe_every=2,
    mlp="swiglu", pos="rope",
    attn_sharding="seq",  # 40 heads not divisible by tp=16
    skip_shapes={"long_500k": "pure full attention (DESIGN.md §4)"},
))
