"""qwen1.5-4b [dense]: 40L d2560 20H (kv=20 -> MHA) ff6912 v151936 — QKV
bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    d_ff=6912, vocab=151936, head_dim=128, qkv_bias=True,
    mlp="swiglu", pos="rope",
    attn_sharding="seq",  # 20 heads not divisible by tp=16
    skip_shapes={"long_500k": "pure full attention (DESIGN.md §4)"},
))
