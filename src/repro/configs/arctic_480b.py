"""arctic-480b [moe]: 35L d7168 56H (GQA kv=8) ff4864 v32000, MoE 128e top-2
PLUS a parallel dense-FFN residual path — the closest structural analogue of
the paper's base-ISA + swappable-extensions split (DESIGN.md §4).
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128,
    num_experts=128, top_k=2, moe_every=1, dense_ff_residual=4864,
    mlp="swiglu", pos="rope",
    attn_sharding="seq",  # 56 heads not divisible by tp=16
    skip_shapes={"long_500k": "pure full attention (DESIGN.md §4)"},
))
