from repro.configs.base import (  # noqa: F401
    ARCH_IDS, SHAPES, ModelConfig, ShapeSpec, cells, get_config,
    list_configs, load_all, register,
)
