"""musicgen-medium [audio]: 48L d1536 24H (MHA kv=24) ff6144 v2048 —
decoder-only over EnCodec tokens.  The EnCodec frontend is a STUB:
input_specs feeds precomputed frame embeddings; the backbone predicts
codebook tokens.  (Positional encoding adapted to RoPE; see DESIGN.md.)
[arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab=2048, head_dim=64, embed_inputs=False,
    mlp="gelu", pos="rope",
    attn_sharding="seq",  # 24 heads not divisible by tp=16
    skip_shapes={"long_500k": "pure full attention (DESIGN.md §4)"},
))
