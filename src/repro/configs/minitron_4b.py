"""minitron-4b [dense]: 32L d3072 24H (GQA kv=8) ff9216 v256000 — pruned
nemotron. [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab=256000, head_dim=128, tie_embeddings=True,
    mlp="swiglu", pos="rope",
    attn_sharding="seq",  # 24 heads not divisible by tp=16
    skip_shapes={"long_500k": "pure full attention (DESIGN.md §4)"},
))
