"""granite-3-2b [dense]: 40L d2048 32H (GQA kv=8) ff8192 v49155 — GQA.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab=49155, head_dim=64, mlp="swiglu", pos="rope",
    attn_sharding="heads",  # 32 % 16 == 0
    tie_embeddings=True,
    skip_shapes={"long_500k": "pure full attention is O(L^2); 512k decode "
                              "KV at batch 1 is out of scope (DESIGN.md §4)"},
))
