"""qwen1.5-110b [dense]: 80L d8192 64H (GQA kv=8) ff49152 v152064 — QKV bias.
[hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab=152064, head_dim=128, qkv_bias=True,
    mlp="swiglu", pos="rope", attn_sharding="heads",  # 64 % 16 == 0
    skip_shapes={"long_500k": "pure full attention (DESIGN.md §4)"},
))
