"""rwkv6-7b [ssm]: 32L d4096 attention-free (Finch: data-dependent decay),
channel-mix ff14336, v65536.  64 heads of 64.  Sub-quadratic => runs
long_500k. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=14336, vocab=65536, head_dim=64, ssm="rwkv6",
    mlp="rwkv_cm", pos="none",
))
