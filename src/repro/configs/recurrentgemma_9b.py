"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1) ff12288 v256000 —
RG-LRU + local attention, pattern (rec, rec, attn).  Sub-quadratic (fixed
2048-token window) => runs long_500k. [arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256, tie_embeddings=True,
    mlp="gelu_glu", pos="rope", pattern=("rec", "rec", "attn"),
    lru_width=4096, conv_width=4, window=2048,
    attn_sharding="heads",  # 16 % 16 == 0
))
