"""qwen2-vl-7b [vlm]: 28L d3584 28H (GQA kv=4) ff18944 v152064 — M-RoPE,
dynamic resolution.  Backbone only: the ViT frontend is a STUB; input_specs
feeds precomputed patch/text embeddings + 3D M-RoPE positions.
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    loss_chunk=512,
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128, qkv_bias=True,
    mlp="swiglu", pos="mrope", mrope_sections=(16, 24, 24),
    embed_inputs=False,
    attn_sharding="seq",  # 28 heads not divisible by tp=16
    skip_shapes={"long_500k": "pure full attention (DESIGN.md §4)"},
))
