"""Model/config system: one `ModelConfig` covers all 10 assigned archs.

Every architecture file in this package registers an exact full-size config
(the dry-run target) plus a `.smoke()` reduction of the same family for
CPU tests.  Input shapes are the four assigned LM shapes; `input_specs()`
returns `jax.ShapeDtypeStruct` stand-ins (weak-type-correct, shardable, no
device allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | ssm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int                 # 0 for attention-free (rwkv6)
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    tie_embeddings: bool = False
    mlp: str = "swiglu"            # swiglu | gelu
    pos: str = "rope"              # rope | mrope | none
    rope_theta: float = 10_000.0

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # MoE layer stride (llama4: 2)
    dense_ff_residual: int = 0     # arctic's parallel dense FFN width
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm: str = ""                  # "rwkv6" | "" (attention archs)
    pattern: tuple = ()            # hybrid block pattern, e.g. ("rec","rec","attn")
    lru_width: int = 0             # RG-LRU recurrence width
    conv_width: int = 4
    window: int = 0                # local-attention window (0 = global)

    # --- modality frontend stubs ---
    embed_inputs: bool = True      # False => input_specs feeds embeddings
    mrope_sections: tuple = ()     # qwen2-vl m-rope head_dim split

    # --- numerics / execution ---
    dtype: str = "bfloat16"
    remat: str = "full"            # full | dots | none
    scan_layers: bool = True
    loss_chunk: int = 0            # 0 = unchunked vocab loss
    # attention sharding strategy (see repro.sharding.partition):
    #   heads: TP over query heads (requires num_heads % tp == 0)
    #   seq:   sequence-parallel attention (any head count)
    attn_sharding: str = "heads"

    # which shapes this arch skips (+reason) — e.g. long_500k for O(L^2) archs
    skip_shapes: dict = field(default_factory=dict)

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.ssm == "rwkv6"

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def moe_layer_mask(self) -> list[bool]:
        if not self.is_moe:
            return [False] * self.num_layers
        return [(i % self.moe_every) == self.moe_every - 1
                for i in range(self.num_layers)]

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline bookkeeping)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.ssm == "rwkv6":
            h = self.d_model // self.head_dim
            tmix = 6 * d * d + 4 * d  # r,k,v,g,w,o + decay/bonus vectors
            cmix = 2 * d * f + d * d
            return emb + self.num_layers * (tmix + cmix)
        att = d * (self.num_heads + 2 * self.num_kv_heads) * self.head_dim \
            + self.num_heads * self.head_dim * d
        mlp_mats = 3 if self.mlp in ("swiglu", "gelu_glu") else 2
        dense_mlp = mlp_mats * d * f
        total = emb
        if self.pattern:  # hybrid: rec blocks replace attention
            n_attn = sum(1 for i in range(self.num_layers)
                         if self.pattern[i % len(self.pattern)] == "attn")
            n_rec = self.num_layers - n_attn
            rec = 3 * d * self.lru_width + self.lru_width * (
                self.conv_width + 4)
            total += n_attn * att + n_rec * rec + self.num_layers * dense_mlp
            return total
        for i, is_moe in enumerate(self.moe_layer_mask()):
            total += att
            if is_moe:
                total += self.num_experts * mlp_mats * d * f
                total += d * self.num_experts  # router
                if self.dense_ff_residual:
                    total += mlp_mats * d * self.dense_ff_residual
            else:
                total += dense_mlp
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: 6*N_active*D rooflines)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        mlp_mats = 3 if self.mlp in ("swiglu", "gelu_glu") else 2
        n_moe = sum(self.moe_layer_mask())
        inactive = n_moe * (self.num_experts - self.top_k) * \
            mlp_mats * self.d_model * self.d_ff
        return full - inactive

    # ------------------------------------------------------------------
    def input_specs(self, shape: str | ShapeSpec) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape.

        Modality archs ([vlm]/[audio]) feed *precomputed* frontend
        embeddings (the stub mandated by the assignment); LM archs feed
        token ids.  Decode shapes describe ONE decode step: a single new
        token against a full cache (built separately by `cache_specs`).
        """
        s = SHAPES[shape] if isinstance(shape, str) else shape
        b, t = s.global_batch, s.seq_len
        f32, i32 = jnp.dtype(self.dtype), jnp.dtype(jnp.int32)
        specs: dict = {}
        if s.kind in ("train", "prefill"):
            if self.embed_inputs:
                specs["tokens"] = jax.ShapeDtypeStruct((b, t), i32)
            else:
                specs["embeds"] = jax.ShapeDtypeStruct((b, t, self.d_model), f32)
                specs["labels"] = jax.ShapeDtypeStruct((b, t), i32)
            if self.pos == "mrope":
                specs["positions"] = jax.ShapeDtypeStruct((b, t, 3), i32)
        else:  # decode: one new token
            if self.embed_inputs:
                specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
            else:
                specs["embeds"] = jax.ShapeDtypeStruct((b, 1, self.d_model), f32)
            specs["positions"] = jax.ShapeDtypeStruct((b,), i32)
        return specs

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        def rd(x, lo):  # reduce but keep divisibility-friendly sizes
            return max(lo, x)
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if not self.pattern
                           else len(self.pattern)),
            d_model=64,
            num_heads=(0 if self.attention_free else
                       max(2, min(4, self.num_heads))),
            num_kv_heads=0, d_ff=128, vocab=256, head_dim=16,
            lru_width=64 if self.lru_width else 0,
            window=min(self.window, 32) if self.window else 0,
            num_experts=8 if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # ample capacity: smoke tests must not drop tokens, so the
            # prefill->decode golden check isolates cache correctness
            capacity_factor=8.0 if self.num_experts else self.capacity_factor,
            dense_ff_residual=64 if self.dense_ff_residual else 0,
            mrope_sections=(2, 3, 3) if self.mrope_sections else (),
            dtype="float32", remat="none", loss_chunk=0,
        )
        kw["num_kv_heads"] = (0 if self.attention_free else
                              (kw["num_heads"] if self.num_kv_heads ==
                               self.num_heads else 2))
        if self.ssm == "rwkv6":
            kw["head_dim"] = 16  # 4 heads of 16
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


def load_all() -> None:
    """Import every arch module so registration side-effects run."""
    import importlib
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


ARCH_MODULES = [
    "granite_3_2b", "qwen1_5_110b", "minitron_4b", "qwen1_5_4b",
    "llama4_maverick_400b_a17b", "arctic_480b", "qwen2_vl_7b",
    "rwkv6_7b", "recurrentgemma_9b", "musicgen_medium",
]

ARCH_IDS = [
    "granite-3-2b", "qwen1.5-110b", "minitron-4b", "qwen1.5-4b",
    "llama4-maverick-400b-a17b", "arctic-480b", "qwen2-vl-7b",
    "rwkv6-7b", "recurrentgemma-9b", "musicgen-medium",
]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honouring documented skips."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            skipped = shape in cfg.skip_shapes
            if skipped and not include_skipped:
                continue
            out.append((arch, shape))
    return out
