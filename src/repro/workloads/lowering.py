"""Deterministic trace lowering: instruction-mix tables -> tag/cost streams.

A `WorkloadSpec` pairs an `OpCount`-derived stationary mix with the
loop-structure knobs `core.traces.paint_trace` needs to lay that mix out
in time.  The knobs are *phase-derived*, mirroring how the two serving
phases actually execute:

  * **prefill** — dense GEMM bursts: long contiguous F runs
    (`f_run_len=8`), tight cold-event spacing, no sporadic spreading.
    Prefill tenants lower F-hot and slot-hungry.
  * **decode** — memory-bound single-token steps: short F runs
    (`f_run_len=2`), wider cold-event spacing, sporadic spreading (op
    clusters separated by base/load-store tails).  Decode tenants lower
    base-heavy and co-reside cheaply.

The painter is the *same code path* Embench traces use, so lowered
traces inherit the whole contract for free: crc32-seeded process
determinism (bit-for-bit across machines and PYTHONHASHSEED values),
the `repro.core.isa` alphabet (29 tags < `bs_cache_entries=64`, so the
stackdist warm path stays eligible), and scenario compatibility — the
fast-path engines (`stackdist`, `stackdist_interleaved`) dispatch on
these traces exactly as they do on Embench ones.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import isa, traces as core_traces
from repro.workloads.opcounts import OpCount

# phase -> paint_trace loop-structure knobs
PHASE_KNOBS = {
    "prefill": {"f_run_len": 8, "cold_event_period": 64, "sporadic": False},
    "decode": {"f_run_len": 2, "cold_event_period": 96, "sporadic": True},
}


@dataclass(frozen=True)
class WorkloadSpec:
    """A lowered model-zoo workload: registry entry + trace factory.

    Frozen so specs can key caches (the `ContentionModel` caches traces
    and solo CPIs per tenant name; a spec must never mutate under it).
    """

    name: str            # "<arch>:<phase>", e.g. "qwen1.5-4b:prefill"
    arch: str
    phase: str
    frac: tuple          # (NUM_GROUPS,) stationary mix, as a hashable tuple
    opcount: OpCount
    hot_f_groups: tuple
    cold_event_period: int
    f_run_len: int
    sporadic: bool

    def mix(self) -> np.ndarray:
        return np.asarray(self.frac, dtype=np.float64)

    def build_trace(self, length: int = 200_000, seed: int = 0) -> np.ndarray:
        """Instruction-id trace realising this spec's mix.

        Same signature and determinism contract as
        `core.traces.build_trace`; the seed key is namespaced with "wl:"
        so a workload can never collide with an Embench bench stream.
        """
        return core_traces.paint_trace(
            self.mix(), length=length, seed_key=f"wl:{self.name}:{seed}",
            hot_f_groups=self.hot_f_groups,
            cold_event_period=self.cold_event_period,
            f_run_len=self.f_run_len, sporadic=self.sporadic)


def spec_from_opcount(arch: str, phase: str, oc: OpCount) -> WorkloadSpec:
    """Derive the full spec: mix from accounting, knobs from the phase."""
    if phase not in PHASE_KNOBS:
        raise ValueError(
            f"phase must be one of {tuple(PHASE_KNOBS)}, got {phase!r}")
    frac = oc.frac()
    # hottest two F groups carry the inner loop (the painter rotates the
    # rest as spaced cold events); ties break lexicographically so the
    # spec — and hence the trace — is deterministic
    by_weight = sorted(
        ((float(frac[isa.GROUP_ID[g]]), g) for g in isa.F_GROUPS
         if frac[isa.GROUP_ID[g]] > 0),
        key=lambda t: (-t[0], t[1]))
    hot = tuple(g for _, g in by_weight[:2])
    knobs = PHASE_KNOBS[phase]
    return WorkloadSpec(
        name=f"{arch}:{phase}", arch=arch, phase=phase,
        frac=tuple(float(x) for x in frac), opcount=oc,
        hot_f_groups=hot, cold_event_period=knobs["cold_event_period"],
        f_run_len=knobs["f_run_len"], sporadic=knobs["sporadic"])
