"""OpCount accounting: model-zoo configs -> per-op instruction-mix tables.

The repo's anchors all consume dynamic instruction traces over the
`repro.core.isa` RV32IMF alphabet.  This module produces the *mix* those
traces should realise for the models the repo actually ships: each
`repro.configs` architecture is lowered (smoke reduction, CPU-compilable)
through its prefill or decode step, the optimized HLO is walked with the
scan-corrected accounting in `repro.analysis.hlo`, and every executed HLO
op is charged to an isa group:

  * float elementwise ops map directly (add->fadd, multiply->fmul,
    divide->fdiv, sqrt/rsqrt->fsqrt, compare/select/min/max->fcmp,
    convert/floor/ceil/round->fcvt);
  * `dot`/`convolution` contractions are fused multiply-adds: FLOPs / 2
    `fma` ops — the dominant term of any prefill;
  * transcendentals (exp, log, tanh, logistic, sine, ...) have no RV32IMF
    instruction; each element expands into a documented soft sequence of
    4 `fma` (Horner polynomial) + 1 `fdiv` (range reduction / reciprocal);
  * integer multiply / divide / remainder map to the M groups (router
    top-k math, position arithmetic, address math the compiler emits);
  * every other integer/pred op, plus the HBM-traffic proxy converted at
    one RV32 word (4 bytes) per load/store, lands in `base` — which is
    what makes decode (memory-bound, low arithmetic intensity) lower as a
    base-heavy, slot-light tenant while prefill lowers F-hot.

The `OpCount` container follows the `FlopCount` accounting idiom
(per-category counts with `+` and scalar `*`, dict round-trip for
serialization); `repro.workloads` turns tables into `WorkloadSpec`s and
`benchmarks/model_serve_study.py` serializes the zoo-wide table to
``experiments/bench/workload_mix.csv`` so mixes are diffable across PRs.

Accounting runs on *smoke* reductions of each config: mixes are relative
fractions and the smoke configs preserve the family structure that shapes
them (MoE routing, rwkv6/RG-LRU recurrences, mrope, layer scans), while
staying compilable on the CPU backend in ~1s per phase.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import isa

# ---------------------------------------------------------------------------
# HLO op -> isa-group mapping
# ---------------------------------------------------------------------------

# float-class elementwise ops with a direct RV32F counterpart group
F_OP_GROUP = {
    "add": "fadd", "subtract": "fadd",
    "reduce": "fadd", "reduce-window": "fadd",   # charged per input element
    "multiply": "fmul",
    "divide": "fdiv", "remainder": "fdiv",
    "sqrt": "fsqrt", "rsqrt": "fsqrt", "cbrt": "fsqrt",
    "compare": "fcmp", "select": "fcmp", "maximum": "fcmp",
    "minimum": "fcmp", "clamp": "fcmp", "abs": "fcmp", "negate": "fcmp",
    "sign": "fcmp", "is-finite": "fcmp",
    "convert": "fcvt", "floor": "fcvt", "ceil": "fcvt",
    "round-nearest-afz": "fcvt", "round-nearest-even": "fcvt",
}

# no RV32IMF instruction: expanded per element into a soft sequence
TRANSCENDENTALS = {
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "logistic", "tanh", "sine", "cosine", "tan", "atan2", "power",
    "erf", "erf-inv",
}
TRANSCENDENTAL_EXPANSION = {"fma": 4.0, "fdiv": 1.0}

# integer-class ops with an RV32M counterpart group
I_OP_GROUP = {"multiply": "mul", "divide": "div", "remainder": "rem"}

# HBM-traffic proxy -> base load/store ops: one RV32 word per 4 bytes
BYTES_PER_BASE_OP = 4.0


@dataclass
class OpCount:
    """Executed-op counts over the isa group alphabet (FlopCount idiom).

    `counts` maps isa group name -> dynamic op count; `flops`, `bytes` and
    `transcendental_elems` keep the raw accounting the mapping consumed,
    so serialized tables stay auditable against the HLO walk.
    """

    counts: dict = field(default_factory=dict)
    flops: float = 0.0
    bytes: float = 0.0
    transcendental_elems: float = 0.0

    def __add__(self, other: "OpCount") -> "OpCount":
        if not isinstance(other, OpCount):
            return NotImplemented
        merged = dict(self.counts)
        for g, v in other.counts.items():
            merged[g] = merged.get(g, 0.0) + v
        return OpCount(merged, self.flops + other.flops,
                       self.bytes + other.bytes,
                       self.transcendental_elems
                       + other.transcendental_elems)

    def __mul__(self, k: float) -> "OpCount":
        return OpCount({g: v * k for g, v in self.counts.items()},
                       self.flops * k, self.bytes * k,
                       self.transcendental_elems * k)

    __rmul__ = __mul__

    def total(self) -> float:
        return float(sum(self.counts.values()))

    def frac(self) -> np.ndarray:
        """(NUM_GROUPS,) stationary fractions — `repro.core.traces.Mix`
        layout, consumable by `paint_trace` / `analytic_cpi`."""
        v = np.zeros(isa.NUM_GROUPS)
        for g, c in self.counts.items():
            v[isa.GROUP_ID[g]] = c
        s = v.sum()
        if s <= 0:
            raise ValueError("OpCount has no executed ops to normalise")
        return v / s

    def to_dict(self) -> dict:
        return {"counts": dict(self.counts), "flops": self.flops,
                "bytes": self.bytes,
                "transcendental_elems": self.transcendental_elems}

    @classmethod
    def from_dict(cls, d: dict) -> "OpCount":
        return cls(dict(d["counts"]), float(d["flops"]), float(d["bytes"]),
                   float(d.get("transcendental_elems", 0.0)))


def opcount_from_hlo(hlo_text: str) -> OpCount:
    """Charge a compiled module's executed ops to isa groups.

    Consumes `hlo.op_histogram` (per-opcode executed elements, scan trip
    counts applied) and `hlo.analyze_module` (the HBM-traffic proxy that
    becomes the base-op load/store count).
    """
    from repro.analysis import hlo

    hist = hlo.op_histogram(hlo_text)
    walk = hlo.analyze_module(hlo_text)
    counts: dict[str, float] = {g: 0.0 for g in isa.GROUP_NAMES}
    trans = 0.0
    for key, n in hist.items():
        op, cls = key.rsplit(":", 1)
        if op == "dot":
            counts["fma"] += n / 2.0       # n carries FLOPs for dot ops
        elif cls == "f" and op in TRANSCENDENTALS:
            trans += n
            for g, k in TRANSCENDENTAL_EXPANSION.items():
                counts[g] += n * k
        elif cls == "f" and op in F_OP_GROUP:
            counts[F_OP_GROUP[op]] += n
        elif cls == "i" and op in I_OP_GROUP:
            counts[I_OP_GROUP[op]] += n
        else:
            counts["base"] += n
    counts["base"] += float(walk["bytes"]) / BYTES_PER_BASE_OP
    counts = {g: v for g, v in counts.items() if v > 0}
    return OpCount(counts, flops=float(walk["flops"]),
                   bytes=float(walk["bytes"]),
                   transcendental_elems=trans)


# ---------------------------------------------------------------------------
# model-zoo lowering: config -> compiled phase step -> OpCount
# ---------------------------------------------------------------------------

PHASES = ("prefill", "decode")

# small enough to compile in ~1s on CPU, large enough that per-token terms
# dominate per-call constants
MIX_BATCH = 2
MIX_SEQ_LEN = 64

_CACHE: dict[tuple[str, str], OpCount] = {}


def _abstract_batch(cfg, phase: str) -> dict:
    import jax
    import jax.numpy as jnp

    i32 = jnp.dtype(jnp.int32)
    act = jnp.dtype(cfg.dtype)
    b, t = MIX_BATCH, MIX_SEQ_LEN
    if phase == "prefill":
        batch: dict = {}
        if cfg.embed_inputs:
            batch["tokens"] = jax.ShapeDtypeStruct((b, t), i32)
        else:
            batch["embeds"] = jax.ShapeDtypeStruct((b, t, cfg.d_model), act)
        if cfg.pos == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((b, t, 3), i32)
        return batch
    # decode: one new token against a prefilled cache; positions are (B,)
    # for every pos scheme (mrope broadcasts t=h=w in text mode)
    batch = {"positions": jax.ShapeDtypeStruct((b,), i32)}
    if cfg.embed_inputs:
        batch["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
    else:
        batch["embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), act)
    return batch


def _compiled_phase(arch: str, phase: str):
    """Lower + compile one (smoke config, phase) cell; returns Compiled."""
    import jax

    from repro.configs import base as cb
    from repro.models import transformer

    cb.load_all()
    cfg = cb.get_config(arch).smoke()
    params = jax.eval_shape(lambda k: transformer.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    pre = _abstract_batch(cfg, "prefill")
    if phase == "prefill":
        fn = lambda p, bt: transformer.prefill(cfg, p, bt)[0]  # noqa: E731
        return jax.jit(fn).lower(params, pre).compile()
    _, cache, _ = jax.eval_shape(
        lambda p, bt: transformer.prefill(cfg, p, bt), params, pre)
    dec = _abstract_batch(cfg, "decode")
    fn = lambda p, c, bt: transformer.decode_step(cfg, p, bt, c)[0]  # noqa: E731
    return jax.jit(fn).lower(params, cache, dec).compile()


def model_opcount(arch: str, phase: str) -> OpCount:
    """Per-phase instruction-mix accounting for one model-zoo config.

    Compiles the smoke config's phase step, validates the backend actually
    reports cost properties (`hlo.xla_cost_analysis` raises a ValueError
    naming the backend otherwise — the contract this layer depends on),
    then charges the walked HLO to isa groups.  Cached per (arch, phase):
    compilation is the expensive part and mixes are pure functions of the
    pinned jax version.
    """
    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
    key = (arch, phase)
    if key not in _CACHE:
        from repro.analysis import hlo

        compiled = _compiled_phase(arch, phase)
        hlo.xla_cost_analysis(compiled)   # backend capability gate
        _CACHE[key] = opcount_from_hlo(compiled.as_text())
    return _CACHE[key]
