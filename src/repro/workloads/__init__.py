"""Model-zoo workloads: configs -> instruction mixes -> servable traces.

The pipeline (tentpole of the "real-workload traces" ROADMAP item):

    repro.configs arch ──compile smoke prefill/decode──▶ optimized HLO
        ──opcounts.model_opcount──▶ OpCount mix table
        ──lowering.spec_from_opcount──▶ WorkloadSpec
        ──spec.build_trace──▶ isa-alphabet tag stream

Workload names are ``"<arch>:<phase>"`` (phase in {prefill, decode}),
disjoint from Embench bench names by construction (no Embench name
contains a colon).  `resolve_trace` is the single entry point the sched
and serve layers use to turn *either* kind of tenant name into a trace;
`ContentionModel.trace` and `serve.engine.estimate_fleet_contention`
route through it, which is what lets `place_tenants`, `OnlineReplacer`,
and `FaultPlan.storm` chaos serves take a model-zoo fleet unchanged.

Registry entries are built lazily and cached: constructing a spec
compiles the arch's smoke config (~1-3s), so nothing compiles until a
workload name is actually used.
"""
from __future__ import annotations

import numpy as np

from repro.core import traces as core_traces
from repro.workloads import opcounts
from repro.workloads.lowering import PHASE_KNOBS, WorkloadSpec, spec_from_opcount
from repro.workloads.opcounts import OpCount, model_opcount

__all__ = [
    "OpCount", "WorkloadSpec", "model_opcount", "spec_from_opcount",
    "workload_name", "is_workload_name", "get_workload", "list_workloads",
    "build_trace", "resolve_trace", "mix_table_rows", "PHASES",
]

PHASES = opcounts.PHASES

_SPECS: dict[str, WorkloadSpec] = {}


def workload_name(arch: str, phase: str) -> str:
    return f"{arch}:{phase}"


def _known_archs() -> tuple:
    from repro.configs import base as cb

    cb.load_all()
    return tuple(cb.ARCH_IDS)


def is_workload_name(name: str) -> bool:
    """Syntactic check only — does not compile anything."""
    if ":" not in name:
        return False
    arch, _, phase = name.rpartition(":")
    return phase in PHASES and arch in _known_archs()


def get_workload(name: str) -> WorkloadSpec:
    """Resolve (lazily building + caching) a workload spec by name."""
    if name not in _SPECS:
        if not is_workload_name(name):
            raise ValueError(
                f"unknown workload {name!r}: expected '<arch>:<phase>' with "
                f"arch in {_known_archs()} and phase in {PHASES}")
        arch, _, phase = name.rpartition(":")
        _SPECS[name] = spec_from_opcount(
            arch, phase, model_opcount(arch, phase))
    return _SPECS[name]


def list_workloads(phases=PHASES) -> list:
    """All registry names for the full zoo (nothing is compiled)."""
    return [workload_name(a, p) for a in _known_archs() for p in phases]


def build_trace(name: str, length: int = 200_000, seed: int = 0) -> np.ndarray:
    return get_workload(name).build_trace(length=length, seed=seed)


def resolve_trace(name: str, length: int = 200_000,
                  seed: int = 0) -> np.ndarray:
    """Name -> trace for Embench benches *and* model-zoo workloads.

    The single resolution point the sched/serve layers call: Embench
    names pass through to `core.traces.build_trace` bit-for-bit
    unchanged; '<arch>:<phase>' names lower through the workloads
    registry; anything else raises a ValueError naming both valid sets.
    """
    if name in core_traces.BENCHES:
        return core_traces.build_trace(name, length=length, seed=seed)
    if is_workload_name(name):
        return build_trace(name, length=length, seed=seed)
    raise ValueError(
        f"unknown tenant name {name!r}: expected an Embench bench "
        f"({sorted(core_traces.BENCHES)}) or a model-zoo workload "
        f"'<arch>:<phase>' with arch in {_known_archs()} and phase in "
        f"{PHASES}")


def mix_table_rows(names=None) -> tuple:
    """(header, rows) for the workload_mix.csv serialization.

    One row per workload: raw accounting (flops / bytes / transcendental
    elements) plus the per-isa-group stationary fractions.  Building a
    row compiles that workload's phase step if it is not cached yet.
    """
    from repro.core import isa

    if names is None:
        names = list_workloads()
    header = (["workload", "arch", "phase", "flops", "bytes",
               "transcendental_elems"]
              + [f"frac_{g}" for g in isa.GROUP_NAMES])
    rows = []
    for name in names:
        spec = get_workload(name)
        oc = spec.opcount
        rows.append([name, spec.arch, spec.phase,
                     f"{oc.flops:.0f}", f"{oc.bytes:.0f}",
                     f"{oc.transcendental_elems:.0f}"]
                    + [f"{x:.6f}" for x in spec.frac])
    return header, rows
