"""Elastic re-meshing: continue training after permanent device loss.

Strategy (DESIGN.md §5): the `model` axis is sacred (layer math depends on
it); capacity loss shrinks the `data` axis to the largest power-of-two that
still divides the global batch, and the checkpoint re-shards onto the new
mesh through the host (repro.checkpoint restore takes new shardings).
The deterministic data pipeline is keyed by step, so training resumes on
exactly the batch schedule the lost configuration would have run.
"""
from __future__ import annotations

import jax

from repro.optim import adamw
from repro.sharding.partition import ShardingPlan
from repro.train import step as train_step
from repro.checkpoint import ckpt


def shrink_mesh(devices_available: int, model: int = 16,
                axis_names=("data", "model")):
    """Largest (data, model) mesh that fits the surviving devices."""
    data = max(1, devices_available // model)
    # largest power of two <= data (keeps global batch divisible)
    while data & (data - 1):
        data &= data - 1
    n = data * model
    devs = jax.devices()[:n]
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(data, model), axis_names)


def reshard_state(directory: str, step: int, cfg, opt_cfg, new_mesh):
    """Load a checkpoint onto a (possibly smaller) mesh."""
    plan = ShardingPlan(new_mesh, cfg, mode="train")
    shapes = train_step.abstract_state(cfg, opt_cfg)
    shardings = train_step.state_shardings(cfg, plan, shapes)
    with new_mesh:
        state = ckpt.restore(directory, step, shapes, shardings)
    return state, plan
