"""Fault-tolerant training runtime: checkpoint/restart, straggler
mitigation, bounded-restart supervision, elastic re-mesh.

At thousands of nodes the *runtime* is the product: the model code only has
to be a pure step function.  This module provides the supervision loop the
launcher (repro.launch.train) runs:

  * `Heartbeat`     — per-step liveness file + step-time log; an external
                      watchdog (or the supervisor below) detects hangs.
  * `StragglerMonitor` — sliding-window step-time tracking; steps slower
                      than `k x median` raise a straggler event.  On real
                      pods the action is to evict/replace the slow host
                      (here: recorded + optional callback).
  * `run_supervised` — bounded-restart loop around a Trainer: on failure,
                      restore the latest checkpoint and continue; honours
                      deterministic data (repro.data) so the retrained
                      steps are bit-identical.
  * elastic shrink  — on permanent device loss, rebuild the mesh with a
                      smaller `data` axis and re-shard the checkpoint
                      (repro.runtime.elastic).
"""
from __future__ import annotations

import json
import os
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable

from repro.checkpoint import ckpt


@dataclass
class Heartbeat:
    path: str
    interval_steps: int = 1
    _last: float = field(default=0.0, repr=False)

    def beat(self, step: int, step_time: float) -> None:
        now = time.time()
        with open(self.path, "w") as f:
            json.dump({"step": step, "time": now,
                       "step_time_s": step_time}, f)
        self._last = now

    def age(self) -> float:
        try:
            with open(self.path) as f:
                return time.time() - json.load(f)["time"]
        except (OSError, ValueError):
            return float("inf")


@dataclass
class StragglerMonitor:
    """Flags steps slower than `threshold` x rolling median."""

    window: int = 32
    threshold: float = 2.0
    on_straggler: Callable | None = None
    times: list = field(default_factory=list)
    events: list = field(default_factory=list)

    def observe(self, step: int, step_time: float) -> bool:
        history = self.times[-self.window:]
        self.times.append(step_time)
        # keep only the sliding window: `history` never looks further
        # back, so trimming is behaviour-free — without it a long run
        # accretes one float per step forever
        if len(self.times) > self.window:
            del self.times[:len(self.times) - self.window]
        if len(history) < 8:
            return False
        med = statistics.median(history)
        if step_time > self.threshold * med:
            self.events.append({"step": step, "step_time": step_time,
                                "median": med})
            if self.on_straggler:
                self.on_straggler(step, step_time, med)
            return True
        return False


@dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.0


class TrainingFailure(RuntimeError):
    pass


def run_supervised(*, init_fn, step_fn, save_fn, restore_fn, num_steps: int,
                   ckpt_every: int, policy: RestartPolicy | None = None,
                   heartbeat: Heartbeat | None = None,
                   straggler: StragglerMonitor | None = None,
                   fail_hook: Callable | None = None,
                   retryable: tuple = (TrainingFailure,)) -> dict:
    """Supervision loop.

    init_fn()                -> (state, start_step)   (restores if possible)
    step_fn(state, step)     -> (state, metrics)
    save_fn(state, step)     -> None
    restore_fn()             -> (state, start_step)
    fail_hook(step)          -> None | raises  (test fault injection)

    `retryable` is the exception tuple the restart policy absorbs —
    anything else propagates immediately.  Defaults to `TrainingFailure`;
    widen it (e.g. ``(TrainingFailure, OSError)``) when the step function
    can fail in recoverable infrastructure-specific ways.

    Returns a report {steps_run, restarts, straggler_events, final_step}.
    """
    retryable = tuple(retryable)
    if not retryable or not all(
            isinstance(e, type) and issubclass(e, BaseException)
            for e in retryable):
        raise TypeError(
            f"retryable must be a non-empty tuple of exception types, "
            f"got {retryable!r}")
    policy = policy or RestartPolicy()
    restarts = 0
    state, step = init_fn()
    steps_run = 0
    while step < num_steps:
        try:
            if fail_hook is not None:
                fail_hook(step)
            t0 = time.time()
            state, metrics = step_fn(state, step)
            dt = time.time() - t0
            steps_run += 1
            step += 1
            if heartbeat:
                heartbeat.beat(step, dt)
            if straggler:
                straggler.observe(step, dt)
            if step % ckpt_every == 0 or step == num_steps:
                save_fn(state, step)
        except retryable:
            restarts += 1
            if restarts > policy.max_restarts:
                raise
            time.sleep(policy.backoff_s)
            state, step = restore_fn()
    return {
        "steps_run": steps_run,
        "restarts": restarts,
        "straggler_events": list(straggler.events) if straggler else [],
        "final_step": step,
    }
