"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,Tq,H,dh); k/v: (B,Tk,KH,dh)."""
    b, tq, h, dh = q.shape
    _, tk, kh, _ = k.shape
    g = h // kh
    qr = q.reshape(b, tq, kh, g, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("btkgd,bskd->btkgs", qr, k.astype(jnp.float32))
    qpos, kpos = jnp.arange(tq), jnp.arange(tk)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", p, v.astype(jnp.float32))
    return o.reshape(b, tq, h, dh).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """q: (B,H,dh); caches: (B,S,KH,dh); kv_len: (B,)."""
    b, h, dh = q.shape
    _, s, kh, _ = k_cache.shape
    g = h // kh
    qr = q.reshape(b, kh, g, dh).astype(jnp.float32) * dh ** -0.5
    sc = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32))
    valid = jnp.arange(s)[None, :] < kv_len[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, dh).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, logw, u):
    """Per-token recurrence oracle, zero initial state.  All (B,T,H,N)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        att = s + (uf[None] * kt)[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt, att)
        s = wt[..., :, None] * s + kt[..., :, None] * vt[..., None, :]
        return s, out

    b, t, h, n = r.shape
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, w))
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    _, out = jax.lax.scan(step, s0, xs)
    return out.transpose(1, 0, 2, 3)


C_RGLRU = 8.0


def rglru_scan_ref(u, w_r, b_r, w_i, b_i, lam):
    """Sequential recurrence oracle, h_0 = 0.  u: (B,T,W)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * w_r + b_r)
    i = jax.nn.sigmoid(uf * w_i + b_i)
    log_a = -C_RGLRU * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(uf[:, 0]),
                         (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def moe_gmm_ref(x, wg, wi, wo, *, gated=True):
    """x: (E,C,D); wg/wi: (E,D,F); wo: (E,F,D)."""
    xf = x.astype(jnp.float32)
    hg = jnp.einsum("ecd,edf->ecf", xf, wg.astype(jnp.float32))
    if gated:
        hi = jnp.einsum("ecd,edf->ecf", xf, wi.astype(jnp.float32))
        h = jax.nn.silu(hg) * hi
    else:
        h = jax.nn.gelu(hg)
    return jnp.einsum("ecf,efd->ecd", h,
                      wo.astype(jnp.float32)).astype(x.dtype)
