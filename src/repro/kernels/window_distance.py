"""Fused Pallas kernel for the interleaved engine's window pass.

`repro.core.stackdist_interleaved._simulate_cell` commits one scheduler
window per `lax.while_loop` iteration: gather the scheduled program's
next W accesses, build the (W, num_tags) occurrence matrix, one `cummax`
pass for the merged-stream stack distances, classify cold/miss, cumsum
the cycle costs, search the quantum-expiry point, and fold the committed
prefix back into the carried per-tag `last_pos` vector.  Under XLA each
of those steps is its own HBM-round-trip over the (W, num_tags) `occ` /
`cm` intermediates, multiplied by the vmap^4 grid.

This module fuses the whole pass — last-occurrence update, stack
distance, cold/miss classification, cost cumsum and quantum-expiry
search — into ONE Pallas kernel.  The per-tag `last_pos` vector (and in
materialise mode `last_miss_pos`) lives in VMEM/registers as the
`while_loop` carry for the whole cell run; the (W, num_tags) matrices
exist only as in-kernel values and never hit HBM.  Two entry points:

* `window_grid` — the one-shot counter-tuple sweep: one `pallas_call`
  whose grid is the full {quantum x fleet x slots x latency} cell grid
  (each grid step runs one cell's entire while-loop), returning the
  `InterleavedGrid` counter arrays.
* `window_cell` — the seeded/`materialise` single-cell form behind
  `resume_preempted`: accepts the engine-coordinate seed and returns the
  full final `CellCarry` field tuple (cumulative counters plus the
  per-tag occurrence vectors the simulator turns back into a
  `FleetState`).

All arithmetic is int32 and mirrors the jnp body operation-for-
operation (the cumulative max/sum use a log-doubling shift scan — exact
for integers), so interpret mode (`pl.pallas_call(..., interpret=True)`)
is bit-for-bit equal to the jnp engine on any backend; CPU CI proves it
without a GPU (tests/test_window_kernel.py).  Dispatch policy lives in
`resolve()`: compiled Pallas on GPU/TPU, interpret-mode parity path on
CPU when the kernel is forced, and the jnp body as the always-available
fallback (the CPU default — interpret mode is a correctness vehicle, not
a fast path).

Like its siblings in this package the kernel is shape-generic and knows
nothing about the RISC-V alphabet; callers pass pre-gathered (P, N) tag
and cost streams.  The tag axis is padded to the 128-lane boundary and
the window to the 8-sublane boundary (padded tags never occur in any
stream and padded rows carry tag -1 / cost 0, so both pads are inert —
see the parity argument in tests/test_window_kernel.py).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["window_grid", "window_cell", "resolve", "set_default_mode",
           "DEFAULT_MODE"]

_LANES = 128      # TPU lane width: tag-axis pad boundary
_SUBLANES = 8     # TPU sublane width: window-axis pad boundary

# knob vocabulary for the `use_kernel` dispatch (see `resolve`); the
# session-wide default can be preset via the REPRO_WINDOW_KERNEL env var
# (benchmarks/run.py --interpret sets it) or `set_default_mode`.
_MODES = ("auto", "kernel", "interpret", "jnp")
DEFAULT_MODE = os.environ.get("REPRO_WINDOW_KERNEL", "auto")


def set_default_mode(mode: str) -> None:
    """Set the session default `use_kernel` mode ('auto'|'kernel'|
    'interpret'|'jnp') that `resolve(None)` falls back to."""
    global DEFAULT_MODE
    if mode not in _MODES:
        raise ValueError(f"unknown window-kernel mode {mode!r} "
                         f"(expected one of {_MODES})")
    DEFAULT_MODE = mode


def resolve(use_kernel=None) -> tuple[bool, bool]:
    """Resolve a `use_kernel` knob value to (run_kernel, interpret).

    None -> the session default mode (env REPRO_WINDOW_KERNEL or 'auto');
    True/'kernel' -> the kernel, compiled on GPU/TPU and interpret-mode
    elsewhere; 'interpret' -> the kernel in interpret mode everywhere
    (the CPU parity path); False/'jnp' -> the jnp window pass.  'auto'
    picks the compiled kernel on GPU/TPU and the jnp body on CPU, where
    interpret mode would be strictly slower than XLA's fused loop.
    """
    mode = use_kernel
    if mode is None:
        mode = DEFAULT_MODE
    elif mode is True:
        mode = "kernel"
    elif mode is False:
        mode = "jnp"
    if mode not in _MODES:
        raise ValueError(f"unknown use_kernel value {use_kernel!r} "
                         f"(expected None/bool or one of {_MODES})")
    accel = jax.default_backend() in ("gpu", "tpu")
    if mode == "auto":
        return accel, False
    if mode == "kernel":
        return True, not accel
    if mode == "interpret":
        return True, True
    return False, False


def _interp(interpret) -> bool:
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() not in ("gpu", "tpu")


def _round_up(n: int, m: int) -> int:
    return -(-int(n) // m) * m


def _iota(n: int) -> jnp.ndarray:
    # 1-D iota via a 2-D broadcasted_iota (plain 1-D iota fails on TPU)
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)[:, 0]


def _shift_scan(x: jnp.ndarray, op, unit) -> jnp.ndarray:
    """Inclusive scan along axis 0 by log-doubling shifts — exact for the
    integer max/add monoids, and built from static slices/concats only so
    it lowers inside a kernel body (no `lax.associative_scan`)."""
    n = x.shape[0]
    shift = 1
    while shift < n:
        pad = jnp.full((shift,) + x.shape[1:], unit, x.dtype)
        x = op(x, jnp.concatenate([pad, x[:-shift]], axis=0))
        shift *= 2
    return x


def _cummax0(x: jnp.ndarray) -> jnp.ndarray:
    return _shift_scan(x, jnp.maximum, jnp.iinfo(jnp.int32).min)


def _cumsum0(x: jnp.ndarray) -> jnp.ndarray:
    return _shift_scan(x, jnp.add, 0)


class _Carry(NamedTuple):
    """In-kernel cell state: `CellCarry` with the per-tag vectors held as
    (1, t_pad) VMEM-resident rows (always including `last_miss`, so one
    loop body serves both modes; non-materialise runs simply never update
    it)."""

    last_pos: jnp.ndarray   # (1, t_pad)
    last_miss: jnp.ndarray  # (1, t_pad)
    cursors: jnp.ndarray    # (P,)
    sched_idx: jnp.ndarray  # ()
    steps_done: jnp.ndarray  # ()
    q_cycles: jnp.ndarray   # ()
    cycles: jnp.ndarray     # (P,)
    instrs: jnp.ndarray     # (P,)
    misses: jnp.ndarray     # (P,)
    bs_misses: jnp.ndarray  # (P,)
    switches: jnp.ndarray   # ()


def _window_loop(tags, costs, num_active, miss_latency, quanta_vec,
                 sched, handler, bs_extra, init: _Carry, *, trace_len: int,
                 total_steps: int, window: int, w_pad: int, t_pad: int,
                 pos_base: int, materialise: bool) -> _Carry:
    """The fused cell run: `_simulate_cell`'s while-loop, every window
    intermediate kept on-chip.  `tags`/`costs` are (P, reps*trace_len)
    VMEM values pre-tiled so one dynamic slice at `cursor % trace_len`
    reads a wrapped window (a window longer than the trace wraps through
    the extra replicas)."""
    num_progs = tags.shape[0]
    sched_len = sched.shape[0]
    warange = _iota(w_pad)
    valid = warange < window
    tag_ids = jax.lax.broadcasted_iota(jnp.int32, (w_pad, t_pad), 1)
    parange = _iota(num_progs)

    def body(c: _Carry) -> _Carry:
        p = sched[c.sched_idx]
        start = jnp.remainder(c.cursors[p], trace_len)
        w_tags = jax.lax.dynamic_slice(tags, (p, start), (1, w_pad))[0]
        w_hw = jax.lax.dynamic_slice(costs, (p, start), (1, w_pad))[0]
        # padded rows are inert: tag -1 never slots, cost 0 keeps the
        # cost cumsum flat past the real window
        w_tags = jnp.where(valid, w_tags, jnp.int32(-1))
        w_hw = jnp.where(valid, w_hw, jnp.int32(0))
        slotted = w_tags >= 0

        pos = jnp.int32(pos_base) + c.steps_done + warange
        match = w_tags[:, None] == tag_ids
        occ = jnp.where(match, pos[:, None], jnp.int32(-1))
        cm = _cummax0(occ)
        # state observed by each access: the previous row's cummax (row 0
        # sees nothing in-window) floored with the carried last_pos
        prev = jnp.maximum(
            jnp.concatenate([jnp.full((1, t_pad), -1, jnp.int32),
                             cm[:-1]], axis=0),
            c.last_pos)
        sel = jnp.clip(w_tags, 0)[:, None] == tag_ids
        prev_self = jnp.sum(jnp.where(sel, prev, 0), axis=1)
        cold = slotted & (prev_self < 0)
        dist = jnp.sum((prev > prev_self[:, None]).astype(jnp.int32),
                       axis=1)
        miss = slotted & (cold | (dist >= num_active))

        cost = (w_hw + jnp.where(miss, miss_latency, 0)
                + jnp.where(cold, bs_extra, 0)).astype(jnp.int32)
        cum = c.q_cycles + _cumsum0(cost)
        expire = cum >= quanta_vec[p]
        any_exp = jnp.any(expire)
        # padded rows repeat cum[window-1], so the first expiring index is
        # always a real row when any real row expires
        first = jnp.min(jnp.where(expire, warange, jnp.int32(w_pad)))
        n_exp = jnp.where(any_exp, first + 1, jnp.int32(window))
        remaining = (jnp.int32(total_steps) - c.steps_done)
        n = jnp.minimum(n_exp, remaining)
        do_switch = any_exp & (n_exp <= remaining)

        last_row = warange == (n - 1)
        committed = jnp.max(
            jnp.where(last_row[:, None], cm, jnp.int32(-1)), axis=0)
        end_cum = jnp.sum(jnp.where(last_row, cum, 0))
        if materialise:
            cm_miss = _cummax0(jnp.where(match & miss[:, None],
                                         pos[:, None], jnp.int32(-1)))
            committed_miss = jnp.max(
                jnp.where(last_row[:, None], cm_miss, jnp.int32(-1)),
                axis=0)
            last_miss = jnp.maximum(c.last_miss, committed_miss[None, :])
        else:
            last_miss = c.last_miss
        run_cycles = (end_cum - c.q_cycles
                      + jnp.where(do_switch, handler, 0).astype(jnp.int32))
        in_run = warange < n
        onehot = (parange == p).astype(jnp.int32)
        return _Carry(
            last_pos=jnp.maximum(c.last_pos, committed[None, :]),
            last_miss=last_miss,
            cursors=c.cursors + onehot * n,
            sched_idx=jnp.where(do_switch,
                                (c.sched_idx + 1) % sched_len,
                                c.sched_idx),
            steps_done=c.steps_done + n,
            q_cycles=jnp.where(do_switch, 0, end_cum).astype(jnp.int32),
            cycles=c.cycles + onehot * run_cycles,
            instrs=c.instrs + onehot * n,
            misses=c.misses + onehot * jnp.sum(
                (miss & in_run).astype(jnp.int32)),
            bs_misses=c.bs_misses + onehot * jnp.sum(
                (cold & in_run).astype(jnp.int32)),
            switches=c.switches + do_switch.astype(jnp.int32),
        )

    return jax.lax.while_loop(
        lambda c: c.steps_done < total_steps, body, init)


def _pads(window: int, num_tags: int, trace_len: int):
    w_pad = _round_up(max(int(window), 1), _SUBLANES)
    t_pad = max(_round_up(max(int(num_tags), 1), _LANES), _LANES)
    # one extra trace replica per w_pad/trace_len so a window slice
    # starting anywhere in [0, trace_len) stays in bounds
    reps = 1 + -(-w_pad // int(trace_len))
    return w_pad, t_pad, reps


def _grid_kernel(tags_ref, costs_ref, counts_ref, lats_ref, quanta_ref,
                 sched_ref, misc_ref, cyc_ref, ins_ref, mis_ref, bsm_ref,
                 sw_ref, *, t_pad, trace_len, total_steps, window, w_pad):
    tags = tags_ref[0]
    costs = costs_ref[0]
    num_progs = tags.shape[0]
    zeros_p = jnp.zeros((num_progs,), jnp.int32)
    init = _Carry(
        last_pos=jnp.full((1, t_pad), -1, jnp.int32),
        last_miss=jnp.full((1, t_pad), -1, jnp.int32),
        cursors=zeros_p, sched_idx=jnp.int32(0), steps_done=jnp.int32(0),
        q_cycles=jnp.int32(0), cycles=zeros_p, instrs=zeros_p,
        misses=zeros_p, bs_misses=zeros_p, switches=jnp.int32(0))
    final = _window_loop(
        tags, costs, counts_ref[0], lats_ref[0], quanta_ref[0],
        sched_ref[...], misc_ref[0], misc_ref[1], init,
        trace_len=trace_len, total_steps=total_steps, window=window,
        w_pad=w_pad, t_pad=t_pad, pos_base=0, materialise=False)
    cyc_ref[0, 0, 0, 0, :] = final.cycles
    ins_ref[0, 0, 0, 0, :] = final.instrs
    mis_ref[0, 0, 0, 0, :] = final.misses
    bsm_ref[0, 0, 0, 0, :] = final.bs_misses
    sw_ref[0, 0, 0, 0] = final.switches


@functools.partial(jax.jit, static_argnames=("num_tags", "total_steps",
                                             "window", "interpret"))
def window_grid(ptags, pcosts, slot_counts, miss_latencies, quanta,
                schedule, handler, bs_miss_extra, *, num_tags: int,
                total_steps: int, window: int, interpret=None):
    """One-shot counter sweep: (B, P, N) pre-gathered tag/cost streams ->
    the 5 `InterleavedGrid` arrays, one fused-kernel cell per point of
    the (Q, B, K, L) Pallas grid.  Bit-for-bit equal to
    `stackdist_interleaved.sweep_preempted`'s jnp path."""
    ptags = jnp.asarray(ptags, jnp.int32)
    pcosts = jnp.asarray(pcosts, jnp.int32)
    slot_counts = jnp.asarray(slot_counts, jnp.int32).reshape(-1)
    miss_latencies = jnp.asarray(miss_latencies, jnp.int32).reshape(-1)
    quanta = jnp.asarray(quanta, jnp.int32)
    schedule = jnp.asarray(schedule, jnp.int32).reshape(-1)
    num_fleets, num_progs, trace_len = ptags.shape
    nq, nk, nl = quanta.shape[0], slot_counts.shape[0], \
        miss_latencies.shape[0]
    sched_len = schedule.shape[0]
    w_pad, t_pad, reps = _pads(window, num_tags, trace_len)
    tags_t = jnp.tile(ptags, (1, 1, reps))
    costs_t = jnp.tile(pcosts, (1, 1, reps))
    misc = jnp.stack([jnp.asarray(handler, jnp.int32),
                      jnp.asarray(bs_miss_extra, jnp.int32)])
    tiled = trace_len * reps
    kernel = functools.partial(
        _grid_kernel, t_pad=t_pad, trace_len=trace_len,
        total_steps=int(total_steps), window=int(window), w_pad=w_pad)
    grid = (nq, num_fleets, nk, nl)
    pvec = jax.ShapeDtypeStruct((nq, num_fleets, nk, nl, num_progs),
                                jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, num_progs, tiled), lambda q, b, k, l: (b, 0, 0)),
            pl.BlockSpec((1, num_progs, tiled), lambda q, b, k, l: (b, 0, 0)),
            pl.BlockSpec((1,), lambda q, b, k, l: (k,)),
            pl.BlockSpec((1,), lambda q, b, k, l: (l,)),
            pl.BlockSpec((1, num_progs), lambda q, b, k, l: (q, 0)),
            pl.BlockSpec((sched_len,), lambda q, b, k, l: (0,)),
            pl.BlockSpec((2,), lambda q, b, k, l: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, 1, num_progs),
                         lambda q, b, k, l: (q, b, k, l, 0)),
            pl.BlockSpec((1, 1, 1, 1, num_progs),
                         lambda q, b, k, l: (q, b, k, l, 0)),
            pl.BlockSpec((1, 1, 1, 1, num_progs),
                         lambda q, b, k, l: (q, b, k, l, 0)),
            pl.BlockSpec((1, 1, 1, 1, num_progs),
                         lambda q, b, k, l: (q, b, k, l, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda q, b, k, l: (q, b, k, l)),
        ],
        out_shape=[pvec, pvec, pvec, pvec,
                   jax.ShapeDtypeStruct((nq, num_fleets, nk, nl),
                                        jnp.int32)],
        interpret=_interp(interpret),
    )(tags_t, costs_t, slot_counts, miss_latencies, quanta, schedule, misc)


def _cell_kernel(tags_ref, costs_ref, args_ref, quanta_ref, sched_ref,
                 seed_vec_ref, seed_sca_ref, seed_last_ref, out_last_ref,
                 out_miss_ref, out_vec_ref, out_sca_ref, *, t_pad,
                 trace_len, total_steps, window, w_pad, pos_base,
                 materialise):
    tags = tags_ref[...]
    costs = costs_ref[...]
    seed_vec = seed_vec_ref[...]
    seed_sca = seed_sca_ref[...]
    init = _Carry(
        last_pos=seed_last_ref[...],
        last_miss=jnp.full((1, t_pad), -1, jnp.int32),
        cursors=seed_vec[0], sched_idx=seed_sca[0],
        steps_done=jnp.int32(0), q_cycles=seed_sca[1],
        cycles=seed_vec[1], instrs=seed_vec[2], misses=seed_vec[3],
        bs_misses=seed_vec[4], switches=seed_sca[2])
    final = _window_loop(
        tags, costs, args_ref[0], args_ref[1], quanta_ref[...],
        sched_ref[...], args_ref[2], args_ref[3], init,
        trace_len=trace_len, total_steps=total_steps, window=window,
        w_pad=w_pad, t_pad=t_pad, pos_base=pos_base,
        materialise=materialise)
    out_last_ref[...] = final.last_pos
    out_miss_ref[...] = final.last_miss
    out_vec_ref[...] = jnp.stack([final.cursors, final.cycles,
                                  final.instrs, final.misses,
                                  final.bs_misses])
    out_sca_ref[...] = jnp.stack([final.sched_idx, final.steps_done,
                                  final.q_cycles, final.switches])


@functools.partial(jax.jit, static_argnames=("num_tags", "total_steps",
                                             "window", "seeded",
                                             "materialise", "interpret"))
def window_cell(ptags, pcosts, num_active, miss_latency, quanta, schedule,
                handler, bs_miss_extra, seed=None, *, num_tags: int,
                total_steps: int, window: int, seeded: bool | None = None,
                materialise: bool = True, interpret=None):
    """One cell through the fused kernel: (P, N) streams (+ optional
    engine-coordinate seed) -> the full `CellCarry` field tuple in
    declaration order.  `seed` is (last_pos, cursors, sched_idx,
    q_cycles, cycles, instrs, misses, bs_misses, switches); None starts
    cold.  Matches `_simulate_cell(..., seed=seed,
    materialise=materialise)` bit-for-bit (its counter-tuple form is the
    tail of the returned fields)."""
    if seeded is None:
        seeded = seed is not None
    ptags = jnp.asarray(ptags, jnp.int32)
    pcosts = jnp.asarray(pcosts, jnp.int32)
    quanta = jnp.asarray(quanta, jnp.int32).reshape(-1)
    schedule = jnp.asarray(schedule, jnp.int32).reshape(-1)
    num_progs, trace_len = ptags.shape
    sched_len = schedule.shape[0]
    w_pad, t_pad, reps = _pads(window, num_tags, trace_len)
    tags_t = jnp.tile(ptags, (1, reps))
    costs_t = jnp.tile(pcosts, (1, reps))
    args = jnp.stack([jnp.asarray(num_active, jnp.int32),
                      jnp.asarray(miss_latency, jnp.int32),
                      jnp.asarray(handler, jnp.int32),
                      jnp.asarray(bs_miss_extra, jnp.int32)])
    zeros_p = jnp.zeros((num_progs,), jnp.int32)
    if seed is None:
        seed_last = jnp.full((num_tags,), -1, jnp.int32)
        seed_vec = jnp.stack([zeros_p] * 5)
        seed_sca = jnp.zeros((3,), jnp.int32)
    else:
        (s_last, s_cursors, s_sched, s_qc, s_cycles, s_instrs, s_misses,
         s_bsm, s_switches) = seed
        seed_last = jnp.asarray(s_last, jnp.int32)
        seed_vec = jnp.stack([jnp.asarray(x, jnp.int32) for x in
                              (s_cursors, s_cycles, s_instrs, s_misses,
                               s_bsm)])
        seed_sca = jnp.stack([jnp.asarray(s_sched, jnp.int32),
                              jnp.asarray(s_qc, jnp.int32),
                              jnp.asarray(s_switches, jnp.int32)])
    seed_last = jnp.full((1, t_pad), -1, jnp.int32).at[0, :num_tags].set(
        seed_last)
    tiled = trace_len * reps
    kernel = functools.partial(
        _cell_kernel, t_pad=t_pad, trace_len=trace_len,
        total_steps=int(total_steps), window=int(window), w_pad=w_pad,
        pos_base=num_tags if seeded else 0, materialise=bool(materialise))
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    out_last, out_miss, out_vec, out_sca = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[full((num_progs, tiled)), full((num_progs, tiled)),
                  full((4,)), full((num_progs,)), full((sched_len,)),
                  full((5, num_progs)), full((3,)), full((1, t_pad))],
        out_specs=[full((1, t_pad)), full((1, t_pad)),
                   full((5, num_progs)), full((4,))],
        out_shape=[jax.ShapeDtypeStruct((1, t_pad), jnp.int32),
                   jax.ShapeDtypeStruct((1, t_pad), jnp.int32),
                   jax.ShapeDtypeStruct((5, num_progs), jnp.int32),
                   jax.ShapeDtypeStruct((4,), jnp.int32)],
        interpret=_interp(interpret),
    )(tags_t, costs_t, args, quanta, schedule, seed_vec, seed_sca,
      seed_last)
    return (out_last[0, :num_tags], out_miss[0, :num_tags], out_vec[0],
            out_sca[0], out_sca[1], out_sca[2], out_vec[1], out_vec[2],
            out_vec[3], out_vec[4], out_sca[3])
