"""Pallas TPU kernel for the RWKV6 chunked recurrence (one head / program).

Grid: (batch, heads, num_chunks) — chunks innermost/sequential; the (N, N)
state matrix lives in VMEM scratch across chunks.  Per chunk (C = chunk
length, N = head dim):

    inter:  o  = (r * exp(clp)) @ S
    intra:  A[t,s] = sum_n r[t,n] k[s,n] exp(clp[t,n] - cl[s,n])   (s < t)
            o += tril(A, -1) @ v + diag-bonus(u)
    state:  S  = diag(exp(cl_C)) S + (k * exp(cl_C - cl))^T @ v

All exponents are differences of log-decay cumsums with the later index as
minuend, hence <= 0 — numerically safe in f32 without 1/cumprod tricks.
The (C, C, N) decay tensor is materialised per chunk in VMEM
(64*64*64*4 B = 1 MiB), traded against recomputation — the exp is VPU work
while both flanking contractions are MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr, *,
            chunk, num_chunks):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)      # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)    # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)         # (1, N) bonus

    cl = jnp.cumsum(lw, axis=0)              # inclusive
    clp = cl - lw                            # exclusive

    s0 = state_scr[...]
    o = jax.lax.dot((r * jnp.exp(clp)), s0)                   # inter-chunk
    # intra-chunk decay tensor (C, C, N): exponent <= 0 on the lower triangle
    diff = jnp.clip(clp[:, None, :] - cl[None, :, :], -60.0, 0.0)
    a = jnp.einsum("tn,sn,tsn->ts", r, k, jnp.exp(diff))
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(tri, a, 0.0)
    o = o + jax.lax.dot(a, v)
    o = o + jnp.sum(r * u * k, axis=1, keepdims=True) * v     # diag bonus

    cl_last = cl[-1:, :]                                      # (1, N)
    k_dec = k * jnp.exp(cl_last - cl)
    state_scr[...] = jnp.exp(cl_last).T * s0 + jax.lax.dot(k_dec.T, v)
    o_ref[0, 0, ...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, logw, u, *, chunk=64, interpret=False):
    """r,k,v,logw: (B, T, H, N); u: (H, N).  Returns o: (B, T, H, N) f32.
    (State threading across calls is the wrapper's job; the kernel starts
    from S = 0 — matching `recurrence_chunked` with zero init.)"""
    b, t, h, n = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    nc = t // chunk

    def lay(x):  # (B, T, H, N) -> (B, H, T, N)
        return x.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, chunk=chunk, num_chunks=nc)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, n), lambda b_, h_, c_: (h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, n),
                               lambda b_, h_, c_: (b_, h_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(lay(r), lay(k), lay(v), lay(logw), u)
    return out.transpose(0, 2, 1, 3)
