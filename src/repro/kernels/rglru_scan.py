"""Pallas TPU kernel for the RG-LRU linear recurrence.

Grid: (batch, width_blocks, num_chunks) — chunks innermost/sequential; the
running hidden state h (1, bw) stays in VMEM scratch.  Each program step
runs `chunk` recurrence steps over a (chunk, bw) tile with a fori_loop —
channel-parallel on the VPU lanes, sequential in time.  Gate math
(sigmoid / softplus / sqrt(1-a^2)) is fused into the same pass so a and b
are never materialised in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

C_RGLRU = 8.0


def _kernel(u_ref, wr_ref, br_ref, wi_ref, bi_ref, lam_ref, o_ref, h_scr, *,
            chunk):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0].astype(jnp.float32)          # (C, bw)
    w_r = wr_ref[0].astype(jnp.float32)       # (1, bw) row params
    b_r = br_ref[0].astype(jnp.float32)
    w_i = wi_ref[0].astype(jnp.float32)
    b_i = bi_ref[0].astype(jnp.float32)
    lam = lam_ref[0].astype(jnp.float32)

    r = jax.nn.sigmoid(u * w_r + b_r)
    i = jax.nn.sigmoid(u * w_i + b_i)
    log_a = -C_RGLRU * jax.nn.softplus(lam) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)

    def step(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        return h, out.at[t].set(h)

    h0 = h_scr[0]
    h_last, out = jax.lax.fori_loop(
        0, chunk, step, (h0, jnp.zeros_like(u)))
    h_scr[...] = h_last[None]
    o_ref[0, ...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_w",
                                             "interpret"))
def rglru_scan(u, w_r, b_r, w_i, b_i, lam, *, chunk=256, block_w=512,
               interpret=False):
    """u: (B, T, W) conv output; gate params: (W,).  Returns h: (B, T, W)
    f32 with h_0 = 0 (state threading is the wrapper's job)."""
    b, t, w = u.shape
    chunk = min(chunk, t)
    block_w = min(block_w, w)
    assert t % chunk == 0 and w % block_w == 0
    nc, nw = t // chunk, w // block_w

    def row(x):
        return x.reshape(1, w)

    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w),
                         lambda b_, w_, c_: (b_, c_, w_)),
        ] + [pl.BlockSpec((1, block_w), lambda b_, w_, c_: (0, w_))] * 5,
        out_specs=pl.BlockSpec((1, chunk, block_w),
                               lambda b_, w_, c_: (b_, c_, w_)),
        out_shape=jax.ShapeDtypeStruct((b, t, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(u, row(w_r), row(b_r), row(w_i), row(b_i), row(lam))
    return out
