"""Pallas TPU flash-decode: one query token against a long KV cache.

Grid: (batch, kv_heads, num_kv_blocks) — kv innermost/sequential; partial
(m, l, acc) statistics live in VMEM scratch across kv blocks.  The query
block is (G, dh) — all the GQA query heads of one kv head — so the MXU
contraction is (G, dh) x (dh, block_kv).  Invalid cache positions
(>= kv_len) are masked; this is the per-shard partial of the sharded
flash-decode in `repro.models.kvcache` (the cross-shard logsumexp combine
stays in shard_map/psum — a collective, not kernel, concern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_kv, num_kv, scale):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(kb * block_kv < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, dh)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)
        kpos = kb * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kb == num_kv - 1)
    def _finalise():
        o_ref[0, 0, ...] = (acc_scr[...] / jnp.maximum(
            l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *, block_kv=512,
                     interpret=False):
    """q: (B, H, dh) one token; k/v_cache: (B, S, KH, dh); kv_len: (B,)
    number of valid positions.  Returns (B, H, dh)."""
    b, h, dh = q.shape
    _, s, kh, _ = k_cache.shape
    g = h // kh
    block_kv = min(block_kv, s)
    assert s % block_kv == 0
    nk = s // block_kv

    qt = q.reshape(b, kh, g, dh)
    kt = k_cache.transpose(0, 2, 1, 3)    # (B, KH, S, dh)
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, block_kv=block_kv, num_kv=nk,
                               scale=dh ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(b, kh, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # kv_len scalar-prefetch
            pl.BlockSpec((1, 1, g, dh), lambda b_, h_, k_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b_, h_, k_: (b_, h_, k_, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b_, h_, k_: (b_, h_, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b_, h_, k_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qt.reshape(b, kh, g, dh), kt, vt)
    return out.reshape(b, h, dh)
