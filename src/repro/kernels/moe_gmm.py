"""Pallas TPU grouped expert FFN (the MoE compute hot-spot).

out[e] = (silu(x[e] @ wg[e]) * (x[e] @ wi[e])) @ wo[e]   per expert e,
x[e] being that expert's capacity buffer (from the jnp dispatch in
repro.models.moe — index bookkeeping is scalar work that belongs on the
host/VPU side, not in this kernel).

Two fused grouped-GEMM stages in one kernel:

  stage A  grid (E, C/bc, F/bf, D/bd): accumulate x@wg and x@wi in two VMEM
           scratch accumulators over the D (contraction) axis; on the last
           D step apply silu-gating and write h.
  stage B  runs as a second pallas_call with grid (E, C/bc, D/bd, F/bf):
           h @ wo accumulated over F.

Block shapes default to MXU-friendly (bc=128-512, bf/bd=512) and keep the
working set (x-block + both weight blocks + 2 accumulators) well under
VMEM:  512*512*4B * 4 ~ 4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# count-aware variant: skip empty experts entirely
# ---------------------------------------------------------------------------
#
# With slot-hit routing (repro.core.expert_slots) most tokens concentrate on
# the resident experts, leaving many capacity buffers EMPTY.  The
# scalar-prefetch grid redirects the weight-block index_map of an empty
# expert to expert 0's block — the pipeline re-uses the already-resident
# block instead of streaming new weights from HBM — and pl.when skips the
# MXU work.  Weight traffic then scales with the *resident working set*
# (the paper's slot pool), not with E.


def _gated_kernel_skip(counts_ref, x_ref, wg_ref, wi_ref, h_ref, accg, acci,
                       *, nd, gated):
    e = pl.program_id(0)
    db = pl.program_id(3)

    @pl.when(db == 0)
    def _init():
        accg[...] = jnp.zeros_like(accg)
        acci[...] = jnp.zeros_like(acci)

    @pl.when(counts_ref[e] > 0)
    def _compute():
        x = x_ref[0].astype(jnp.float32)
        accg[...] += jax.lax.dot(x, wg_ref[0].astype(jnp.float32))
        if gated:
            acci[...] += jax.lax.dot(x, wi_ref[0].astype(jnp.float32))

    @pl.when(db == nd - 1)
    def _fin():
        if gated:
            h = jax.nn.silu(accg[...]) * acci[...]
        else:
            h = jax.nn.gelu(accg[...])
        h_ref[0, ...] = jnp.where(counts_ref[e] > 0, h, 0.0).astype(
            h_ref.dtype)


def _out_kernel_skip(counts_ref, h_ref, wo_ref, o_ref, acc, *, nf):
    e = pl.program_id(0)
    fb = pl.program_id(3)

    @pl.when(fb == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    @pl.when(counts_ref[e] > 0)
    def _compute():
        acc[...] += jax.lax.dot(h_ref[0].astype(jnp.float32),
                                wo_ref[0].astype(jnp.float32))

    @pl.when(fb == nf - 1)
    def _fin():
        o_ref[0, ...] = acc[...].astype(o_ref.dtype)


def _gated_kernel(x_ref, wg_ref, wi_ref, h_ref, accg, acci, *, nd, gated):
    db = pl.program_id(3)

    @pl.when(db == 0)
    def _init():
        accg[...] = jnp.zeros_like(accg)
        acci[...] = jnp.zeros_like(acci)

    x = x_ref[0].astype(jnp.float32)
    accg[...] += jax.lax.dot(x, wg_ref[0].astype(jnp.float32))
    if gated:
        acci[...] += jax.lax.dot(x, wi_ref[0].astype(jnp.float32))

    @pl.when(db == nd - 1)
    def _fin():
        if gated:
            h = jax.nn.silu(accg[...]) * acci[...]
        else:
            h = jax.nn.gelu(accg[...])
        h_ref[0, ...] = h.astype(h_ref.dtype)


def _out_kernel(h_ref, wo_ref, o_ref, acc, *, nf):
    fb = pl.program_id(3)

    @pl.when(fb == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot(h_ref[0].astype(jnp.float32),
                            wo_ref[0].astype(jnp.float32))

    @pl.when(fb == nf - 1)
    def _fin():
        o_ref[0, ...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "gated", "block_c", "block_f", "block_d", "interpret"))
def moe_gmm_skip(x, wg, wi, wo, counts, *, gated=True, block_c=128,
                 block_f=512, block_d=512, interpret=False):
    """Count-aware grouped FFN: experts with counts[e] == 0 are skipped and
    their weight blocks never stream (index_map redirection).  Oracle:
    moe_gmm with the empty experts' outputs ignored (they are zeroed)."""
    e, c, d = x.shape
    f = wg.shape[-1]
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0
    nc, nf, nd = c // bc, f // bf, d // bd

    def live(e_, counts_ref):
        # redirect empty experts' loads to expert 0's (resident) block
        return jnp.where(counts_ref[e_] > 0, e_, 0)

    h = pl.pallas_call(
        functools.partial(_gated_kernel_skip, nd=nd, gated=gated),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(e, nc, nf, nd),
            in_specs=[
                pl.BlockSpec((1, bc, bd),
                             lambda e_, c_, f_, d_, ct: (e_, c_, d_)),
                pl.BlockSpec((1, bd, bf),
                             lambda e_, c_, f_, d_, ct: (live(e_, ct), d_, f_)),
                pl.BlockSpec((1, bd, bf),
                             lambda e_, c_, f_, d_, ct: (live(e_, ct), d_, f_)),
            ],
            out_specs=pl.BlockSpec((1, bc, bf),
                                   lambda e_, c_, f_, d_, ct: (e_, c_, f_)),
            scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32),
                            pltpu.VMEM((bc, bf), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        interpret=interpret,
    )(counts, x, wg, wi)

    out = pl.pallas_call(
        functools.partial(_out_kernel_skip, nf=nf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(e, nc, nd, nf),
            in_specs=[
                pl.BlockSpec((1, bc, bf),
                             lambda e_, c_, d_, f_, ct: (e_, c_, f_)),
                pl.BlockSpec((1, bf, bd),
                             lambda e_, c_, d_, f_, ct: (live(e_, ct), f_, d_)),
            ],
            out_specs=pl.BlockSpec((1, bc, bd),
                                   lambda e_, c_, d_, f_, ct: (e_, c_, d_)),
            scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        interpret=interpret,
    )(counts, h, wo)
    return out


@functools.partial(jax.jit, static_argnames=(
    "gated", "block_c", "block_f", "block_d", "interpret"))
def moe_gmm(x, wg, wi, wo, *, gated=True, block_c=128, block_f=512,
            block_d=512, interpret=False):
    """x: (E, C, D); wg/wi: (E, D, F); wo: (E, F, D) -> (E, C, D)."""
    e, c, d = x.shape
    f = wg.shape[-1]
    bc = min(block_c, c)
    bf = min(block_f, f)
    bd = min(block_d, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0
    nc, nf, nd = c // bc, f // bf, d // bd

    h = pl.pallas_call(
        functools.partial(_gated_kernel, nd=nd, gated=gated),
        grid=(e, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, c_, f_, d_: (e_, c_, d_)),
            pl.BlockSpec((1, bd, bf), lambda e_, c_, f_, d_: (e_, d_, f_)),
            pl.BlockSpec((1, bd, bf), lambda e_, c_, f_, d_: (e_, d_, f_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e_, c_, f_, d_: (e_, c_, f_)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32),
                        pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, wg, wi)

    out = pl.pallas_call(
        functools.partial(_out_kernel, nf=nf),
        grid=(e, nc, nd, nf),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e_, c_, d_, f_: (e_, c_, f_)),
            pl.BlockSpec((1, bf, bd), lambda e_, c_, d_, f_: (e_, f_, d_)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd),
                               lambda e_, c_, d_, f_: (e_, c_, d_)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(h, wo)
    return out
