# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas kernels for the repo's compute hot-spots.

`repro.kernels.ops` wraps the model-zoo kernels (flash/decode attention,
MoE GMM, RG-LRU and RWKV6 scans) with interpret-mode auto-selection; the
window-distance kernel — the interleaved sweep engine's fused window
pass — is re-exported here next to them (see the README kernels table).
"""
from repro.kernels.ops import (decode_attention, flash_attention, moe_gmm,
                               rglru_scan, rwkv6_scan)
from repro.kernels.window_distance import window_cell, window_grid

__all__ = ["decode_attention", "flash_attention", "moe_gmm", "rglru_scan",
           "rwkv6_scan", "window_cell", "window_grid"]
