"""Jitted public wrappers for the Pallas kernels.

`interpret=None` (default) auto-selects: compiled Pallas on TPU backends,
interpret mode elsewhere (this container is CPU-only; interpret mode runs
the kernel bodies exactly, which is what the allclose suite validates).
"""
from __future__ import annotations

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _rw


def _interp(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_kv=128, interpret=None):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, interpret=_interp(interpret))


def decode_attention(q, k_cache, v_cache, kv_len, *, block_kv=512,
                     interpret=None):
    return _dec.decode_attention(
        q, k_cache, v_cache, kv_len, block_kv=block_kv,
        interpret=_interp(interpret))


def rwkv6_scan(r, k, v, logw, u, *, chunk=64, interpret=None):
    return _rw.rwkv6_scan(r, k, v, logw, u, chunk=chunk,
                          interpret=_interp(interpret))


def rglru_scan(u, w_r, b_r, w_i, b_i, lam, *, chunk=256, block_w=512,
               interpret=None):
    return _rg.rglru_scan(u, w_r, b_r, w_i, b_i, lam, chunk=chunk,
                          block_w=block_w, interpret=_interp(interpret))


def moe_gmm(x, wg, wi, wo, *, gated=True, block_c=128, block_f=512,
            block_d=512, interpret=None):
    return _gmm.moe_gmm(x, wg, wi, wo, gated=gated, block_c=block_c,
                        block_f=block_f, block_d=block_d,
                        interpret=_interp(interpret))


def moe_gmm_skip(x, wg, wi, wo, counts, *, gated=True, block_c=128,
                 block_f=512, block_d=512, interpret=None):
    return _gmm.moe_gmm_skip(x, wg, wi, wo, counts, gated=gated,
                             block_c=block_c, block_f=block_f,
                             block_d=block_d, interpret=_interp(interpret))
