"""Pallas TPU flash attention (causal, GQA, optional sliding window).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — kv innermost
(sequential on TPU), with running flash statistics (m, l, acc) in VMEM
scratch; the output block is written once on the last kv step.

BlockSpecs tile Q/K/V to (block_q, head_dim) / (block_kv, head_dim) VMEM
windows per (b, h); head_dim is MXU-lane aligned (64/128/256 across the
assigned archs).  GQA is expressed in the K/V index_map (kv head =
q head // group).  The causal band also *skips* fully-masked kv blocks via
pl.when (no MXU work issued for them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q, block_kv, num_kv, causal, window, scale):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal band: kv block strictly above the diagonal has no unmasked
    # element; windowed attention also skips blocks older than the band
    q_lo = qb * block_q
    k_lo = kb * block_kv
    in_band = jnp.bool_(True)
    if causal:
        in_band &= k_lo <= q_lo + block_q - 1
    if window:
        in_band &= (k_lo + block_kv - 1) > (q_lo - window)

    @pl.when(in_band)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        qpos = q_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kb == num_kv - 1)
    def _finalise():
        o_ref[0, 0, ...] = (acc_scr[...] / jnp.maximum(
            l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                              "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_kv=128, interpret=False):
    """q: (B, Tq, H, dh); k/v: (B, Tk, KH, dh).  Returns (B, Tq, H, dh)."""
    b, tq, h, dh = q.shape
    _, tk, kh, _ = k.shape
    g = h // kh
    block_q = min(block_q, tq)
    block_kv = min(block_kv, tk)
    assert tq % block_q == 0 and tk % block_kv == 0, (tq, tk)
    nq, nk = tq // block_q, tk // block_kv

    qt = q.transpose(0, 2, 1, 3)   # (B, H, Tq, dh)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, num_kv=nk,
        causal=causal, window=window, scale=dh ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
