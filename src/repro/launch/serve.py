"""Serving driver: continuous batching + slot-resident experts.

    PYTHONPATH=src python -m repro.launch.serve --arch arctic-480b --smoke \
        --requests 12 --batch 4 --max-len 64

Runs the full serving path on CPU at smoke scale (the same engine code
drives a production slice with a ShardingPlan + production mesh): requests
roll through a fixed-width decode batch; MoE archs additionally report the
expert-slot disambiguator statistics.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import transformer
from repro.serve.batching import Request
from repro.serve.engine import (EngineConfig, SlotServeEngine, Tenant,
                                model_batcher)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--hit-bias", type=float, default=0.0)
    args = ap.parse_args()

    cb.load_all()
    cfg = cb.get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # --- continuous batching over a fixed-width decode batch ---
    batcher = model_batcher(cfg, params, args.batch, args.max_len)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
        batcher.submit(Request(i, prompt, max_new_tokens=args.new_tokens))
    report = batcher.run_until_drained()
    print("continuous batching:", json.dumps(report))

    # --- slot-resident expert accounting (MoE archs) ---
    if cfg.is_moe:
        tenants = []
        for i in range(3):
            bias = np.full((cfg.num_experts,), -6.0, np.float32)
            lo = (i * cfg.num_experts // 3) % cfg.num_experts
            bias[lo:lo + cfg.num_experts // 3 + 1] = 6.0
            tenants.append(Tenant(
                name=f"tenant{i}",
                tokens=rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32),
                router_bias=bias))
        eng = SlotServeEngine(
            cfg, params,
            EngineConfig(quantum_tokens=16, slots_per_shard=args.slots,
                         hit_bias=args.hit_bias),
            tenants, max_len=args.max_len)
        rep = eng.run(48)
        print("expert slots:", json.dumps(
            {k: v for k, v in rep.items() if not isinstance(v, dict)}))


if __name__ == "__main__":
    main()
