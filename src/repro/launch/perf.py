import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: lower a cell under a named variant, re-derive
the roofline terms, and append the (hypothesis, before, after) record to
experiments/perf/.

Variants are small, explicit deltas over the paper-faithful baseline:

    base          — the EXPERIMENTS.md §Roofline baseline
    dp            — pure data parallelism + ZeRO-3 (batch over all 256/512
                    chips, per-layer weight all-gather) for train cells
    dp_mb1        — dp with microbatching disabled (weight AGs amortise
                    over the whole batch; activations are tiny under dp)
    flash1024     — flash block 1024 (fewer scan trips, bigger transients)
    nochunk_loss  — disable the chunked loss (isolates its cost)

Usage:
    PYTHONPATH=src python -m repro.launch.perf --arch granite-3-2b \
        --shape train_4k --variant dp --hypothesis "..."
"""  # noqa: E402

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.analysis import hlo  # noqa: E402
from repro.configs import base as cb  # noqa: E402
from repro.launch import dryrun  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.serve import step as serve_step  # noqa: E402
from repro.sharding.partition import ShardingPlan  # noqa: E402
from repro.train import step as train_step  # noqa: E402


def lower_variant(arch: str, shape: str, variant: str, mesh):
    cfg = cb.get_config(arch)
    spec = cb.SHAPES[shape]
    if variant == "nochunk_loss":
        cfg = dataclasses.replace(cfg, loss_chunk=0)
    if variant.endswith("_noremat"):
        cfg = dataclasses.replace(cfg, remat="none")
    specs = cfg.input_specs(shape)
    if spec.kind == "train":
        plan = ShardingPlan(mesh, cfg, mode="train")
        micro = dryrun.microbatches_for(cfg)
        if variant.startswith("dp"):
            plan.strategy_override = "dp"
            plan.strategy = "dp"
            if variant == "dp_mb1":
                micro = 1
            if variant == "dp_mb4":
                micro = 4
        jitted, state_shapes, _ = train_step.jit_train_step(
            cfg, dryrun.opt_config_for(cfg), plan, specs, micro)
        return jitted.lower(state_shapes, specs)
    if spec.kind == "prefill":
        plan = ShardingPlan(mesh, cfg, mode="prefill")
        jitted, params_shapes = serve_step.jit_prefill_step(cfg, plan, specs)
        return jitted.lower(params_shapes, specs)
    plan = ShardingPlan(mesh, cfg, mode="decode")
    jitted, params_shapes, cache_shapes = serve_step.jit_decode_step(
        cfg, plan, specs, spec.global_batch, spec.seq_len)
    return jitted.lower(params_shapes, cache_shapes, specs)


def measure(arch: str, shape: str, variant: str, multi_pod=False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        lowered = lower_variant(arch, shape, variant, mesh)
        compiled = lowered.compile()
        walk = hlo.analyze_module(compiled.as_text())
        mem = compiled.memory_analysis()
    terms = hlo.roofline_terms(walk["flops"], walk["bytes"],
                               walk["collective_bytes"])
    return {
        "arch": arch, "shape": shape, "variant": variant,
        "flops_per_device": walk["flops"],
        "bytes_per_device": walk["bytes"],
        "collective_bytes_per_device": walk["collective_bytes"],
        "roofline": terms,
        "xla_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "compile_s": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="base")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    cb.load_all()
    r = measure(args.arch, args.shape, args.variant)
    r["hypothesis"] = args.hypothesis
    os.makedirs(args.out, exist_ok=True)
    fn = f"{args.arch}_{args.shape}_{args.variant}.json"
    with open(os.path.join(args.out, fn), "w") as f:
        json.dump(r, f, indent=1)
    rf = r["roofline"]
    print(f"{args.arch} x {args.shape} [{args.variant}]: "
          f"compute={rf['compute_s']:.3e}s mem={rf['memory_s']:.3e}s "
          f"coll={rf['collective_s']:.3e}s dominant={rf['dominant']}")


if __name__ == "__main__":
    main()
