"""Production meshes.  Functions, not module constants, so importing this
module never touches jax device state (the dry-run must set XLA_FLAGS
before anything initialises the backend)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16); `pod`
    composes with `data` for data parallelism (hierarchical all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced host devices (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
