import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import — jax locks the device
count at first backend init.  Every cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(**abstract inputs)
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

Cells run on the 16x16 single-pod mesh (roofline source) and the 2x16x16
multi-pod mesh (proves the `pod` axis shards).  Results land as JSON in
experiments/dryrun/ for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out DIR]
"""  # noqa: E402

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.analysis import hlo  # noqa: E402
from repro.configs import base as cb  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.serve import step as serve_step  # noqa: E402
from repro.sharding.partition import ShardingPlan  # noqa: E402
from repro.train import step as train_step  # noqa: E402

V5E_HBM = 16 * 1024**3


def opt_config_for(cfg) -> adamw.AdamWConfig:
    """>=100B: bf16 m, no fp32 master; >=300B additionally factor the
    second moment (Adafactor-style) — without it arctic-480b's optimizer
    alone exceeds the single-pod HBM budget (DESIGN.md §5)."""
    big = cfg.param_count() > 100e9
    return adamw.AdamWConfig(
        state_dtype="bfloat16" if big else "float32",
        master_fp32=not big,
        factored_v=cfg.param_count() > 300e9)


def microbatches_for(cfg) -> int:
    return 2 if cfg.param_count() > 100e9 else 1


def lower_cell(arch: str, shape: str, mesh):
    """Returns (lowered, meta) for one dry-run cell."""
    cfg = cb.get_config(arch)
    spec = cb.SHAPES[shape]
    specs = cfg.input_specs(shape)
    if spec.kind == "train":
        plan = ShardingPlan(mesh, cfg, mode="train")
        jitted, state_shapes, _ = train_step.jit_train_step(
            cfg, opt_config_for(cfg), plan, specs,
            microbatches=microbatches_for(cfg))
        lowered = jitted.lower(state_shapes, specs)
    elif spec.kind == "prefill":
        plan = ShardingPlan(mesh, cfg, mode="prefill")
        jitted, params_shapes = serve_step.jit_prefill_step(cfg, plan, specs)
        lowered = jitted.lower(params_shapes, specs)
    else:  # decode
        plan = ShardingPlan(mesh, cfg, mode="decode")
        jitted, params_shapes, cache_shapes = serve_step.jit_decode_step(
            cfg, plan, specs, spec.global_batch, spec.seq_len)
        lowered = jitted.lower(params_shapes, cache_shapes, specs)
    return lowered, {"arch": arch, "shape": shape, "kind": spec.kind,
                     "tokens": spec.global_batch * (
                         spec.seq_len if spec.kind != "decode" else 1)}


def hbm_budget(arch: str, shape: str, chips: int) -> dict:
    """Analytical per-device HBM budget (bytes) — the auditable fits-16GB
    number.  CPU-XLA's buffer assignment (temp_bytes) overestimates a TPU
    compile: it promotes flash/softmax transients to f32 without fusing
    them and keeps f32 embedding-gradient scatters live; the TPU backend
    fuses these (see EXPERIMENTS.md §Dry-run note)."""
    cfg = cb.get_config(arch)
    spec = cb.SHAPES[shape]
    n_params = cfg.param_count()
    p_bytes = 2 * n_params / chips           # bf16 params, fully sharded
    out = {"params": p_bytes}
    if spec.kind == "train":
        opt = opt_config_for(cfg)
        sd = 2 if opt.state_dtype == "bfloat16" else 4
        v_bytes = (0.02 if opt.factored_v else sd) * n_params / chips
        out["opt_mv"] = sd * n_params / chips + v_bytes
        out["master"] = (4 * n_params / chips) if opt.master_fp32 else 0.0
        out["grads"] = 2 * n_params / chips   # transient, sharded like params
        tp = 16
        b_loc = spec.global_batch / (chips // tp) / microbatches_for(cfg)
        # per-layer remat checkpoints: seq-sharded residual stream
        out["act_checkpoints"] = (
            cfg.num_layers * b_loc * spec.seq_len / tp * cfg.d_model * 2)
        # working set of one rematerialised layer (hidden + ffn blocks, f32)
        out["layer_workspace"] = b_loc * spec.seq_len * cfg.d_model * 4 * 3
    elif spec.kind == "prefill":
        tp = 16
        b_loc = spec.global_batch / (chips // tp)
        out["kv_cache_out"] = (cfg.num_layers * b_loc * spec.seq_len / tp *
                               2 * max(cfg.num_kv_heads, 1) * cfg.head_dim * 2)
        out["layer_workspace"] = b_loc * spec.seq_len * cfg.d_model * 4 * 3
    else:
        tp = 16
        b_loc = max(spec.global_batch / (chips // tp), 1)
        seq_loc = spec.seq_len / tp
        if cfg.attention_free:
            h = cfg.d_model // cfg.head_dim
            out["state"] = (cfg.num_layers * b_loc *
                            (h * cfg.head_dim ** 2 + 2 * cfg.d_model) * 4)
        elif cfg.pattern:
            n_attn = sum(1 for i in range(cfg.num_layers)
                         if cfg.pattern[i % len(cfg.pattern)] == "attn")
            out["state"] = ((cfg.num_layers - n_attn) * b_loc *
                            cfg.lru_width * cfg.conv_width * 4 +
                            n_attn * b_loc * cfg.window * 2 *
                            cfg.num_kv_heads * cfg.head_dim * 2)
        else:
            out["kv_cache"] = (cfg.num_layers * b_loc * seq_loc * 2 *
                               cfg.num_kv_heads * cfg.head_dim * 2)
        out["logits"] = b_loc * cfg.vocab * 4
    out["total"] = float(sum(out.values()))
    return out


def analyse(lowered, compiled, meta, chips: int) -> dict:
    cost = hlo.xla_cost_analysis(compiled)  # list-vs-dict across jax pins
    mem = compiled.memory_analysis()
    # XLA's cost_analysis counts scan bodies once (not x trip count) — the
    # graph walker in repro.analysis.hlo applies while-loop multipliers
    walk = hlo.analyze_module(compiled.as_text())
    flops = float(walk["flops"])
    bytes_acc = float(walk["bytes"])
    out = dict(meta)
    out.update({
        "chips": chips,
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": int(walk["collective_bytes"]),
        "collectives": walk["collectives"],
        "xla_cost_analysis": {  # reference only: scan bodies counted once
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": hlo.roofline_terms(
            flops, bytes_acc, walk["collective_bytes"]),
    })
    budget = hbm_budget(meta["arch"], meta["shape"], chips)
    out["memory"]["hbm_budget"] = budget
    out["memory"]["fits_hbm"] = bool(budget["total"] < V5E_HBM)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    with mesh:
        lowered, meta = lower_cell(arch, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        result = analyse(lowered, compiled, meta, chips)
    result["mesh"] = "2x16x16" if multi_pod else "16x16"
    result["lower_s"] = round(t_lower, 1)
    result["compile_s"] = round(t_compile, 1)
    fn = f"{arch}_{shape}_{result['mesh'].replace('x','-')}.json"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cb.load_all()
    cells = cb.cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch} x {shape} x {'2x16x16' if multi else '16x16'}"
            try:
                r = run_cell(arch, shape, multi, args.out)
                rf = r["roofline"]
                print(f"OK   {tag}: dominant={rf['dominant']} "
                      f"compute={rf['compute_s']:.3e}s "
                      f"mem={rf['memory_s']:.3e}s "
                      f"coll={rf['collective_s']:.3e}s "
                      f"peak={r['memory']['temp_bytes']} "
                      f"(compile {r['compile_s']}s)", flush=True)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e!r}", flush=True)
                traceback.print_exc()
    print(f"\n{len(cells) * len(meshes) - len(failures)} passed, "
          f"{len(failures)} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
