"""End-to-end training driver with the fault-tolerant runtime.

CPU-scale example (the examples/ scripts call this):

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt --batch 8 --seq 128

On a real slice the same driver runs the full config on
`make_production_mesh()`; everything below is mesh-size agnostic.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import base as cb
from repro.data import pipeline
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import fault
from repro.sharding.partition import ShardingPlan
from repro.train import step as train_step_mod


def build(cfg, opt_cfg, mesh, batch: int, seq: int, microbatches: int = 1):
    plan = ShardingPlan(mesh, cfg, mode="train") if mesh is not None else None
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if not cfg.embed_inputs:
        specs = {
            "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                           jnp.dtype(cfg.dtype)),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    if cfg.pos == "mrope":
        specs["positions"] = jax.ShapeDtypeStruct((batch, seq, 3), jnp.int32)
    if mesh is not None:
        jitted, state_shapes, st_sh = train_step_mod.jit_train_step(
            cfg, opt_cfg, plan, specs, microbatches)
        batch_sh = plan.input_shardings(specs)
    else:
        jitted = jax.jit(train_step_mod.make_train_step(
            cfg, opt_cfg, None, microbatches), donate_argnums=(0,))
        state_shapes, st_sh, batch_sh = None, None, None
    return jitted, plan, specs, batch_sh


def batch_for(cfg, dcfg, step, batch_sh, specs):
    tokens = pipeline.global_batch_at(dcfg, step)
    out = {}
    if "tokens" in specs:
        out["tokens"] = jnp.asarray(tokens)
    else:
        key = jax.random.PRNGKey(step)
        out["embeds"] = jax.random.normal(
            key, specs["embeds"].shape, specs["embeds"].dtype) * 0.02
        out["labels"] = jnp.asarray(tokens)
    if "positions" in specs:
        b, t = tokens.shape
        out["positions"] = jnp.broadcast_to(
            jnp.arange(t, dtype=jnp.int32)[None, :, None], (b, t, 3))
    if batch_sh is not None:
        out = {k: jax.device_put(v, batch_sh[k]) for k, v in out.items()}
    return out


def run(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
        seq: int = 128, ckpt_dir: str | None = None, ckpt_every: int = 20,
        mesh=None, fail_at: int | None = None, lr: float = 1e-3,
        log_every: int = 10, microbatches: int = 1) -> dict:
    cb.load_all()
    cfg = cb.get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup=max(steps // 10, 1),
                                total_steps=steps)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=seq,
                               global_batch=batch)
    jitted, plan, specs, batch_sh = build(cfg, opt_cfg, mesh, batch, seq,
                                          microbatches)
    losses = []

    def fresh_state():
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        return adamw.init_state(opt_cfg, params)

    def init_fn():
        if ckpt_dir:
            last = ckpt.latest_step(ckpt_dir)
            if last is not None:
                shapes = jax.eval_shape(fresh_state)
                return ckpt.restore(ckpt_dir, last, shapes), last
        return fresh_state(), 0

    def step_fn(state, step):
        b = batch_for(cfg, dcfg, step, batch_sh, specs)
        state, metrics = jitted(state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return state, metrics

    def save_fn(state, step):
        if ckpt_dir:
            ckpt.save(ckpt_dir, step, state)

    failed = {"done": False}

    def fail_hook(step):
        if fail_at is not None and step == fail_at and not failed["done"]:
            failed["done"] = True
            raise fault.TrainingFailure(f"injected failure at step {step}")

    hb = fault.Heartbeat(f"/tmp/heartbeat_{arch}.json") if ckpt_dir else None
    report = fault.run_supervised(
        init_fn=init_fn, step_fn=step_fn, save_fn=save_fn,
        restore_fn=lambda: init_fn(), num_steps=steps,
        ckpt_every=ckpt_every, heartbeat=hb,
        straggler=fault.StragglerMonitor(),
        fail_hook=fail_hook if fail_at is not None else None)
    report["losses"] = losses
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()
    report = run(args.arch, smoke=args.smoke, steps=args.steps,
                 batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                 ckpt_every=args.ckpt_every, fail_at=args.fail_at)
    print(json.dumps({k: v for k, v in report.items() if k != "losses"},
                     indent=1))


if __name__ == "__main__":
    main()
