"""Serving step builders: prefill and single-token decode, fully sharded.

`serve_step` (decode) is what the `decode_32k` / `long_500k` dry-run cells
lower: one new token per sequence against a max-context cache.  The cache
is sharded (batch -> data, seq -> model) and flash-decode combines shard
partials via psum (repro.models.kvcache).
"""
from __future__ import annotations

import jax

from repro.models import transformer
from repro.sharding.partition import ShardingPlan


def abstract_params(cfg):
    return jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_cache(cfg, batch: int, length: int):
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, length))


def make_prefill(cfg, plan: ShardingPlan):
    def prefill_step(params, batch):
        logits, cache, aux = transformer.prefill(cfg, params, batch,
                                                 shd=plan)
        loads = [a["expert_load"] for seg in aux for a in seg
                 if isinstance(a, dict) and "expert_load" in a]
        return logits, cache, loads
    return prefill_step


def make_decode(cfg, plan: ShardingPlan):
    def decode(params, cache, batch):
        logits, cache, aux = transformer.decode_step(cfg, params, batch,
                                                     cache, shd=plan)
        loads = [a["expert_load"] for seg in aux for a in seg
                 if isinstance(a, dict) and "expert_load" in a]
        return logits, cache, loads
    return decode


def jit_decode_step(cfg, plan: ShardingPlan, batch_specs, batch: int,
                    length: int):
    params_shapes = abstract_params(cfg)
    params_sh = plan.param_shardings(params_shapes)
    cache_shapes = abstract_cache(cfg, batch, length)
    cache_sh = plan.cache_shardings(cache_shapes)
    batch_sh = plan.input_shardings(batch_specs)
    jitted = jax.jit(
        make_decode(cfg, plan),
        in_shardings=(params_sh, cache_sh, batch_sh),
        out_shardings=(None, cache_sh, None),
        donate_argnums=(1,),
    )
    return jitted, params_shapes, cache_shapes


def jit_prefill_step(cfg, plan: ShardingPlan, batch_specs):
    params_shapes = abstract_params(cfg)
    params_sh = plan.param_shardings(params_shapes)
    batch_sh = plan.input_shardings(batch_specs)
    fn = make_prefill(cfg, plan)
    # the emitted cache leaves prefill in the DECODE layout (batch->data,
    # seq->model): without this the per-device KV output alone busts the
    # HBM budget for the 32k MoE/large-vocab cells
    out_shapes = jax.eval_shape(fn, params_shapes, batch_specs)
    cache_sh = plan.cache_shardings(out_shapes[1])
    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, batch_sh),
        out_shardings=(None, cache_sh, None),
    )
    return jitted, params_shapes


def _num_moe_layers(cfg) -> int:
    return sum(cfg.moe_layer_mask()) if cfg.is_moe else 0
