"""Slot-aware multi-tenant serving engine — the paper's §VI-C at the
serving level.

Mapping (DESIGN.md §2): tenants are processes; each tenant's routing
distribution is its instruction mix; per-device expert slots are the
reconfigurable regions; the round-robin token quantum is FreeRTOS's timer
quantum.  Per decode step the engine:

  1. picks the active tenant (round-robin, `quantum_tokens` per turn);
  2. runs the jitted decode step on that tenant's batch/cache;
  3. feeds the per-layer expert-load vectors into each model-shard's
     block-LRU disambiguator (repro.core.expert_slots) — misses are slot
     fills costed at bytes/bandwidth;
  4. optionally computes a *slot-hit routing* bias from the resident sets
     (the beyond-paper knob): +hit_bias on resident experts' logits.

The report gives per-tenant tokens, hit rates, modelled fill seconds and
modelled step seconds — the quantities behind benchmarks/bench_expert_slots.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expert_slots as es
from repro.core import isa, simulator
from repro.models import transformer


@dataclass
class Tenant:
    name: str
    tokens: np.ndarray            # (B, T) prompt/stream tokens
    # the tenant's "extension working set": a fixed router bias favouring
    # its preferred experts (the process binary carrying its own
    # instruction extensions, paper §IV)
    router_bias: np.ndarray | None = None
    position: int = 0
    done_tokens: int = 0
    cache: object = None


@dataclass
class EngineConfig:
    quantum_tokens: int = 32      # tokens per tenant turn (OS quantum)
    slots_per_shard: int = 4      # resident experts per model shard
    expert_shards: int = 1        # model-axis shards holding experts
    hit_bias: float = 0.0         # 0 = paper-faithful LRU (no reroute)
    fill_bandwidth: float = 50e9  # bytes/s for slot fills (PCIe-class)
    compute_s_per_token: float = 1e-3  # modelled decode compute time


class SlotServeEngine:
    def __init__(self, cfg, params, engine_cfg: EngineConfig,
                 tenants: list[Tenant], max_len: int = 128, shd=None):
        self.cfg = cfg
        self.params = params
        self.ecfg = engine_cfg
        self.tenants = tenants
        self.shd = shd
        self.max_len = max_len
        mlp_mats = 3 if cfg.mlp in ("swiglu", "gelu_glu") else 2
        expert_bytes = mlp_mats * cfg.d_model * cfg.d_ff * 2
        e_per_shard = max(cfg.num_experts // engine_cfg.expert_shards, 1)
        self.slot_cfg = es.ExpertSlotConfig(
            num_experts=e_per_shard,
            slots_per_device=engine_cfg.slots_per_shard,
            expert_bytes=expert_bytes,
            fill_bandwidth=engine_cfg.fill_bandwidth,
            hit_bias=engine_cfg.hit_bias)
        self.shard_states = [es.init_state(self.slot_cfg)
                             for _ in range(engine_cfg.expert_shards)]
        self.deferred: list[Tenant] = []   # tenants parked by admission
        self.stats = {"fills": 0, "accesses": 0, "fill_seconds": 0.0,
                      "steps": 0, "per_tenant": {t.name: 0 for t in tenants}}
        for t in tenants:
            t.cache = transformer.init_cache(cfg, t.tokens.shape[0], max_len)
        self._decode = jax.jit(
            lambda params, cache, batch: transformer.decode_step(
                self.cfg, params, batch, cache, shd=self.shd),
            donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _router_bias(self, tenant: Tenant):
        if not self.cfg.is_moe:
            return None
        bias = np.zeros((self.cfg.num_experts,), np.float32)
        if tenant.router_bias is not None:
            bias += tenant.router_bias
        if self.ecfg.hit_bias != 0.0:
            e_per = self.slot_cfg.num_experts
            for s, st in enumerate(self.shard_states):
                res = np.asarray(st.resident)
                bias[s * e_per:(s + 1) * e_per] += res * self.ecfg.hit_bias
        if not bias.any():
            return None
        return jnp.asarray(bias)

    def _account(self, loads):
        """Feed per-layer global expert loads into the shard slot pools.
        Each aux entry is stacked (num_layers_in_segment, E) by the layer
        scan — account layer by layer (each MoE layer's slot pool is the
        same physical pool here; finer per-layer pools are a knob)."""
        e_per = self.slot_cfg.num_experts
        for stacked in loads:
            stacked = np.atleast_2d(np.asarray(stacked))
            for load in stacked:
                for s in range(self.ecfg.expert_shards):
                    shard_load = load[s * e_per:(s + 1) * e_per]
                    ids = np.nonzero(shard_load)[0]
                    if len(ids) == 0:
                        continue
                    st, stats = es.access_block(
                        self.shard_states[s], jnp.asarray(ids, jnp.int32),
                        self.slot_cfg)
                    self.shard_states[s] = st
                    self.stats["fills"] += int(stats.misses)
                    self.stats["accesses"] += int(stats.accessed)
                    self.stats["fill_seconds"] += float(stats.fill_seconds)

    def _decode_once(self, tenant: Tenant):
        b = tenant.tokens.shape[0]
        pos = min(tenant.position, self.max_len - 1)
        batch = {
            "positions": jnp.full((b,), pos, jnp.int32),
        }
        if self.cfg.embed_inputs:
            batch["tokens"] = jnp.asarray(
                tenant.tokens[:, pos % tenant.tokens.shape[1]][:, None])
        else:
            batch["embeds"] = jnp.zeros((b, 1, self.cfg.d_model),
                                        jnp.dtype(self.cfg.dtype))
        rb = self._router_bias(tenant)
        if rb is not None:
            batch["router_bias"] = rb
        logits, cache, aux = self._decode(self.params, tenant.cache, batch)
        tenant.cache = cache
        tenant.position += 1
        tenant.done_tokens += b
        loads = [a["expert_load"] for seg in aux for a in seg
                 if isinstance(a, dict) and "expert_load" in a]
        self._account(loads)

    # ------------------------------------------------------------------
    def fleet_contention(self, tenant_benches: dict[str, str],
                         **kw) -> dict:
        """Slot-contention estimate for this engine's tenant set.

        `tenant_benches` maps tenant name -> instruction-mix profile
        (benchmark name).  Slot count defaults to the engine's
        `slots_per_shard`; everything else forwards to
        `estimate_fleet_contention`.
        """
        benches = [tenant_benches[t.name] for t in self.tenants]
        kw.setdefault("num_slots", self.ecfg.slots_per_shard)
        return estimate_fleet_contention(benches, **kw)

    # ------------------------------------------------------------------
    def plan_coresidency(self, tenant_benches: dict[str, str], *,
                         slo: float = 1.5, num_cores: int = 1,
                         model=None, max_rounds: int = 8,
                         slo_weights: dict[str, float] | None = None):
        """Contention-aware admission plan for this engine's tenant set.

        Instead of taking tenant order as given, ask `repro.sched` which
        tenants should co-reside: tenants are placed onto `num_cores`
        model replicas minimising predicted worst-tenant slot contention,
        and any tenant whose best placement still violates the slowdown
        `slo` is deferred.  `slo_weights` (name -> positive weight)
        protects foreground tenants: deferral picks the worst
        slowdown/weight, so batch tenants absorb contention first.
        Returns the `AdmissionDecision`; use `apply_admission` to restrict
        this engine to one core's residents.
        """
        from repro.sched.admission import AdmissionController
        from repro.sched.placement import ContentionModel, PlacementConfig

        if model is None:
            model = ContentionModel(
                PlacementConfig(num_slots=self.ecfg.slots_per_shard))
        ctrl = AdmissionController(slo=slo, num_cores=num_cores,
                                   model=model, max_rounds=max_rounds)
        return ctrl.decide({t.name: tenant_benches[t.name]
                            for t in self.tenants},
                           slo_weights=slo_weights)

    def serve_online(self, events, *, policy: str = "warm",
                     num_cores: int = 2, model=None, online_cfg=None,
                     num_epochs: int | None = None, apply_core=None,
                     faults=None, recovery: str = "warm"):
        """Serve a churn workload (tenants arriving/leaving mid-serve)
        with online re-placement — the dynamic counterpart of the static
        `plan_coresidency` flow.

        `events` is a sequence of `repro.sched.TenantEvent`s; the epoch
        loop (`repro.sched.online.OnlineReplacer`) carries warm
        slot/bitstream state per core across epochs and, under the default
        "warm" policy, migrates a tenant only when the predicted
        contention saving beats the measured warm-state migration penalty.
        Every epoch is 100% fast path: the per-epoch advances and the
        migration probes resume `FleetState`s through the interleaved
        engine's resumable entry, and the contention model's one-shot
        sweeps ride its windowed entry — no cycle-by-cycle scan anywhere
        in the loop.  Returns the `OnlineReport`.  With `apply_core=<i>` the engine
        afterwards restricts itself to the tenants the final placement
        left on that core (deferred/other-core tenants are parked like
        `apply_admission` does).

        `faults` (a `repro.sched.FaultPlan`) injects a deterministic
        fault storm into the serve; `recovery` picks the reaction
        (`repro.sched.RECOVERY_POLICIES`: "warm" evacuation /
        "cold_restart" / "none") — the report's `fault_log` and
        `worst_lifetime_slowdown` quantify the outcome.  Faulted epochs
        may route segments through the cycle-by-cycle scan: SEU- or
        flush-mutated caches are not interleaved-seedable until they
        re-warm, and degraded (masked) cores always scan.
        """
        from repro.sched.online import OnlineConfig, OnlineReplacer
        from repro.sched.placement import PlacementConfig

        if online_cfg is None:
            online_cfg = OnlineConfig(
                num_cores=num_cores,
                placement=PlacementConfig(
                    num_slots=self.ecfg.slots_per_shard))
        rep = OnlineReplacer(online_cfg, model=model, policy=policy,
                             faults=faults,
                             recovery=recovery).run(events, num_epochs)
        if apply_core is not None:
            if not 0 <= apply_core < len(rep.final_cores):
                raise ValueError(
                    f"core index {apply_core} out of range for "
                    f"{len(rep.final_cores)} cores")
            keep_names = set(rep.final_cores[apply_core])
            keep = [t for t in self.tenants if t.name in keep_names]
            self.deferred += [t for t in self.tenants
                              if t.name not in keep_names]
            self.tenants = keep
        return rep

    def apply_admission(self, decision, core: int = 0) -> list[Tenant]:
        """Keep only `core`'s admitted co-residents; park everything else.

        Deferred (and other-core) tenants move to `self.deferred` so the
        caller can serve them in a later round or on another replica.
        Returns the retained tenant list (in placement order).
        """
        keep_names: tuple[str, ...] = ()
        if decision.placement is not None:
            if not 0 <= core < len(decision.placement.cores):
                raise ValueError(
                    f"core index {core} out of range for a placement with "
                    f"{len(decision.placement.cores)} cores")
            keep_names = decision.placement.cores[core]
        by_name = {t.name: t for t in self.tenants}
        keep = [by_name[n] for n in keep_names if n in by_name]
        kept = {t.name for t in keep}
        self.deferred += [t for t in self.tenants if t.name not in kept]
        self.tenants = keep
        return keep

    # ------------------------------------------------------------------
    def run(self, total_steps: int) -> dict:
        if not self.tenants:
            raise ValueError(
                "engine has no resident tenants (all deferred by "
                "admission?) — nothing to serve")
        ti = 0
        quantum_left = self.ecfg.quantum_tokens
        for _ in range(total_steps):
            tenant = self.tenants[ti]
            self._decode_once(tenant)
            self.stats["steps"] += 1
            self.stats["per_tenant"][tenant.name] += 1
            quantum_left -= tenant.tokens.shape[0]
            if quantum_left <= 0:
                ti = (ti + 1) % len(self.tenants)
                quantum_left = self.ecfg.quantum_tokens
        s = self.stats
        hit_rate = (1.0 - s["fills"] / s["accesses"]
                    if s["accesses"] else 1.0)
        compute_s = s["steps"] * self.ecfg.compute_s_per_token
        return {
            **s,
            "hit_rate": hit_rate,
            "modelled_compute_s": compute_s,
            "overhead_frac": s["fill_seconds"] /
            max(compute_s + s["fill_seconds"], 1e-12),
        }


def estimate_fleet_contention(benches: list[str], *, num_slots: int = 4,
                              miss_latency: int = 50,
                              quantum_cycles=20_000,
                              handler_cycles: int = 150,
                              priorities=None,
                              scenarios=None,
                              trace_len: int = 60_000,
                              total_steps: int = 160_000) -> dict:
    """Multi-tenant slot-contention estimate from the core fleet simulator.

    Maps each tenant to an instruction-mix profile (an Embench name from
    `repro.core.traces` or a model-zoo "<arch>:<phase>" workload from
    `repro.workloads`) and runs the SAME `simulate_many` machinery that
    produces the paper's Fig. 7 numbers: one reconfigurable core, round-robin
    quantum, slot state persisting across switches.  Per tenant it reports
    the fleet CPI, the solo (unpreempted) CPI, and their ratio — the
    contention slowdown a tenant should expect from co-residency — plus
    fleet-level switch/miss counters.

    `scenarios` may be one `SlotScenario` or a per-tenant list (tenants can
    disagree about which opcodes are slotted).  `quantum_cycles` may be a
    per-tenant vector and `priorities` a per-tenant weight tuple — the
    heterogeneous-quantum / weighted-round-robin axes of `SchedulerConfig`.
    """
    if scenarios is None:
        scenarios = isa.SCENARIO_2
    cfg = simulator.ReconfigConfig(num_slots=num_slots,
                                   miss_latency=miss_latency)
    sched = simulator.SchedulerConfig(quantum_cycles=quantum_cycles,
                                      handler_cycles=handler_cycles,
                                      priorities=priorities)
    # resolve_trace: Embench names pass through to core_traces bit-for-bit;
    # "<arch>:<phase>" names lower the model zoo (lazy import keeps the
    # serve layer importable without the model/configs stack)
    from repro import workloads

    tr = np.stack([workloads.resolve_trace(n, trace_len) for n in benches])
    # one-shot preempted fleet with a warm bitstream cache: the dispatcher
    # serves this from the interleave-aware stack-distance engine
    # (scheduler-window replay, bit-for-bit equal to the scan)
    fleet = simulator.simulate_many(tr, cfg, scenarios, sched, total_steps)

    # solo reference: each tenant alone on the core, never preempted — both
    # branches route through `sweep_fleet`, whose dispatcher collapses these
    # warm-cache unpreempted runs into stack-distance passes (no scan)
    solo_sched = simulator.SchedulerConfig.no_preempt(handler_cycles)
    if isinstance(scenarios, (list, tuple)):
        # per-tenant taxonomies: one P=1 sweep cell per (bench, scenario)
        solo_cpis = [
            float(np.asarray(simulator.sweep_fleet(
                tr[i:i + 1, None, :], [miss_latency], s, solo_sched,
                slot_counts=[num_slots],
                total_steps=trace_len).cpi)[0, 0, 0, 0])
            for i, s in enumerate(scenarios)]
    else:
        # shared taxonomy: all P solo runs as one batched sweep cell
        solo = simulator.sweep_fleet(
            tr[:, None, :], [miss_latency], scenarios, solo_sched,
            slot_counts=[num_slots], total_steps=trace_len)
        solo_cpis = [float(c) for c in np.asarray(solo.cpi)[:, 0, 0, 0]]
    per_tenant = {}
    fleet_cpi = np.asarray(fleet.cpi)
    fleet_instrs = np.asarray(fleet.instructions)
    for i, name in enumerate(benches):
        solo_cpi = solo_cpis[i]
        # a tenant the round-robin never reached (total_steps exhausted
        # inside earlier quanta) has no CPI — report NaN, not the
        # "zero slowdown" that a 0/instructions division would fake
        scheduled = int(fleet_instrs[i]) > 0
        cpi_i = float(fleet_cpi[i]) if scheduled else float("nan")
        per_tenant[f"{i}:{name}"] = {
            "fleet_cpi": cpi_i,
            "solo_cpi": solo_cpi,
            "contention_slowdown": cpi_i / solo_cpi,
            "slot_misses": int(np.asarray(fleet.slot_misses)[i]),
            "scheduled": scheduled,
        }
    return {
        "tenants": per_tenant,
        "switches": int(fleet.switches),
        "total_slot_misses": int(np.asarray(fleet.slot_misses).sum()),
        "num_slots": num_slots,
        "miss_latency": miss_latency,
        "quantum_cycles": quantum_cycles,
    }


def model_batcher(cfg, params, batch_size: int, max_len: int, shd=None):
    """A ContinuousBatcher wired to the real model: per-row prompt prefill
    writes the (1, T) prefill cache into the shared fixed-width decode
    cache; the decode callback is the jitted single-token step."""
    import jax.numpy as jnp

    from repro.serve.batching import ContinuousBatcher

    cache = transformer.init_cache(cfg, batch_size, max_len)
    decode_fn = jax.jit(
        lambda p, c, b: transformer.decode_step(cfg, p, b, c, shd=shd))

    def prefill_row(row, tokens):
        nonlocal cache
        t0 = len(tokens)
        _, row_cache, _ = transformer.prefill(
            cfg, params, {"tokens": jnp.asarray(tokens)[None, :]}, shd=shd)

        def write(dst, src):
            # dst: (n, B, S, ...) shared cache; src: (n, 1, t0, ...) row
            if dst.ndim >= 3 and src.shape[2] == t0 and \
                    dst.shape[2] >= t0 and dst.shape[1] == batch_size:
                return dst.at[:, row, :t0].set(src[:, 0].astype(dst.dtype))
            if dst.ndim >= 2 and dst.shape[1] == batch_size:
                return dst.at[:, row].set(src[:, 0].astype(dst.dtype))
            return dst

        cache = jax.tree_util.tree_map(write, cache, row_cache)

    def decode(tokens, positions):
        nonlocal cache
        logits, cache, _ = decode_fn(
            params, cache,
            {"tokens": jnp.asarray(tokens),
             "positions": jnp.asarray(positions)})
        return np.asarray(jnp.argmax(logits[:, 0], axis=-1))

    return ContinuousBatcher(batch_size, max_len, prefill_row=prefill_row,
                             decode=decode)
