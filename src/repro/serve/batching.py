"""Continuous batching for the serving engine.

The decode step function has a fixed batch width B; real request streams do
not.  The `ContinuousBatcher` keeps a fixed-width decode batch whose ROWS
are independently leased to requests: finished sequences release their row,
queued requests claim it (their prompt is prefilled into the row's cache
slice at claim time).  The decode step then always runs at full shape —
no recompilation, no head-of-line blocking on long generations.

The row lease also carries the request's *extension working set* (the
paper's process identity): the engine can aggregate the active rows' router
biases so the slot pool serves the union of resident tenants, making
continuous batching and the slot architecture compose.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) token ids
    max_new_tokens: int
    router_bias: np.ndarray | None = None
    generated: list = field(default_factory=list)
    row: int | None = None

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


@dataclass
class RowState:
    request: Request | None = None
    position: int = 0                   # next absolute position in the row


class ContinuousBatcher:
    """Fixed-width rolling decode batch.

    The model-side callbacks are injected so the batcher is backend
    agnostic (tests drive it with a toy step):

        prefill_row(row, tokens) -> None   # write prompt KV into row
        decode(tokens (B,1), positions (B,)) -> next_token (B,)
    """

    def __init__(self, batch_size: int, max_len: int, *, prefill_row,
                 decode):
        self.rows = [RowState() for _ in range(batch_size)]
        self.max_len = max_len
        self.queue: collections.deque[Request] = collections.deque()
        self.finished: list[Request] = []
        self._prefill_row = prefill_row
        self._decode = decode
        self.steps = 0
        self.occupancy_log: list[int] = []

    # -- admission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, row in enumerate(self.rows):
            if row.request is not None or not self.queue:
                continue
            req = self.queue.popleft()
            req.row = i
            row.request = req
            row.position = len(req.prompt)
            self._prefill_row(i, req.prompt)

    # -- one decode step over the full fixed-width batch ----------------
    def step(self) -> int:
        """Runs one decode step; returns the number of active rows."""
        self._admit()
        active = [r for r in self.rows if r.request is not None]
        if not active:
            return 0
        b = len(self.rows)
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        for i, row in enumerate(self.rows):
            if row.request is None:
                continue
            last = (row.request.generated[-1] if row.request.generated
                    else row.request.prompt[-1])
            tokens[i, 0] = last
            positions[i] = row.position
        nxt = np.asarray(self._decode(tokens, positions))
        for i, row in enumerate(self.rows):
            req = row.request
            if req is None:
                continue
            req.generated.append(int(nxt[i]))
            row.position += 1
            if req.done or row.position >= self.max_len:
                self.finished.append(req)
                row.request = None      # row released for the queue
        self.steps += 1
        self.occupancy_log.append(len(active))
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> dict:
        while (self.queue or any(r.request for r in self.rows)) and \
                self.steps < max_steps:
            self.step()
        occ = np.asarray(self.occupancy_log, np.float64)
        return {
            "steps": self.steps,
            "finished": len(self.finished),
            "mean_occupancy": float(occ.mean()) if len(occ) else 0.0,
            "batch_size": len(self.rows),
        }

    # -- slot integration ------------------------------------------------
    def active_router_bias(self, num_experts: int) -> np.ndarray | None:
        """Union of the active rows' tenant working sets (max per expert)."""
        biases = [r.request.router_bias for r in self.rows
                  if r.request is not None
                  and r.request.router_bias is not None]
        if not biases:
            return None
        return np.max(np.stack(biases), axis=0)
