"""AdamW with dtype-configurable state (distributed-optimization trick).

For >=100B models the optimizer footprint dominates: full fp32 Adam is
16 bytes/param (master+m+v+grad).  We keep a knob: m/v in bf16 and an
optional fp32 master copy.  With ZeRO sharding (states sharded over `data`)
arctic-480b training fits the single-pod 4 TB HBM budget (EXPERIMENTS.md
§Dry-run).  Global-norm clipping included; weight decay skips norms/biases.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"    # "bfloat16" for >=100B models
    master_fp32: bool = True        # keep fp32 master when params are bf16
    factored_v: bool = False        # Adafactor-style row/col second moment
                                    # for >=2D leaves (>=300B models): cuts
                                    # v from O(params) to O(rows+cols)
    warmup: int = 100
    schedule: str = "cosine"        # cosine | constant
    total_steps: int = 10_000


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    m: Any
    v: Any
    master: Any          # fp32 master copy or None


def _lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    if cfg.schedule == "cosine":
        frac = jnp.clip((step - cfg.warmup) /
                        max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
        base = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    else:
        base = 1.0
    return cfg.lr * warm * base


def _v_init(cfg, p):
    if cfg.factored_v and p.ndim >= 2:
        return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
    return jnp.zeros(p.shape, jnp.dtype(cfg.state_dtype))


def init_state(cfg: AdamWConfig, params) -> TrainState:
    sd = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sd)
    needs_master = cfg.master_fp32 and any(
        l.dtype == jnp.bfloat16 for l in jax.tree_util.tree_leaves(params))
    master = (jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32), params) if needs_master else None)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(lambda p: _v_init(cfg, p), params),
        master=master,
    )


def _decay_mask(params):
    def mask(path, leaf):
        name = jax.tree_util.keystr(path)
        return leaf.ndim >= 2 and not any(
            t in name for t in ("ln1", "ln2", "final_norm", "mu", "w0",
                                "lam", "b_r", "b_i", "ln_o"))
    return jax.tree_util.tree_map_with_path(mask, params)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)))
        for l in jax.tree_util.tree_leaves(tree)))


def apply_updates(cfg: AdamWConfig, state: TrainState, grads) -> tuple[
        TrainState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = _lr_at(cfg, step)
    sd = jnp.dtype(cfg.state_dtype)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    wd_mask = _decay_mask(state.params)

    ref = state.master if state.master is not None else state.params

    def upd(g, m, v, p_ref, decay):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        mhat = m32 / b1c
        if isinstance(v, dict):  # factored second moment
            g2 = g * g + 1e-30
            r = cfg.b2 * v["r"] + (1 - cfg.b2) * g2.mean(axis=-1)
            c = cfg.b2 * v["c"] + (1 - cfg.b2) * g2.mean(axis=-2)
            rhat, chat = r / b2c, c / b2c
            denom = rhat.mean(axis=-1, keepdims=True)
            vhat = (rhat[..., None] * chat[..., None, :]
                    / jnp.maximum(denom[..., None], 1e-30))
            v_new = {"r": r, "c": c}
        else:
            v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
            vhat = v32 / b2c
            v_new = v32.astype(sd)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p_ref.astype(jnp.float32)
        if decay:
            delta = delta + cfg.weight_decay * p32
        p_new = p32 - lr * delta
        return p_new, m32.astype(sd), v_new

    flat_ref, treedef = jax.tree_util.tree_flatten(ref)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)   # dicts stay unflattened leaves
    flat_mask = treedef.flatten_up_to(_decay_mask(ref))
    new_p32, new_m, new_v = [], [], []
    for g, m, v, p, dm in zip(flat_g, flat_m, flat_v, flat_ref, flat_mask):
        pn, mn, vn = upd(g, m, v, p, dm)
        new_p32.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    p32_tree = jax.tree_util.tree_unflatten(treedef, new_p32)
    params_dtypes = jax.tree_util.tree_leaves(state.params)
    new_params = jax.tree_util.tree_unflatten(treedef, [
        p.astype(old.dtype) for p, old in zip(new_p32, params_dtypes)])
    new_master = p32_tree if state.master is not None else None
    new_state = TrainState(
        step=step, params=new_params,
        m=jax.tree_util.tree_unflatten(treedef, new_m),
        v=jax.tree_util.tree_unflatten(treedef, new_v),
        master=new_master)
    return new_state, {"grad_norm": gnorm, "lr": lr}
