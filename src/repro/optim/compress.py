"""Cross-pod gradient compression (distributed-optimization trick).

On a multi-pod mesh the gradient reduction is hierarchical: full-precision
reduce-scatter *inside* a pod (fast ICI), then a cross-pod all-reduce over
the slow inter-pod links.  The cross-pod hop is the one worth compressing:
per-tensor-scaled int8 quantisation cuts its wire bytes 2x vs bf16 / 4x vs
f32, with an error-feedback residual (1-bit-Adam-style EF) so quantisation
noise is carried into the next step instead of lost.

`compressed_psum_mean` is a primitive for use INSIDE `shard_map` (the pod
axis must be a manual axis at the call site) — see
tests/test_compress.py for the composition pattern and DESIGN.md §5 for
the dp-plan integration point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


def compressed_psum_mean(g, ef, axis: str):
    """int8-compressed mean of `g` across `axis` with error feedback.

    g:  gradient shard (any float dtype);
    ef: error-feedback residual (f32, same shape) or None;
    returns (mean (g.dtype), new_ef (f32)).

    Wire traffic: one int8 payload of g.size bytes + one scalar, instead of
    a 2-4 byte/element payload — 2x (bf16) to 4x (f32) compression.
    """
    gf = g.astype(jnp.float32)
    if ef is not None:
        gf = gf + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)) / 127.0, 1e-12)
    # all pods must agree on the scale (one scalar pmax on the wire)
    scale = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    # int8 payload on the wire; the reduction accumulates in int32
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    npods = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    mean = total.astype(jnp.float32) * scale / npods.astype(jnp.float32)
    new_ef = gf - q.astype(jnp.float32) * scale
    return mean.astype(g.dtype), new_ef


def cross_pod_mean_tree(grads, ef_state, mesh, pod_axis: str = "pod"):
    """Compressed cross-pod mean of a replicated-per-pod gradient tree.

    Demonstration wrapper: every leaf is treated as fully local to the
    device (specs P() over all axes, values may differ across `pod`).  In
    the production dp plan the same primitive runs inside the train step's
    shard_map with the plan's own specs.
    """
    if ef_state is None:
        ef_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def body(g_tree, e_tree):
        flat_g, treedef = jax.tree_util.tree_flatten(g_tree)
        flat_e = treedef.flatten_up_to(e_tree)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            m, ne = compressed_psum_mean(g, e, pod_axis)
            out_g.append(m)
            out_e.append(ne)
        return (jax.tree_util.tree_unflatten(treedef, out_g),
                jax.tree_util.tree_unflatten(treedef, out_e))

    specs = jax.tree_util.tree_map(lambda l: P(*([pod_axis] + [None] * (
        l.ndim - 1))) if l.ndim else P(pod_axis), grads)
    # leaves carry a leading per-pod dim in the demo layout
    return shard_map(body, mesh=mesh, in_specs=(specs, specs),
                     out_specs=(specs, specs), check_vma=False)(
        grads, ef_state)
