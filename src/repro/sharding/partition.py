"""Partitioning plans: how every tensor of every arch lays out on the mesh.

Two attention strategies (DESIGN.md §5):

  * `heads` — classic Megatron TP: activations replicated over `model`,
    query heads / d_ff / vocab sharded.  Requires num_heads % tp == 0
    (granite, qwen1.5-110b, recurrentgemma, rwkv6).
  * `seq`  — sequence-parallel attention for awkward head counts (24/20/
    40/56/28): activations seq-sharded in the attention region (QKV weights
    replicated there), KV all-gathered for the flash scan, then the MLP
    region all-gathers tokens and runs d_ff TP with a reduce-scatter back.

Decode always runs a third layout: activations replicated over `model`
(T == 1 cannot shard), full KV caches sharded (batch -> data, seq -> model)
for the shard_map flash-decode, d_ff/vocab TP as usual.

FSDP (ZeRO-3) shards parameters over the data axes as well — switched on
automatically for >=20B-parameter archs; optimizer states always shard over
data (ZeRO-1) when divisibility allows.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

# At 256+ chips FSDP (params sharded over data) is strictly better for
# every assigned arch: the per-layer all-gather overlaps with compute and
# the replicated-params + replicated-grads footprint would otherwise
# dominate HBM even for 2.5B models (grad tree + fp32 update transients).
FSDP_THRESHOLD = 1e9


def _dp(data_axes: tuple) -> Any:
    return data_axes if len(data_axes) > 1 else data_axes[0]


@dataclass
class ShardingPlan:
    mesh: Mesh
    cfg: Any
    mode: str = "train"            # train | prefill | decode
    model_axis: str = "model"
    data_axes: tuple = ("data",)
    fsdp: bool | None = None

    # optional override: "dp" = pure data parallelism with ZeRO-3 (batch
    # sharded over EVERY mesh axis, weights gathered per layer).  The
    # §Perf hillclimb shows this beats TP+SP for small-and-mid dense
    # models at global batch 256 (see EXPERIMENTS.md).
    strategy_override: str | None = None

    def __post_init__(self):
        axes = self.mesh.axis_names
        self.data_axes = tuple(a for a in axes if a != self.model_axis)
        if self.fsdp is None:
            self.fsdp = self.cfg.param_count() > FSDP_THRESHOLD
        self.strategy = (self.cfg.attn_sharding
                         if self.mode != "decode" else "decode")
        if self.strategy_override and self.mode != "decode":
            self.strategy = self.strategy_override

    # -- helpers --------------------------------------------------------
    @property
    def dp(self):
        return _dp(self.data_axes)

    def _f(self, dim_size_ok=True):
        """The FSDP axis (or None) for weight dim 0/1."""
        return self.dp if self.fsdp else None

    def ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def _divisible(self, n: int, axes) -> bool:
        if axes is None:
            return True
        axes = (axes,) if isinstance(axes, str) else tuple(
            a for t in ((axes,) if isinstance(axes, str) else axes)
            for a in ((t,) if isinstance(t, str) else t))
        size = int(np.prod([self.mesh.shape[a] for a in axes]))
        return n % size == 0

    # -- activation constraints ----------------------------------------
    def act(self, x, kind: str):
        if x is None:
            return x
        spec = self.act_spec(kind, x.ndim)
        if spec is None:
            return x
        spec = self._fit_cache(spec, x.shape)  # drop non-dividing axes
        return jax.lax.with_sharding_constraint(x, self.ns(spec))

    def act_spec(self, kind: str, ndim: int = 3):
        dp, m = self.dp, self.model_axis
        if self.strategy == "dp":
            # batch over every axis; nothing else sharded
            allax = tuple(self.data_axes) + (m,)
            table = {
                "hidden": P(allax, None, None),
                "attn_in": P(allax, None, None),
                "mlp_in": P(allax, None, None),
                "q_heads": P(allax, None, None, None),
                "kv_heads": P(allax, None, None, None),
                "attn_out": P(allax, None, None),
                "logits": P(allax, None, None),
            }
            return table.get(kind)
        seq = self.strategy == "seq"
        heads = self.strategy == "heads"
        table = {
            # (B, T, D) — the residual stream stays *sequence-sharded*
            # (Megatron-SP): the per-layer remat checkpoints are then 1/tp
            # of the replicated size, which is what lets the 80-layer /
            # 35-layer giants fit (DESIGN.md §5)
            "hidden": P(dp, m, None),
            # attention region: seq strategy computes QKV on the seq shards
            # directly; heads strategy all-gathers tokens first
            "attn_in": P(dp, m if seq else None, None),
            "mlp_in": P(dp, None, None),
            # (B, T, H, dh)
            "q_heads": P(dp, m if seq else None, m if heads else None, None),
            # (B, T, K, dh) — replicated for the flash scan
            "kv_heads": P(dp, None, None, None),
            # (B, T, H*dh)
            "attn_out": P(dp, m if seq else None, m if heads else None),
            # (B, T, V)
            "logits": P(dp, None, m),
        }
        if self.mode == "decode":  # T == 1: never shard the time dim
            table.update({
                "hidden": P(dp, None, None),
                "attn_in": P(dp, None, None),
                "q_heads": P(dp, None, None, None),
                "attn_out": P(dp, None, None),
            })
        return table.get(kind)

    # -- parameter specs ------------------------------------------------
    def param_specs(self, params_shapes) -> Any:
        """Map a (possibly eval_shape'd) param tree to PartitionSpecs."""
        if self.strategy == "dp":
            allax = tuple(self.data_axes) + (self.model_axis,)

            def dp_spec(path, leaf):
                # shard the largest dim over all axes (ZeRO-3 storage);
                # XLA all-gathers per layer for compute
                if leaf.ndim == 0:
                    return P()
                dims = list(leaf.shape)
                big = max(range(leaf.ndim), key=lambda i: dims[i])
                ent = [None] * leaf.ndim
                if dims[big] % (np.prod([self.mesh.shape[a]
                                         for a in allax])) == 0:
                    ent[big] = allax
                else:
                    f = self.dp
                    if self._divisible(dims[big], f):
                        ent[big] = f
                return P(*ent)

            return jax.tree_util.tree_map_with_path(dp_spec, params_shapes)
        f = self._f()
        m = self.model_axis
        seq = self.cfg.attn_sharding == "seq"

        rules = [
            # attention
            (r"attn/w[qkv]$", P(f, None) if seq else None),  # resolved below
            (r"attn/wq$", P(f, None if seq else m)),
            (r"attn/w[kv]$", P(f, None)),
            (r"attn/wo$", P(None if seq else m, f)),
            (r"attn/b[qkv]$", P(None)),
            # dense mlp / arctic residual
            (r"(mlp|dense)/w[ig]$", P(f, m)),
            (r"(mlp|dense)/wo$", P(m, f)),
            # moe
            (r"moe/router$", P(None, None)),
            (r"moe/w[ig]$", P(m, f, None)),
            (r"moe/wo$", P(m, None, f)),
            # rwkv time mix / channel mix
            (r"(wr|wk|wv|wg)$", P(f, m)),
            (r"wo$", P(m, f)),
            (r"ck$", P(f, m)),
            (r"cv$", P(m, f)),
            (r"cr$", P(f, None)),  # gate output replicated to match the
                                   # psum'd (kk @ cv) product elementwise
            (r"lora_a$", P(f, None)),
            (r"lora_b$", P(None, None)),
            (r"(u|ln_o|ln_o_b)$", P(m, None)),
            (r"(w0|mu|mu_cm)$", P(None)),
            # rg-lru
            (r"rec/wx$", P(f, m)),
            (r"rec/wgate$", P(f, m)),
            (r"rec/wout$", P(m, f)),
            (r"rec/conv$", P(None, m)),
            (r"rec/(w_r|b_r|w_i|b_i|lam)$", P(m,)),
            # embeddings / head
            (r"^embed$", P(m, None)),
            (r"^head$", P(f, m)),
            (r"(ln1|ln2|final_norm)$", P(None)),
        ]

        def spec_for(path, leaf):
            name = compat.keystr(path, simple=True, separator="/")
            # strip list indices like segments/0/1/... and factored-moment
            # suffixes (opt v = {r, c}) so they inherit the parent's rule
            clean = re.sub(r"/\d+", "", name)
            clean = re.sub(r"/(r|c)$", "", clean)
            stacked = "segments" in name
            for pat, spec in rules:
                if spec is None:
                    continue
                if re.search(pat, clean):
                    spec = self._fit(spec, leaf.shape, stacked)
                    return spec
            return P(*((None,) * leaf.ndim))

        return jax.tree_util.tree_map_with_path(spec_for, params_shapes)

    def _fit(self, spec: P, shape, stacked: bool) -> P:
        """Prepend None for the stacked layer dim, pad to rank, and drop
        axes that do not divide the dimension."""
        entries = list(spec)
        if stacked:
            entries = [None] + entries
        while len(entries) < len(shape):
            entries.append(None)
        entries = entries[:len(shape)]
        out = []
        for dim, ax in zip(shape, entries):
            if ax is not None and not self._divisible(
                    dim, ax if isinstance(ax, tuple) else (ax,)):
                ax = None
            out.append(ax)
        return P(*out)

    def param_shardings(self, params_shapes):
        return jax.tree_util.tree_map(
            self.ns, self.param_specs(params_shapes))

    # -- inputs / cache --------------------------------------------------
    def input_shardings(self, specs: dict) -> dict:
        dp = self.dp
        if self.strategy == "dp":
            dp = tuple(self.data_axes) + (self.model_axis,)
        out = {}
        for k, v in specs.items():
            spec = P(dp) if v.ndim == 1 else P(*([dp] + [None] * (v.ndim - 1)))
            out[k] = self.ns(self._fit_cache(spec, v.shape))
        return out

    def cache_specs(self, cache_shapes):
        """Full attn caches: (n, B, S, K, dh) -> (None, dp, model, ...);
        everything else: batch over data, channel/head dims over model
        where divisible."""
        dp, m = self.dp, self.model_axis

        def spec_for(path, leaf):
            name = compat.keystr(path, simple=True, separator="/")
            shape = leaf.shape
            if re.search(r"/(k|v)$", name):
                if shape[2] > max(self.cfg.window, 1):  # full cache
                    return self._fit_cache(P(None, dp, m, None, None), shape)
                return self._fit_cache(P(None, dp, None, None, None), shape)
            if re.search(r"/s$", name):      # rwkv state (n,B,H,N,N)
                return self._fit_cache(P(None, dp, m, None, None), shape)
            if re.search(r"/h$", name):      # rg-lru (n,B,W)
                return self._fit_cache(P(None, dp, m), shape)
            if re.search(r"/conv$", name):   # (n,B,cw-1,W)
                return self._fit_cache(P(None, dp, None, m), shape)
            return self._fit_cache(P(None, dp), shape)

        return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)

    def _fit_cache(self, spec, shape):
        entries = list(spec)
        while len(entries) < len(shape):
            entries.append(None)
        entries = entries[:len(shape)]
        out = []
        for dim, ax in zip(shape, entries):
            if ax is not None and not self._divisible(
                    dim, ax if isinstance(ax, tuple) else (ax,)):
                ax = None
            out.append(ax)
        return P(*out)

    def cache_shardings(self, cache_shapes):
        return jax.tree_util.tree_map(self.ns, self.cache_specs(cache_shapes))
