"""Checkpointing: sharded save/restore with an integrity manifest.

Layout:   <dir>/step_<k>/
              manifest.json        {step, tree structure, leaf checksums}
              arr_<i>.npy          one file per leaf (process-local shards
                                   are gathered via addressable_shards)

Restore re-shards onto *any* mesh: leaves are loaded host-side and put back
through `jax.device_put(x, sharding)`, so an elastic restart with a smaller
`data` axis (repro.runtime.elastic) reuses the same files.  The manifest
checksum catches torn writes: a crashed save leaves no manifest, so
`latest_step` never returns a partial checkpoint (write-then-rename).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading

import jax
import ml_dtypes
import numpy as np

# numpy cannot serialise ml_dtypes (bfloat16 etc.); store them as raw
# uint16/uint8 views and record the logical dtype in the manifest
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8)}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        stored = arr
        if dtype_name in _EXOTIC:
            stored = arr.view(_EXOTIC[dtype_name][1])
        path = os.path.join(tmp, f"arr_{i}.npy")
        np.save(path, stored)
        manifest["leaves"].append({
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


class AsyncSaver:
    """Overlap checkpoint writes with training: `save()` snapshots leaves
    to host (blocking only for device->host copies) and serialises on a
    background thread; `wait()` joins before the next save or shutdown —
    the write-then-rename protocol keeps partial saves invisible either
    way."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, directory: str, step: int, tree) -> None:
        self.wait()
        import numpy as _np
        host_tree = jax.tree_util.tree_map(
            lambda l: _np.asarray(l), tree)

        def work():
            try:
                save(directory, step, host_tree)
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Load step-k checkpoint into the structure of `like_tree`; device_put
    with `shardings` (same structure) when given — this is the elastic
    re-shard path."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == len(manifest["leaves"]), "tree structure changed"
    sh_leaves = (treedef.flatten_up_to(shardings)
                 if shardings is not None else [None] * len(leaves))
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        want = manifest["leaves"][i]
        if want["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[want["dtype"]][0])
        if hashlib.sha1(arr.tobytes()).hexdigest() != want["sha1"]:
            raise IOError(f"checksum mismatch for leaf {i} at step {step}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
